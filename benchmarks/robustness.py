"""Robustness benchmark: what the fault-tolerance layer costs when nothing
fails, and what recovery costs when something does.

Rows (none are gated by baseline.json yet — informational until a few
commits of history exist):
  * ``checkpoint_save_us``    — blocking CheckpointManager.save of a
    params+opt-sized tree with the full aux payload (cursor, losses,
    PlanCache state); the async writer hides this off the hot path, so the
    row bounds the worst case, not the steady state
  * ``checkpoint_restore_us`` — restore + load_aux round trip
  * ``checkpoint_overhead_pct`` — wall-clock cost of training WITH periodic
    async checkpoints vs without, same seed/steps (the real steady-state
    price; expect single-digit percent on CPU)
  * ``resume_replay_us``      — per-batch cost of the resume fast path:
    fast_forward through the sampler draw stream + cache state_dict load
    (what a restart pays before the first real step)
  * ``retry_overhead_us``     — extra per-batch wall time of a run that
    absorbed injected transient faults with zero-delay retries vs the
    fault-free run (the retry machinery itself, not the backoff)
  * ``quarantine_reselect_us`` — one-shot cost of the consumer's kernel
    quarantine: re-skeleton + quarantine + re-select + re-pad + degraded
    step dispatch, measured from the injected compile failure
  * ``fault_counters``        — retries + quarantined + nonfinite_skips
    seen by the *fault-free* pipeline run (value should be 0; nonzero
    means the environment itself is flaky)
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core import gnn
from repro.distributed import checkpoint as ckpt_mod
from repro.distributed import fault_tolerance as ft
from repro.graphs import graph as G
from repro.train import gnn_steps


def run(dataset: str = "pubmed", scale: float = 0.04, steps: int = 12,
        verbose: bool = True) -> dict:
    graph = G.synth_dataset(dataset, scale=scale, seed=0)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", reorder="louvain",
                        clusters_per_batch=8, inter_buckets=2)

    base = gnn_steps.train_minibatch(graph, cfg, steps=steps, eval_batches=0)
    base_iter = base.iter_seconds

    # checkpoint save/restore on a real params+opt tree with a real aux
    tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        key = __import__("jax").random.PRNGKey(0)
        params = gnn.init_model(key, cfg, graph.features.shape[-1],
                                graph.n_classes)
        opt = gnn._adam_init(params)
        tree = dict(params=params, opt=opt)
        aux = dict(cursor=steps, losses=base.losses,
                   hit_history=base.hit_history,
                   cache=base.plan_cache.state_dict(), plans=[], sigs=[])
        mgr = ckpt_mod.CheckpointManager(tmp, async_write=False)
        ts = []
        for i in range(5):
            t0 = time.perf_counter()
            mgr.save(i, tree, aux=aux, blocking=True)
            ts.append(time.perf_counter() - t0)
        save_us = float(np.median(ts)) * 1e6
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            mgr.restore(tree)
            mgr.load_aux()
            ts.append(time.perf_counter() - t0)
        restore_us = float(np.median(ts)) * 1e6
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # steady-state checkpoint overhead: periodic async saves riding a run
    tmp = tempfile.mkdtemp(prefix="bench_ckpt_run_")
    try:
        ck_cfg = dataclasses.replace(cfg, checkpoint_dir=tmp,
                                     checkpoint_every=3)
        ck = gnn_steps.train_minibatch(graph, ck_cfg, steps=steps,
                                       eval_batches=0)
        ck_pct = 100.0 * (ck.iter_seconds - base_iter) / max(base_iter, 1e-12)

        # resume fast path: sampler fast_forward + cache state reload
        t0 = time.perf_counter()
        res = gnn_steps.train_minibatch(
            graph, dataclasses.replace(ck_cfg, resume_from=tmp),
            steps=steps, eval_batches=0)
        resume_batches = max(res.faults["resumed_at"], 1)
        replay_us = (time.perf_counter() - t0
                     - res.iter_seconds * len(res.losses)) / resume_batches
        replay_us = max(replay_us, 0.0) * 1e6
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # retry machinery overhead (zero-delay backoff, 1 injected fault/batch)
    rcfg = dataclasses.replace(cfg, retry_max=2, retry_base_delay_s=0.0)
    fp = ft.FaultPlan(worker_faults={i: 1 for i in range(steps)})
    retried = gnn_steps.train_minibatch(graph, rcfg, steps=steps,
                                        eval_batches=0, fault_plan=fp)
    retry_us = max(retried.iter_seconds - base_iter, 0.0) * 1e6

    # kernel quarantine: time the recovery batch itself.  A dense-community
    # graph makes the cost model commit the Pallas bell/block_diag path;
    # inject a compile failure and compare the run's iteration time to the
    # fault-free one — both pay one trace, the delta is the recovery.
    nb, B = 4, 64
    n = nb * B
    src, dst = G.community_graph(n, 40 * n, comm_size=B, intra_frac=0.9,
                                 seed=0)
    rng = np.random.default_rng(1)
    dense = G.Graph(n, src, dst,
                    rng.standard_normal((n, 16)).astype(np.float32),
                    rng.integers(0, 4, n).astype(np.int32), 4)
    qcfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=B,
                         clusters_per_batch=2, reorder="bfs",
                         inter_buckets=2)
    probe = gnn_steps.train_minibatch(dense, qcfg, steps=2, eval_batches=0)
    from repro.kernels.registry import REGISTRY
    pallas_used = sorted({k for plan in probe.plans for layer in plan
                          for k in layer if REGISTRY.get(k).pallas})
    quarantine_us = 0.0
    quarantined = 0
    if pallas_used:
        fp = ft.FaultPlan(kernel_faults={pallas_used[0]: "compile"})
        with fp.activate():
            q = gnn_steps.train_minibatch(dense, qcfg, steps=2,
                                          eval_batches=0, fault_plan=fp)
        quarantined = q.faults["quarantined"]
        quarantine_us = max(q.iter_seconds - probe.iter_seconds, 0.0) * 1e6

    # fault counters of a clean async run (should be zero)
    pcfg = dataclasses.replace(cfg, prefetch_depth=4, pipeline_workers=2,
                               retry_max=2, retry_base_delay_s=0.0)
    clean = gnn_steps.train_minibatch(graph, pcfg, steps=steps,
                                      eval_batches=0)
    counters = clean.faults
    total_faults = (counters["retries"] + counters["quarantined"]
                    + counters["nonfinite_skips"])

    out = dict(checkpoint_save_us=save_us,
               checkpoint_restore_us=restore_us,
               checkpoint_overhead_pct=ck_pct,
               resume_replay_us=replay_us,
               retry_overhead_us=retry_us,
               quarantine_reselect_us=quarantine_us,
               fault_counters=counters,
               resumed_losses_match=res.losses == base.losses)
    if verbose:
        emit("checkpoint_save_us", save_us,
             "blocking params+opt+aux save (async writer hides this)")
        emit("checkpoint_restore_us", restore_us,
             "restore + load_aux round trip")
        emit("checkpoint_overhead_pct", ck_pct,
             f"iter with every-3-batch async checkpoints vs without "
             f"(ckpts={ck.faults['checkpoints']})")
        emit("resume_replay_us", replay_us,
             f"per-batch draw fast-forward + cache reload at resume "
             f"(cursor={res.faults['resumed_at']}, "
             f"losses_match={res.losses == base.losses})")
        emit("retry_overhead_us", retry_us,
             f"per-iter cost of absorbing {retried.faults['retries']} "
             f"zero-delay retries over {steps} batches")
        emit("quarantine_reselect_us", quarantine_us,
             f"re-skeleton+re-select+degraded dispatch after injected "
             f"compile failure (quarantined={quarantined}, "
             f"target={pallas_used[0] if pallas_used else 'n/a'})")
        emit("fault_counters", float(total_faults),
             f"clean-run retries={counters['retries']} "
             f"quarantined={counters['quarantined']} "
             f"nonfinite={counters['nonfinite_skips']} (expect 0)")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()

"""Benchmark harness entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  PYTHONPATH=src python -m benchmarks.run            # all paper benchmarks
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --json out/   # also BENCH_*.json
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="also write machine-readable BENCH_<name>.json "
                         "(per-row us_per_call) into DIR")
    args = ap.parse_args()

    from benchmarks import (ablation_o123, common, density_analysis,
                            end_to_end, format_crossover, fused,
                            granularity_baselines, memory_overhead,
                            minibatch, overhead, robustness, serving)

    scale = 0.04 if args.quick else 0.08
    jobs = {
        "fused_transform_aggregate": lambda: fused.run(
            n=1024 if args.quick else 2048,
            e=12000 if args.quick else 30000,
            fin=32 if args.quick else 64,
            fout=256 if args.quick else 512),
        "fig2b_format_crossover": lambda: format_crossover.run(
            n=512 if args.quick else 1024),
        "fig4_density_analysis": lambda: density_analysis.run(
            scale=0.03 if args.quick else 0.05),
        "fig8_end_to_end": lambda: end_to_end.run(
            scale=0.05 if args.quick else 0.1,
            steps=5 if args.quick else 8),
        "fig9_10_granularity": lambda: granularity_baselines.run(scale=scale),
        "fig11_ablation_o123": lambda: ablation_o123.run(scale=scale),
        "sec6_3_overhead": lambda: overhead.run(
            scale=0.05 if args.quick else 0.1,
            steps=10 if args.quick else 20),
        "minibatch_sampling": lambda: minibatch.run(
            scale=0.04 if args.quick else 0.05,
            steps=15 if args.quick else 25),
        "robustness": lambda: robustness.run(
            scale=0.03 if args.quick else 0.04,
            steps=9 if args.quick else 12),
        "serving": lambda: serving.run(
            scale=0.1 if args.quick else 0.15,
            train_steps=6 if args.quick else 8,
            seconds=0.6 if args.quick else 1.0),
        "fig12_memory_overhead": lambda: memory_overhead.run(),
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, job in jobs.items():
        if only and name not in only:
            continue
        common.drain_records()
        try:
            job()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},NaN,FAILED")
        if args.json:
            common.write_bench_json(name, common.drain_records(), args.json)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

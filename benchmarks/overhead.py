"""Paper §6.3: runtime overhead — graph reordering, decomposition, and the
adaptive selector's probing, vs a training run."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import decompose, gnn, selector as sel_mod
from repro.graphs import graph as G


def run(dataset: str = "pubmed", scale: float = 0.1, steps: int = 20,
        verbose: bool = True) -> dict:
    g = G.synth_dataset(dataset, scale=scale, seed=0)

    t0 = time.perf_counter()
    perm = decompose.REORDERERS["louvain"](g.n, g.senders, g.receivers, 16)
    t_reorder = time.perf_counter() - t0

    t0 = time.perf_counter()
    dec = decompose.decompose(g, comm_size=16, method="bfs")
    t_decomp = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((dec.n_pad, 16)), jnp.float32)
    t0 = time.perf_counter()
    sel = sel_mod.AdaptiveSelector(dec, warmup_iters=2)
    sel.probe(x, iters=2)
    t_probe = time.perf_counter() - t0

    cfg = gnn.GNNConfig(model="gcn", selector="cost_model")
    res = gnn.train(g, cfg, steps=steps)
    t_train = res.step_seconds * steps

    out = dict(reorder_s=t_reorder, decompose_s=t_decomp, probe_s=t_probe,
               train_s=t_train,
               overhead_frac=(t_reorder + t_decomp + t_probe)
               / max(t_train, 1e-9))
    if verbose:
        emit(f"sec6_3_overhead_{dataset}", (t_reorder + t_decomp) * 1e6,
             f"reorder={t_reorder:.3f}s;decomp={t_decomp:.3f}s;"
             f"probe={t_probe:.3f}s;train{steps}steps={t_train:.3f}s")
    return out


if __name__ == "__main__":
    run()

"""Serving benchmark: open-loop load against the in-process GNN inference
server (repro.serve) — steady-state latency, warm-start compile count,
and behavior under injected overload.

Rows:
  * ``serve_p50_us`` / ``serve_p99_us`` — admitted-request latency over
    the steady-state window (arrival rate ~half of measured capacity),
    AFTER a traffic warmup window so compiles never pollute the tail
  * ``serve_qps``        — completed requests/second in the same window
    (HIGHER_IS_BETTER in check_regression)
  * ``serve_warm_traces`` — new jit traces recorded during the measured
    steady-state window; the warm-start contract says 0 (ABS_MAX gate)
  * ``serve_shed_pct``   — share of requests shed during the overload
    window (arrival rate ~6x capacity): nonzero means the server sheds
    instead of queuing unboundedly, while admitted requests keep making
    their deadlines (``serve_over_p99_us`` reports their tail)
  * ``serve_over_p99_us`` — admitted-request p99 during overload; the CI
    serving-smoke job asserts it stays within the configured deadline

Overload is *relative*: arrival rates are derived from the server's own
EWMA service estimate after warmup, so the same benchmark overloads a
fast desktop and a throttled CI runner alike.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.launch.serve import build_server, open_loop_burst
from repro.serve import OK, ServeConfig


def _latencies_us(futs) -> np.ndarray:
    out = []
    for f in futs:
        status, value = f.result(timeout=30)
        if status == OK:
            out.append(value["latency_s"] * 1e6)
    return np.asarray(out)


def run(dataset: str = "cora", scale: float = 0.15, train_steps: int = 8,
        deadline_ms: float = 150.0, seconds: float = 1.0,
        verbose: bool = True) -> dict:
    scfg = ServeConfig(deadline_s=deadline_ms / 1e3, queue_limit=32,
                       max_batch=8, seed=0)
    server = build_server(dataset, scale=scale, train_steps=train_steps,
                          batch_nodes=32, fanouts=(4, 2), serve_cfg=scfg)
    server.warmup()

    with server:
        # traffic warmup: converge the EWMA service estimate and absorb
        # any first-signature plan selections before measuring
        for f in open_loop_burst(server, qps=50, seconds=0.5, seed=1):
            f.result(timeout=30)
        est = server.stats()["est_service_s"]
        capacity = scfg.max_batch / max(est, 1e-6)   # requests/second

        traces0 = server.n_traces
        steady_qps = max(capacity * 0.5, 20.0)
        futs = open_loop_burst(server, qps=steady_qps, seconds=seconds,
                               seed=2)
        lat = _latencies_us(futs)
        warm_traces = server.n_traces - traces0
        qps_done = len(lat) / max(seconds, 1e-9)

        over_qps = max(capacity * 6.0, 200.0)
        over = open_loop_burst(server, qps=over_qps, seconds=seconds,
                               seed=3)
        over_lat = _latencies_us(over)
    st = server.stats()

    emit("serve_p50_us", float(np.percentile(lat, 50)) if len(lat) else 0.0,
         f"steady {steady_qps:.0f} qps offered")
    emit("serve_p99_us", float(np.percentile(lat, 99)) if len(lat) else 0.0,
         f"{len(lat)} admitted")
    emit("serve_qps", qps_done, "completed/s, steady window")
    emit("serve_warm_traces", float(warm_traces),
         "new jit traces in steady state (contract: 0)")
    emit("serve_shed_pct", st["shed_pct"],
         f"overload {over_qps:.0f} qps offered; shed {st['shed']}")
    emit("serve_over_p99_us",
         float(np.percentile(over_lat, 99)) if len(over_lat) else 0.0,
         f"admitted p99 under overload (deadline {deadline_ms * 1e3:.0f}us)")
    if verbose:
        print(f"# capacity~{capacity:.0f} qps, est_service "
              f"{est * 1e3:.1f}ms, rung {st['rung']}, "
              f"degrades {st['degrades']}, timeouts {st['timeouts']}")
    return dict(stats=st, steady_lat_us=lat, over_lat_us=over_lat,
                warm_traces=warm_traces, deadline_us=deadline_ms * 1e3)

"""Paper Fig. 11: optimization-version breakdown.

  O1: static full-graph-level CSR kernel
  O2: static per-subgraph kernels (CSR intra + COO inter)
  O3: subgraph-level *adaptive* kernels (full AdaptGear)
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, emit
from repro.core import adaptgear, decompose, selector as sel_mod
from repro.graphs import graph as G

DATASETS = ["cora", "citeseer", "pubmed"]


def run(scale: float = 0.08, feat: int = 32, verbose: bool = True):
    rows = []
    for name in DATASETS:
        g = G.synth_dataset(name, scale=scale, seed=0, max_feat=feat)
        dec = decompose.decompose(g, comm_size=16, method="louvain")
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((dec.n_pad, feat)), jnp.float32)

        t_o1 = timeit(jax.jit(
            lambda x: adaptgear.aggregate_full_static(dec, x, "ell")), x)
        t_o2 = timeit(jax.jit(
            lambda x: adaptgear.aggregate(dec, x, ("ell", "coo"))), x)
        sel = sel_mod.AdaptiveSelector(dec, warmup_iters=1)
        choice = sel.probe(x, iters=1).choice
        t_o3 = timeit(jax.jit(
            lambda x: adaptgear.aggregate(dec, x, choice)), x)
        rows.append(dict(dataset=name, o1_us=t_o1 * 1e6, o2_us=t_o2 * 1e6,
                         o3_us=t_o3 * 1e6, choice=choice))
        if verbose:
            emit(f"fig11_{name}", t_o3 * 1e6,
                 f"o1={t_o1*1e6:.0f};o2={t_o2*1e6:.0f};o3={t_o3*1e6:.0f}")
    return rows


if __name__ == "__main__":
    run()

"""Shared benchmark utilities: timing harness + CSV emission + an optional
machine-readable recorder (``BENCH_*.json``) so the perf trajectory can be
accumulated across runs/commits."""
from __future__ import annotations

import json
import os
import time

import jax

# rows emitted since the last drain: list of dicts
_RECORDS: list[dict] = []


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append(dict(name=name, us_per_call=us_per_call, derived=derived))


def drain_records() -> list[dict]:
    """Return and clear the rows emitted since the last drain."""
    global _RECORDS
    out, _RECORDS = _RECORDS, []
    return out


def write_bench_json(benchmark: str, rows: list[dict], out_dir: str) -> str:
    """Write one benchmark's emitted rows as ``BENCH_<benchmark>.json``."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{benchmark}.json")
    with open(path, "w") as f:
        json.dump(dict(benchmark=benchmark, rows=rows), f, indent=1)
    return path

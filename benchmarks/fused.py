"""Fused transform+aggregate vs the unfused two-pass layer, per model.

GCN rows measure one layer Y = A (X W) + b on the block-diagonal-dominant
synthetic graph (aligned MXU-scale communities, ring-structured inter
edges): the fully-fused plan against the unfused Pallas pair with the
standalone XLA transform, plus per-tier kernel rows isolating where the
saved H round-trip lands.  The expanding layer width (fin < fout) is the
regime fusion targets — the unfused path materializes the *wide* H.

GIN/SAGE rows measure the epilogue-fused layers (core.epilogue) against
the *legacy* unfused layers that aggregate raw features and apply the
dense epilogue after.  Their winning regime is the contracting width
(fin > fout): pushing W through the aggregation shrinks the aggregated
width from fin to fout/hidden and kills the (n, fin) intermediate, so the
model rows run the GCN widths in the opposite direction.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, emit
from repro.core import adaptgear, decompose
from repro.graphs import graph as G

FUSED_PLAN = ("block_diag_fused", "bell_fused")
UNFUSED_PLAN = ("block_diag", "bell")


def run(n: int = 2048, e: int = 30000, fin: int = 64, fout: int = 512,
        verbose: bool = True) -> list[dict]:
    src, dst = G.aligned_community_graph(n, e, block=128, intra_frac=0.9,
                                         seed=0)
    g = G.Graph(n, src, dst, np.zeros((n, 4), np.float32),
                np.zeros(n, np.int32), 2)
    dec = decompose.decompose(g, comm_size=128, method="bfs", reorder=False,
                              inter_buckets=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((dec.n_pad, fin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((fin, fout)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(fout), jnp.float32)

    layer = {
        "unfused": jax.jit(lambda x, w, b: adaptgear.aggregate_transform(
            dec, x, w, UNFUSED_PLAN, bias=b, acc=False)),
        "fused": jax.jit(lambda x, w, b: adaptgear.aggregate_transform(
            dec, x, w, FUSED_PLAN, bias=b)),
    }
    times = {k: timeit(fn, x, w, b, iters=3) for k, fn in layer.items()}
    speedup = times["unfused"] / max(times["fused"], 1e-12)

    # per-tier isolation: the unfused side is charged the transform it needs
    tier = {
        "intra_unfused": jax.jit(lambda x, w: adaptgear.aggregate_sub(
            dec.intra, x @ w, "block_diag")),
        "intra_fused": jax.jit(lambda x, w: adaptgear.aggregate_sub_fused(
            dec.intra, x, w, "block_diag_fused")),
        "inter_unfused": jax.jit(lambda x, w: adaptgear.aggregate_sub(
            dec.inters[0], x @ w, "bell")),
        "inter_fused": jax.jit(lambda x, w: adaptgear.aggregate_sub_fused(
            dec.inters[0], x, w, "bell_fused")),
    }
    tier_times = {k: timeit(fn, x, w, iters=3) for k, fn in tier.items()}

    rows = []
    if verbose:
        emit("fused_gcn_layer_unfused", times["unfused"] * 1e6,
             f"n={n};fin={fin};fout={fout}")
        emit("fused_gcn_layer_fused", times["fused"] * 1e6,
             f"speedup_vs_unfused={speedup:.2f}x")
        for k, t in tier_times.items():
            emit(f"fused_{k}", t * 1e6, "")
    rows.append(dict(n=n, fin=fin, fout=fout, speedup=speedup,
                     **{k: v * 1e6 for k, v in times.items()},
                     **{k: v * 1e6 for k, v in tier_times.items()}))
    # epilogue-fused GIN/SAGE on the contracting profile (widths reversed)
    rows += run_models(n=n, e=e, fin=fout, fout=fin, verbose=verbose)
    # column-condensed MXU tiles vs blocked-ELL vs dense across occupancy
    rows += run_tcgnn(verbose=verbose)
    return rows


def _paired_ratio(fn_a, fn_b, x, reps: int = 5):
    """Interleaved min-times + median paired ratio t_a/t_b (machine-load
    noise is common-mode within a pair — same estimator as run_models)."""
    jax.block_until_ready(fn_a(x))
    jax.block_until_ready(fn_b(x))
    ta_s, tb_s = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(x))
        ta_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(x))
        tb_s.append(time.perf_counter() - t0)
    ratio = float(np.median(np.asarray(ta_s) / np.asarray(tb_s)))
    return min(ta_s), min(tb_s), ratio


def _occupancy_tier(n, B, cols_per_brow, edges_per_col, seed=0):
    """One inter tier with ~cols_per_brow distinct columns per block row,
    each ~edges_per_col/B occupied — the knob that sweeps the
    blocked-ELL padding-waste vs condensation-occupancy crossover."""
    from repro.core import decompose as dm
    rng = np.random.default_rng(seed)
    nbr = n // B
    rows_, cols_ = [], []
    for i in range(nbr):
        cs = rng.choice(n, size=cols_per_brow, replace=False)
        for c in cs:
            rr = rng.choice(B, size=edges_per_col, replace=False) + i * B
            rows_.extend(rr)
            cols_.extend([c] * edges_per_col)
    rows_ = np.asarray(rows_, np.int64)
    cols_ = np.asarray(cols_, np.int64)
    return dm.build_subgraph("inter0", "offdiag", n, B, rows_, cols_,
                             np.ones(len(rows_), np.float32))


def run_tcgnn(n: int = 512, B: int = 32, F: int = 16,
              verbose: bool = True) -> list[dict]:
    """tcgnn_tile vs bell vs dense across column occupancy: the crossover
    the cost model prices.  Sparse tiers (few distinct columns) belong to
    blocked-ELL, mid-density tiers (many half-occupied columns) to the
    condensed tiles, near-dense block rows to a plain MXU matmul.  Rows
    are interpret-mode paired ratios — relative kernel work, not TPU
    wall time."""
    from repro.core import selector as sel_mod
    from repro.kernels.registry import REGISTRY
    hw = sel_mod.HwModel()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((n, F)), jnp.float32)
    profiles = {          # cols_per_brow, edges_per_col
        "sparse": (8, 4),
        "mid": (100, 16),
        "dense": (n // 2, B),
    }
    rows = []
    for name, (cpb, epc) in profiles.items():
        sub = _occupancy_tier(n, B, cpb, epc)
        p_tc = sub.formats["tcgnn_tile"]
        p_bell = sub.formats["bell"]
        a_dense = np.zeros((n, n), np.float32)
        co = sub.formats["coo"]
        a_dense[np.asarray(co.rows), np.asarray(co.cols)] = \
            np.asarray(co.vals)
        a_dense = jnp.asarray(a_dense)
        tc = jax.jit(lambda xx: REGISTRY.get("tcgnn_tile").matvec(p_tc, xx))
        bell = jax.jit(lambda xx: REGISTRY.get("bell").matvec(p_bell, xx))
        dense = jax.jit(lambda xx: a_dense @ xx)
        t_bell, t_tc, r_bell = _paired_ratio(bell, tc, x)
        t_dense, _, r_dense = _paired_ratio(dense, tc, x)
        pick = sel_mod.select_for_subgraph(sub, F, hw=hw)
        if verbose:
            emit(f"tcgnn_crossover_{name}", t_tc * 1e6,
                 f"paired bell/tcgnn={r_bell:.2f}x dense/tcgnn="
                 f"{r_dense:.2f}x nnz={sub.stats['nnz']} "
                 f"col_occ={sub.stats['col_occupancy']:.2f} "
                 f"cost_model_pick={pick}")
        rows.append(dict(profile=name, tcgnn_us=t_tc * 1e6,
                         bell_us=t_bell * 1e6, dense_us=t_dense * 1e6,
                         bell_over_tcgnn=r_bell, dense_over_tcgnn=r_dense,
                         pick=pick))
        if name == "mid":
            # fused A @ (X W) on the condensed tiles vs fused blocked-ELL —
            # the layer-shaped row (W folded in, (n, F) intermediate dead)
            w = jnp.asarray(rng.standard_normal((F, F)), jnp.float32)
            tcf = jax.jit(lambda xx: REGISTRY.get(
                "tcgnn_tile_fused").fused_matvec(p_tc, xx, w))
            bellf = jax.jit(lambda xx: REGISTRY.get(
                "bell_fused").fused_matvec(p_bell, xx, w))
            t_bf, t_tf, r_f = _paired_ratio(bellf, tcf, x)
            if verbose:
                emit("tcgnn_fused_mid", t_tf * 1e6,
                     f"paired bell_fused/tcgnn_fused={r_f:.2f}x")
            rows.append(dict(profile="mid_fused", tcgnn_us=t_tf * 1e6,
                             bell_us=t_bf * 1e6, bell_over_tcgnn=r_f))
    return rows


def run_models(n: int = 2048, e: int = 30000, fin: int = 512, fout: int = 64,
               verbose: bool = True) -> list[dict]:
    """Epilogue-fused GIN/SAGE layers vs their legacy unfused forms.

    The unfused baselines aggregate raw features at width ``fin`` and
    apply the dense epilogue after (the pre-epilogue-fusion dispatch).
    The epilogue layers push the weight through the aggregation (width
    ``fout``) and dispatch the plan the cost model commits *under the
    layer's epilogue on this machine's hw model* — exactly what training
    does: fused Pallas kernels where they are modeled to win (SAGE's dual
    epilogue pays the shared-transform surcharge unfused), unfused
    candidates where the epilogue makes the transform free and the
    pushdown alone carries the win (GIN on compute-bound backends)."""
    from repro.core import epilogue as ep_mod, selector as sel_mod
    src, dst = G.aligned_community_graph(n, e, block=128, intra_frac=0.9,
                                         seed=0)
    g = G.Graph(n, src, dst, np.zeros((n, 4), np.float32),
                np.zeros(n, np.int32), 2)
    # SAGE's mean norm baked into the edge values (core.gnn.prepare does
    # this); GIN sums, so the same unit-valued decomposition serves both
    dec_mean = decompose.decompose(
        g, comm_size=128, method="bfs", reorder=False, inter_buckets=1,
        edge_vals=G.mean_norm_values(n, src, dst))
    dec_sum = decompose.decompose(g, comm_size=128, method="bfs",
                                  reorder=False, inter_buckets=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((dec_sum.n_pad, fin)), jnp.float32)
    w_n = jnp.asarray(rng.standard_normal((fin, fout)), jnp.float32)
    w_s = jnp.asarray(rng.standard_normal((fin, fout)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(fout), jnp.float32)
    gin_p = dict(eps=jnp.zeros(()), w1=w_n, b1=b,
                 w2=jnp.asarray(rng.standard_normal((fout, 16)), jnp.float32),
                 b2=jnp.asarray(rng.standard_normal(16), jnp.float32))
    hw = sel_mod.default_hw()
    plans = {
        "sage": sel_mod.select_by_cost_model(
            dec_mean, fout, hw=hw, in_dim=fin,
            epilogue=ep_mod.EpilogueSpec(kind="dual", mean_norm=True)),
        "gin": sel_mod.select_by_cost_model(
            dec_sum, fout, hw=hw, in_dim=fin,
            epilogue=ep_mod.EpilogueSpec(kind="mlp", activation="relu",
                                         out_dim=16)),
    }

    def sage_unfused(x):
        agg = adaptgear.aggregate(dec_mean, x, UNFUSED_PLAN, acc=False)
        return x @ w_s + agg @ w_n + b

    def gin_unfused(x):
        agg = adaptgear.aggregate(dec_sum, x, UNFUSED_PLAN, acc=False)
        h = (1.0 + gin_p["eps"]) * x + agg
        return jax.nn.relu(h @ w_n + b) @ gin_p["w2"] + gin_p["b2"]

    layers = {
        "sage": (jax.jit(sage_unfused),
                 jax.jit(lambda x: adaptgear.sage_conv(
                     dict(w_self=w_s, w_neigh=w_n, b=b), dec_mean, x,
                     plans["sage"]))),
        "gin": (jax.jit(gin_unfused),
                jax.jit(lambda x: adaptgear.gin_conv(
                    gin_p, dec_sum, x, plans["gin"]))),
    }
    rows = []
    for model, (unf, fus) in layers.items():
        # interleave the pair so machine-load noise hits both alike (the
        # paired-ratio estimator minibatch.py uses for host prepare): an
        # unpaired A-then-B measurement inverts the ratio under load spikes
        jax.block_until_ready(unf(x))
        jax.block_until_ready(fus(x))
        tu_s, tf_s = [], []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(unf(x))
            tu_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fus(x))
            tf_s.append(time.perf_counter() - t0)
        tu, tf = min(tu_s), min(tf_s)
        speedup = float(np.median(np.asarray(tu_s) / np.asarray(tf_s)))
        if verbose:
            emit(f"fused_{model}_layer_unfused", tu * 1e6,
                 f"n={n};fin={fin};fout={fout} (legacy aggregate-at-fin)")
            emit(f"fused_{model}_layer_fused", tf * 1e6,
                 f"paired_speedup_vs_unfused={speedup:.2f}x "
                 f"plan={','.join(plans[model])}")
        rows.append(dict(model=model, n=n, fin=fin, fout=fout,
                         unfused_us=tu * 1e6, fused_us=tf * 1e6,
                         speedup=speedup, plan=plans[model]))
    return rows


if __name__ == "__main__":
    run()

"""Fused transform+aggregate vs the unfused two-pass GCN layer.

Rows measure one GCN layer Y = A (X W) + b on the block-diagonal-dominant
synthetic graph (aligned MXU-scale communities, ring-structured inter
edges): the fully-fused plan against the unfused Pallas pair with the
standalone XLA transform, plus per-tier kernel rows isolating where the
saved H round-trip lands.  The expanding layer width (fin < fout) is the
regime fusion targets — the unfused path materializes the *wide* H.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, emit
from repro.core import adaptgear, decompose
from repro.graphs import graph as G

FUSED_PLAN = ("block_diag_fused", "bell_fused")
UNFUSED_PLAN = ("block_diag", "bell")


def run(n: int = 2048, e: int = 30000, fin: int = 64, fout: int = 512,
        verbose: bool = True) -> list[dict]:
    src, dst = G.aligned_community_graph(n, e, block=128, intra_frac=0.9,
                                         seed=0)
    g = G.Graph(n, src, dst, np.zeros((n, 4), np.float32),
                np.zeros(n, np.int32), 2)
    dec = decompose.decompose(g, comm_size=128, method="bfs", reorder=False,
                              inter_buckets=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((dec.n_pad, fin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((fin, fout)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(fout), jnp.float32)

    layer = {
        "unfused": jax.jit(lambda x, w, b: adaptgear.aggregate_transform(
            dec, x, w, UNFUSED_PLAN, bias=b, acc=False)),
        "fused": jax.jit(lambda x, w, b: adaptgear.aggregate_transform(
            dec, x, w, FUSED_PLAN, bias=b)),
    }
    times = {k: timeit(fn, x, w, b, iters=3) for k, fn in layer.items()}
    speedup = times["unfused"] / max(times["fused"], 1e-12)

    # per-tier isolation: the unfused side is charged the transform it needs
    tier = {
        "intra_unfused": jax.jit(lambda x, w: adaptgear.aggregate_sub(
            dec.intra, x @ w, "block_diag")),
        "intra_fused": jax.jit(lambda x, w: adaptgear.aggregate_sub_fused(
            dec.intra, x, w, "block_diag_fused")),
        "inter_unfused": jax.jit(lambda x, w: adaptgear.aggregate_sub(
            dec.inters[0], x @ w, "bell")),
        "inter_fused": jax.jit(lambda x, w: adaptgear.aggregate_sub_fused(
            dec.inters[0], x, w, "bell_fused")),
    }
    tier_times = {k: timeit(fn, x, w, iters=3) for k, fn in tier.items()}

    rows = []
    if verbose:
        emit("fused_gcn_layer_unfused", times["unfused"] * 1e6,
             f"n={n};fin={fin};fout={fout}")
        emit("fused_gcn_layer_fused", times["fused"] * 1e6,
             f"speedup_vs_unfused={speedup:.2f}x")
        for k, t in tier_times.items():
            emit(f"fused_{k}", t * 1e6, "")
    rows.append(dict(n=n, fin=fin, fout=fout, speedup=speedup,
                     **{k: v * 1e6 for k, v in times.items()},
                     **{k: v * 1e6 for k, v in tier_times.items()}))
    return rows


if __name__ == "__main__":
    run()

"""Perf-regression gate over the quick-bench machine-readable output.

Compares every row of ``BENCH_*.json`` in a directory against the committed
``benchmarks/baseline.json`` and exits non-zero when any row's
``us_per_call`` regresses beyond the threshold (default +25%).  Rows absent
from the baseline (new benchmarks) pass; zero/NaN rows (derived-only
benchmarks) and sub-50us rows (pure launch noise) are skipped.

Most rows are timings where LOWER is better and the gate fires on a rise;
rows named in ``HIGHER_IS_BETTER`` (pipeline overlap/efficiency) gate the
other way — they fail when the value *drops* below 1/threshold of the
baseline, and are exempt from the sub-50us skip (efficiency is a percent,
not a latency).

Rows named in ``ABS_MAX`` carry an absolute ceiling checked against the
*current* run regardless of the baseline (they are percents small enough
that the sub-50us skip would otherwise exempt them): today that is
``telemetry_overhead_pct``, the repro.obs contract that disabled
telemetry hooks cost < 2% of the per-batch host prepare.

  PYTHONPATH=src python -m benchmarks.run --quick --json bench-out
  PYTHONPATH=src python -m benchmarks.check_regression bench-out
  PYTHONPATH=src python -m benchmarks.check_regression bench-out --write

``--write`` regenerates the baseline from the directory instead of gating
(run on the reference machine, commit the result).
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
MIN_US = 50.0
# row names (the part after "<benchmark>/") whose value regresses DOWNWARD:
# hidden overlap microseconds and device-busy percent shrink when the
# pipeline stops overlapping prepare with compute; serving throughput
# shrinks when the read path slows down
HIGHER_IS_BETTER = ("pipeline_efficiency_pct", "step_overlap_us",
                    "serve_qps")
# absolute ceilings on CURRENT rows (no baseline needed): contract gates
# rather than drift gates.  serve_warm_traces = 0 is the serving
# warm-start contract: a warmed server never compiles in steady state.
ABS_MAX = {"telemetry_overhead_pct": 2.0,
           "serve_warm_traces": 0.0}


def load_rows(bench_dir: str) -> dict:
    """{"<benchmark>/<row>": us_per_call} for every BENCH_*.json in dir."""
    rows: dict[str, float] = {}
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        with open(path) as f:
            doc = json.load(f)
        for r in doc.get("rows", []):
            us = float(r.get("us_per_call", float("nan")))
            rows[f"{doc['benchmark']}/{r['name']}"] = us
    return rows


def gate(current: dict, baseline: dict, threshold: float) -> list[str]:
    failures = []
    for key, us in sorted(current.items()):
        cap = ABS_MAX.get(key.rsplit("/", 1)[-1])
        if cap is not None and math.isfinite(us) and us > cap:
            failures.append(
                f"{key}: {us:.2f} exceeds absolute cap {cap:.2f} "
                f"(contract gate, independent of baseline)")
    for key, base_us in sorted(baseline.get("rows", {}).items()):
        us = current.get(key)
        if us is None:
            continue                      # benchmark renamed/removed: no gate
        if not (math.isfinite(us) and math.isfinite(base_us)):
            continue
        if key.rsplit("/", 1)[-1] in HIGHER_IS_BETTER:
            if us * threshold < base_us:
                failures.append(
                    f"{key}: {us:.1f} vs baseline {base_us:.1f} "
                    f"({(us / base_us - 1) * 100:.0f}% < "
                    f"-{(1 - 1 / threshold) * 100:.0f}%, higher is better)")
            continue
        if base_us < MIN_US or us < MIN_US:
            continue
        if us > threshold * base_us:
            failures.append(
                f"{key}: {us:.1f}us vs baseline {base_us:.1f}us "
                f"(+{(us / base_us - 1) * 100:.0f}% > "
                f"+{(threshold - 1) * 100:.0f}%)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_dir", help="directory holding BENCH_*.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when us_per_call exceeds threshold x baseline")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the baseline from bench_dir and exit")
    args = ap.parse_args()

    current = load_rows(args.bench_dir)
    if args.write:
        doc = dict(threshold=args.threshold,
                   rows={k: round(v, 1) for k, v in sorted(current.items())
                         if math.isfinite(v)})
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {len(doc['rows'])} baseline rows to {args.baseline}")
        return

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; nothing to gate")
        return
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = gate(current, baseline, args.threshold)
    checked = len(set(current) & set(baseline.get("rows", {})))
    if failures:
        print(f"PERF REGRESSION ({len(failures)}/{checked} gated rows):")
        for line in failures:
            print(" ", line)
        sys.exit(1)
    print(f"perf gate OK ({checked} rows within "
          f"+{(args.threshold - 1) * 100:.0f}%)")


if __name__ == "__main__":
    main()

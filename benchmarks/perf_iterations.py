import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""§Perf hillclimbing: hypothesis -> change -> measure -> validate, for the
three selected (arch x shape) cells (see EXPERIMENTS.md §Perf for the
selection rationale):

  qwen2_5_14b  x train_4k    -- most collective-bound cell
  whisper_large_v3 x prefill_32k -- worst roofline fraction
  deepseek_v3_671b x train_4k -- most representative of the paper's
                                 technique (density-adaptive MoE dispatch)

Each variant re-lowers the cell through the scan-corrected cost pipeline
(benchmarks/roofline.py) with config/sharding overrides.  The flash variant
uses measured attention-core isolation: costs are re-measured with
attn_core="identity" and the Pallas flash kernel's analytic FLOPs/HBM bytes
(kernels/flash_attention.py, validated against the oracle in tests) are
added back — because XLA on the CPU dry-run cannot express VMEM-resident
attention, while the TPU kernel does exactly that.

  PYTHONPATH=src python -m benchmarks.perf_iterations --out results/perf.json
"""
import argparse   # noqa: E402
import json       # noqa: E402

import numpy as np  # noqa: E402

from benchmarks import hw                                 # noqa: E402
from benchmarks.roofline import (corrected_costs, model_flops)  # noqa: E402
from repro import configs                                 # noqa: E402
from repro.kernels.flash_attention import (flash_flops,   # noqa: E402
                                           flash_hbm_bytes)
from repro.launch import mesh as mesh_mod                 # noqa: E402

N_CHIPS = 256


def attn_shape(cfg, shape_name):
    sh = configs.SHAPES[shape_name]
    if sh["mode"] == "decode":
        sq, skv = 1, sh["seq"]
    else:
        sq = skv = sh["seq"]
    return sh["batch"], sq, skv


def flash_cell_costs(cfg, shape_name, train: bool) -> dict:
    """Analytic per-device cost of running every attention core through the
    Pallas flash kernel (GQA-aware; MLA uses qk_dim/v_dim head geometry)."""
    B, sq, skv = attn_shape(cfg, shape_name)
    if cfg.attn_type == "mla":
        hq, hkv, d = cfg.n_heads, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim
    else:
        hq, hkv, d = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    if cfg.family == "encdec":
        layers = []
        layers.append(("enc", cfg.encoder_layers, cfg.encoder_seq,
                       cfg.encoder_seq, False))
        layers.append(("dec_self", cfg.n_layers, sq, sq, True))
        layers.append(("dec_cross", cfg.n_layers, sq, cfg.encoder_seq, False))
    elif cfg.layer_pattern == "jamba":
        layers = [("attn", cfg.n_layers // 8, sq, skv, True)]
    elif cfg.layer_pattern == "rwkv":
        layers = []
    else:
        layers = [("attn", cfg.n_layers, sq, skv, True)]
    fl = by = 0.0
    mult = 3.0 if train else 1.0   # bwd = 2x fwd with flash recompute
    for _, n, s_q, s_kv, causal in layers:
        fl += n * mult * flash_flops(B, hq, s_q, s_kv, d, causal=causal)
        by += n * mult * flash_hbm_bytes(B, hq, hkv, s_q, s_kv, d)
    return dict(flops=fl / N_CHIPS, bytes=by / N_CHIPS, coll=0.0)


def terms_of(costs: dict) -> dict:
    return dict(compute=costs["flops"] / hw.PEAK_FLOPS_BF16,
                memory=costs["bytes"] / hw.HBM_BW,
                collective=costs["coll"] / hw.ICI_BW_PER_LINK)


def run_cell(arch: str, shape_name: str, variants: list[dict], mesh,
             out_rows: list):
    cfg = configs.get_config(arch)
    sh = configs.SHAPES[shape_name]
    mf = model_flops(cfg, sh["mode"], sh["seq"], sh["batch"]) / N_CHIPS
    print(f"\n=== {arch} x {shape_name} ===", flush=True)
    prev_dom = None
    for v in variants:
        extra = dict(v.get("extra", {}))
        rules = v.get("rules")
        if v.get("flash"):
            # measured isolation: identity-core probe + analytic flash cost
            ident = corrected_costs(arch, shape_name, mesh,
                                    extra={**extra, "attn_core": "identity"},
                                    rules_overrides=rules)
            fc = flash_cell_costs(
                cfg if "n_heads" not in extra else
                __import__("dataclasses").replace(
                    cfg, n_heads=extra["n_heads"],
                    kv_heads=extra.get("kv_heads", cfg.kv_heads)),
                shape_name, train=(sh["mode"] == "train"))
            costs = {k: ident[k] + fc[k] for k in ("flops", "bytes", "coll")}
        else:
            costs = corrected_costs(arch, shape_name, mesh, extra=extra,
                                    rules_overrides=rules)
        t = terms_of(costs)
        dom = max(t, key=t.get)
        frac = (mf / hw.PEAK_FLOPS_BF16) / max(max(t.values()), 1e-30)
        row = dict(arch=arch, shape=shape_name, variant=v["name"],
                   hypothesis=v["hypothesis"], **{f"t_{k}_s": tv
                                                  for k, tv in t.items()},
                   dominant=dom, roofline_fraction=frac,
                   flops_per_dev=costs["flops"], bytes_per_dev=costs["bytes"],
                   coll_bytes_per_dev=costs["coll"])
        out_rows.append(row)
        print(f"  {v['name']:28s} c={t['compute']:.3e} m={t['memory']:.3e} "
              f"x={t['collective']:.3e} dom={dom:10s} frac={frac:.2%}",
              flush=True)
        prev_dom = dom


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/perf.json")
    ap.add_argument("--cell", default=None,
                    help="qwen | whisper | dsv3 (default: all)")
    args = ap.parse_args()
    mesh = mesh_mod.make_production_mesh(multi_pod=False)
    rows: list[dict] = []

    cells = {
        "qwen": ("qwen2_5_14b", "train_4k", [
            dict(name="v0_baseline",
                 hypothesis="baseline: 40 heads !% 16 -> attention runs "
                            "head-replicated; expect collective-dominant"),
            dict(name="v1_pad_heads_48_16",
                 hypothesis="pad heads 40->48, kv 8->16 (+20% attn params) "
                            "=> head-dim TP becomes divisible; the S^2 "
                            "score tensors shard 16-way; predict collective "
                            "term drops ~10x and memory ~2x",
                 extra=dict(n_heads=48, kv_heads=16)),
            dict(name="v2_pad_heads_flash",
                 hypothesis="Pallas flash attention keeps scores in VMEM: "
                            "predict memory term falls from S^2 (~1e13 B) "
                            "to QKVO streaming (~1e10 B) -> compute-bound",
                 extra=dict(n_heads=48, kv_heads=16), flash=True),
            dict(name="v3_flash_remat_full",
                 hypothesis="with memory no longer dominant, full remat "
                            "trades flops for bytes; predict <5% change in "
                            "the dominant term (stop-rule probe)",
                 extra=dict(n_heads=48, kv_heads=16, remat="full"),
                 flash=True),
        ]),
        "whisper": ("whisper_large_v3", "prefill_32k", [
            dict(name="v0_baseline",
                 hypothesis="decoder self-attn at 32k dominates: S^2 scores "
                            "~32768^2*20H -> memory-bound"),
            dict(name="v1_flash",
                 hypothesis="flash substitution removes enc 1500^2, dec "
                            "32k^2 and cross 32kx1500 score traffic; "
                            "predict memory term drops >10x",
                 flash=True),
            dict(name="v2_flash_pad_heads",
                 hypothesis="20 heads !% 16: pad to 32 (+60% attn flops) to "
                            "unlock head TP; predict collective down but "
                            "compute up — net win only if collective "
                            "dominated after v1",
                 extra=dict(n_heads=32, kv_heads=32), flash=True),
        ]),
        "dsv3": ("deepseek_v3_671b", "train_4k", [
            dict(name="v0_baseline_sparse",
                 hypothesis="baseline uses AdaptGear's sparse dispatch "
                            "(density 8/256=3%); memory-bound via MLA "
                            "S^2 + dispatch buffers"),
            dict(name="v1_dense_dispatch",
                 hypothesis="paper-technique validation: dense all-expert "
                            "path at 3% density should explode compute "
                            "~E/topk=32x — confirms the selector's choice",
                 extra=dict(moe_dispatch="dense")),
            dict(name="v2_capacity_1_0",
                 hypothesis="capacity factor 1.25->1.0 shrinks dispatch "
                            "buffers and expert GEMMs 20%; predict memory "
                            "term down ~5-10% (MoE share of bytes)",
                 extra=dict(capacity_factor=1.0)),
            dict(name="v3_flash_mla",
                 hypothesis="flash for the MLA core (128 heads, qk 192): "
                            "removes S^2 score traffic; predict memory "
                            "term drops >5x, dominant flips",
                 flash=True),
            dict(name="v4_flash_capacity_1_0",
                 hypothesis="combine v2+v3; predict additive small gain on "
                            "top of v3",
                 extra=dict(capacity_factor=1.0), flash=True),
        ]),
    }
    targets = [args.cell] if args.cell else list(cells)
    for key in targets:
        arch, shape, variants = cells[key]
        run_cell(arch, shape, variants, mesh, rows)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()

"""Hardware model constants (target: TPU v5e) used by the roofline analysis
and the selector's analytic cost model."""

PEAK_FLOPS_BF16 = 197e12       # per chip, bf16
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW_PER_LINK = 50e9         # bytes/s per link
VMEM_BYTES = 16 * 2**20        # ~16 MiB usable VMEM (v5e ~128MB CMEM? use 16MiB/core working spec)
CHIPS_PER_POD = 256
MXU_DIM = 128

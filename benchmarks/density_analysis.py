"""Paper Fig. 4: average density of full / intra-community /
inter-community subgraphs per dataset after community reordering
(community size 16, as in the paper)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import decompose
from repro.graphs import graph as G

DATASETS = ["cora", "citeseer", "pubmed", "proteins_full", "artist", "ppi"]


def run(scale: float = 0.05, verbose: bool = True) -> list[dict]:
    rows = []
    for name in DATASETS:
        g = G.synth_dataset(name, scale=scale, seed=0, max_feat=64)
        dec = decompose.decompose(g, comm_size=16, method="louvain")
        q = decompose.decomposition_quality(dec)
        rows.append(dict(dataset=name, **q))
        if verbose:
            emit(f"fig4_{name}", 0.0,
                 f"full={q['full']:.2e};intra={q['intra']:.2e};"
                 f"inter={q['inter']:.2e};intra_frac={q['intra_frac']:.2f}")
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 8: end-to-end GCN/GIN training time, AdaptGear vs framework
baselines.

Baseline strategies reimplemented in-repo (the originals are CUDA systems):
  dgl_style  : full-graph single-format aggregation, ELL/gather path
               (vertex-parallel — what DGL's CSR SpMM does)
  pyg_style  : full-graph single-format aggregation, COO/scatter path
               (edge-parallel — what PyG's scatter_add does)
  adaptgear  : community decomposition + per-subgraph adaptive kernels
               (feedback-selected)
Reported: per-step wall time, normalized to AdaptGear (=1.0).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import gnn
from repro.graphs import graph as G

DATASETS = ["cora", "citeseer", "pubmed"]


def run(models=("gcn", "gin"), scale: float = 0.1, steps: int = 8,
        verbose: bool = True) -> list[dict]:
    rows = []
    for name in DATASETS:
        g = G.synth_dataset(name, scale=scale, seed=0)
        for model in models:
            variants = {
                "dgl_style": gnn.GNNConfig(model=model, selector="fixed",
                                           fixed_kernels=("ell", "ell"),
                                           reorder="bfs"),
                "pyg_style": gnn.GNNConfig(model=model, selector="fixed",
                                           fixed_kernels=("coo", "coo"),
                                           reorder="bfs"),
                "adaptgear": gnn.GNNConfig(model=model, selector="feedback",
                                           warmup_iters=2, reorder="louvain"),
            }
            times = {}
            for vname, cfg in variants.items():
                res = gnn.train(g, cfg, steps=steps)
                times[vname] = res.step_seconds
            base = times["adaptgear"]
            row = dict(dataset=name, model=model,
                       **{k: v / max(base, 1e-12) for k, v in times.items()},
                       adaptgear_us=base * 1e6)
            rows.append(row)
            if verbose:
                emit(f"fig8_{name}_{model}", base * 1e6,
                     f"speedup_vs_dgl={times['dgl_style']/base:.2f};"
                     f"speedup_vs_pyg={times['pyg_style']/base:.2f}")
    return rows


if __name__ == "__main__":
    run()

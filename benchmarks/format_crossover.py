"""Paper Fig. 2b: aggregate-sum performance vs graph density per format.

RMAT graphs at Pubmed scale (scaled down for CPU) across a density sweep;
each point times the aggregation through COO (edge-parallel), ELL
(vertex-parallel CSR analogue), and dense block formats.  The paper's
finding — dense wins at high density, CSR mid, COO low — re-emerges with
TPU-shifted crossover points (the reason the adaptive selector exists).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, emit
from repro.core import decompose, formats
from repro.graphs import graph as G
from repro.kernels import ops, ref


def run(n: int = 1024, feat: int = 64, verbose: bool = True) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, feat)), jnp.float32)
    for density in (1e-3, 5e-3, 2e-2, 1e-1, 3e-1):
        e = max(int(n * n * density), n)
        src, dst = G.rmat(n, e, seed=1)
        coo = formats.coo_from_edges(n, n, dst, src)
        ell = formats.coo_to_ell(coo)
        # dense: one (n, n) matrix (the format the paper's Fig 2b uses)
        dense = jnp.zeros((n, n), jnp.float32).at[coo.rows, coo.cols].set(coo.vals)

        t_coo = timeit(jax.jit(lambda x: ops.coo_matvec(coo, x)), x)
        t_ell = timeit(jax.jit(lambda x: ops.ell_matvec(ell, x)), x)
        t_dense = timeit(jax.jit(lambda x: dense @ x), x)
        best = min(("coo", t_coo), ("ell", t_ell), ("dense", t_dense),
                   key=lambda kv: kv[1])[0]
        row = dict(density=coo.nnz / (n * n), coo_us=t_coo * 1e6,
                   ell_us=t_ell * 1e6, dense_us=t_dense * 1e6, best=best)
        rows.append(row)
        if verbose:
            emit(f"fig2b_density_{row['density']:.4f}",
                 min(t_coo, t_ell, t_dense) * 1e6,
                 f"best={best};coo={t_coo*1e6:.0f};ell={t_ell*1e6:.0f};"
                 f"dense={t_dense*1e6:.0f}")
    return rows


if __name__ == "__main__":
    run()

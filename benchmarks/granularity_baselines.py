"""Paper Figs. 9/10: AdaptGear vs full-graph-level (GNNAdvisor-style) and
block-level (PCGCN-style) kernel-mapping granularities.

  gnna_style  : community reordering as orthogonal preprocessing, then ONE
                static kernel for the whole graph (granularity: full graph)
  pcgcn_style : per-block adaptive execution — every diagonal block and every
                off-diagonal block row issues its own kernel call, results
                merged afterwards.  We execute it honestly as one device call
                per block (a Python loop of jitted calls), which is exactly
                the launch+merge overhead the paper measures against.
  adaptgear   : two kernels total (one per subgraph), adaptively selected.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, emit
from repro.core import adaptgear, decompose, gnn
from repro.graphs import graph as G
from repro.kernels import ops

DATASETS = ["cora", "citeseer", "pubmed"]


def pcgcn_style_aggregate(dec, x):
    """Block-level execution: one call per diagonal block + one per block
    row of each inter bucket, then merge."""
    B = dec.block_size
    nb = dec.n_pad // B
    blocks = dec.intra.formats["block_diag"].blocks
    xb = x.reshape(nb, B, -1)
    mm = jax.jit(lambda a, b: a @ b)
    parts = [mm(blocks[i], xb[i]) for i in range(nb)]        # launch per block
    y = jnp.stack(parts).reshape(dec.n_pad, -1)
    # per-bucket tiling: each bell payload carries its own block size
    row_call = jax.jit(lambda blk, idx, xx: jnp.einsum(
        "kij,kjf->if", blk, xx.reshape(-1, blk.shape[-1], xx.shape[-1])[idx]))
    for sub in dec.inters:
        bell = sub.formats["bell"][0]
        y_rows = [row_call(bell.blocks[i], bell.col_idx[i], x)
                  for i in range(bell.n_brow)]                # launch per row
        y = y + jnp.concatenate(y_rows).reshape(dec.n_pad, -1)
    return y


def run(scale: float = 0.08, feat: int = 32, verbose: bool = True):
    rows = []
    for name in DATASETS:
        g = G.synth_dataset(name, scale=scale, seed=0, max_feat=feat)
        dec = decompose.decompose(g, comm_size=16, method="louvain")
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((dec.n_pad, feat)), jnp.float32)

        # full-graph-level static kernel (GNNAdvisor-style)
        t_gnna = timeit(jax.jit(
            lambda x: adaptgear.aggregate_full_static(dec, x, "ell")), x)
        # block-level (PCGCN-style): honest per-block launches
        t_pcgcn = timeit(lambda x: pcgcn_style_aggregate(dec, x), x, iters=3)
        # AdaptGear subgraph-level, adaptively selected
        from repro.core import selector as sel_mod
        sel = sel_mod.AdaptiveSelector(dec, warmup_iters=1)
        choice = sel.probe(x, iters=1).choice
        t_ag = timeit(jax.jit(
            lambda x: adaptgear.aggregate(dec, x, choice)), x)

        row = dict(dataset=name, gnna_us=t_gnna * 1e6, pcgcn_us=t_pcgcn * 1e6,
                   adaptgear_us=t_ag * 1e6, choice=choice)
        rows.append(row)
        if verbose:
            emit(f"fig9_10_{name}", t_ag * 1e6,
                 f"vs_gnna={t_gnna/t_ag:.2f}x;vs_pcgcn={t_pcgcn/t_ag:.2f}x;"
                 f"choice={'+'.join(choice)}")
    return rows


if __name__ == "__main__":
    run()

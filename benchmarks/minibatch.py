"""Mini-batch sampling benchmark: cached vs uncached per-batch kernel
selection, and sampled vs full-batch step time.

Rows:
  * ``selection_uncached`` — cost-model selection run fresh per batch
    (what every step would pay without the PlanCache)
  * ``selection_cached``   — PlanCache.plan_for in steady state (signature
    lookup; the derived column carries the post-warmup hit rate, which the
    acceptance bar pins at >= 80% in this config)
  * ``sampled_step`` / ``fullbatch_step`` — jitted train-step wall time
  * ``batch_prepare``      — per-batch decompose + select + pad overhead
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import gnn, selector as sel_mod
from repro.graphs import graph as G
from repro.sampling.plan_cache import PlanCache
from repro.train import gnn_steps

WARMUP = 5


def run(dataset: str = "pubmed", scale: float = 0.05, steps: int = 25,
        clusters_per_batch: int = 16, verbose: bool = True) -> dict:
    graph = G.synth_dataset(dataset, scale=scale, seed=0)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", reorder="louvain",
                        clusters_per_batch=clusters_per_batch,
                        inter_buckets=2)

    res = gnn_steps.train_minibatch(graph, cfg, steps=steps, eval_batches=1)
    hit_rate = res.hit_rate(WARMUP)

    # selection overhead on a fixed stream of pre-decomposed batches:
    # cached = steady-state plan_for, uncached = fresh selection per batch
    sampler = gnn_steps.make_sampler(graph, cfg)
    pairs = gnn.agg_width_pairs(cfg, graph.features.shape[-1],
                                graph.n_classes)
    decs = []
    for _ in range(10):
        dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
        decs.append(dec)
    cache = PlanCache(pairs, hw=sel_mod.default_hw())
    for dec in decs:
        cache.plan_for(dec)          # warm: every signature now resident

    t0 = time.perf_counter()
    for dec in decs:
        cache.plan_for(dec)
    t_cached = (time.perf_counter() - t0) / len(decs)
    t0 = time.perf_counter()
    for dec in decs:
        cache.select(dec)
    t_uncached = (time.perf_counter() - t0) / len(decs)

    full = gnn.train(graph, gnn.GNNConfig(
        model="gcn", selector="cost_model", reorder="louvain",
        inter_buckets=2), steps=6)

    out = dict(hit_rate=hit_rate, cache=res.cache, n_traces=res.n_traces,
               t_cached=t_cached, t_uncached=t_uncached,
               sampled_step=res.step_seconds, full_step=full.step_seconds)
    if verbose:
        emit("selection_uncached", t_uncached * 1e6,
             f"per-batch cost-model selection x{len(decs)}")
        emit("selection_cached", t_cached * 1e6,
             f"hit_rate={hit_rate:.2f} (post-warmup, target >=0.80); "
             f"{t_uncached / max(t_cached, 1e-12):.1f}x cheaper than "
             f"uncached")
        emit("sampled_step", res.step_seconds * 1e6,
             f"traces={res.n_traces} plans={len(res.plans)} "
             f"prep_us={res.prepare_seconds*1e6:.0f}")
        emit("batch_prepare", res.prepare_seconds * 1e6,
             "decompose+select+pad per batch")
        emit("fullbatch_step", full.step_seconds * 1e6,
             f"n={graph.n} vs node_budget={cfg.clusters_per_batch * cfg.comm_size}")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()

"""Mini-batch sampling benchmark: cached vs uncached per-batch kernel
selection, single-pass vs two-pass host prepare, and sampled vs full-batch
step time.

Rows (the *_us rows are gated by benchmarks/baseline.json in CI):
  * ``selection_uncached_us`` — cost-model selection run fresh per batch
    (what every step would pay without the PlanCache)
  * ``selection_cached_us``   — PlanCache.plan_for in steady state
    (signature lookup; derived column carries the post-warmup hit rate,
    which the acceptance bar pins at >= 80% in this config)
  * ``prepare_us``            — single-pass per-batch host prepare: ONE
    partition into a DecomposeSkeleton, cache lookup on its stats-only
    view, payloads materialized from the same skeleton
  * ``prepare_twopass_us``    — the pre-skeleton baseline: a stats-only
    decompose for the lookup plus a second full decompose for the
    committed payloads (the edges partitioned twice); the derived column
    records the speedup, expected >= 1.5x
  * ``sampled_step`` / ``fullbatch_step`` — jitted train-step wall time
  * ``cache_hit_rate_pct``    — PlanCache health (hits / near-hits /
    misses / evictions / probes in the derived column) so the trend table
    tracks cache behavior per commit
  * ``skeleton_hit_rate_pct`` — repeated cluster tuples reusing a cached
    DecomposeSkeleton (skipping even the single partition pass)
  * ``sage_fused_step`` — mini-batch SAGE step time with the cost model
    free to commit the fused dual-weight epilogue plan
  * ``budget_k_slack``  — adapted blocked-ELL budget slack (value column =
    the slack factor; spill fraction and slack steps in the derived
    column), from a short run with ``adapt_budget_k`` on
  * ``pipeline_step_us`` — median full-iteration wall time with the async
    sampler->trainer pipeline on (prefetch_depth=4, 2 workers), real
    model; derived column carries the sync iteration, core count, traces,
    hit rate, and backpressure counters
  * ``step_overlap_us``  — microseconds of host prepare the pipeline hides
    per iteration, measured on timed (sleep) stages sized like the real
    ones so the row is meaningful on single-core CI runners: sync pays
    prepare + compute serially, async pays ~max(prepare, compute);
    HIGHER is better and check_regression gates it downward
  * ``pipeline_efficiency_pct`` — device-busy share of the steady-state
    async consumer loop (100% = prepare fully hidden behind compute);
    gated downward
  * ``telemetry_overhead_pct`` — cost of the repro.obs hooks when
    telemetry is DISABLED (the default), as a percent of the single-pass
    prepare: null-span + registry-counter cost measured empirically,
    multiplied by the per-batch hook count observed on a short
    telemetry-enabled run; check_regression gates this row absolutely
    at < 2% (the obs contract)
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import gnn, selector as sel_mod
from repro.graphs import graph as G
from repro.sampling.plan_cache import PlanCache, plan_payload_keys, fix_shapes
from repro.train import gnn_steps
from repro.train.pipeline import BatchPipeline

WARMUP = 5


def _best_us(fn, items, reps: int = 5) -> float:
    """Min over reps of (total seconds over items) / len(items) — the
    least-noise estimator for host-side work on shared runners."""
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for it in items:
            fn(it)
        ts.append((time.perf_counter() - t0) / max(len(items), 1))
    return float(min(ts)) * 1e6


def run(dataset: str = "pubmed", scale: float = 0.05, steps: int = 25,
        clusters_per_batch: int = 16, verbose: bool = True) -> dict:
    graph = G.synth_dataset(dataset, scale=scale, seed=0)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", reorder="louvain",
                        clusters_per_batch=clusters_per_batch,
                        inter_buckets=2)

    res = gnn_steps.train_minibatch(graph, cfg, steps=steps, eval_batches=1)
    hit_rate = res.hit_rate(WARMUP)

    # selection overhead on a fixed stream of pre-decomposed batches:
    # cached = steady-state plan_for, uncached = fresh selection per batch
    sampler = gnn_steps.make_sampler(graph, cfg)
    pairs = gnn.agg_width_pairs(cfg, graph.features.shape[-1],
                                graph.n_classes)
    batches = [sampler.sample() for _ in range(10)]
    decs = []
    for b in batches:
        dec, _ = gnn_steps.prepare_batch(b, cfg)
        decs.append(dec)
    cache = PlanCache(pairs, hw=sel_mod.default_hw())
    plans = [cache.plan_for(dec)[0] for dec in decs]   # warm: all resident

    t_cached = _best_us(cache.plan_for, decs) / 1e6
    t_uncached = _best_us(cache.select, decs) / 1e6

    # host prepare: single-pass skeleton flow vs the two-pass baseline,
    # on the same batch stream with the same (warm) committed plans, end
    # to end through fix_shapes — what one hot-loop iteration pays
    budget = sampler.edge_budget + (sampler.node_budget
                                    if cfg.model == "gcn" else 0)

    plan_of = {id(b): p for b, p in zip(batches, plans)}

    def one_pass(b):
        """This PR's hot path: one partition, per-tier payload keeps."""
        skel, _ = gnn_steps.prepare_skeleton(b, cfg)
        plan = cache.lookup(skel) or plan_of[id(b)]
        dec = skel.materialize(plan_payload_keys(plan))
        fix_shapes(dec, budget, keep=plan_payload_keys(plan))

    def two_pass(b):
        """The pre-skeleton plan_and_fix, faithfully: a stats-only
        decomposition for the lookup, then a SECOND full decomposition
        building the global union of the plan's kernels on every tier,
        padded with the same global keep set."""
        dec0, _ = gnn_steps.prepare_batch(b, cfg, kernels=())  # lookup pass
        plan = cache.lookup(dec0) or plan_of[id(b)]
        names = tuple({k for layer in plan.layers for k in layer})
        dec, _ = gnn_steps.prepare_batch(b, cfg, kernels=names)
        keep = frozenset().union(*plan_payload_keys(plan))
        fix_shapes(dec, budget, keep=keep)

    # interleave the two variants so background noise hits both alike
    # (an unpaired A-then-B measurement can invert the ratio on a noisy
    # shared runner); min-of-reps per side is the paired estimator
    import gc
    gc.collect()
    gc.disable()               # GC pauses are the dominant noise source
    one_ts, two_ts = [], []
    for _ in range(9):
        t0 = time.perf_counter()
        for b in batches:
            one_pass(b)
        one_ts.append((time.perf_counter() - t0) / len(batches))
        t0 = time.perf_counter()
        for b in batches:
            two_pass(b)
        two_ts.append((time.perf_counter() - t0) / len(batches))
    gc.enable()
    prep_one_us = min(one_ts) * 1e6
    prep_two_us = min(two_ts) * 1e6
    # speedup from the paired per-rep ratios (noise is common-mode within
    # a pair, so the ratio is far stabler than a ratio of minima)
    prep_speedup = float(np.median(np.asarray(two_ts) / np.asarray(one_ts)))

    full = gnn.train(graph, gnn.GNNConfig(
        model="gcn", selector="cost_model", reorder="louvain",
        inter_buckets=2), steps=6)

    # epilogue-fused mini-batch SAGE (dual-weight plan when the cost model
    # commits it) — the hot path the epilogue fusion targets
    sage_cfg = gnn.GNNConfig(model="sage", sampler="cluster",
                             reorder="louvain",
                             clusters_per_batch=clusters_per_batch,
                             inter_buckets=2)
    sage_res = gnn_steps.train_minibatch(graph, sage_cfg,
                                         steps=max(steps // 2, 6),
                                         eval_batches=1)
    sage_used = sorted({k for plan in sage_res.plans
                        for layer in plan for k in layer})

    # column-condensed MXU tiles in the fixed-shape mini-batch path:
    # pin tcgnn_tile on the inter tiers (budget-capped C + COO spill keeps
    # the payload pytree fixed) and confirm the jitted step never retraces
    tc_cfg = gnn.GNNConfig(model="gin", sampler="cluster",
                           reorder="louvain",
                           clusters_per_batch=clusters_per_batch,
                           inter_buckets=2, selector="fixed",
                           fixed_kernels=("block_diag", "tcgnn_tile"))
    tc_res = gnn_steps.train_minibatch(graph, tc_cfg,
                                       steps=max(steps // 2, 6),
                                       eval_batches=1)
    tc_used = sorted({k for plan in tc_res.plans
                      for layer in plan for k in layer})

    # budget-K autotuning: short adaptive run, slack + spill in the JSON
    adapt_cfg = gnn.GNNConfig(model="gin", sampler="cluster",
                              reorder="louvain",
                              clusters_per_batch=clusters_per_batch,
                              inter_buckets=2, adapt_budget_k=True)
    adapt_res = gnn_steps.train_minibatch(graph, adapt_cfg,
                                          steps=max(steps // 2, 8),
                                          eval_batches=1)
    ac = adapt_res.cache

    # async sampler->trainer pipeline vs the sync loop, same config/seed:
    # sync pays compute + prepare serially per iteration, the pipeline
    # pays ~max(compute, prepare).  Run the real model through both paths
    # (plans/traces/hit-rate must be unchanged), then measure the
    # orchestration overlap on timed stages sized like the real ones —
    # sleeps yield the core the way device compute does, so this row
    # stays meaningful on core-starved CI runners where real numpy
    # prepare and XLA compute merely time-slice one CPU
    pipe_cfg = dataclasses.replace(cfg, prefetch_depth=4,
                                   pipeline_workers=2)
    pipe_res = gnn_steps.train_minibatch(graph, pipe_cfg, steps=steps,
                                         eval_batches=1)
    sync_iter_us = res.iter_seconds * 1e6
    pipe_iter_us = pipe_res.iter_seconds * 1e6
    efficiency = pipe_res.pipeline["efficiency_pct"]

    prep_s, compute_s, n_sim = 0.002, 0.005, 30

    def timed_sync():
        t0 = time.perf_counter()
        for _ in range(n_sim):
            time.sleep(prep_s)          # host prepare
            time.sleep(compute_s)       # device step
        return (time.perf_counter() - t0) / n_sim

    def timed_async():
        counter = iter(range(n_sim))
        t0 = time.perf_counter()
        with BatchPipeline(lambda: next(counter),
                           lambda i, t: time.sleep(prep_s) or t,
                           n_items=n_sim, prefetch_depth=4,
                           workers=2) as pipe:
            for _ in range(n_sim):
                pipe.get()
                time.sleep(compute_s)
        return (time.perf_counter() - t0) / n_sim

    sim_sync_us = timed_sync() * 1e6
    sim_async_us = timed_async() * 1e6
    overlap_us = max(sim_sync_us - sim_async_us, 0.0)
    bound_us = max(prep_s, compute_s) * 1e6

    skel_total = res.skeleton_hits + res.skeleton_misses
    skel_rate = res.skeleton_hits / max(skel_total, 1)

    # telemetry disabled-path overhead: every instrumented call site pays
    # one null-object hook (shared _NULL_SPAN context manager or a
    # registry Counter.inc) whether or not telemetry is on.  Measure the
    # hook cost empirically, count hooks-per-batch on a short
    # telemetry-ENABLED run (span events are exactly the spans the
    # disabled path would have null'd), and express the product as a
    # percent of the single-pass prepare those hooks ride on.  The obs
    # contract is < 2%; check_regression gates this row absolutely.
    from repro.obs import Telemetry

    tele_cfg = dataclasses.replace(cfg, telemetry=True)
    tele_steps = max(steps // 2, 8)
    tele_res = gnn_steps.train_minibatch(graph, tele_cfg, steps=tele_steps,
                                         eval_batches=1)
    spans_per_batch = tele_res.telemetry["n_span_events"] / tele_steps
    # counters fire on cache hit/miss bookkeeping, fault tallies, and
    # pipeline waits — roughly 4 increments per span in the hot loop
    counters_per_batch = 4.0 * spans_per_batch

    null = Telemetry()                       # enabled=False: default path
    null_ctr = null.metrics.counter("bench.null_hook")
    n_hook = 5000

    def span_hooks(_):
        for _ in range(n_hook):
            with null.tracer.span("bench"):
                pass

    def ctr_hooks(_):
        for _ in range(n_hook):
            null_ctr.inc()

    # min-of-reps like every host-side row: scheduler noise only ever
    # inflates a 1.5ms timing window
    null_span_us = _best_us(span_hooks, [None], reps=7) / n_hook
    ctr_inc_us = _best_us(ctr_hooks, [None], reps=7) / n_hook
    hook_us = (spans_per_batch * null_span_us
               + counters_per_batch * ctr_inc_us)
    telemetry_overhead_pct = 100.0 * hook_us / max(prep_one_us, 1e-9)

    out = dict(hit_rate=hit_rate, cache=res.cache, n_traces=res.n_traces,
               t_cached=t_cached, t_uncached=t_uncached,
               prepare_us=prep_one_us, prepare_twopass_us=prep_two_us,
               prepare_speedup=prep_speedup,
               sampled_step=res.step_seconds, full_step=full.step_seconds,
               sage_step=sage_res.step_seconds, sage_plans=sage_used,
               tcgnn_step=tc_res.step_seconds, tcgnn_plans=tc_used,
               tcgnn_traces=tc_res.n_traces,
               skeleton_hit_rate=skel_rate,
               pipeline_iter=pipe_res.iter_seconds,
               sync_iter=res.iter_seconds,
               sim_sync_us=sim_sync_us, sim_async_us=sim_async_us,
               step_overlap_us=overlap_us,
               pipeline_efficiency_pct=efficiency,
               pipeline_stats=pipe_res.pipeline,
               pipeline_hit_rate=pipe_res.hit_rate(WARMUP),
               pipeline_traces=pipe_res.n_traces,
               bell_slack=ac.get("bell_slack"),
               spill_frac=ac.get("spill_frac"),
               fault_counters=pipe_res.faults,
               telemetry_overhead_pct=telemetry_overhead_pct,
               spans_per_batch=spans_per_batch,
               null_span_us=null_span_us, ctr_inc_us=ctr_inc_us)
    if verbose:
        emit("selection_uncached_us", t_uncached * 1e6,
             f"per-batch cost-model selection x{len(decs)}")
        emit("selection_cached_us", t_cached * 1e6,
             f"hit_rate={hit_rate:.2f} (post-warmup, target >=0.80); "
             f"{t_uncached / max(t_cached, 1e-12):.1f}x cheaper than "
             f"uncached")
        emit("prepare_us", prep_one_us,
             f"single-pass skeleton prepare; {prep_speedup:.2f}x vs "
             f"two-pass (target >=1.5x)")
        emit("prepare_twopass_us", prep_two_us,
             "legacy baseline: edges partitioned twice per batch")
        emit("sampled_step", res.step_seconds * 1e6,
             f"traces={res.n_traces} plans={len(res.plans)} "
             f"prep_us={res.prepare_seconds*1e6:.0f}")
        emit("fullbatch_step", full.step_seconds * 1e6,
             f"n={graph.n} vs node_budget={cfg.clusters_per_batch * cfg.comm_size}")
        c = res.cache
        emit("cache_hit_rate_pct", hit_rate * 100,
             f"hits={c['hits']} near={c['near_hits']} miss={c['misses']} "
             f"evict={c['evictions']} probes={c['probes']} "
             f"entries={c['entries']}")
        emit("skeleton_hit_rate_pct", skel_rate * 100,
             f"hits={res.skeleton_hits} misses={res.skeleton_misses} "
             "(repeated cluster tuples skip decompose_skeleton)")
        emit("sage_fused_step", sage_res.step_seconds * 1e6,
             f"traces={sage_res.n_traces} kernels={','.join(sage_used)}")
        emit("tcgnn_selected_step", tc_res.step_seconds * 1e6,
             f"traces={tc_res.n_traces} kernels={','.join(tc_used)} "
             "(condensed tiles pinned on inter tiers, fixed-shape "
             "budget-capped payload)")
        emit("budget_k_slack", ac.get("bell_slack", 0.0),
             f"spill_frac={ac.get('spill_frac', 0.0):.4f} "
             f"slack_changes={ac.get('slack_changes', 0)} "
             f"spill_nnz={ac.get('spill_nnz', 0)}")
        ps = pipe_res.pipeline
        emit("pipeline_step_us", pipe_iter_us,
             f"async iter vs sync {sync_iter_us:.0f}us on "
             f"{os.cpu_count()} core(s); traces={pipe_res.n_traces} "
             f"hit_rate={pipe_res.hit_rate(WARMUP):.2f} "
             f"ready_mean={ps['ready_mean']:.1f}/{ps['depth']} "
             f"wait_full_ms={ps['wait_full_s']*1e3:.1f} "
             f"wait_empty_ms={ps['wait_empty_s']*1e3:.1f}")
        emit("step_overlap_us", overlap_us,
             f"prepare hidden per iteration on timed stages (higher "
             f"better): async {sim_async_us:.0f}us vs sync "
             f"{sim_sync_us:.0f}us, bound max(compute,prepare)*1.15="
             f"{bound_us * 1.15:.0f}us")
        emit("pipeline_efficiency_pct", efficiency,
             f"device-busy share of steady-state async loop (higher "
             f"better); workers={ps['workers']} starved={ps['starved']}")
        fc = pipe_res.faults
        emit("pipeline_fault_counters",
             float(fc["retries"] + fc["quarantined"]
                   + fc["nonfinite_skips"]),
             f"retries={fc['retries']} quarantined={fc['quarantined']} "
             f"nonfinite={fc['nonfinite_skips']} (clean run: expect 0)")
        emit("telemetry_overhead_pct", telemetry_overhead_pct,
             f"disabled-path hooks vs prepare {prep_one_us:.0f}us: "
             f"{spans_per_batch:.1f} null spans/batch @ "
             f"{null_span_us:.3f}us + ~{counters_per_batch:.0f} counter "
             f"incs @ {ctr_inc_us:.3f}us (absolute gate < 2%)")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()

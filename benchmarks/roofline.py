import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Roofline analysis (§Roofline of EXPERIMENTS.md).

Derives, per (architecture x input shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / ICI link bw   (per chip)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
from summing operand sizes of all-gather/all-reduce/reduce-scatter/
all-to-all/collective-permute in the compiled HLO text.

SCAN CORRECTION.  XLA's cost analysis counts a while-loop body ONCE, and the
production configs scan over layers.  We therefore lower each cell at 2-3
small UNROLLED layer counts (same remat, same shardings, scan_layers=False),
solve the linear system for (base, per-layer) costs, and compose to the full
depth.  This is exact for layer-local costs (XLA optimizations do not cross
layer boundaries in these graphs) and is validated against a directly
unrolled mid-size model in tests.

MODEL_FLOPS uses 6*N*D (training) / 2*N*D (inference) with N = active
non-embedding params (MoE counts shared + top_k/E of routed experts).

  PYTHONPATH=src python -m benchmarks.roofline --out results/roofline.json
  PYTHONPATH=src python -m benchmarks.roofline --arch rwkv6_7b --shape train_4k
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

import numpy as np   # noqa: E402

from benchmarks import hw                      # noqa: E402
from repro import configs                      # noqa: E402
from repro.launch import mesh as mesh_mod      # noqa: E402


# ---------------------------------------------------------------------------
# active-parameter counting (for MODEL_FLOPS)
# ---------------------------------------------------------------------------

def active_params(cfg) -> float:
    """Non-embedding params touched per token (MoE: shared + top_k routed)."""
    import jax
    from repro.models import lm

    def layer_params(kind):
        shapes = jax.eval_shape(
            lambda: lm.init_layer(jax.random.PRNGKey(0), cfg, kind))
        return shapes

    total = 0.0
    for kind, n in cfg.layer_groups():
        shapes = layer_params(kind)
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = "/".join(str(getattr(p, "key", "")) for p in path)
            size = float(np.prod(leaf.shape))
            if "w_gate" in keys or "w_up" in keys or "w_down" in keys:
                # routed experts in an (E, ., .) stack -> top_k/E active
                if len(leaf.shape) == 3 and leaf.shape[0] == cfg.n_experts \
                        and cfg.n_experts:
                    size *= cfg.top_k / cfg.n_experts
            total += size * n
    # lm head is a real matmul per token
    total += cfg.d_model * cfg.padded_vocab
    if cfg.mtp:
        total += 2 * cfg.d_model * cfg.d_model
    return total


def model_flops(cfg, mode: str, seq: int, batch: int) -> float:
    n_act = active_params(cfg)
    if mode == "train":
        return 6.0 * n_act * seq * batch
    if mode == "prefill":
        return 2.0 * n_act * seq * batch
    return 2.0 * n_act * batch      # decode: one token per sequence


# ---------------------------------------------------------------------------
# scan-corrected costs via small unrolled probes
# ---------------------------------------------------------------------------

def _probe(arch, shape_name, mesh, overrides, extra=None,
           rules_overrides=None):
    from repro.launch.dryrun import dryrun_cell
    base = dict(scan_layers=False, mtp=False)
    base.update(overrides)
    base.update(extra or {})
    r = dryrun_cell(arch, shape_name, mesh, verbose=False,
                    model_overrides=base, rules_overrides=rules_overrides)
    assert r["status"] == "ok", r
    return dict(flops=r["flops"], bytes=r["bytes_accessed"],
                coll=float(r["collective_bytes"]["total"]),
                mem=r["memory"])


def _lin(a, b):
    """per-unit cost from two probes differing by one unit."""
    return {k: b[k] - a[k] for k in ("flops", "bytes", "coll")}


def _compose(base_probe, units):
    """base_probe costs minus probe-units plus full-depth units."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = base_probe[k] + sum(per[k] * extra for per, extra in units)
    return out


def corrected_costs(arch: str, shape_name: str, mesh, extra: dict | None = None,
                    rules_overrides: dict | None = None) -> dict:
    """Compose full-depth costs from small unrolled probes.  ``extra``
    model-config overrides and ``rules_overrides`` sharding-rule overrides
    define §Perf variants (head padding, dispatch path, remat policy...)."""
    cfg = configs.get_config(arch)
    L = cfg.n_layers
    kw = dict(extra=extra, rules_overrides=rules_overrides)
    if cfg.family == "encdec":
        p11 = _probe(arch, shape_name, mesh,
                     dict(n_layers=1, encoder_layers=1), **kw)
        p21 = _probe(arch, shape_name, mesh,
                     dict(n_layers=1, encoder_layers=2), **kw)
        p12 = _probe(arch, shape_name, mesh,
                     dict(n_layers=2, encoder_layers=1), **kw)
        enc = _lin(p11, p21)
        dec = _lin(p11, p12)
        return _compose(p11, [(enc, cfg.encoder_layers - 1), (dec, L - 1)])
    if cfg.layer_pattern == "jamba":
        p1 = _probe(arch, shape_name, mesh, dict(n_layers=8), **kw)
        p2 = _probe(arch, shape_name, mesh, dict(n_layers=16), **kw)
        per = _lin(p1, p2)
        return _compose(p1, [(per, L // 8 - 1)])
    if cfg.n_experts and cfg.first_k_dense:
        pa = _probe(arch, shape_name, mesh,
                    dict(n_layers=2, first_k_dense=1), **kw)
        pb = _probe(arch, shape_name, mesh,
                    dict(n_layers=3, first_k_dense=1), **kw)
        pc = _probe(arch, shape_name, mesh,
                    dict(n_layers=3, first_k_dense=2), **kw)
        moe = _lin(pa, pb)
        dense = {k: pc[k] - pb[k] + moe[k] for k in moe}
        return _compose(pa, [(dense, cfg.first_k_dense - 1),
                             (moe, (L - cfg.first_k_dense) - 1)])
    # uniform decoder (dense / uniform-moe / rwkv)
    p1 = _probe(arch, shape_name, mesh, dict(n_layers=1, first_k_dense=0), **kw)
    p2 = _probe(arch, shape_name, mesh, dict(n_layers=2, first_k_dense=0), **kw)
    per = _lin(p1, p2)
    return _compose(p1, [(per, L - 1)])


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------

def roofline_row(arch: str, shape_name: str, mesh, n_chips: int = 256) -> dict:
    cfg = configs.get_config(arch)
    ok, reason = configs.shape_applicable(cfg, shape_name)
    if not ok:
        return dict(arch=arch, shape=shape_name, status="skipped",
                    reason=reason)
    sh = configs.SHAPES[shape_name]
    costs = corrected_costs(arch, shape_name, mesh)
    # cost_analysis is per-device (the SPMD-partitioned program)
    t_compute = costs["flops"] / hw.PEAK_FLOPS_BF16
    t_memory = costs["bytes"] / hw.HBM_BW
    t_coll = costs["coll"] / hw.ICI_BW_PER_LINK
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, sh["mode"], sh["seq"], sh["batch"]) / n_chips
    useful = mf / max(costs["flops"], 1.0)
    frac = (mf / hw.PEAK_FLOPS_BF16) / max(max(terms.values()), 1e-30)
    return dict(arch=arch, shape=shape_name, status="ok", mode=sh["mode"],
                flops_per_dev=costs["flops"], bytes_per_dev=costs["bytes"],
                coll_bytes_per_dev=costs["coll"],
                t_compute_s=t_compute, t_memory_s=t_memory,
                t_collective_s=t_coll, dominant=dominant,
                model_flops_per_dev=mf, useful_flop_ratio=useful,
                roofline_fraction=frac)


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2%} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    mesh = mesh_mod.make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(configs.SHAPES)
    rows = []
    for arch in archs:
        for shape in shapes:
            try:
                r = roofline_row(arch, shape, mesh)
            except Exception as e:
                import traceback
                traceback.print_exc()
                r = dict(arch=arch, shape=shape, status="FAILED",
                         error=str(e)[-500:])
            rows.append(r)
            if r["status"] == "ok":
                print(f"{arch:20s} {shape:12s} dom={r['dominant']:10s} "
                      f"c={r['t_compute_s']:.2e} m={r['t_memory_s']:.2e} "
                      f"x={r['t_collective_s']:.2e} "
                      f"useful={r['useful_flop_ratio']:.2f} "
                      f"frac={r['roofline_fraction']:.1%}", flush=True)
            else:
                print(f"{arch:20s} {shape:12s} {r['status']}", flush=True)
    print()
    print(fmt_table(rows))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()

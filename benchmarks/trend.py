"""Perf-trend accumulator over the quick-bench machine-readable output.

Folds a directory of ``BENCH_*.json`` (one CI run) into a rolling history
file and renders a markdown trend table — the across-commits view the
single-commit regression gate (check_regression.py) cannot give.  CI runs
it right after the gate and uploads both artifacts; locally:

  PYTHONPATH=src python -m benchmarks.run --quick --json bench-out
  PYTHONPATH=src python -m benchmarks.trend bench-out \\
      --history trend-history.json --commit $(git rev-parse HEAD) \\
      --markdown trend.md

History schema: ``{"entries": [{"commit", "time", "rows": {key: us}}]}``
with one entry per commit (re-running a commit replaces its entry), capped
at ``--max-entries``.  The markdown table shows the last ``--last`` commits
as columns, one benchmark row per line, with the newest column annotated
by its delta vs the previous commit.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

from benchmarks.check_regression import load_rows


def load_history(path: str) -> dict:
    if path and os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        if isinstance(doc.get("entries"), list):
            return doc
    return {"entries": []}


def accumulate(history: dict, commit: str, rows: dict,
               max_entries: int = 200, now: float | None = None) -> dict:
    """Fold one run's rows into the history; keep the newest entries.

    A commit already present is replaced *in place* (a CI re-run of an old
    commit must not reorder the chronology — deltas compare each column to
    the one before it); a new commit appends."""
    entries = list(history.get("entries", []))
    entry = dict(
        commit=commit,
        time=time.strftime("%Y-%m-%dT%H:%M:%SZ",
                           time.gmtime(now if now is not None else None)),
        rows={k: round(v, 1) for k, v in sorted(rows.items())
              if math.isfinite(v)})
    slots = [i for i, e in enumerate(entries) if e.get("commit") == commit]
    if slots:
        entries[slots[0]] = entry
        entries = [e for i, e in enumerate(entries)
                   if i == slots[0] or e.get("commit") != commit]
    else:
        entries.append(entry)
    return {"entries": entries[-max_entries:]}


def _fmt_us(us: float | None) -> str:
    return "-" if us is None else f"{us:.0f}"


def markdown_table(history: dict, last: int = 10) -> str:
    """One row per benchmark key, one column per commit (oldest first),
    newest column annotated with its delta vs the previous commit."""
    entries = history.get("entries", [])[-last:]
    if not entries:
        return "(no perf history)\n"
    keys = sorted({k for e in entries for k in e["rows"]})
    heads = [e["commit"][:9] for e in entries]
    lines = ["# Perf trend (us_per_call)", "",
             "| benchmark/row | " + " | ".join(heads) + " |",
             "|---|" + "---|" * len(entries)]
    for k in keys:
        cells = [_fmt_us(e["rows"].get(k)) for e in entries]
        if len(entries) >= 2:
            cur = entries[-1]["rows"].get(k)
            prev = entries[-2]["rows"].get(k)
            if cur is not None and prev:
                cells[-1] += f" ({(cur / prev - 1) * 100:+.0f}%)"
        lines.append(f"| {k} | " + " | ".join(cells) + " |")
    lines += ["", f"({len(history.get('entries', []))} commits tracked; "
                  f"showing last {len(entries)})", ""]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_dir", help="directory holding BENCH_*.json")
    ap.add_argument("--history", default="trend-history.json",
                    help="rolling JSON history file (read + rewritten)")
    ap.add_argument("--commit", default="worktree",
                    help="commit id labelling this run's column")
    ap.add_argument("--markdown", default=None, metavar="PATH",
                    help="also render the trend table to PATH")
    ap.add_argument("--last", type=int, default=10,
                    help="commits shown in the markdown table")
    ap.add_argument("--max-entries", type=int, default=200)
    args = ap.parse_args()

    rows = load_rows(args.bench_dir)
    history = accumulate(load_history(args.history), args.commit, rows,
                         max_entries=args.max_entries)
    with open(args.history, "w") as f:
        json.dump(history, f, indent=1)
        f.write("\n")
    table = markdown_table(history, last=args.last)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(table)
    print(table)
    print(f"history: {len(history['entries'])} entries -> {args.history}")


if __name__ == "__main__":
    main()

"""Paper Fig. 12: memory overhead of storing the decomposed subgraph
topology vs total training memory (features + activations + params + grads
+ optimizer moments)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import decompose
from repro.graphs import graph as G

DATASETS = ["cora", "citeseer", "pubmed", "proteins_full"]


def selected_topology_bytes(dec, plan_layer) -> int:
    """Bytes of the format payloads the selected plan keeps on device
    (a kernel's payload already includes its VJP operand, e.g. the
    blocked-ELL transpose; fused kernels alias their unfused payload)."""
    from repro.kernels.registry import REGISTRY, payload_nbytes
    return sum(payload_nbytes(sub.formats[REGISTRY.get(k).payload_key])
               for sub, k in zip(dec.subgraphs, plan_layer))


def run(scale: float = 0.05, hidden: int = 16, verbose: bool = True):
    from repro.core import selector as sel_mod
    rows = []
    for name in DATASETS:
        g = G.synth_dataset(name, scale=scale, seed=0)
        dec = decompose.decompose(g, comm_size=16, method="louvain")
        # topology bytes for the SELECTED pair only — what lives on device
        # during training (paper Fig. 12 counts the kept subgraph tensors)
        plan_layer = sel_mod.select_by_cost_model(dec, hidden,
                                                  hw=sel_mod.CPU_HW)
        topo = selected_topology_bytes(dec, plan_layer)
        feat = g.features.size * 4
        nf = g.features.shape[1]
        # GCN training footprint: features + 2x activations + params(+grads,
        # +2 Adam moments)
        act = dec.n_pad * hidden * 4 * 2 * 2
        params = (nf * hidden + hidden * g.n_classes) * 4 * 4
        total = feat + act + params + topo
        frac = topo / total
        rows.append(dict(dataset=name, topo_mb=topo / 2**20,
                         total_mb=total / 2**20, frac=frac))
        if verbose:
            emit(f"fig12_{name}", 0.0,
                 f"topo={topo/2**20:.2f}MB;total={total/2**20:.2f}MB;"
                 f"frac={frac*100:.2f}%")
    return rows


if __name__ == "__main__":
    run()

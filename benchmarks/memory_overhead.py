"""Paper Fig. 12: memory overhead of storing the decomposed subgraph
topology vs total training memory (features + activations + params + grads
+ optimizer moments)."""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core import decompose
from repro.graphs import graph as G

DATASETS = ["cora", "citeseer", "pubmed", "proteins_full"]


def fmt_bytes(fmt) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(fmt)
               if hasattr(a, "size"))


def selected_topology_bytes(dec, intra_k: str, inter_k: str) -> int:
    """Bytes of the formats the selector actually keeps on device."""
    intra = {"block_diag": [dec.intra_bd], "ell": [dec.intra_ell],
             "coo": [dec.intra_coo]}[intra_k]
    inter = {"bell": [dec.inter_bell, dec.inter_bell_t],
             "ell": [dec.inter_ell, dec.inter_coo],   # ell fwd + coo-T bwd
             "coo": [dec.inter_coo]}[inter_k]
    return sum(fmt_bytes(f) for f in intra + inter)


def run(scale: float = 0.05, hidden: int = 16, verbose: bool = True):
    from repro.core import selector as sel_mod
    rows = []
    for name in DATASETS:
        g = G.synth_dataset(name, scale=scale, seed=0)
        dec = decompose.decompose(g, comm_size=16, method="louvain")
        # topology bytes for the SELECTED pair only — what lives on device
        # during training (paper Fig. 12 counts the kept subgraph tensors)
        ik, ek = sel_mod.select_by_cost_model(dec, hidden, hw=sel_mod.CPU_HW)
        topo = selected_topology_bytes(dec, ik, ek)
        feat = g.features.size * 4
        nf = g.features.shape[1]
        # GCN training footprint: features + 2x activations + params(+grads,
        # +2 Adam moments)
        act = dec.n_pad * hidden * 4 * 2 * 2
        params = (nf * hidden + hidden * g.n_classes) * 4 * 4
        total = feat + act + params + topo
        frac = topo / total
        rows.append(dict(dataset=name, topo_mb=topo / 2**20,
                         total_mb=total / 2**20, frac=frac))
        if verbose:
            emit(f"fig12_{name}", 0.0,
                 f"topo={topo/2**20:.2f}MB;total={total/2**20:.2f}MB;"
                 f"frac={frac*100:.2f}%")
    return rows


if __name__ == "__main__":
    run()

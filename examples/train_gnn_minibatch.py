"""Mini-batch sampled-subgraph training driver: the sampling subsystem
end-to-end (Graph -> Sampler -> SampledBatch -> per-batch decompose ->
PlanCache -> jitted step), with the plan-cache and no-retrace accounting
printed next to a full-batch reference run.

By default the async sampler->trainer pipeline is on (--prefetch 4
--workers 2): background threads sample, decompose, resolve the PlanCache,
pad, and stage batches ahead of the jitted step, so one iteration pays
~max(compute, prepare) instead of their sum; --prefetch 0 runs the
synchronous loop.

Fault tolerance: --checkpoint-dir + --checkpoint-every snapshot params,
optimizer state, the batch cursor, and the PlanCache periodically (atomic
+ crc-verified, async writer); --resume restarts from the latest valid
checkpoint bit-identically to the uninterrupted run; --retry-max absorbs
transient sampler/stage failures with backoff.  Kill the process mid-run
and rerun with --resume to see the recovery contract in action.

  PYTHONPATH=src python examples/train_gnn_minibatch.py [--steps 100]
  PYTHONPATH=src python examples/train_gnn_minibatch.py --sampler neighbor
  PYTHONPATH=src python examples/train_gnn_minibatch.py --prefetch 0
  PYTHONPATH=src python examples/train_gnn_minibatch.py \\
      --checkpoint-dir /tmp/gnn_ckpt --checkpoint-every 20   # then ^C ...
  PYTHONPATH=src python examples/train_gnn_minibatch.py \\
      --checkpoint-dir /tmp/gnn_ckpt --checkpoint-every 20 --resume
"""
import argparse

from repro.core import gnn
from repro.graphs import graph as G


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--dataset", default="pubmed")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--model", default="gcn", choices=["gcn", "gin", "sage"])
    ap.add_argument("--sampler", default="cluster",
                    choices=["cluster", "neighbor"])
    ap.add_argument("--clusters-per-batch", type=int, default=16)
    ap.add_argument("--batch-nodes", type=int, default=128)
    ap.add_argument("--inter-buckets", type=int, default=2)
    ap.add_argument("--probe-every", type=int, default=0,
                    help="wall-clock the top-2 cost-model candidates on "
                         "every Nth PlanCache miss and pin the winner "
                         "(0 = cost model only)")
    ap.add_argument("--prefetch", type=int, default=4,
                    help="async pipeline prefetch depth: background "
                         "workers sample/decompose/stage this many batches "
                         "ahead of the training step (0 = synchronous)")
    ap.add_argument("--workers", type=int, default=2,
                    help="background sampler/prepare threads for the "
                         "async pipeline")
    ap.add_argument("--full-batch", action="store_true",
                    help="also train full-batch for a step-time reference")
    ap.add_argument("--checkpoint-dir", default="",
                    help="directory for periodic crash-safe checkpoints "
                         "(params + opt + cursor + PlanCache state)")
    ap.add_argument("--checkpoint-every", type=int, default=25,
                    help="checkpoint every N batches (with "
                         "--checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest valid checkpoint in "
                         "--checkpoint-dir (bit-identical to the "
                         "uninterrupted run)")
    ap.add_argument("--retry-max", type=int, default=0,
                    help="retry transient batch-build/stage failures up "
                         "to N times with exponential backoff")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")

    graph = G.synth_dataset(args.dataset, scale=args.scale, seed=0)
    print(f"{args.dataset}: {graph.n} vertices, {graph.n_edges} edges, "
          f"sampler={args.sampler}")

    cfg = gnn.GNNConfig(
        model=args.model, sampler=args.sampler, reorder="louvain",
        clusters_per_batch=args.clusters_per_batch,
        batch_nodes=args.batch_nodes, inter_buckets=args.inter_buckets,
        probe_every=args.probe_every, prefetch_depth=args.prefetch,
        pipeline_workers=args.workers,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every if args.checkpoint_dir else 0,
        resume_from=args.checkpoint_dir if args.resume else "",
        retry_max=args.retry_max)
    res = gnn.train(graph, cfg, steps=args.steps)
    warm = min(args.steps // 4, 10)
    print(f"{args.model}/{args.sampler}: {res.step_seconds*1e3:.2f} ms/step "
          f"(+{res.sample_seconds*1e3:.2f} sample, "
          f"+{res.prepare_seconds*1e3:.2f} decompose+select+pad)")
    if res.pipeline is not None:
        p = res.pipeline
        print(f"  pipeline: {res.iter_seconds*1e3:.2f} ms/iter, "
              f"{p['efficiency_pct']:.0f}% device-busy "
              f"(depth={p['depth']} workers={p['workers']} "
              f"ready={p['ready_mean']:.1f} "
              f"wait_full={p['wait_full_s']*1e3:.0f}ms "
              f"wait_empty={p['wait_empty_s']*1e3:.0f}ms"
              f"{' STARVED' if p['starved'] else ''})")
    else:
        print(f"  sync loop: {res.iter_seconds*1e3:.2f} ms/iter "
              f"(sample + prepare + step, serial; --prefetch N enables "
              f"the async pipeline)")
    print(f"  plan cache: {res.cache} "
          f"post-warmup hit rate {res.hit_rate(warm):.0%}")
    print(f"  jit traces: {res.n_traces} across {args.steps} batches "
          f"({len(res.plans)} distinct plan(s): {res.plans})")
    print(f"  loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}, "
          f"eval acc {res.accuracy:.3f}, dropped edges {res.dropped_edges}")
    if res.faults is not None:
        f = res.faults
        resumed = (f"resumed at batch {f['resumed_at']}"
                   if f["resumed_at"] >= 0 else "fresh run")
        print(f"  fault tolerance: {resumed}, "
              f"checkpoints={f['checkpoints']} retries={f['retries']} "
              f"quarantined={f['quarantined']} "
              f"nonfinite_skips={f['nonfinite_skips']}")

    if args.full_batch:
        full = gnn.train(graph, gnn.GNNConfig(
            model=args.model, selector="cost_model", reorder="louvain",
            inter_buckets=args.inter_buckets),
            steps=max(args.steps // 4, 10))
        print(f"full-batch reference: {full.step_seconds*1e3:.2f} ms/step "
              f"(plan {full.kernels[0]}), acc {full.accuracy:.3f}")


if __name__ == "__main__":
    main()

"""Mini-batch sampled-subgraph training driver: the sampling subsystem
end-to-end (Graph -> Sampler -> SampledBatch -> per-batch decompose ->
PlanCache -> jitted step), with the plan-cache and no-retrace accounting
printed next to a full-batch reference run.

By default the async sampler->trainer pipeline is on (--prefetch 4
--workers 2): background threads sample, decompose, resolve the PlanCache,
pad, and stage batches ahead of the jitted step, so one iteration pays
~max(compute, prepare) instead of their sum; --prefetch 0 runs the
synchronous loop.

Fault tolerance: --checkpoint-dir + --checkpoint-every snapshot params,
optimizer state, the batch cursor, and the PlanCache periodically (atomic
+ crc-verified, async writer); --resume restarts from the latest valid
checkpoint bit-identically to the uninterrupted run; --retry-max absorbs
transient sampler/stage failures with backoff.  Kill the process mid-run
and rerun with --resume to see the recovery contract in action.

Telemetry (repro.obs): --trace-out writes a Chrome trace-event JSON of the
run's spans (load it in chrome://tracing or https://ui.perfetto.dev — each
pipeline worker gets its own swim lane); --telemetry-out writes the
selector audit as JSONL (per-plan kernel choices with modeled costs, probe
measurements, the cost-model calibration report, the final metrics
snapshot).  Either flag enables telemetry for the run.

  PYTHONPATH=src python examples/train_gnn_minibatch.py [--steps 100]
  PYTHONPATH=src python examples/train_gnn_minibatch.py --sampler neighbor
  PYTHONPATH=src python examples/train_gnn_minibatch.py --prefetch 0
  PYTHONPATH=src python examples/train_gnn_minibatch.py \\
      --trace-out /tmp/gnn_trace.json --telemetry-out /tmp/gnn_audit.jsonl
  PYTHONPATH=src python examples/train_gnn_minibatch.py \\
      --checkpoint-dir /tmp/gnn_ckpt --checkpoint-every 20   # then ^C ...
  PYTHONPATH=src python examples/train_gnn_minibatch.py \\
      --checkpoint-dir /tmp/gnn_ckpt --checkpoint-every 20 --resume
"""
import argparse

from repro.core import gnn
from repro.graphs import graph as G
from repro.obs import enable_verbose

# the driver's output goes through the namespaced repro.train logger with a
# plain stdout handler — same stream the old prints used, so piping the
# example (CI greps "loss " / "resumed at batch") keeps working
log = enable_verbose("repro.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--dataset", default="pubmed")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--model", default="gcn", choices=["gcn", "gin", "sage"])
    ap.add_argument("--sampler", default="cluster",
                    choices=["cluster", "neighbor"])
    ap.add_argument("--clusters-per-batch", type=int, default=16)
    ap.add_argument("--batch-nodes", type=int, default=128)
    ap.add_argument("--inter-buckets", type=int, default=2)
    ap.add_argument("--probe-every", type=int, default=0,
                    help="wall-clock the top-2 cost-model candidates on "
                         "every Nth PlanCache miss and pin the winner "
                         "(0 = cost model only)")
    ap.add_argument("--prefetch", type=int, default=4,
                    help="async pipeline prefetch depth: background "
                         "workers sample/decompose/stage this many batches "
                         "ahead of the training step (0 = synchronous)")
    ap.add_argument("--workers", type=int, default=2,
                    help="background sampler/prepare threads for the "
                         "async pipeline")
    ap.add_argument("--full-batch", action="store_true",
                    help="also train full-batch for a step-time reference")
    ap.add_argument("--checkpoint-dir", default="",
                    help="directory for periodic crash-safe checkpoints "
                         "(params + opt + cursor + PlanCache state)")
    ap.add_argument("--checkpoint-every", type=int, default=25,
                    help="checkpoint every N batches (with "
                         "--checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest valid checkpoint in "
                         "--checkpoint-dir (bit-identical to the "
                         "uninterrupted run)")
    ap.add_argument("--retry-max", type=int, default=0,
                    help="retry transient batch-build/stage failures up "
                         "to N times with exponential backoff")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run's "
                         "spans here (implies telemetry on)")
    ap.add_argument("--telemetry-out", default="",
                    help="write the selector-audit JSONL export here "
                         "(implies telemetry on)")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")

    graph = G.synth_dataset(args.dataset, scale=args.scale, seed=0)
    log.info("%s: %d vertices, %d edges, sampler=%s",
             args.dataset, graph.n, graph.n_edges, args.sampler)

    cfg = gnn.GNNConfig(
        model=args.model, sampler=args.sampler, reorder="louvain",
        clusters_per_batch=args.clusters_per_batch,
        batch_nodes=args.batch_nodes, inter_buckets=args.inter_buckets,
        probe_every=args.probe_every, prefetch_depth=args.prefetch,
        pipeline_workers=args.workers,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every if args.checkpoint_dir else 0,
        resume_from=args.checkpoint_dir if args.resume else "",
        retry_max=args.retry_max,
        trace_out=args.trace_out, telemetry_out=args.telemetry_out)
    res = gnn.train(graph, cfg, steps=args.steps)
    warm = min(args.steps // 4, 10)
    log.info("%s/%s: %.2f ms/step (+%.2f sample, "
             "+%.2f decompose+select+pad)",
             args.model, args.sampler, res.step_seconds * 1e3,
             res.sample_seconds * 1e3, res.prepare_seconds * 1e3)
    if res.pipeline is not None:
        p = res.pipeline
        log.info("  pipeline: %.2f ms/iter, %.0f%% device-busy "
                 "(depth=%d workers=%d ready=%.1f wait_full=%.0fms "
                 "wait_empty=%.0fms%s)",
                 res.iter_seconds * 1e3, p["efficiency_pct"], p["depth"],
                 p["workers"], p["ready_mean"], p["wait_full_s"] * 1e3,
                 p["wait_empty_s"] * 1e3, " STARVED" if p["starved"] else "")
    else:
        log.info("  sync loop: %.2f ms/iter (sample + prepare + step, "
                 "serial; --prefetch N enables the async pipeline)",
                 res.iter_seconds * 1e3)
    log.info("  plan cache: %s post-warmup hit rate %.0f%%",
             res.cache, 100 * res.hit_rate(warm))
    log.info("  jit traces: %d across %d batches (%d distinct plan(s): %s)",
             res.n_traces, args.steps, len(res.plans), res.plans)
    log.info("  loss %.4f -> %.4f, eval acc %.3f, dropped edges %d",
             res.losses[0], res.losses[-1], res.accuracy, res.dropped_edges)
    if res.faults is not None:
        f = res.faults
        resumed = (f"resumed at batch {f['resumed_at']}"
                   if f["resumed_at"] >= 0 else "fresh run")
        log.info("  fault tolerance: %s, checkpoints=%d retries=%d "
                 "quarantined=%d nonfinite_skips=%d",
                 resumed, f["checkpoints"], f["retries"],
                 f["quarantined"], f["nonfinite_skips"])
    if res.telemetry is not None and res.telemetry["enabled"]:
        t = res.telemetry
        cal = t["calibration"]
        log.info("  telemetry: %d span events, %d audit events, "
                 "%d calibrated kernel(s), %d plan(s) observed",
                 t["n_span_events"], t["n_audit_events"],
                 len(cal["kernels"]), len(cal["plans"]))
        for name, k in cal["kernels"].items():
            log.info("    %s: modeled %.3g s vs measured %.3g s "
                     "(rel err %.0f%%, n=%d)",
                     name, k["modeled_s"], k["measured_s"],
                     100 * k["rel_err"], k["n"])

    if args.full_batch:
        full = gnn.train(graph, gnn.GNNConfig(
            model=args.model, selector="cost_model", reorder="louvain",
            inter_buckets=args.inter_buckets),
            steps=max(args.steps // 4, 10))
        log.info("full-batch reference: %.2f ms/step (plan %s), acc %.3f",
                 full.step_seconds * 1e3, full.kernels[0], full.accuracy)


if __name__ == "__main__":
    main()

"""AdaptGear's idea applied to the LM stack: the MoE layer's token->expert
assignment is a sparse 'adjacency' whose density = top_k/E; the dispatch
selector picks dense all-experts compute vs sort-scatter capacity dispatch
exactly the way the GNN selector picks dense-block vs sparse kernels.

  PYTHONPATH=src python examples/moe_adaptive_dispatch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks as B

rng = np.random.default_rng(0)
key = jax.random.PRNGKey(0)

for E, k, n_tok in [(4, 2, 4096), (64, 6, 4096), (256, 8, 4096)]:
    cfg = B.MoEConfig(d_model=64, n_experts=E, top_k=k, d_ff_expert=128,
                      capacity_factor=2.0)
    params = B.init_moe(key, cfg)
    x = jnp.asarray(rng.standard_normal((n_tok, 64)), jnp.float32)
    choice = B.choose_moe_path(cfg, n_tok)

    t = {}
    for path in ("dense", "sparse"):
        fn = jax.jit(lambda x, p=path: (B.moe_apply_dense if p == "dense"
                                        else B.moe_apply_sparse)(params, cfg, x)[0])
        fn(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(x).block_until_ready()
        t[path] = (time.perf_counter() - t0) / 3

    print(f"E={E:4d} top_k={k} density={k/E:.3f}: dense={t['dense']*1e3:7.2f}ms "
          f"sparse={t['sparse']*1e3:7.2f}ms  selector-> {choice} "
          f"({'correct' if t[choice] <= min(t.values()) * 1.2 else 'suboptimal on CPU'})")

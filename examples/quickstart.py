"""Quickstart: the AdaptGear user-level API (paper Fig. 7 equivalent).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import gnn
from repro.graphs import graph as G

# Loading graph dataset (synthetic stand-in for the offline container;
# statistics match the paper's Table-1 citeseer row).
graph = G.synth_dataset("citeseer", scale=0.2, seed=0)
print(f"graph: {graph.n} vertices, {graph.n_edges} edges, "
      f"{graph.features.shape[1]} features, {graph.n_classes} classes")

# Define a GCN and train it.  Reorder + decomposition (AG.graph_decompose)
# and the feedback-driven kernel selection happen inside gnn.train — the
# selector is transparent to the user, as in the paper (§4.1).
cfg = gnn.GNNConfig(model="gcn", hidden=16, n_layers=2,
                    comm_size=16, reorder="louvain", selector="feedback")
result = gnn.train(graph, cfg, steps=60, verbose=True)

print()
for i, (ik, ek) in enumerate(result.kernels):
    print(f"layer {i}: selected intra={ik} inter={ek}")
print(f"final loss {result.losses[-1]:.4f}, train accuracy {result.accuracy:.3f}")
print(f"preprocessing {result.preprocess_seconds:.2f}s, "
      f"per-step {result.step_seconds*1e3:.1f}ms")

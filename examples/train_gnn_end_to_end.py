"""End-to-end driver (the paper's kind: GNN training): train GCN and GIN
for a few hundred steps on a pubmed-scale synthetic graph with the full
AdaptGear pipeline, reporting the paper's Fig. 8-style comparison against
the static-kernel baselines.

``--inter-buckets k`` splits the inter-community subgraph into k density
tiers, each with its own feedback-selected kernel (k=1 is the paper's
two-subgraph decomposition).

  PYTHONPATH=src python examples/train_gnn_end_to_end.py [--steps 200]
"""
import argparse

from repro.core import gnn
from repro.graphs import graph as G


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dataset", default="pubmed")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--inter-buckets", type=int, default=1,
                    help="inter-community density tiers (1 = paper-faithful)")
    args = ap.parse_args()

    graph = G.synth_dataset(args.dataset, scale=args.scale, seed=0)
    print(f"{args.dataset}: {graph.n} vertices, {graph.n_edges} edges, "
          f"inter_buckets={args.inter_buckets}")

    for model in ("gcn", "gin"):
        ag = gnn.train(graph, gnn.GNNConfig(
            model=model, selector="feedback", reorder="louvain",
            inter_buckets=args.inter_buckets, warmup_iters=2),
            steps=args.steps)
        static = gnn.train(graph, gnn.GNNConfig(
            model=model, selector="fixed", fixed_kernels=("ell", "ell"),
            reorder="bfs"), steps=max(args.steps // 4, 10))
        print(f"{model}: adaptgear {ag.step_seconds*1e3:.2f} ms/step "
              f"(plan {ag.kernels}), static-full-graph "
              f"{static.step_seconds*1e3:.2f} ms/step  "
              f"-> {static.step_seconds/max(ag.step_seconds,1e-12):.2f}x; "
              f"final loss {ag.losses[-1]:.4f}, acc {ag.accuracy:.3f}")


if __name__ == "__main__":
    main()

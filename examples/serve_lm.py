"""Batched LM serving demo on the assigned-architecture stack: prefill a
batch of prompts, greedy-decode continuations.

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6_7b --gen 24

(Previously lived in repro.launch.serve, which is now the GNN inference
server's CLI; the LM demo moved here whole.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.train import steps as steps_mod


def serve_lm(arch: str, *, reduced: bool = True, batch: int = 4,
             prompt_len: int = 16, gen: int = 16, seed: int = 0,
             use_mesh=None, verbose: bool = True) -> dict:
    cfg = configs.get_config(arch, reduced=reduced)
    assert cfg.input_mode == "tokens" and cfg.family == "decoder", \
        "serving demo drives token-mode decoder archs"
    mesh = use_mesh or mesh_mod.host_local_mesh()
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)

    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    s_max = prompt_len + gen
    caches = lm.init_cache(cfg, batch, s_max)
    serve_step = jax.jit(steps_mod.make_serve_step(cfg))

    toks = []
    t0 = time.perf_counter()
    with mesh:
        # one-shot cache-producing prefill, then token-by-token decode
        prefill_fn = jax.jit(lambda p, b: lm.prefill(p, cfg, b, s_max),
                             static_argnames=())
        logits, caches = prefill_fn(params, dict(tokens=prompts))
        nxt = jnp.argmax(logits[:, -1:, : cfg.vocab],
                         axis=-1).astype(jnp.int32)
        for t in range(prompt_len, s_max):
            toks.append(nxt)
            nxt, logits, caches = serve_step(params, caches, nxt, t)
    jax.block_until_ready(nxt)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    tput = batch * (prompt_len + gen) / dt
    if verbose:
        print(f"{arch}: generated {out.shape} in {dt:.2f}s "
              f"({tput:.1f} tok/s incl. compile)")
    return dict(tokens=np.asarray(out), seconds=dt, tokens_per_s=tput)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve_lm(args.arch, reduced=True, batch=args.batch,
                   prompt_len=args.prompt_len, gen=args.gen)
    print("generated token ids:\n", out["tokens"])


if __name__ == "__main__":
    main()

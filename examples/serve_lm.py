"""Batched LM serving demo on the assigned-architecture stack: prefill a
batch of prompts, greedy-decode continuations.

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6_7b --gen 24
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve.serve(args.arch, reduced=True, batch=args.batch,
                      prompt_len=args.prompt_len, gen=args.gen)
    print("generated token ids:\n", out["tokens"])


if __name__ == "__main__":
    main()

"""Resilient online inference serving (repro.serve + satellites):
deadline shedding, micro-batch flush triggers, hysteretic degradation,
PlanCache disk persistence + warm starts, decorrelated retry jitter, and
kernel-fault quarantine on the request path."""
import os
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import gnn
from repro.distributed import fault_tolerance as ft
from repro.graphs import graph as G
from repro.sampling.plan_cache import PlanCache
from repro.serve import (ERROR, OK, SHED, TIMEOUT, AdmissionController,
                         DegradationLadder, InferenceServer, ServeConfig,
                         default_rungs)
from repro.train import gnn_steps

from test_sampling import dense_community_graph


def serve_cfg(**kw):
    d = dict(deadline_s=5.0, queue_limit=16, max_batch=8, max_wait_s=0.002)
    d.update(kw)
    return ServeConfig(**d)


def gnn_cfg(**kw):
    d = dict(model="gcn", sampler="neighbor", batch_nodes=16,
             fanouts=(4, 2), hidden=8, n_layers=2, comm_size=16, seed=0)
    d.update(kw)
    return gnn.GNNConfig(**d)


def small_server(g=None, cfg=None, scfg=None, steps=4, **server_kw):
    g = g if g is not None else G.synth_dataset("cora", scale=0.1, seed=0)
    cfg = cfg or gnn_cfg()
    res = gnn_steps.train_minibatch(g, cfg, steps=steps, eval_batches=0)
    return InferenceServer(g, cfg, res.params, serve_cfg=scfg or serve_cfg(),
                           plan_cache=res.plan_cache, **server_kw)


def drive(server, futs):
    """Single-threaded deterministic serving: step until every future
    lands."""
    while any(not f.done() for f in futs):
        server.step()
    return [f.result(0) for f in futs]


# -- ego tickets (sampling/sampler.py satellite) ------------------------------

def test_ego_ticket_dedupes_validates_and_reproduces():
    g = G.synth_dataset("cora", scale=0.1, seed=0)
    cfg = gnn_cfg()
    s = gnn_steps.make_sampler(g, cfg)
    t = s.ego_ticket([5, 3, 5, 3, 9], index=7)
    assert t.index == 7
    assert t.chosen.tolist() == [3, 5, 9]          # deduped, sorted
    with pytest.raises(ValueError):
        s.ego_ticket([], index=0)
    with pytest.raises(ValueError):
        s.ego_ticket([g.n], index=0)
    with pytest.raises(ValueError):
        s.ego_ticket([-1], index=0)
    with pytest.raises(ValueError):
        s.ego_ticket(list(range(cfg.batch_nodes + 1)), index=0)
    # pure in (seed set, index): bit-identical rebuilds on any thread
    a = s.build(s.ego_ticket([3, 5, 9], 7))
    b = s.build(s.ego_ticket([9, 5, 3, 3], 7))
    np.testing.assert_array_equal(a.nodes, b.nodes)
    np.testing.assert_array_equal(a.senders, b.senders)
    np.testing.assert_array_equal(a.features, b.features)
    # the epoch stream is untouched by ego queries
    assert s._n_drawn == 0


# -- PlanCache disk persistence (satellite) -----------------------------------

def trained_cache():
    g = G.synth_dataset("cora", scale=0.1, seed=0)
    res = gnn_steps.train_minibatch(g, gnn_cfg(), steps=5, eval_batches=0)
    return res.plan_cache


def test_plan_cache_save_load_bit_identical(tmp_path):
    cache = trained_cache()
    path = str(tmp_path / "plans.bin")
    cache.save(path)
    fresh = PlanCache(cache.pairs, dtype=np.float32)
    assert fresh.load(path)
    a, b = cache.state_dict(), fresh.state_dict()
    assert a["entries"] == b["entries"]      # plans bit-identical
    assert a == b                            # counters/ladder/quarantine too


def test_plan_cache_load_missing_and_corrupt(tmp_path):
    cache = trained_cache()
    before = cache.state_dict()
    assert not cache.load(str(tmp_path / "nope.bin"))   # missing: quiet
    path = str(tmp_path / "plans.bin")
    cache.save(path)
    blob = open(path, "rb").read()
    for corrupt in [b"garbage", blob[:-4], blob[:11] + b"\xff" + blob[12:]]:
        with open(path, "wb") as f:
            f.write(corrupt)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            assert not cache.load(path)                 # corrupt: cold start
        assert any("starting cold" in str(x.message) for x in w)
        assert cache.state_dict() == before             # cache untouched
    assert not os.path.exists(path + ".tmp")            # atomic write


# -- decorrelated retry jitter (satellite) ------------------------------------

def test_retry_jitter_deterministic_and_decorrelated():
    mk = lambda: ft.RetryPolicy(max_retries=4, base_delay_s=0.01,
                                jitter=True, seed=11, max_delay_s=0.08)
    a, b = mk(), mk()
    s0, s1 = a.delays(), a.delays()
    assert s0 == b.delays()              # run N is a pure function of seed
    assert s1 == b.delays()
    assert s0 != s1                      # concurrent runs decorrelate
    assert all(0.01 <= d <= 0.08 for d in s0 + s1)
    # run() consumes the same ladder the Nth delays() call would
    waits, calls = [], dict(n=0)

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise ft.TransientError("boom")
        return "done"

    c = mk()
    expect = mk().delays()
    assert c.run(flaky, _sleep=waits.append) == "done"
    assert waits == expect[:3]


def test_retry_without_jitter_unchanged():
    p = ft.RetryPolicy(max_retries=3, base_delay_s=1.0, backoff=2.0)
    assert p.delays() == [1.0, 2.0, 4.0]
    assert p.delays() == [1.0, 2.0, 4.0]   # no hidden state without jitter
    p2 = ft.RetryPolicy(max_retries=3, base_delay_s=1.0, backoff=2.0,
                        max_delay_s=1.5)
    assert p2.delays() == [1.0, 1.5, 1.5]


# -- admission control + micro-batching ---------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_admission_sheds_on_full_queue_and_hopeless_deadline():
    clk = FakeClock()
    adm = AdmissionController(limit=2, estimate_wait=lambda q: 0.0,
                              clock=clk)
    f1, f2 = adm.submit(1, 1.0), adm.submit(2, 1.0)
    f3 = adm.submit(3, 1.0)                       # queue full
    assert f3.status == SHED and f3.done()
    assert f1.status == f2.status == "pending"
    slow = AdmissionController(limit=8, estimate_wait=lambda q: 0.5,
                               clock=clk)
    assert slow.submit(1, 0.1).status == SHED     # predicted wait > deadline
    assert slow.submit(2, 1.0).status == "pending"


def test_deadline_expired_requests_shed_not_served():
    clk = FakeClock()
    adm = AdmissionController(limit=8, estimate_wait=lambda q: 0.0,
                              clock=clk)
    futs = [adm.submit(i, 0.05) for i in range(3)]
    clk.t += 1.0                                   # deadlines long gone
    live = adm.submit(99, 5.0)
    got = adm.collect(max_n=1, service_s=0.01)     # size flush: no wall wait
    assert [r.node for r in got] == [99]           # expired never served
    for f in futs:
        assert f.status == TIMEOUT and f.done()
    assert live.status == "pending"


def test_microbatch_flush_on_size():
    clk = FakeClock()
    adm = AdmissionController(limit=32, estimate_wait=lambda q: 0.0,
                              clock=clk)
    futs = [adm.submit(i, 10.0) for i in range(8)]
    t0 = time.perf_counter()
    got = adm.collect(max_n=4, service_s=0.01)
    assert len(got) == 4                           # size flush, no waiting
    assert time.perf_counter() - t0 < 1.0
    assert len(adm) == 4
    assert all(f.status == "pending" for f in futs)


def test_microbatch_flush_on_deadline():
    adm = AdmissionController(limit=32, estimate_wait=lambda q: 0.0)
    adm.submit(1, 0.08)
    t0 = time.perf_counter()
    got = adm.collect(max_n=8, service_s=0.02)     # never fills: must flush
    dt = time.perf_counter() - t0                  # on deadline slack
    assert [r.node for r in got] == [1]
    assert dt < 0.08                               # before the deadline
    assert dt >= 0.02                              # after some coalescing


def test_microbatch_max_wait_caps_coalescing():
    adm = AdmissionController(limit=32, estimate_wait=lambda q: 0.0)
    adm.submit(1, 10.0)                            # generous deadline
    t0 = time.perf_counter()
    got = adm.collect(max_n=8, service_s=0.01, max_wait_s=0.02)
    assert len(got) == 1
    assert time.perf_counter() - t0 < 5.0          # not the whole deadline


# -- degradation ladder hysteresis --------------------------------------------

def test_ladder_steps_down_and_up_with_hysteresis():
    lad = DegradationLadder(3, down_after=2, up_after=4, cooldown=0)
    assert not lad.observe(True)
    assert lad.observe(True) and lad.rung == 1      # 2 consecutive hot
    for _ in range(3):
        assert not lad.observe(False)
    assert lad.observe(False) and lad.rung == 0     # 4 consecutive calm
    assert not lad.observe(False)                   # floor: no underflow


def test_ladder_never_flaps():
    lad = DegradationLadder(3, down_after=2, up_after=4, cooldown=2)
    for i in range(40):                             # alternating load:
        assert not lad.observe(i % 2 == 0)          # never a transition
    assert lad.rung == 0
    # a square wave of load: cooldown damps the transition rate — a
    # 2-rung ladder moves at most once per half-period
    lad2 = DegradationLadder(2, down_after=2, up_after=4, cooldown=2)
    changes = sum(lad2.observe(True) for _ in range(10))
    assert changes == 1 and lad2.rung == 1
    changes = sum(lad2.observe(False) for _ in range(10))
    assert changes == 1 and lad2.rung == 0


def test_ladder_rejects_degenerate_hysteresis():
    with pytest.raises(ValueError):
        DegradationLadder(3, down_after=4, up_after=4)
    with pytest.raises(ValueError):
        DegradationLadder(0)


def test_default_rungs_halve_to_floor():
    assert default_rungs((8, 4)) == ((8, 4), (4, 2), (2, 1))
    assert default_rungs((1, 1)) == ((1, 1),)


# -- the server end to end ----------------------------------------------------

def test_server_serves_admitted_requests():
    srv = small_server()
    srv.warmup()
    t0 = srv.n_traces
    futs = [srv.submit(i * 3 % srv.ego.graph.n) for i in range(12)]
    results = drive(srv, futs)
    assert {s for s, _ in results} == {OK}
    for (_, v), f in zip(results, futs):
        assert v["logits"].shape == (srv.ego.graph.n_classes,)
        assert v["pred"] == int(np.argmax(v["logits"]))
    assert srv.n_traces == t0                   # warm: zero new compiles
    st = srv.stats()
    assert st["admitted"] == 12 and st["errors"] == 0


def test_server_background_thread_and_stop_sheds_stragglers():
    srv = small_server(scfg=serve_cfg(est_service_s=0.001))
    srv.warmup()
    with srv:
        futs = [srv.submit(i % srv.ego.graph.n) for i in range(6)]
        assert all(f.result(timeout=30)[0] == OK for f in futs)
    # post-stop: anything still queued is shed, never silently dropped
    late = srv.admission.submit(0, 5.0)
    srv.stop()
    assert late.status in (SHED, "pending") or late.done()


def test_server_sheds_under_synthetic_overload():
    # a giant service estimate makes every deep-queue arrival hopeless:
    # the controller must shed rather than queue unboundedly
    srv = small_server(scfg=serve_cfg(queue_limit=4, est_service_s=3.0,
                                      deadline_s=1.0))
    futs = [srv.submit(i % srv.ego.graph.n) for i in range(12)]
    assert sum(f.status == SHED for f in futs) == 12   # est_wait > deadline
    st = srv.stats()
    assert st["shed"] == 12 and st["shed_pct"] == 100.0


def test_warm_start_from_persisted_cache_bit_identical(tmp_path):
    path = str(tmp_path / "plans.bin")
    g = G.synth_dataset("cora", scale=0.1, seed=0)
    cfg = gnn_cfg()
    res = gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=0)

    writer = InferenceServer(g, cfg, res.params, serve_cfg=serve_cfg(),
                             plan_cache=res.plan_cache)
    writer.warmup()
    futs = [writer.submit(i * 5 % g.n) for i in range(10)]
    ref = drive(writer, futs)
    writer.cache.save(path)
    saved = {sig: (plan, anchor)
             for sig, plan, anchor in writer.cache.state_dict()["entries"]}

    # cold process: fresh server + fresh cache, warm-started from disk
    reader = InferenceServer(g, cfg, res.params, serve_cfg=serve_cfg())
    warm = reader.warmup(path=path)
    assert warm["loaded"]
    # plans bit-identical to the writer's snapshot (warmup probes may
    # reorder the LRU, so compare as a mapping)
    got = {sig: (plan, anchor)
           for sig, plan, anchor in reader.cache.state_dict()["entries"]}
    assert got == saved
    t0 = reader.n_traces
    futs = [reader.submit(i * 5 % g.n) for i in range(10)]
    out = drive(reader, futs)
    assert reader.n_traces == t0            # steady state: zero compiles
    # identical params + identical plans -> identical predictions
    for (sa, va), (sb, vb) in zip(ref, out):
        assert sa == sb == OK and va["pred"] == vb["pred"]
        np.testing.assert_allclose(va["logits"], vb["logits"],
                                   rtol=1e-6, atol=1e-6)


def test_warmup_corrupt_cache_falls_back_cold(tmp_path):
    path = str(tmp_path / "plans.bin")
    with open(path, "wb") as f:
        f.write(b"not a plan cache")
    srv = small_server()
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        warm = srv.warmup(path=path)
    assert not warm["loaded"]               # cold start, not a crash
    futs = [srv.submit(0)]
    assert drive(srv, futs)[0][0] == OK


def test_transient_build_faults_retried_on_request_path():
    # injections are keyed by the ego stream index: warmup consumes one
    # probe per rung (fanouts (4, 2) halve into 3 rungs -> indices 0..2),
    # so the first query batches land on 3 and 4 — the jittered retry
    # policy must absorb their transient build faults without the client
    # ever noticing
    fp = ft.FaultPlan(worker_faults={3: 1, 4: 2})
    srv = small_server(scfg=serve_cfg(retry_max=3, retry_base_delay_s=0.001),
                       fault_plan=fp)
    assert len(srv.ego) == 3
    srv.warmup()
    futs = [srv.submit(i % srv.ego.graph.n) for i in range(4)]
    results = drive(srv, futs)
    assert {s for s, _ in results} == {OK}
    assert fp.injected_worker >= 1
    assert srv.stats()["retries"] >= 1 and srv.stats()["errors"] == 0


def test_kernel_fault_on_request_path_quarantines_and_degrades():
    """An executing Pallas kernel that starts failing mid-traffic is
    quarantined for its signature in the shared PlanCache; the SAME
    admitted requests are then served on the degraded plan — quarantine +
    degrade, zero dropped requests."""
    g = dense_community_graph()
    cfg = gnn_cfg(model="gin", batch_nodes=16, fanouts=(512, 512),
                  comm_size=64, reorder="bfs", inter_buckets=2,
                  selector="cost_model")
    res = gnn_steps.train_minibatch(g, cfg, steps=3, eval_batches=0)
    # kernel faults patch the registry, so they bake in at trace time:
    # everything that compiles — warmup probes included — runs inside
    # activate(), exactly as the training robustness tests do.  Both
    # Pallas kernels these dense plans commit are broken, so recovery has
    # to escalate down the ladder until it reaches the XLA floor.
    fp = ft.FaultPlan(kernel_faults={"bell": "execute",
                                     "block_diag": "execute"})
    srv = InferenceServer(g, cfg, res.params,
                          serve_cfg=serve_cfg(max_batch=16),
                          plan_cache=res.plan_cache, fault_plan=fp)
    with fp.activate():
        srv.warmup()
        # the fault targets must actually be on the serving plans
        used = {k for layers in srv._infer_fns for layer in layers
                for k in layer}
        assert used & {"bell", "block_diag"}
        futs = [srv.submit(i * 17 % g.n) for i in range(16)]
        results = drive(srv, futs)
        assert {s for s, _ in results} == {OK}      # nobody dropped
        assert fp.kernel_trips >= 1
        st = srv.stats()
        assert st["quarantined"] >= 1 and st["recoveries"] >= 1
        assert st["errors"] == 0
        # post-quarantine traffic keeps being served (same contract)
        futs = [srv.submit(i * 13 % g.n) for i in range(16)]
        results = drive(srv, futs)
        assert {s for s, _ in results} == {OK}
    quarantined = {k for q in srv.cache.state_dict()["quarantine"].values()
                   for k in q}
    assert quarantined & {"bell", "block_diag"}

"""Format containers + converters: every format must represent the same
matrix as the dense ground truth."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import formats
from repro.kernels import ref


def random_coo(rng, n, nnz, block_diag_only=False, B=8):
    if block_diag_only:
        nb = n // B
        b = rng.integers(0, nb, nnz)
        r = b * B + rng.integers(0, B, nnz)
        c = b * B + rng.integers(0, B, nnz)
    else:
        r = rng.integers(0, n, nnz)
        c = rng.integers(0, n, nnz)
    # dedup (r, c)
    key = r.astype(np.int64) * n + c
    _, keep = np.unique(key, return_index=True)
    r, c = r[keep], c[keep]
    v = rng.standard_normal(len(r)).astype(np.float32)
    return formats.coo_from_edges(n, n, r, c, v)


def dense_of(coo: formats.COO) -> np.ndarray:
    a = np.zeros((coo.n_rows, coo.n_cols), np.float32)
    a[np.asarray(coo.rows), np.asarray(coo.cols)] = np.asarray(coo.vals)
    return a


@pytest.mark.parametrize("n,nnz", [(16, 5), (64, 100), (128, 500)])
def test_coo_csr_ell_agree(rng, n, nnz):
    coo = random_coo(rng, n, nnz)
    dense = dense_of(coo)
    x = rng.standard_normal((n, 7)).astype(np.float32)
    y_ref = dense @ x
    y_coo = ref.coo_spmm(coo.rows, coo.cols, coo.vals, jnp.asarray(x), n)
    np.testing.assert_allclose(y_coo, y_ref, atol=1e-4)
    ell = formats.coo_to_ell(coo)
    y_ell = ref.ell_spmm(ell.indices, ell.vals, jnp.asarray(x))
    np.testing.assert_allclose(y_ell, y_ref, atol=1e-4)
    csr = formats.coo_to_csr(coo)
    assert csr.nnz == coo.nnz
    indptr = np.asarray(csr.indptr)
    assert indptr[0] == 0 and indptr[-1] == coo.nnz
    assert np.all(np.diff(indptr) >= 0)


@pytest.mark.parametrize("B", [4, 8, 16])
def test_blockdiag_roundtrip(rng, B):
    n = 8 * B
    coo = random_coo(rng, n, 3 * n, block_diag_only=True, B=B)
    bd = formats.coo_to_blockdiag(coo, B)
    dense = dense_of(coo)
    x = rng.standard_normal((bd.n, 5)).astype(np.float32)
    y = ref.block_diag_spmm(bd.blocks, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y)[:n], dense @ x[:n], atol=1e-4)


@pytest.mark.parametrize("B", [4, 8])
def test_bell_roundtrip(rng, B):
    n = 6 * B
    coo = random_coo(rng, n, 4 * n)
    bell = formats.coo_to_bell(coo, B)
    dense = dense_of(coo)
    x = rng.standard_normal((bell.n_cols, 9)).astype(np.float32)
    y = ref.bell_spmm(bell.blocks, bell.col_idx, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y)[:n], dense @ x[:bell.n_cols][:n],
                               atol=1e-4)
    # padding blocks must be all-zero
    nv = np.asarray(bell.n_valid)
    blocks = np.asarray(bell.blocks)
    for i in range(bell.n_brow):
        for k in range(nv[i], bell.max_blocks):
            assert not blocks[i, k].any()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 64), nnz=st.integers(1, 200), f=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_property_all_formats_agree(n, nnz, f, seed):
    """Property: COO/ELL/BELL/dense all compute the same SpMM."""
    rng = np.random.default_rng(seed)
    coo = random_coo(rng, n, nnz)
    if coo.nnz == 0:
        return
    dense = dense_of(coo)
    x = rng.standard_normal((max(coo.n_cols, ((n + 7) // 8) * 8), f)).astype(np.float32)
    y_ref = dense @ x[:n]
    y_coo = np.asarray(ref.coo_spmm(coo.rows, coo.cols, coo.vals,
                                    jnp.asarray(x[:n]), n))
    np.testing.assert_allclose(y_coo, y_ref, atol=1e-3, rtol=1e-3)
    ell = formats.coo_to_ell(coo)
    y_ell = np.asarray(ref.ell_spmm(ell.indices, ell.vals, jnp.asarray(x[:n])))
    np.testing.assert_allclose(y_ell, y_ref, atol=1e-3, rtol=1e-3)
    bell = formats.coo_to_bell(coo, 8)
    xp = np.zeros((bell.n_cols, f), np.float32)
    xp[:n] = x[:n]
    y_bell = np.asarray(ref.bell_spmm(bell.blocks, bell.col_idx,
                                      jnp.asarray(xp)))[:n]
    np.testing.assert_allclose(y_bell, y_ref, atol=1e-3, rtol=1e-3)

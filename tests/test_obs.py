"""Telemetry subsystem (repro.obs): Chrome-trace validity with nested +
thread-attributed spans, metrics-registry thread safety under racing
workers, the selector-audit calibration report and JSONL export, the
null-object disabled path, verbose-logging idempotence, and the
non-interference contract — telemetry on vs off leaves losses, plans,
hit history, and trace counts bit-identical."""
import dataclasses
import json
import logging
import threading
import time

import numpy as np
import pytest

from repro.core import gnn
from repro.graphs import graph as G
from repro.obs import (NULL_AUDIT, NULL_TRACER, Counter, Histogram,
                       MetricsRegistry, SelectorAudit, Telemetry, Tracer,
                       enable_verbose)
from repro.train import gnn_steps


def small_graph(n=96, e=700, nf=5, nc=3, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, nf)).astype(np.float32)
    labels = rng.integers(0, nc, n).astype(np.int32)
    return G.Graph(n, src, dst, feats, labels, nc)


# -- tracer ------------------------------------------------------------------

def test_tracer_nested_spans_and_chrome_trace_shape():
    tr = Tracer()
    with tr.span("outer", cat="host", index=0):
        with tr.span("inner", cat="host"):
            time.sleep(0.001)
    tr.instant("marker", cat="cache", what="x")
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    inst = [e for e in evs if e["ph"] == "i"]
    assert [m["name"] for m in meta] == ["thread_name"]
    assert meta[0]["args"]["name"] == threading.current_thread().name
    assert set(xs) == {"outer", "inner"}
    assert len(inst) == 1 and inst[0]["name"] == "marker"
    # nesting: inner lies inside outer on the same (remapped, small) tid
    out, inn = xs["outer"], xs["inner"]
    assert out["tid"] == inn["tid"] == 0
    assert out["ts"] <= inn["ts"]
    assert inn["ts"] + inn["dur"] <= out["ts"] + out["dur"] + 1e-3
    assert out["args"] == dict(index=0)
    # the whole document round-trips through JSON
    json.loads(json.dumps(doc))


def test_tracer_attributes_spans_to_emitting_thread():
    tr = Tracer()

    def worker(i):
        with tr.span("work", cat="host", i=i):
            time.sleep(0.001)

    ts = [threading.Thread(target=worker, args=(i,), name=f"obs-worker-{i}")
          for i in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    doc = tr.chrome_trace()
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M"}
    assert {"obs-worker-0", "obs-worker-1", "obs-worker-2"} <= names
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert len(tids) == 3 and max(tids) <= 3      # remapped, not raw idents


def test_tracer_export_writes_valid_json(tmp_path):
    tr = Tracer()
    with tr.span("s"):
        pass
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert any(e["ph"] == "X" and e["name"] == "s" for e in doc["traceEvents"])


def test_null_tracer_is_shared_noop_and_refuses_export():
    s1 = NULL_TRACER.span("a", cat="x", k=1)
    s2 = NULL_TRACER.span("b")
    assert s1 is s2                      # one shared singleton, no alloc
    with s1:
        pass
    assert NULL_TRACER.events() == []
    assert not NULL_TRACER.enabled
    with pytest.raises(RuntimeError):
        NULL_TRACER.export("/tmp/nope.json")


# -- metrics registry --------------------------------------------------------

def test_registry_get_or_create_and_type_collision():
    reg = MetricsRegistry()
    c1 = reg.counter("a.hits")
    c2 = reg.counter("a.hits")
    assert c1 is c2
    with pytest.raises(TypeError):
        reg.gauge("a.hits")
    g = reg.gauge("a.depth")
    g.set(7)
    h = reg.histogram("a.lat")
    h.observe(1.0)
    snap = reg.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["a.depth"] == 7
    assert snap["a.lat"]["count"] == 1


def test_counter_exact_under_racing_threads():
    # the bug class the registry exists for: CPython `x += 1` is not
    # atomic across threads, a locked Counter.inc is
    reg = MetricsRegistry()
    n_threads, n_inc = 8, 5000

    def worker():
        c = reg.counter("race")          # racing get-or-create too
        for _ in range(n_inc):
            c.inc()

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter("race").value == n_threads * n_inc


def test_histogram_window_and_percentiles():
    h = Histogram("lat", window=100)
    for v in range(1000):
        h.observe(float(v))
    assert h.count == 1000               # exact forever
    assert h.total == sum(range(1000))
    # percentiles over the last 100 observations only
    assert h.percentile(0) == 900.0
    assert h.percentile(100) == 999.0
    snap = h.snapshot()
    assert snap["p50"] == pytest.approx(950.0, abs=1)
    assert snap["max"] == 999.0


def test_counter_set_supports_restore():
    c = Counter("x")
    c.inc(3)
    c.set(11)
    assert c.value == 11


# -- selector audit ----------------------------------------------------------

def test_audit_calibration_and_jsonl_export(tmp_path):
    au = SelectorAudit()
    au.plan(sig="sig0", layers=[["csr", "bell"]], tiers=["intra", "inter0"],
            modeled_s=[[1e-4, 2e-4]], source="cost_model")
    au.probe(tier="intra", kernel="csr", modeled_s=1e-4, measured_s=2e-4)
    au.probe(tier="intra", kernel="csr", modeled_s=1e-4, measured_s=3e-4)
    au.quarantine(sig="sig0", kernels=["bell"], reason="nan")
    au.observe_step([["csr", "bell"]], 5e-4)
    au.observe_step([["csr", "bell"]], 7e-4)
    cal = au.calibration()
    k = cal["kernels"]["csr"]
    assert k["n"] == 2
    assert k["rel_err"] == pytest.approx(1.5)   # median of {1.0, 2.0}
    (p,) = cal["plans"]
    assert p["n_steps"] == 2
    assert p["observed_step_s"] == pytest.approx(6e-4)
    assert p["modeled_s"] == pytest.approx(3e-4)
    path = au.export_jsonl(str(tmp_path / "audit.jsonl"))
    with open(path) as f:
        recs = [json.loads(line) for line in f]
    by_event = {}
    for r in recs:
        by_event.setdefault(r["event"], []).append(r)
    assert len(by_event["plan"]) == 1
    assert len(by_event["probe"]) == 2
    assert len(by_event["quarantine"]) == 1
    assert len(by_event["calibration"]) == 1


def test_null_audit_noop_and_refuses_export():
    NULL_AUDIT.plan(sig="s", layers=[], tiers=[], modeled_s=[], source="x")
    NULL_AUDIT.observe_step([], 0.1)
    assert NULL_AUDIT.events() == []
    assert NULL_AUDIT.calibration() == dict(kernels={}, plans=[])
    with pytest.raises(RuntimeError):
        NULL_AUDIT.export_jsonl("/tmp/nope.jsonl")


# -- Telemetry facade + logging ----------------------------------------------

def test_telemetry_disabled_uses_null_singletons_live_registry():
    t = Telemetry()
    assert t.tracer is NULL_TRACER
    assert t.audit is NULL_AUDIT
    t.metrics.counter("c").inc()
    s = t.summary()
    assert s["enabled"] is False
    assert s["n_span_events"] == 0
    assert s["metrics"]["c"] == 1


def test_enable_verbose_is_idempotent():
    logger = logging.getLogger("repro.test_obs")
    before = len(logger.handlers)
    enable_verbose("repro.test_obs")
    enable_verbose("repro.test_obs")
    assert len(logger.handlers) == before + 1


# -- non-interference: telemetry on vs off, bit-identical training -----------

def _run(cfg, g, steps=6):
    return gnn_steps.train_minibatch(g, cfg, steps=steps, eval_batches=1)


def test_telemetry_on_off_training_bit_identical(tmp_path):
    g = small_graph(n=128, e=1200)
    # no probing here: probe pinning keys on wall-clock measurements, a
    # nondeterminism source of its own that would confound the on/off
    # plan-equality assertion (the probe audit has its own test below)
    base = gnn.GNNConfig(model="gcn", sampler="cluster", comm_size=8,
                         clusters_per_batch=4, inter_buckets=2,
                         reorder="bfs")
    off = _run(base, g)
    on = _run(dataclasses.replace(
        base, telemetry=True,
        trace_out=str(tmp_path / "trace.json"),
        telemetry_out=str(tmp_path / "audit.jsonl")), g)
    # recording is append-only and never read back: identical training
    assert np.array_equal(np.asarray(off.losses), np.asarray(on.losses))
    assert off.plans == on.plans
    assert off.hit_history == on.hit_history
    assert off.n_traces == on.n_traces
    # the off run carries a disabled summary, the on run a full one
    assert off.telemetry["enabled"] is False
    assert on.telemetry["enabled"] is True
    assert on.telemetry["n_span_events"] > 0
    # exports landed and parse; the trace covers the instrumented stages
    with open(tmp_path / "trace.json") as f:
        doc = json.load(f)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"build", "resolve", "finish", "device_step"} <= names
    with open(tmp_path / "audit.jsonl") as f:
        recs = [json.loads(line) for line in f]
    events = {r["event"] for r in recs}
    assert {"plan", "calibration", "metrics"} <= events


def test_audit_logs_tcgnn_candidates(tmp_path):
    """The selector-audit receipt covers the condensed-tile kernel: a
    mini-batch run committing tcgnn_tile on the inter tiers leaves plan
    events that name it, each with a modeled cost per (layer, tier) —
    so calibration reports price the MXU-dense candidate like any other
    registry kernel (telemetry-smoke CI gate)."""
    g = small_graph(n=128, e=1200)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs", selector="fixed",
                        fixed_kernels=("block_diag", "tcgnn_tile"),
                        telemetry=True,
                        telemetry_out=str(tmp_path / "audit.jsonl"))
    res = _run(cfg, g)
    assert any("tcgnn_tile" in layer for plan in res.plans for layer in plan)
    with open(tmp_path / "audit.jsonl") as f:
        recs = [json.loads(line) for line in f]
    plans = [r for r in recs if r["event"] == "plan"]
    assert plans, "telemetry-enabled run must leave plan receipts"
    tc_plans = [p for p in plans
                if any("tcgnn_tile" in layer for layer in p["layers"])]
    assert tc_plans, "audit must log the condensed-tile kernel candidate"
    for p in tc_plans:
        # every committed choice is priced: one modeled cost per
        # (layer, tier), finite and positive for tcgnn_tile too
        assert len(p["modeled_s"]) == len(p["layers"])
        for layer, row in zip(p["layers"], p["modeled_s"]):
            assert len(row) == len(p["tiers"])
            for kernel, cost in zip(layer, row):
                assert np.isfinite(cost) and cost > 0.0, (kernel, cost)
        assert p["modeled_total_s"] > 0.0


def test_probe_audit_records_modeled_vs_measured():
    g = small_graph(n=128, e=1200)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs", probe_every=1, telemetry=True)
    res = _run(cfg, g)
    cal = res.telemetry["calibration"]
    assert cal["kernels"], "probe-on-every-miss must calibrate kernels"
    for k in cal["kernels"].values():
        assert k["n"] >= 1
        assert k["measured_s"] > 0
        assert k["rel_err"] >= 0
    # every observed plan carries its mint-time modeled total
    assert cal["plans"]
    assert all(p["n_steps"] > 0 for p in cal["plans"])


def test_telemetry_on_off_identical_through_async_pipeline():
    g = small_graph(n=128, e=1200)
    base = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                         clusters_per_batch=4, inter_buckets=2,
                         reorder="bfs", prefetch_depth=3,
                         pipeline_workers=2)
    off = _run(base, g, steps=8)
    on = _run(dataclasses.replace(base, telemetry=True), g, steps=8)
    assert np.array_equal(np.asarray(off.losses), np.asarray(on.losses))
    assert off.plans == on.plans
    assert off.hit_history == on.hit_history
    assert off.n_traces == on.n_traces
    assert on.telemetry["n_span_events"] > 0

"""Flash-attention Pallas kernel vs the mha oracle: shape/dtype/GQA/causal
sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention, flash_hbm_bytes


def make_qkv(rng, B, Hq, Hkv, Sq, Skv, d, dt=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, d)), dt)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, d)), dt)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, d)), dt)
    return q, k, v


@pytest.mark.parametrize("B,Hq,Hkv,S,d,blk", [
    (1, 1, 1, 64, 32, 16), (2, 4, 2, 128, 64, 32), (1, 8, 1, 128, 128, 64),
    (2, 2, 2, 256, 64, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_oracle(rng, B, Hq, Hkv, S, d, blk, causal):
    q, k, v = make_qkv(rng, B, Hq, Hkv, S, S, d)
    o = flash_attention(q, k, v, causal=causal, blk_q=blk, blk_k=blk,
                        interpret=True)
    o_ref = ref.mha(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_bf16(rng):
    q, k, v = make_qkv(rng, 1, 2, 2, 128, 128, 64, jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64,
                        interpret=True)
    o_ref = ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_flash_cross_lengths(rng):
    """Sq != Skv (chunked-prefill shape)."""
    q, k, v = make_qkv(rng, 1, 2, 2, 64, 256, 32)
    o = flash_attention(q, k, v, causal=False, blk_q=32, blk_k=64,
                        interpret=True)
    o_ref = ref.mha(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_hbm_model_scales_linearly():
    """The analytic HBM model must be O(S*d) per q-block, not O(S^2)."""
    b1 = flash_hbm_bytes(1, 8, 8, 4096, 4096, 128)
    b2 = flash_hbm_bytes(1, 8, 8, 8192, 8192, 128)
    assert b2 / b1 < 4.5
    # naive unfused attention writes+reads the fp32 score tensor at least
    # 3x (logits, softmax, p@V); flash must be far below that
    naive_3pass = 3 * 4096 * 4096 * 8 * 4
    assert b1 < naive_3pass / 2

"""Budget-padded blocked-ELL (capped K + COO spill): the mini-batch variant
of the flagship inter kernel.

Property tests: for any random graph tier and any cap, the capped payload's
forward AND backward (through the registry dispatch, i.e. the Pallas kernel
+ the spill segment-sum, with their custom VJPs) must match the uncapped
``bell`` kernel and the dense reference — pad + spill is a *decomposition*
of the same matrix, never an approximation.  Plus the fixed-shape contract
itself: payloads built at one budget share one pytree/shape signature no
matter the batch's edges, which is what admits ``bell`` to ``MB_KERNELS``
and keeps the jitted step at one trace.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import formats, gnn
from repro.graphs import graph as G
from repro.kernels.registry import REGISTRY
from repro.sampling.plan_cache import MB_KERNELS
from repro.train import gnn_steps


def random_tier(seed, n, nnz):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, nnz)
    c = rng.integers(0, n, nnz)
    key = r.astype(np.int64) * n + c
    _, keep = np.unique(key, return_index=True)
    r, c = r[keep], c[keep]
    v = rng.standard_normal(len(r)).astype(np.float32)
    return formats.coo_from_edges(n, n, r, c, v), \
        formats.coo_from_edges(n, n, c, r, v)


def dense_of(coo: formats.COO) -> np.ndarray:
    a = np.zeros((coo.n_rows, coo.n_cols), np.float32)
    a[np.asarray(coo.rows), np.asarray(coo.cols)] = np.asarray(coo.vals)
    return a


def capped_payload(coo, coo_t, B, k_max):
    """Registry build path, with the budget reverse-engineered so
    bell_budget_k lands exactly on k_max (inf -> the uncapped-equivalent
    block-column bound)."""
    nbr = coo.n_rows // B
    if k_max is None:                      # "infinite" cap
        budget = coo.n_rows * coo.n_cols   # -> K = nbr (vacuous cap)
    else:
        budget = max(1, int(k_max * nbr * B / 2.0))   # slack = 2.0
        assert formats.bell_budget_k(budget, coo.n_rows, B) == min(k_max, nbr)
    stats = dict(nnz=coo.nnz, edge_budget=budget)
    return REGISTRY.get("bell").build(coo, coo_t, B, stats)


CAPS = [1, 2, 8, None]     # None = unbounded (no spill)


@settings(max_examples=14, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nnz=st.integers(1, 600),
       cap_i=st.integers(0, len(CAPS) - 1), bf16=st.booleans())
def test_capped_bell_matches_uncapped_and_dense(seed, nnz, cap_i, bf16):
    dtype, tol = (jnp.bfloat16, 2e-1) if bf16 else (jnp.float32, 1e-4)
    n, B, F = 64, 8, 16
    k_max = CAPS[cap_i]
    coo, coo_t = random_tier(seed, n, nnz)
    A = dense_of(coo)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.standard_normal((n, F)).astype(np.float32), dtype)
    spec = REGISTRY.get("bell")

    p = capped_payload(coo, coo_t, B, k_max)
    assert len(p) == 3 and p[0].budgeted and p[1].budgeted
    if k_max is None:
        assert p[2].nnz == 0               # unbounded cap never spills
    # stored + spilled edges partition the tier exactly (no dup, no drop)
    stored_nnz = int(np.count_nonzero(np.asarray(jax.device_get(p[0].blocks))))
    assert stored_nnz + p[2].nnz == coo.nnz
    y = np.asarray(jax.device_get(spec.matvec(p, x)), np.float32)

    # uncapped bell payload (full-batch build path)
    p_full = spec.build(coo, coo_t, B, dict(nnz=coo.nnz))
    y_full = np.asarray(jax.device_get(spec.matvec(p_full, x)), np.float32)
    y_ref = A @ np.asarray(jax.device_get(x), np.float32)

    np.testing.assert_allclose(y, y_ref, rtol=tol, atol=tol)
    np.testing.assert_allclose(y, y_full, rtol=tol, atol=tol)

    # backward: d sum(A@x) / dx = A^T 1 through the capped custom VJP +
    # natively-differentiated spill
    g = jax.grad(lambda xx: spec.matvec(p, xx).astype(jnp.float32).sum())(x)
    g_ref = A.T @ np.ones((n, F), np.float32)
    np.testing.assert_allclose(np.asarray(jax.device_get(g), np.float32),
                               g_ref, rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nnz=st.integers(1, 600),
       cap_i=st.integers(0, len(CAPS) - 1), bf16=st.booleans())
def test_capped_bell_fused_matches_dense(seed, nnz, cap_i, bf16):
    dtype, tol = (jnp.bfloat16, 2e-1) if bf16 else (jnp.float32, 1e-4)
    n, B, Fi, Fo = 64, 8, 12, 16
    coo, coo_t = random_tier(seed, n, nnz)
    A = dense_of(coo)
    rng = np.random.default_rng(seed + 2)
    x = jnp.asarray(rng.standard_normal((n, Fi)).astype(np.float32), dtype)
    w = jnp.asarray(rng.standard_normal((Fi, Fo)).astype(np.float32), dtype)
    p = capped_payload(coo, coo_t, B, CAPS[cap_i])
    spec = REGISTRY.get("bell_fused")

    xf = np.asarray(jax.device_get(x), np.float32)
    wf = np.asarray(jax.device_get(w), np.float32)
    y = np.asarray(jax.device_get(spec.fused_matvec(p, x, w)), np.float32)
    np.testing.assert_allclose(y, A @ (xf @ wf), rtol=tol, atol=tol)

    gx, gw = jax.grad(
        lambda xx, ww: spec.fused_matvec(p, xx, ww).astype(jnp.float32).sum(),
        argnums=(0, 1))(x, w)
    ones = np.ones((n, Fo), np.float32)
    np.testing.assert_allclose(np.asarray(jax.device_get(gx), np.float32),
                               (A.T @ ones) @ wf.T, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(jax.device_get(gw), np.float32),
                               xf.T @ (A.T @ ones), rtol=tol, atol=tol)


def test_capped_payload_shape_fixed_across_edge_sets():
    """Two batches with very different edges but one budget must produce
    identical treedefs and leaf shapes — the MB_KERNELS admission rule."""
    n, B, budget = 64, 8, 500
    sigs = []
    for seed, nnz in [(0, 30), (1, 480), (2, 1)]:
        coo, coo_t = random_tier(seed, n, nnz)
        p = REGISTRY.get("bell").build(coo, coo_t, B,
                                       dict(nnz=coo.nnz, edge_budget=budget))
        # pad the spill like fix_shapes would
        from repro.sampling.plan_cache import _pad_coo
        p = p[:2] + (_pad_coo(p[2], budget),)
        leaves, treedef = jax.tree_util.tree_flatten(p)
        sigs.append((treedef, [(np.shape(l), np.asarray(l).dtype)
                               for l in leaves]))
    assert sigs[0] == sigs[1] == sigs[2]


def test_bell_budget_k_bounds():
    assert formats.bell_budget_k(0, 64, 8) == 1
    assert formats.bell_budget_k(10**9, 64, 8) == 8      # <= block columns
    k1 = formats.bell_budget_k(200, 64, 8)
    k2 = formats.bell_budget_k(400, 64, 8)
    assert 1 <= k1 <= k2 <= 8                            # monotone in budget


def test_uncapped_payload_rejected_by_fix_shapes():
    """A data-dependent-K payload must not silently enter the mini-batch
    path (it would retrace every batch)."""
    from repro.sampling.plan_cache import _pad_payload
    coo, coo_t = random_tier(0, 64, 200)
    p = REGISTRY.get("bell").build(coo, coo_t, 8, dict(nnz=coo.nnz))
    with pytest.raises(TypeError, match="budget"):
        _pad_payload("bell", p, 500)


def test_no_retrace_with_bell_in_mb_kernels():
    """Trace-counter contract: with bell admitted to MB_KERNELS the jitted
    step still compiles exactly once across batches (fixed selector pins
    the plan so the count isolates payload-shape stability)."""
    assert "bell" in MB_KERNELS and "bell_fused" in MB_KERNELS
    rng = np.random.default_rng(0)
    n = 128
    src = rng.integers(0, n, 1500).astype(np.int32)
    dst = rng.integers(0, n, 1500).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, 5)).astype(np.float32)
    labels = rng.integers(0, 3, n).astype(np.int32)
    g = G.Graph(n, src, dst, feats, labels, 3)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs", selector="fixed",
                        fixed_kernels=("block_diag", "bell"))
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
    assert res.n_traces == 1
    assert res.plans == [(("block_diag", "bell", "bell"),) * cfg.n_layers]
    assert np.isfinite(res.losses).all()

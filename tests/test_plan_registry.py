"""Registry + KernelPlan layer: every registered kernel, under every bucket
count, must match the dense A @ X reference forward AND backward; both
selector modes must enumerate candidates from the registry; plan
normalization must broadcast and validate."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import adaptgear, decompose, selector
from repro.core.plan import KernelPlan, normalize_layer
from repro.graphs import graph as G
from repro.kernels.registry import DIAG, OFFDIAG, REGISTRY, payload_nbytes


def make_graph(n=180, e=1400, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    vals = rng.standard_normal(len(src)).astype(np.float32)
    g = G.Graph(n, src, dst, np.zeros((n, 3), np.float32),
                np.zeros(n, np.int32), 2)
    return g, vals


def dense_adj(g, vals):
    a = np.zeros((g.n, g.n), np.float32)
    # duplicate-free edges: direct assignment matches the formats' semantics
    a[g.receivers, g.senders] = vals
    return a


PAIRS = [(ik.name, ek.name) for ik in REGISTRY.candidates(DIAG)
         for ek in REGISTRY.candidates(OFFDIAG)]

import functools


@functools.lru_cache(maxsize=None)
def cached_dec(k):
    """One decomposition per bucket count, shared across the PAIRS sweep
    (formats are read-only; rebuilding them per test is pure overhead)."""
    g, vals = make_graph()
    return g, vals, decompose.decompose(g, comm_size=8, method="bfs",
                                        edge_vals=vals, inter_buckets=k)


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("ik,ek", PAIRS)
def test_aggregate_matches_dense_fwd_and_grad(ik, ek, k, rng):
    g, vals, dec = cached_dec(k)
    a = dense_adj(g, vals)
    x = rng.standard_normal((g.n, 5)).astype(np.float32)
    y_ref = a @ x

    def agg(x_orig):
        xr = adaptgear.to_reordered(dec, x_orig)
        return adaptgear.from_reordered(
            dec, adaptgear.aggregate(dec, xr, (ik, ek)))

    y = agg(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-5, rtol=1e-4,
                               err_msg=f"{ik}/{ek} k={k}")

    # grad: d/dx sum(w * (A @ x)) == A^T w  — exercises every kernel's VJP
    w = rng.standard_normal(y_ref.shape).astype(np.float32)
    grad = jax.grad(lambda x: jnp.sum(agg(x) * w))(jnp.asarray(x))
    grad_ref = a.T @ w
    np.testing.assert_allclose(np.asarray(grad), grad_ref, atol=1e-4,
                               rtol=1e-4, err_msg=f"{ik}/{ek} k={k} grad")


@pytest.mark.parametrize("k", [1, 2, 4])
def test_bucket_partition_and_identity(k):
    g, vals = make_graph(n=240, e=2200, seed=3)
    dec = decompose.decompose(g, comm_size=8, method="bfs", edge_vals=vals,
                              inter_buckets=k)
    assert dec.stats["inter_buckets"] <= k
    assert dec.intra.kind == DIAG and dec.subgraphs[0].name == "intra"
    assert all(s.kind == OFFDIAG for s in dec.inters)
    assert sum(s.stats["nnz"] for s in dec.subgraphs) == g.n_edges


def test_registry_candidates_and_costs():
    """Every registered kernel exposes a positive, finite cost on the
    subgraph kinds it supports, and select_by_cost_model agrees with the
    per-candidate argmin."""
    g, vals = make_graph(n=256, e=3000, seed=1)
    dec = decompose.decompose(g, comm_size=8, method="bfs", edge_vals=vals,
                              inter_buckets=2)
    hw = selector.HwModel()
    for sub in dec.subgraphs:
        cands = REGISTRY.candidates_for(sub)
        assert cands, sub.name
        for spec in cands:
            c = spec.cost(sub, 64, np.float32, hw)
            assert np.isfinite(c) and c > 0, (sub.name, spec.name, c)
    choice = selector.select_by_cost_model(dec, 64, hw=hw)
    for sub, k in zip(dec.subgraphs, choice):
        costs = {s.name: s.cost(sub, 64, np.float32, hw)
                 for s in REGISTRY.candidates_for(sub)}
        assert costs[k] == min(costs.values())


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(KeyError):
        REGISTRY.get("no_such_kernel")
    import dataclasses
    spec = dataclasses.replace(REGISTRY.get("coo"))
    with pytest.raises(ValueError):
        REGISTRY.register(spec)


def test_plan_normalization_and_validation():
    g, vals = make_graph()
    dec = decompose.decompose(g, comm_size=8, method="bfs",
                              inter_buckets=3)
    n_sub = len(dec.subgraphs)
    # (intra, inter) shorthand broadcasts over buckets
    layer = normalize_layer(dec, ("block_diag", "bell"))
    assert layer == ("block_diag",) + ("bell",) * (n_sub - 1)
    # full tuple passes through
    full = ("ell",) * n_sub
    assert normalize_layer(dec, full) == full
    # plans broadcast a single layer choice
    plan = KernelPlan.make(dec, ("coo", "coo"), n_layers=3)
    assert plan.n_layers == 3 and plan.subgraph_names[0] == "intra"
    # invalid: kernel that does not apply to the subgraph kind
    with pytest.raises(ValueError):
        normalize_layer(dec, ("bell",) * n_sub)     # bell is offdiag-only
    with pytest.raises(KeyError):
        normalize_layer(dec, ("nope", "coo"))
    with pytest.raises(ValueError):
        normalize_layer(dec, ("ell", "coo", "coo"))  # wrong arity (3 != 4)


def test_decompose_kernel_filter_materializes_subset():
    g, vals = make_graph()
    dec = decompose.decompose(g, comm_size=8, method="bfs",
                              kernels=("ell", "coo"))
    for sub in dec.subgraphs:
        assert set(sub.formats) == {"ell", "coo"}
        assert payload_nbytes(sub.formats["coo"]) > 0
    # selection still works, restricted to materialized formats
    choice = selector.select_by_cost_model(dec, 32, hw=selector.CPU_HW)
    assert all(k in ("ell", "coo") for k in choice)

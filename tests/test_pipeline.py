"""Async sampler->trainer pipeline (train/pipeline.py): determinism of the
async batch stream vs the sequential one, loss-curve/plan equivalence of
async vs sync training under one seed, the no-retrace contract under
concurrent prepare, worker-exception propagation, clean shutdown, the
backpressure counters + starvation warn-once, thread-safe PlanCache
resolution, and the adaptive-K recompile cap."""
import dataclasses
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import gnn, selector as sel_mod
from repro.graphs import graph as G
from repro.sampling import ClusterSampler, NeighborSampler, PlanCache
from repro.train import gnn_steps
from repro.train.pipeline import BatchPipeline, PipelineError


def small_graph(n=96, e=700, nf=5, nc=3, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, nf)).astype(np.float32)
    labels = rng.integers(0, nc, n).astype(np.int32)
    return G.Graph(n, src, dst, feats, labels, nc)


def batch_equal(a, b):
    return (np.array_equal(a.nodes, b.nodes)
            and np.array_equal(a.node_mask, b.node_mask)
            and np.array_equal(a.senders, b.senders)
            and np.array_equal(a.receivers, b.receivers)
            and np.array_equal(a.edge_mask, b.edge_mask)
            and np.array_equal(a.target_mask, b.target_mask)
            and np.allclose(a.features, b.features))


def pipeline_threads():
    return [t for t in threading.enumerate() if t.name.startswith("pipeline-")]


# -- BatchPipeline unit behavior ---------------------------------------------

def test_items_delivered_in_index_order_despite_racing_workers():
    # workers finish out of order (even items sleep); get() must still
    # yield 0..n-1 in order, each the work of its own draw
    def work(idx, ticket):
        if idx % 2 == 0:
            time.sleep(0.01)
        return (idx, ticket * 10)

    counter = iter(range(100))
    with BatchPipeline(lambda: next(counter), work, n_items=12,
                       prefetch_depth=4, workers=4) as pipe:
        out = [pipe.get() for _ in range(12)]
    assert out == [(i, i * 10) for i in range(12)]
    assert pipe.stats["delivered"] == 12


def test_resolve_stage_runs_in_index_order_and_finish_races():
    # the determinism linchpin: work_fn completes wildly out of order, but
    # resolve_fn (where shared-cache decisions live) must still run 0..n-1
    # strictly in index order; finish_fn races afterwards
    resolved, finished = [], []

    def work(idx, ticket):
        if idx % 2 == 0:
            time.sleep(0.008)
        return ticket

    def resolve(idx, item):
        resolved.append(idx)
        return item

    def finish(idx, item):
        finished.append(idx)
        return item * 10

    counter = iter(range(100))
    n = 12
    with BatchPipeline(lambda: next(counter), work, n_items=n,
                       prefetch_depth=4, workers=4,
                       resolve_fn=resolve, finish_fn=finish) as pipe:
        out = [pipe.get() for _ in range(n)]
    assert out == [i * 10 for i in range(n)]
    assert resolved == list(range(n))      # strict index order
    assert sorted(finished) == list(range(n))


def test_failed_item_vacates_its_resolve_turn():
    # an item that dies in work_fn must not deadlock later items behind
    # its never-run resolve turn; its error still surfaces at its get()
    resolved = []

    def work(idx, ticket):
        if idx == 1:
            raise ValueError("boom at 1")
        return ticket

    counter = iter(range(100))
    pipe = BatchPipeline(lambda: next(counter), work, n_items=6,
                         prefetch_depth=3, workers=3,
                         resolve_fn=lambda i, x: resolved.append(i) or x)
    assert pipe.get() == 0
    with pytest.raises(ValueError, match="boom at 1"):
        pipe.get()
    assert 1 not in resolved               # its turn was vacated, not run
    assert not pipeline_threads()


def test_worker_exception_propagates_and_closes():
    def work(idx, ticket):
        if idx == 3:
            raise ValueError("boom at 3")
        return idx

    counter = iter(range(100))
    pipe = BatchPipeline(lambda: next(counter), work, n_items=10,
                         prefetch_depth=2, workers=2)
    got = [pipe.get() for _ in range(3)]
    assert got == [0, 1, 2]
    with pytest.raises(ValueError, match="boom at 3"):
        pipe.get()
    # the failed get closed the pipeline: workers joined, further gets raise
    assert not pipeline_threads()
    with pytest.raises(PipelineError):
        pipe.get()


def test_draw_exception_propagates_at_its_index():
    calls = dict(n=0)

    def draw():
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("bad draw")
        return calls["n"]

    pipe = BatchPipeline(draw, lambda i, t: t, n_items=6,
                         prefetch_depth=2, workers=2)
    assert pipe.get() == 1
    with pytest.raises(RuntimeError, match="bad draw"):
        pipe.get()
    assert not pipeline_threads()


def test_clean_shutdown_midstream_and_after_drain():
    counter = iter(range(1000))
    pipe = BatchPipeline(lambda: next(counter),
                         lambda i, t: time.sleep(0.002) or t, n_items=500,
                         prefetch_depth=4, workers=3)
    assert pipe.get() == 0
    pipe.close()                       # mid-stream, items still staged
    pipe.close()                       # idempotent
    assert not pipeline_threads()
    with pytest.raises(PipelineError):
        pipe.get()

    # full drain also leaves no threads and refuses extra gets
    counter = iter(range(100))
    with BatchPipeline(lambda: next(counter), lambda i, t: t,
                       n_items=5, prefetch_depth=2, workers=2) as pipe:
        assert [pipe.get() for _ in range(5)] == [0, 1, 2, 3, 4]
        with pytest.raises(PipelineError, match="already delivered"):
            pipe.get()
    assert not pipeline_threads()


def test_backpressure_counters_and_depth_bound():
    # slow consumer: producers fill every slot then block -> wait_full
    # accrues, and no more than depth items are ever staged ahead
    max_ahead = dict(v=0)
    delivered = dict(v=0)

    def work(idx, ticket):
        max_ahead["v"] = max(max_ahead["v"], idx - delivered["v"])
        return idx

    counter = iter(range(100))
    depth = 3
    with BatchPipeline(lambda: next(counter), work, n_items=20,
                       prefetch_depth=depth, workers=2) as pipe:
        for _ in range(20):
            time.sleep(0.005)
            pipe.get()
            delivered["v"] += 1
    s = pipe.stats
    assert s["wait_full_s"] > 0.0
    # depth permits + the one the consumer is holding
    assert max_ahead["v"] <= depth + 1
    assert s["ready_mean"] > 0.0

    # slow producer: consumer blocks -> wait_empty accrues
    counter = iter(range(100))
    with BatchPipeline(lambda: next(counter),
                       lambda i, t: time.sleep(0.005) or t, n_items=8,
                       prefetch_depth=4, workers=1) as pipe:
        for _ in range(8):
            pipe.get()
    assert pipe.stats["wait_empty_s"] > 0.0


def test_starvation_warns_once():
    counter = iter(range(1000))
    with BatchPipeline(lambda: next(counter),
                       lambda i, t: time.sleep(0.003) or t, n_items=40,
                       prefetch_depth=4, workers=1, warn_after=8) as pipe:
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for _ in range(40):
                pipe.get()
    starve = [w for w in rec if "prefetch queue averaged" in str(w.message)]
    assert len(starve) == 1            # warn-once latch
    assert pipe.stats["starved"] is True


# -- async batch stream == sequential batch stream ---------------------------

@pytest.mark.parametrize("make", [
    lambda g, s: ClusterSampler(g, block=8, clusters_per_batch=4,
                                method="bfs", seed=s),
    lambda g, s: NeighborSampler(g, batch_nodes=16, fanouts=(4, 2),
                                 method="bfs", block=8, seed=s),
])
def test_async_batch_stream_matches_sequential(make):
    g = small_graph()
    seq = [make(g, 7).sample() for _ in range(1)]  # warm type caches
    ref_sampler = make(g, 7)
    n = 14                                         # crosses an epoch refill
    ref = [ref_sampler.sample() for _ in range(n)]

    pipe_sampler = make(g, 7)

    def work(idx, ticket):
        if idx % 3 == 0:               # force out-of-order builds
            time.sleep(0.004)
        return pipe_sampler.build(ticket)

    with BatchPipeline(pipe_sampler.draw, work, n_items=n,
                       prefetch_depth=4, workers=3) as pipe:
        got = [pipe.get() for _ in range(n)]
    for a, b in zip(ref, got):
        assert batch_equal(a, b)
    # the sampler continues identically after the pipeline closes (the
    # eval loop depends on this)
    assert batch_equal(ref_sampler.sample(), pipe_sampler.sample())


def test_ticket_build_is_pure_and_order_independent():
    g = small_graph()
    s = ClusterSampler(g, block=8, clusters_per_batch=4, method="bfs", seed=3)
    tickets = [s.draw() for _ in range(6)]
    fwd = [s.build(t) for t in tickets]
    rev = [s.build(t) for t in reversed(tickets)]
    for a, b in zip(fwd, reversed(rev)):
        assert batch_equal(a, b)


# -- async training == sync training -----------------------------------------

def test_async_training_matches_sync_and_never_retraces():
    g = small_graph(n=160, e=1400)
    cfg = gnn.GNNConfig(model="gcn", n_layers=2, hidden=8, comm_size=8,
                        sampler="cluster", clusters_per_batch=4,
                        inter_buckets=2, reorder="bfs",
                        selector="cost_model", seed=11)
    sync = gnn_steps.train_minibatch(g, cfg, steps=16, eval_batches=2)
    acfg = dataclasses.replace(cfg, prefetch_depth=4, pipeline_workers=2)
    asyn = gnn_steps.train_minibatch(g, acfg, steps=16, eval_batches=2)

    # identical committed plans and cache decisions (tolerance-free)
    assert asyn.plans == sync.plans
    assert asyn.hit_history == sync.hit_history
    assert asyn.cache["hit_rate"] == sync.cache["hit_rate"]
    # identical loss curve (fp tolerance) and eval accuracy
    np.testing.assert_allclose(asyn.losses, sync.losses, atol=1e-4)
    assert asyn.accuracy == sync.accuracy
    # one trace per step function, whether compiled by a worker (async
    # warm-compile) or by the consumer (sync)
    assert sync.n_traces == len(sync.plans)
    assert asyn.n_traces == len(asyn.plans)
    # stats surfaced only on the async path
    assert sync.pipeline is None
    assert asyn.pipeline["delivered"] == 16
    assert asyn.pipeline["depth"] == 4
    assert asyn.pipeline["efficiency_pct"] > 0.0
    # clean shutdown: no pipeline worker threads outlive the call
    assert not pipeline_threads()


def test_async_adapt_budget_k_training_matches_sync():
    # with the budget-K autotuner live, spill feedback and the slack
    # ladder are also part of the ordered-resolve contract: committed
    # payloads materialize in index order, so plans, hit history, and
    # every cache counter stay bit-identical to the sync path
    g = small_graph(n=160, e=1400)
    cfg = gnn.GNNConfig(model="gcn", n_layers=2, hidden=8, comm_size=8,
                        sampler="cluster", clusters_per_batch=4,
                        inter_buckets=2, reorder="bfs",
                        selector="cost_model", adapt_budget_k=True,
                        max_ladder_recompiles=2, seed=11)
    sync = gnn_steps.train_minibatch(g, cfg, steps=12, eval_batches=1)
    acfg = dataclasses.replace(cfg, prefetch_depth=4, pipeline_workers=2)
    asyn = gnn_steps.train_minibatch(g, acfg, steps=12, eval_batches=1)
    assert asyn.plans == sync.plans
    assert asyn.hit_history == sync.hit_history
    assert asyn.cache == sync.cache        # incl. spill/slack counters
    np.testing.assert_allclose(asyn.losses, sync.losses, atol=1e-4)
    assert not pipeline_threads()


def test_async_training_worker_failure_shuts_down_cleanly(monkeypatch):
    g = small_graph()
    cfg = gnn.GNNConfig(model="gin", n_layers=2, hidden=8, comm_size=8,
                        sampler="cluster", clusters_per_batch=4,
                        inter_buckets=2, reorder="bfs", selector="fixed",
                        fixed_kernels=("block_diag", "bell"),
                        prefetch_depth=2, pipeline_workers=2, seed=5)
    calls = dict(n=0)
    real = gnn_steps.prepare_skeleton

    def flaky(batch, cfg_, bell_slack=None):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("prepare blew up")
        return real(batch, cfg_, bell_slack=bell_slack)

    monkeypatch.setattr(gnn_steps, "prepare_skeleton", flaky)
    with pytest.raises(RuntimeError, match="prepare blew up"):
        gnn_steps.train_minibatch(g, cfg, steps=12, eval_batches=0)
    assert not pipeline_threads()


# -- PlanCache thread-safety + adaptive-K recompile cap -----------------------

def test_plan_cache_concurrent_resolution_single_miss_per_signature():
    g = small_graph(n=160, e=1400)
    cfg = gnn.GNNConfig(model="gcn", n_layers=2, hidden=8, comm_size=8,
                        sampler="cluster", clusters_per_batch=4,
                        inter_buckets=2, reorder="bfs", seed=2)
    sampler = gnn_steps.make_sampler(g, cfg)
    pad = sampler.edge_budget + sampler.node_budget
    pairs = gnn.agg_width_pairs(cfg, g.features.shape[-1], g.n_classes)
    cache = PlanCache(pairs, hw=sel_mod.default_hw(), edge_budget=pad)
    decs = []
    for _ in range(6):
        skel, _ = gnn_steps.prepare_skeleton(sampler.sample(), cfg)
        decs.append(skel.materialize(("block_diag", "bell", "csr")))

    n_threads, per_thread = 4, 12
    errs = []

    def hammer(t):
        rng = np.random.default_rng(t)
        try:
            for _ in range(per_thread):
                dec = decs[rng.integers(len(decs))]
                plan = cache.lookup(dec)
                if plan is None:
                    plan, _ = cache.plan_for(dec)
                assert plan is not None
        except BaseException as e:      # noqa: BLE001 — surfaced below
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    s = cache.stats
    # every resolution is accounted for, and racing threads on one fresh
    # signature paid exactly one miss: misses == distinct entries minted
    assert s["hits"] + s["near_hits"] + s["misses"] >= n_threads * per_thread
    assert s["misses"] == s["entries"] + s["evictions"]
    unique_sigs = {cache.signature(d) for d in decs}
    assert s["misses"] <= len(unique_sigs)


def test_max_slack_changes_caps_ladder_steps():
    pairs = [(None, 8)]

    def spill_hard(cache, steps=5):
        for _ in range(steps):
            cache._spill_window.extend(
                [(0.5, 0.9)] * cache.spill_min_obs)   # heavy spill: step up
            cache._maybe_step_slack()

    capped = PlanCache(pairs, adapt_budget_k=True, bell_slack=1.0,
                       spill_min_obs=4, max_slack_changes=2)
    spill_hard(capped)
    assert capped.slack_changes == 2           # froze at the cap
    held = capped.bell_slack
    spill_hard(capped)
    assert capped.slack_changes == 2 and capped.bell_slack == held
    # and the window keeps draining so it cannot grow without bound
    assert len(capped._spill_window) == 0

    free = PlanCache(pairs, adapt_budget_k=True, bell_slack=1.0,
                     spill_min_obs=4, max_slack_changes=None)
    spill_hard(free)
    assert free.slack_changes > 2              # unbounded default still walks
    assert free.stats["slack_changes"] == free.slack_changes


def test_config_threads_recompile_cap_into_cache():
    g = small_graph()
    cfg = gnn.GNNConfig(model="gcn", n_layers=1, hidden=8, comm_size=8,
                        sampler="cluster", clusters_per_batch=4,
                        inter_buckets=2, reorder="bfs",
                        adapt_budget_k=True, max_ladder_recompiles=1, seed=4)
    res = gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=0)
    assert res.plan_cache.max_slack_changes == 1
    assert res.cache["slack_changes"] <= 1

"""TC-GNN-style column-condensed MXU tiles (kernels/tcgnn_tile.py).

Property tests: the condensed contraction (XLA row gather + batched Pallas
MXU pass, with its custom VJPs) must match the dense reference for forward
AND grads, f32 and bf16, uncapped and budget-capped with *real* spill (the
C floor is one lane = 128 columns, so spill needs tiers wider than 128);
the fused A @ (X W) path and the accumulating variants must agree with
their unfused/seeded twins; budget-capped payloads must be shape-fixed
across edge sets (the MB_KERNELS admission rule) and keep the jitted
mini-batch step at one trace; and the cost model must prefer the
condensed tiles over blocked-ELL on a mid-density tier whose blocks are
mostly padding but whose columns are mostly occupied.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import decompose as dm, formats, gnn
from repro.core import selector as sel_mod
from repro.graphs import graph as G
from repro.kernels import tcgnn_tile as tc_mod
from repro.kernels.registry import REGISTRY
from repro.sampling.plan_cache import MB_KERNELS, _pad_payload
from repro.train import gnn_steps


def random_tier(seed, n, nnz):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, nnz)
    c = rng.integers(0, n, nnz)
    key = r.astype(np.int64) * n + c
    _, keep = np.unique(key, return_index=True)
    r, c = r[keep], c[keep]
    v = rng.standard_normal(len(r)).astype(np.float32)
    return formats.coo_from_edges(n, n, r, c, v), \
        formats.coo_from_edges(n, n, c, r, v)


def dense_of(coo: formats.COO) -> np.ndarray:
    a = np.zeros((coo.n_rows, coo.n_cols), np.float32)
    a[np.asarray(coo.rows), np.asarray(coo.cols)] = np.asarray(coo.vals)
    return a


def hub_tier(seed, n, fan, extra):
    """Block row 0 fans out to ``fan`` distinct columns (forcing real
    spill whenever fan > the budgeted C) + ``extra`` random edges."""
    rng = np.random.default_rng(seed)
    cols0 = rng.choice(n, size=fan, replace=False)
    rows0 = rng.integers(0, 8, fan)
    r2 = rng.integers(0, n, extra)
    c2 = rng.integers(0, n, extra)
    r = np.concatenate([rows0, r2])
    c = np.concatenate([cols0, c2])
    key = r.astype(np.int64) * n + c
    _, keep = np.unique(key, return_index=True)
    r, c = r[keep], c[keep]
    v = rng.standard_normal(len(r)).astype(np.float32)
    return formats.coo_from_edges(n, n, r, c, v), \
        formats.coo_from_edges(n, n, c, r, v)


BLOCKS = [8, 16, 32]


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nnz=st.integers(1, 600),
       bi=st.integers(0, len(BLOCKS) - 1), bf16=st.booleans())
def test_tcgnn_matches_dense_fwd_and_grad(seed, nnz, bi, bf16):
    """Uncapped condensed tiles == dense, forward and dX, through the
    registry dispatch (Pallas kernel + custom VJP), any block size."""
    dtype, tol = (jnp.bfloat16, 2e-1) if bf16 else (jnp.float32, 1e-4)
    n, F = 64, 16
    B = BLOCKS[bi]
    coo, coo_t = random_tier(seed, n, nnz)
    A = dense_of(coo)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.standard_normal((n, F)).astype(np.float32), dtype)
    spec = REGISTRY.get("tcgnn_tile")
    p = spec.build(coo, coo_t, B, dict(nnz=coo.nnz))
    assert len(p) == 2 and not p[0].budgeted

    y = np.asarray(jax.device_get(spec.matvec(p, x)), np.float32)
    y_ref = A @ np.asarray(jax.device_get(x), np.float32)
    np.testing.assert_allclose(y, y_ref, rtol=tol, atol=tol)

    g = jax.grad(lambda xx: spec.matvec(p, xx).astype(jnp.float32).sum())(x)
    g_ref = A.T @ np.ones((n, F), np.float32)
    np.testing.assert_allclose(np.asarray(jax.device_get(g), np.float32),
                               g_ref, rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), fan=st.integers(150, 230),
       bf16=st.booleans())
def test_tcgnn_capped_with_real_spill_matches_dense(seed, fan, bf16):
    """Budget-capped triple with spill actually flowing (C pinned at the
    128-lane floor, hub block row fanning past it): stored + spilled edges
    partition the tier exactly, and fwd + dX + the fused path still match
    dense — pad + spill is a decomposition, never an approximation."""
    dtype, tol = (jnp.bfloat16, 2e-1) if bf16 else (jnp.float32, 1e-4)
    n, B, Fi, Fo = 256, 8, 8, 16
    coo, coo_t = hub_tier(seed, n, fan, 200)
    A = dense_of(coo)
    budget = 500                 # C = lane-ceil(2*500/32) = 128 < fan
    assert tc_mod.tcgnn_budget_c(budget, n, B) == 128
    spec = REGISTRY.get("tcgnn_tile")
    p = spec.build(coo, None, B, dict(nnz=coo.nnz, edge_budget=budget))
    assert len(p) == 3 and p[0].budgeted and p[1].budgeted
    assert p[2].nnz > 0          # the hub really spilled
    stored = int(np.count_nonzero(np.asarray(jax.device_get(p[0].tiles))))
    assert stored + p[2].nnz == coo.nnz

    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.standard_normal((n, Fi)).astype(np.float32), dtype)
    w = jnp.asarray(rng.standard_normal((Fi, Fo)).astype(np.float32), dtype)
    xf = np.asarray(jax.device_get(x), np.float32)
    wf = np.asarray(jax.device_get(w), np.float32)

    y = np.asarray(jax.device_get(spec.matvec(p, x)), np.float32)
    np.testing.assert_allclose(y, A @ xf, rtol=tol, atol=tol)
    g = jax.grad(lambda xx: spec.matvec(p, xx).astype(jnp.float32).sum())(x)
    np.testing.assert_allclose(np.asarray(jax.device_get(g), np.float32),
                               A.T @ np.ones((n, Fi), np.float32),
                               rtol=tol, atol=tol)

    fspec = REGISTRY.get("tcgnn_tile_fused")
    yf = np.asarray(jax.device_get(fspec.fused_matvec(p, x, w)), np.float32)
    np.testing.assert_allclose(yf, A @ (xf @ wf), rtol=tol, atol=tol)
    gx, gw = jax.grad(
        lambda xx, ww: fspec.fused_matvec(p, xx, ww).astype(
            jnp.float32).sum(), argnums=(0, 1))(x, w)
    ones = np.ones((n, Fo), np.float32)
    np.testing.assert_allclose(np.asarray(jax.device_get(gx), np.float32),
                               (A.T @ ones) @ wf.T, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(jax.device_get(gw), np.float32),
                               xf.T @ (A.T @ ones), rtol=tol, atol=tol)


def test_tcgnn_acc_mode_equivalence(rng):
    """matvec_acc(p, x, y0) == matvec(p, x) + y0 (and the fused twin),
    forward and grads — the threaded-accumulator dispatch contract."""
    n, B, Fi, Fo = 64, 8, 8, 16
    coo, coo_t = random_tier(5, n, 400)
    spec = REGISTRY.get("tcgnn_tile")
    fspec = REGISTRY.get("tcgnn_tile_fused")
    p = spec.build(coo, coo_t, B, dict(nnz=coo.nnz))
    x = jnp.asarray(rng.standard_normal((n, Fi)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((Fi, Fo)), jnp.float32)
    y0 = jnp.asarray(rng.standard_normal((n, Fi)), jnp.float32)
    z0 = jnp.asarray(rng.standard_normal((n, Fo)), jnp.float32)

    np.testing.assert_allclose(
        np.asarray(spec.matvec_acc(p, x, y0)),
        np.asarray(spec.matvec(p, x) + y0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(fspec.fused_matvec_acc(p, x, w, z0)),
        np.asarray(fspec.fused_matvec(p, x, w) + z0), rtol=1e-5, atol=1e-5)

    ga = jax.grad(lambda xx: spec.matvec_acc(p, xx, y0).sum())(x)
    gb = jax.grad(lambda xx: (spec.matvec(p, xx) + y0).sum())(x)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=1e-5, atol=1e-5)
    gaw = jax.grad(lambda ww: fspec.fused_matvec_acc(p, x, ww, z0).sum())(w)
    gbw = jax.grad(lambda ww: (fspec.fused_matvec(p, x, ww) + z0).sum())(w)
    np.testing.assert_allclose(np.asarray(gaw), np.asarray(gbw),
                               rtol=1e-4, atol=1e-4)


def test_tcgnn_capped_payload_shape_fixed_across_edge_sets():
    """One (budget, n_pad, B) -> one treedef + leaf-shape signature, no
    matter the batch's edges — the MB_KERNELS admission rule."""
    n, B, budget = 256, 8, 500
    sigs = []
    for seed, nnz in [(0, 30), (1, 900), (2, 1)]:
        coo, _ = random_tier(seed, n, nnz)
        p = REGISTRY.get("tcgnn_tile").build(
            coo, None, B, dict(nnz=coo.nnz, edge_budget=budget))
        p = _pad_payload("tcgnn_tile", p, budget)
        leaves, treedef = jax.tree_util.tree_flatten(p)
        sigs.append((treedef, [(np.shape(l), np.asarray(l).dtype)
                               for l in leaves]))
    assert sigs[0] == sigs[1] == sigs[2]


def test_tcgnn_uncapped_payload_rejected_by_fix_shapes():
    """A data-dependent-C payload must not silently enter the mini-batch
    path (it would retrace every batch)."""
    coo, coo_t = random_tier(0, 64, 200)
    p = REGISTRY.get("tcgnn_tile").build(coo, coo_t, 8, dict(nnz=coo.nnz))
    with pytest.raises(TypeError, match="fixed-shape"):
        _pad_payload("tcgnn_tile", p, 500)


def test_tcgnn_budget_c_bounds():
    assert tc_mod.tcgnn_budget_c(0, 256, 8) == 128          # lane floor
    assert tc_mod.tcgnn_budget_c(10**9, 256, 8) == 256      # <= lane-pad(n)
    c1 = tc_mod.tcgnn_budget_c(1000, 1024, 8)
    c2 = tc_mod.tcgnn_budget_c(4000, 1024, 8)
    assert 128 <= c1 <= c2 <= 1024                          # monotone
    assert c1 % 128 == 0 and c2 % 128 == 0                  # lane aligned


def mid_density_tier(n=512, B=32, cols_per_brow=100, edges_per_col=16,
                     seed=0):
    """The regime the condensed tiles own: block rows touching ~100
    distinct columns, each column half-occupied — blocked-ELL stores a
    mostly-empty (B, B) block per touched block column, while the
    condensed tile stores exactly the occupied columns."""
    rng = np.random.default_rng(seed)
    nbr = n // B
    rows, cols = [], []
    for i in range(nbr):
        cs = rng.choice(n, size=cols_per_brow, replace=False)
        for c in cs:
            rr = rng.choice(B, size=edges_per_col, replace=False) + i * B
            rows.extend(rr)
            cols.extend([c] * edges_per_col)
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.ones(len(rows), np.float32)
    return dm.build_subgraph("inter0", "offdiag", n, B, rows, cols, vals)


def test_cost_model_selects_tcgnn_on_mid_density_tier():
    """The acceptance tier: the cost model prefers the condensed tiles
    over blocked-ELL (and every other candidate) where column occupancy
    is high but block occupancy is low."""
    sub = mid_density_tier()
    hw = sel_mod.HwModel()       # deterministic: never the CPU fallback
    pick = sel_mod.select_for_subgraph(sub, 16, hw=hw)
    assert pick == "tcgnn_tile"
    c_tc = sel_mod.candidate_cost(sub, "tcgnn_tile", 16, hw=hw)
    c_bell = sel_mod.candidate_cost(sub, "bell", 16, hw=hw)
    assert c_tc < c_bell
    # the signature the PlanCache keys on sees the column occupancy
    assert 0.0 < sub.stats["col_occupancy"] <= 1.0


def test_tcgnn_competes_in_both_selector_modes():
    """Registered for real: present in the full-sweep candidate set (both
    specs), in MB_KERNELS, and probed by the feedback selector."""
    assert "tcgnn_tile" in MB_KERNELS and "tcgnn_tile_fused" in MB_KERNELS
    sub = mid_density_tier(n=128, B=8, cols_per_brow=20, edges_per_col=4)
    names = {s.name for s in REGISTRY.candidates_for(sub,
                                                     include_fused=True)}
    assert {"tcgnn_tile", "tcgnn_tile_fused"} <= names


def test_no_retrace_with_tcgnn_in_mb_kernels():
    """Trace-counter contract: committing tcgnn_tile in the mini-batch
    plan keeps the jitted step at exactly one trace across batches (fixed
    selector pins the plan so the count isolates payload-shape
    stability)."""
    rng = np.random.default_rng(0)
    n = 128
    src = rng.integers(0, n, 1500).astype(np.int32)
    dst = rng.integers(0, n, 1500).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, 5)).astype(np.float32)
    labels = rng.integers(0, 3, n).astype(np.int32)
    g = G.Graph(n, src, dst, feats, labels, 3)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs", selector="fixed",
                        fixed_kernels=("block_diag", "tcgnn_tile"))
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
    assert res.n_traces == 1
    assert res.plans == [(("block_diag", "tcgnn_tile", "tcgnn_tile"),)
                         * cfg.n_layers]
    assert np.isfinite(res.losses).all()


@pytest.mark.parametrize("B", BLOCKS)
@pytest.mark.parametrize("bf16", [False, True])
def test_tcgnn_matches_dense_deterministic(B, bf16):
    """Non-hypothesis twin of the uncapped property test (runs on
    machines without hypothesis): fwd + dX, one seed per (block size,
    dtype)."""
    dtype, tol = (jnp.bfloat16, 2e-1) if bf16 else (jnp.float32, 1e-4)
    n, F = 64, 16
    coo, coo_t = random_tier(7, n, 450)
    A = dense_of(coo)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((n, F)).astype(np.float32), dtype)
    spec = REGISTRY.get("tcgnn_tile")
    p = spec.build(coo, coo_t, B, dict(nnz=coo.nnz))
    y = np.asarray(jax.device_get(spec.matvec(p, x)), np.float32)
    np.testing.assert_allclose(y, A @ np.asarray(jax.device_get(x),
                                                 np.float32),
                               rtol=tol, atol=tol)
    g = jax.grad(lambda xx: spec.matvec(p, xx).astype(jnp.float32).sum())(x)
    np.testing.assert_allclose(np.asarray(jax.device_get(g), np.float32),
                               A.T @ np.ones((n, F), np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bf16", [False, True])
def test_tcgnn_capped_spill_deterministic(bf16):
    """Non-hypothesis twin of the capped-with-real-spill property test."""
    dtype, tol = (jnp.bfloat16, 2e-1) if bf16 else (jnp.float32, 1e-4)
    n, B, Fi, Fo = 256, 8, 8, 16
    coo, _ = hub_tier(3, n, 200, 200)
    A = dense_of(coo)
    spec = REGISTRY.get("tcgnn_tile")
    p = spec.build(coo, None, B, dict(nnz=coo.nnz, edge_budget=500))
    assert p[2].nnz > 0
    stored = int(np.count_nonzero(np.asarray(jax.device_get(p[0].tiles))))
    assert stored + p[2].nnz == coo.nnz
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((n, Fi)).astype(np.float32), dtype)
    w = jnp.asarray(rng.standard_normal((Fi, Fo)).astype(np.float32), dtype)
    xf = np.asarray(jax.device_get(x), np.float32)
    wf = np.asarray(jax.device_get(w), np.float32)
    y = np.asarray(jax.device_get(spec.matvec(p, x)), np.float32)
    np.testing.assert_allclose(y, A @ xf, rtol=tol, atol=tol)
    fspec = REGISTRY.get("tcgnn_tile_fused")
    yf = np.asarray(jax.device_get(fspec.fused_matvec(p, x, w)), np.float32)
    np.testing.assert_allclose(yf, A @ (xf @ wf), rtol=tol, atol=tol)
    gx, gw = jax.grad(
        lambda xx, ww: fspec.fused_matvec(p, xx, ww).astype(
            jnp.float32).sum(), argnums=(0, 1))(x, w)
    ones = np.ones((n, Fo), np.float32)
    np.testing.assert_allclose(np.asarray(jax.device_get(gx), np.float32),
                               (A.T @ ones) @ wf.T, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(jax.device_get(gw), np.float32),
                               xf.T @ (A.T @ ones), rtol=tol, atol=tol)

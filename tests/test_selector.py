"""Adaptive selector: cost-model ranking sanity, feedback commit protocol,
calibration loop — candidates enumerated from the kernel registry."""
import numpy as np
import pytest

from repro.core import decompose, selector
from repro.core.selector import HwModel
from repro.graphs import graph as G
from repro.kernels.registry import REGISTRY


def kernel_names(kind):
    return [s.name for s in REGISTRY.candidates(kind)]


def make_dec(intra_frac, n=512, e=4096, seed=0, inter_buckets=1):
    src, dst = G.community_graph(n, e, comm_size=16, intra_frac=intra_frac,
                                 seed=seed)
    g = G.Graph(n, src, dst, np.zeros((n, 4), np.float32),
                np.zeros(n, np.int32), 2)
    return decompose.decompose(g, comm_size=16, method="louvain",
                               inter_buckets=inter_buckets)


def test_cost_model_returns_valid_kernels():
    dec = make_dec(0.6)
    choice = selector.select_by_cost_model(dec, feat_dim=64)
    assert len(choice) == len(dec.subgraphs)
    for sub, k in zip(dec.subgraphs, choice):
        assert k in [s.name for s in REGISTRY.candidates_for(sub)]


def test_cost_model_per_bucket_choices():
    dec = make_dec(0.6, inter_buckets=3)
    choice = selector.select_by_cost_model(dec, feat_dim=64)
    assert len(choice) == len(dec.subgraphs)
    # the argmin per subgraph matches candidate_cost directly
    for sub, k in zip(dec.subgraphs, choice):
        costs = {s.name: selector.candidate_cost(sub, s.name, 64)
                 for s in REGISTRY.candidates_for(sub)}
        assert costs[k] == min(costs.values())


def test_cost_model_dense_wins_at_high_density():
    """On the TPU model, a near-full diagonal block favors the MXU dense
    kernel over gather/scatter paths."""
    dec = make_dec(0.95, n=256, e=12000)
    hw = HwModel()
    costs = {k: selector.candidate_cost(dec.intra, k, 256, hw=hw)
             for k in kernel_names("diag")}
    assert costs["block_diag"] == min(costs.values()), costs


def test_cost_model_coo_wins_at_extreme_sparsity():
    dec = make_dec(0.05, n=2048, e=2100)
    hw = HwModel()
    inter = dec.inters[0]
    costs = {k: selector.candidate_cost(inter, k, 64, hw=hw)
             for k in kernel_names("offdiag")}
    # edge-parallel COO beats padded formats when rows are nearly empty
    assert costs["coo"] <= costs["bell"], costs


def test_feedback_commit_protocol():
    dec = make_dec(0.5)
    sel = selector.AdaptiveSelector(dec, warmup_iters=2)
    # feed synthetic timings for every registry candidate: make 'ell'
    # fastest intra, 'coo' fastest inter
    fastest = {"intra": "ell", "inter": "coo"}
    for sub in dec.subgraphs:
        for spec in REGISTRY.candidates_for(sub):
            t = 1e-4 if spec.name == fastest[sub.name] else 3e-3
            for _ in range(2):
                sel.observe(sub.name, spec.name, t)
    assert sel.ready()
    assert sel.choice() == ("ell", "coo")
    # committed choice is sticky
    sel.observe("intra", "coo", 1e-9)
    assert sel.choice() == ("ell", "coo")


def test_feedback_probe_end_to_end(rng):
    dec = make_dec(0.5, n=128, e=512)
    sel = selector.AdaptiveSelector(dec, warmup_iters=1)
    import jax.numpy as jnp
    x = jnp.asarray(rng.standard_normal((dec.n_pad, 16)), jnp.float32)
    res = sel.probe(x, iters=1)
    n_cand = 0
    for sub, k in zip(dec.subgraphs, res.choice):
        cands = [s.name for s in REGISTRY.candidates_for(sub)]
        assert k in cands
        n_cand += len(cands)
    assert len(res.times) == n_cand


def test_feedback_probe_multi_bucket(rng):
    dec = make_dec(0.5, n=256, e=2048, inter_buckets=2)
    assert len(dec.subgraphs) == 3
    sel = selector.AdaptiveSelector(dec, warmup_iters=1)
    import jax.numpy as jnp
    x = jnp.asarray(rng.standard_normal((dec.n_pad, 8)), jnp.float32)
    res = sel.probe(x, iters=1)
    assert len(res.choice) == 3
    assert {s for (s, _) in res.times} == {"intra", "inter0", "inter1"}


def test_calibration_scales_model(rng):
    dec = make_dec(0.5, n=128, e=512)
    sel = selector.AdaptiveSelector(dec, warmup_iters=1)
    import jax.numpy as jnp
    x = jnp.asarray(rng.standard_normal((dec.n_pad, 16)), jnp.float32)
    sel.probe(x, iters=1)
    hw = sel.calibrate_cost_model(feat_dim=16)
    # calibrated model should predict the probed medians within ~100x
    # (CPU interpret-mode variance is huge; we check order of magnitude)
    t_est = selector.candidate_cost(dec.inters[0], "coo", 16, hw=hw)
    t_obs = np.median(sel._times[("inter", "coo", (0, 16))])
    assert t_est > 0 and 1e-3 < t_obs / t_est < 1e3

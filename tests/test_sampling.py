"""Mini-batch sampling subsystem: sampler determinism, fixed-shape padded
batches (the no-retrace contract), masked-loss equivalence to full-batch,
PlanCache hit/miss behavior, and the warn-once metis fallback that keeps
per-batch decomposition from warning every step."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import decompose as dec_mod, gnn, selector as sel_mod
from repro.graphs import graph as G
from repro.sampling import (ClusterSampler, NeighborSampler, PlanCache,
                            density_signature, fix_shapes)
from repro.train import gnn_steps


def small_graph(n=96, e=700, nf=5, nc=3, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, nf)).astype(np.float32)
    labels = rng.integers(0, nc, n).astype(np.int32)
    return G.Graph(n, src, dst, feats, labels, nc)


def batch_equal(a, b):
    return (np.array_equal(a.nodes, b.nodes)
            and np.array_equal(a.node_mask, b.node_mask)
            and np.array_equal(a.senders, b.senders)
            and np.array_equal(a.receivers, b.receivers)
            and np.array_equal(a.edge_mask, b.edge_mask)
            and np.array_equal(a.target_mask, b.target_mask)
            and np.allclose(a.features, b.features))


@pytest.mark.parametrize("make", [
    lambda g, s: ClusterSampler(g, block=8, clusters_per_batch=4,
                                method="bfs", seed=s),
    lambda g, s: NeighborSampler(g, batch_nodes=16, fanouts=(4, 2),
                                 method="bfs", block=8, seed=s),
])
def test_sampler_deterministic_under_fixed_seed(make):
    g = small_graph()
    s1, s2 = make(g, 7), make(g, 7)
    for _ in range(3):
        assert batch_equal(s1.sample(), s2.sample())
    # a different seed diverges (not a constant sampler); compare several
    # batches so a single coincidental collision cannot fail the test
    sa, sb = make(g, 7), make(g, 8)
    assert any(not batch_equal(sa.sample(), sb.sample()) for _ in range(3))
    b = s1.sample()
    assert b.n_real_edges == b.edge_mask.sum()
    real_s, real_r = b.real_edges()
    assert real_s.min(initial=0) >= 0
    assert b.node_mask[real_r].all() and b.node_mask[real_s].all()


def _shape_sig(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, [jax.ShapeDtypeStruct(np.shape(l), np.asarray(l).dtype)
                     for l in leaves]


def test_padded_shape_invariance_across_batches():
    """Every batch's fixed decomposition presents the same treedef and the
    same ShapeDtypeStructs — the precondition for a single jit trace."""
    g = small_graph(n=128, e=1200)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs")
    sampler = gnn_steps.make_sampler(g, cfg)
    budget = sampler.edge_budget + sampler.node_budget
    sigs = []
    for _ in range(4):
        dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
        assert len(dec.subgraphs) == 3          # intra + 2 pinned buckets
        fixed = fix_shapes(dec, budget)
        assert fixed.stats is None
        assert all(s.stats is None for s in fixed.subgraphs)
        sigs.append(_shape_sig(fixed))
    treedef0, leaves0 = sigs[0]
    for treedef, leaves in sigs[1:]:
        assert treedef == treedef0
        assert leaves == leaves0


def test_no_retrace_across_batches():
    g = small_graph(n=128, e=1200)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs")
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
    # one trace per distinct committed plan, none per batch
    assert res.n_traces == len(res.plans)
    assert res.n_traces <= 2
    assert len(res.losses) == 6 and np.isfinite(res.losses).all()


def test_masked_loss_matches_full_batch_when_sampling_whole_graph():
    """clusters_per_batch = n_clusters makes the 'mini'-batch the whole
    graph; the sampled masked loss must equal the full-batch loss."""
    g = small_graph(n=64, e=500)
    cfg = gnn.GNNConfig(model="gcn", comm_size=8, reorder="bfs",
                        inter_buckets=2, sampler="cluster",
                        clusters_per_batch=8)

    key = jax.random.PRNGKey(0)
    params = gnn.init_model(key, cfg, g.features.shape[-1], g.n_classes)

    # --- full-batch loss (core/gnn.py path)
    dec_full = gnn.prepare(g, cfg)
    x = gnn.adaptgear.to_reordered(dec_full, jnp.asarray(g.features))
    labels_r = np.zeros((dec_full.n_pad,), np.int32)
    labels_r[np.asarray(dec_full.perm)] = g.labels
    node_mask = np.zeros((dec_full.n_pad,), bool)
    node_mask[np.asarray(dec_full.perm)] = True
    plan_full = gnn.KernelPlan.make(
        dec_full, sel_mod.select_by_cost_model(dec_full, g.n_classes),
        n_layers=cfg.n_layers)
    loss_full = gnn._loss(params, cfg, dec_full, x, jnp.asarray(labels_r),
                          jnp.asarray(node_mask), plan_full, None)

    # --- sampled loss over the whole graph in one batch
    sampler = gnn_steps.make_sampler(g, cfg)
    batch = sampler.sample()
    assert batch.n_real_nodes == g.n and batch.meta["dropped_edges"] == 0
    dec_b, inv_deg = gnn_steps.prepare_batch(batch, cfg)
    cache = PlanCache(gnn.agg_width_pairs(cfg, g.features.shape[-1],
                                          g.n_classes))
    plan_b, hit = cache.plan_for(dec_b)
    assert not hit
    fixed = fix_shapes(dec_b, sampler.edge_budget + sampler.node_budget)
    loss_mb = gnn._loss(params, cfg, fixed, jnp.asarray(batch.features),
                        jnp.asarray(batch.labels),
                        jnp.asarray(batch.target_mask), plan_b,
                        jnp.asarray(inv_deg))
    # full-batch and mini-batch may commit different kernels (the MB
    # candidate set includes the fused CSR path), which sum edges in
    # different orders — equality holds to fp-reassociation noise
    np.testing.assert_allclose(float(loss_mb), float(loss_full),
                               atol=1e-4, rtol=1e-4)


def test_plan_cache_hit_miss_and_eviction():
    g = small_graph(n=128, e=1000)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs")
    sampler = gnn_steps.make_sampler(g, cfg)
    dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
    pairs = gnn.agg_width_pairs(cfg, g.features.shape[-1], g.n_classes)

    cache = PlanCache(pairs)
    plan1, hit1 = cache.plan_for(dec)
    plan2, hit2 = cache.plan_for(dec)
    assert not hit1 and hit2 and plan2 is plan1
    assert cache.stats == dict(hits=1, near_hits=0, misses=1, entries=1,
                               evictions=0, probes=0, hit_rate=0.5,
                               quarantined=0)
    # the memoized plan equals fresh selection (cache changes cost, not
    # outcome)
    assert cache.select(dec).layers == plan1.layers

    # near-hit: a batch straddling a quantization-cell boundary lands on
    # a new signature but matches the resident anchor within half a cell,
    # reusing the plan without re-selection (simulated by re-keying the
    # entry so the exact lookup misses while the anchor stays resident)
    near = PlanCache(pairs)
    plan_a, _ = near.plan_for(dec)
    entry = near._entries.pop(near.signature(dec))
    near._entries[("boundary-neighbor",)] = entry
    plan_b, hit = near.plan_for(dec)
    assert hit and plan_b is plan_a
    assert near.near_hits == 1 and near.misses == 1
    # and the flapping signature is now aliased: next lookup is exact
    _, hit = near.plan_for(dec)
    assert hit and near.hits == 1

    # a structurally different graph (much denser) misses
    g2 = small_graph(n=128, e=4000, seed=3)
    dec2, _ = gnn_steps.prepare_batch(
        gnn_steps.make_sampler(g2, cfg).sample(), cfg)
    assert cache.signature(dec2) != cache.signature(dec)
    _, hit3 = cache.plan_for(dec2)
    assert not hit3

    # LRU bound evicts the oldest signature (and counts the eviction)
    tiny = PlanCache(pairs, max_entries=1)
    tiny.plan_for(dec)
    tiny.plan_for(dec2)
    assert tiny.stats["entries"] == 1
    assert tiny.stats["evictions"] == 1
    _, hit = tiny.plan_for(dec)      # evicted -> miss again
    assert not hit
    assert tiny.stats["evictions"] == 2


def test_density_signature_quantizes():
    g = small_graph(n=128, e=1000)
    dec = dec_mod.decompose(g, comm_size=8, method="bfs", inter_buckets=2)
    sig = density_signature(dec)
    assert sig[0] == dec.n_pad and sig[1] == 8
    assert len(sig[2]) == len(dec.subgraphs)
    # coarse: identical decomposition -> identical signature
    assert sig == density_signature(
        dec_mod.decompose(g, comm_size=8, method="bfs", inter_buckets=2))
    for s in dec.subgraphs:
        assert 0.0 <= s.stats["brow_occupancy"] <= 1.0
        assert 0.0 < s.stats["col_occupancy"] <= 1.0 or not s.stats["nnz"]


def test_signature_col_occupancy_bin_distinguishes():
    """Two decompositions alike in nnz and block-row occupancy but unlike
    in column condensability must not share a signature (the tcgnn cost
    crossover lives exactly on that axis)."""
    import dataclasses as dc
    g = small_graph(n=128, e=1000)
    dec = dec_mod.decompose(g, comm_size=8, method="bfs", inter_buckets=2)

    def with_col_occ(d, v):
        subs = tuple(dc.replace(s, stats={**s.stats, "col_occupancy": v})
                     for s in d.subgraphs)
        return dc.replace(d, subgraphs=subs)

    lo, hi = with_col_occ(dec, 0.2), with_col_occ(dec, 0.9)
    assert density_signature(lo) != density_signature(hi)
    # and each tier key carries the 4th (column-occupancy) element
    assert all(len(t) == 4 for t in density_signature(dec)[2])


def test_legacy_signatures_keep_hitting(tmp_path):
    """Regression: entries minted before the column-occupancy bin (3-element
    per-tier signature keys, 3-tuple anchors — e.g. a persisted PlanCache
    snapshot) must keep serving their plans after the upgrade via the
    length-tolerant near-hit path, and the flapping key re-aliases."""
    g = small_graph(n=128, e=1000)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs")
    sampler = gnn_steps.make_sampler(g, cfg)
    dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
    pairs = gnn.agg_width_pairs(cfg, g.features.shape[-1], g.n_classes)

    cache = PlanCache(pairs)
    plan, _ = cache.plan_for(dec)

    # rewrite the minted entry into its pre-upgrade shape: strip the 4th
    # per-tier element from both the signature key and the anchor (this is
    # exactly what load()ing an old snapshot leaves resident)
    sig = cache.signature(dec)
    legacy_sig = sig[:2] + (tuple(t[:3] for t in sig[2]),)
    assert legacy_sig != sig
    _, anchor = cache._entries.pop(sig)
    legacy_anchor = (anchor[0], tuple(t[:3] for t in anchor[1]))
    cache._entries[legacy_sig] = (plan, legacy_anchor)

    # save/load round-trips the legacy-shaped entry verbatim
    path = str(tmp_path / "plans.bin")
    cache.save(path)
    fresh = PlanCache(pairs)
    assert fresh.load(path)

    m0 = fresh.misses                # counters ride the snapshot
    got, hit = fresh.plan_for(dec)
    assert hit and got.layers == plan.layers
    assert fresh.near_hits == 1 and fresh.misses == m0
    # the new-format signature is aliased now: next lookup is exact
    _, hit = fresh.plan_for(dec)
    assert hit and fresh.hits == 1


def test_keep_empty_buckets_pins_tier_count():
    # a graph whose inter edges cannot fill 4 occupancy tiers
    g = small_graph(n=32, e=40)
    dec = dec_mod.decompose(g, comm_size=8, method="bfs", inter_buckets=4,
                            keep_empty_buckets=True)
    assert len(dec.subgraphs) == 5
    dec_drop = dec_mod.decompose(g, comm_size=8, method="bfs",
                                 inter_buckets=4)
    assert len(dec_drop.subgraphs) <= len(dec.subgraphs)
    assert sum(s.stats["nnz"] for s in dec.subgraphs) == g.n_edges


def test_metis_fallback_warns_once_per_process():
    """Per-batch decomposition must not re-warn every step."""
    g = small_graph(n=48, e=200)
    dec_mod._warned_substitutions.discard("metis")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dec_mod.decompose(g, comm_size=8, method="metis")
        dec_mod.decompose(g, comm_size=8, method="metis")
    ours = [x for x in w if "substituting" in str(x.message)]
    assert len(ours) == 1
    assert dec_mod.decompose(g, comm_size=8,
                             method="metis").stats["effective_method"] == \
        "louvain"


def test_no_duplicate_draws_across_epoch_boundaries():
    """Batches straddling an epoch refill must not contain a duplicate
    cluster/seed (duplicated nodes would double-count in the masked loss,
    duplicated seeds would emit their sampled edges twice)."""
    g = small_graph(n=96, e=800)
    s = ClusterSampler(g, block=8, clusters_per_batch=5, method="bfs",
                       seed=0)           # 12 clusters: boundary every 3rd
    for _ in range(8):
        b = s.sample()
        real = b.nodes[b.node_mask]
        assert len(np.unique(real)) == len(real)
    ns = NeighborSampler(g, batch_nodes=40, fanouts=(3,), method="bfs",
                         block=8, seed=0)  # 96 nodes: boundary every 3rd
    for _ in range(8):
        b = ns.sample()
        real = b.nodes[b.node_mask]
        assert len(np.unique(real)) == len(real)
        es, er = b.real_edges()
        eid = es.astype(np.int64) * b.n + er
        assert len(np.unique(eid)) == len(eid)


def test_neighbor_sampler_targets_only_seeds():
    g = small_graph(n=128, e=1500)
    s = NeighborSampler(g, batch_nodes=16, fanouts=(4,), method="bfs",
                        block=8, seed=0)
    b = s.sample()
    assert b.target_mask.sum() == 16
    assert (b.target_mask & ~b.node_mask).sum() == 0
    # every real edge's destination aggregates toward the batch
    _, r = b.real_edges()
    assert b.node_mask[r].all()
    # budgets honored
    assert len(b.senders) == s.edge_budget
    assert len(b.nodes) == s.node_budget


def test_neighbor_budgets_clamped_to_graph():
    """Worst-case fanout budgets must not pad batches past the graph."""
    g = small_graph(n=96, e=700)
    s = NeighborSampler(g, batch_nodes=64, fanouts=(8, 4), method="bfs",
                        block=8, seed=0)
    assert s.node_budget <= -(-g.n // 8) * 8
    assert s.edge_budget <= g.n_edges
    b = s.sample()
    assert b.n_real_nodes <= s.node_budget
    assert b.n_real_edges <= s.edge_budget


def dense_community_graph(nb=4, B=64, inter_draws=100, intra_draws=6,
                          seed=0, nf=16, nc=4):
    """Fully-connected dense communities: every off-diagonal (B,B) block is
    ~80% dense — the blocked-ELL regime (few stored blocks, each nearly
    full, so the MXU path beats gather/scatter on any sampled pair)."""
    n = nb * B
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    for i in range(nb):
        s = rng.integers(0, B, intra_draws * B)
        d = rng.integers(0, B, intra_draws * B)
        src_l.append(i * B + s)
        dst_l.append(i * B + d)
        for j in range(nb):
            if i == j:
                continue
            s = rng.integers(0, B, inter_draws * B)
            d = rng.integers(0, B, inter_draws * B)
            src_l.append(j * B + s)
            dst_l.append(i * B + d)
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    eid = src.astype(np.int64) * n + dst
    _, keep = np.unique(eid, return_index=True)
    src, dst = src[keep].astype(np.int32), dst[keep].astype(np.int32)
    feats = rng.standard_normal((n, nf)).astype(np.float32)
    labels = rng.integers(0, nc, n).astype(np.int32)
    return G.Graph(n, src, dst, feats, labels, nc)


def test_cost_model_selects_bell_on_dense_inter_profile():
    """Acceptance bar for the budget-padded blocked-ELL: on a sampled
    batch whose inter tiers are dense block neighborhoods, the cost model
    must commit bell (unfused, GIN) / bell_fused (transform-first, GCN)
    for inter tiers, and the jitted step must compile exactly once across
    batches with them dispatched."""
    g = dense_community_graph()
    for model, kernel in (("gin", "bell"), ("gcn", "bell_fused")):
        cfg = gnn.GNNConfig(model=model, sampler="cluster", comm_size=64,
                            clusters_per_batch=2, reorder="bfs",
                            inter_buckets=2)
        res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
        used = {k for plan in res.plans for layer in plan for k in layer}
        assert kernel in used, (model, res.plans)
        assert res.n_traces == 1            # one compile, bell dispatched
        assert np.isfinite(res.losses).all()


def test_fix_shapes_preserves_signature_bins():
    """fix_shapes used to scrub *all* stats; with ``stats=`` it stamps the
    plan's quantized signature bins on the fixed Decomposed (per-subgraph
    dicts stay scrubbed — their bins live in the signature tuple)."""
    g = small_graph(n=128, e=1200)
    cfg = gnn.GNNConfig(model="gcn", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs")
    sampler = gnn_steps.make_sampler(g, cfg)
    budget = sampler.edge_budget + sampler.node_budget
    dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
    sig = density_signature(dec)
    fixed = fix_shapes(dec, budget, stats=sig)
    assert fixed.stats == sig
    assert hash(fixed.stats) is not None     # static jit metadata: hashable
    assert all(s.stats is None for s in fixed.subgraphs)
    # default stays the full scrub
    assert fix_shapes(dec, budget).stats is None
    # and the training loop stamps one canonical signature per step fn
    res = gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=1)
    assert res.n_traces == len(res.plans)


def test_plan_cache_probe_on_nth_miss():
    """Every Nth miss wall-clocks the top-2 cost-model candidates and pins
    the measured winner (the full-batch probe machinery, amortized through
    the cache)."""
    g = small_graph(n=96, e=700)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, inter_buckets=2,
                        reorder="bfs")
    sampler = gnn_steps.make_sampler(g, cfg)
    dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
    pairs = gnn.agg_width_pairs(cfg, g.features.shape[-1], g.n_classes)

    probing = PlanCache(pairs, probe_every=1)
    plan, hit = probing.plan_for(dec)
    assert not hit and probing.stats["probes"] == 1
    # the pinned plan is a valid registry plan over this decomposition
    assert len(plan.layers) == len(pairs)
    for layer in plan.layers:
        assert len(layer) == len(dec.subgraphs)
    # second lookup reuses the pinned entry, no new probe
    plan2, hit2 = probing.plan_for(dec)
    assert hit2 and plan2 is plan and probing.stats["probes"] == 1

    # probe_every=0 (default) never probes
    cold = PlanCache(pairs)
    cold.plan_for(dec)
    assert cold.stats["probes"] == 0


def test_minibatch_fixed_selector_is_honored():
    g = small_graph(n=96, e=700)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, reorder="bfs",
                        selector="fixed",
                        fixed_kernels=("block_diag", "coo"))
    res = gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=1)
    n_sub = 2  # intra + 1 inter bucket (cfg.inter_buckets=1)
    expect = ("block_diag",) + ("coo",) * (n_sub - 1)
    assert res.plans == [(expect,) * cfg.n_layers]
    assert res.cache["misses"] == 0          # no selection ran
    assert all(res.hit_history)

"""Import hypothesis when available; otherwise provide stand-ins so modules
still collect and their non-property tests run.  A ``@given``-decorated test
becomes a skip instead of an import-time crash on machines without the
dependency."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # clean machine: property tests skip
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.<anything>(...) placeholder; never drawn from."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg replacement: the strategy kwargs must not be
            # mistaken for pytest fixtures
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

"""Fault-tolerant GNN training (distributed/{checkpoint,fault_tolerance}
wired into train_minibatch): crash-safe checkpoint/resume bit-identical to
the uninterrupted run, transient-failure retry in the pipeline, kernel
quarantine with graceful degradation to the XLA floor, the non-finite
loss/grad guard, and the deterministic FaultPlan injection harness that
drives all of it."""
import dataclasses
import pickle
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import gnn
from repro.distributed import fault_tolerance as ft
from repro.graphs import graph as G  # noqa: F401  (re-exported helpers)
from repro.train import gnn_steps
from repro.train.pipeline import BatchPipeline

from test_pipeline import small_graph, pipeline_threads
from test_sampling import dense_community_graph


def base_cfg(**kw):
    d = dict(model="gcn", n_layers=2, hidden=8, comm_size=8,
             sampler="cluster", clusters_per_batch=2,
             selector="cost_model", seed=7)
    d.update(kw)
    return gnn.GNNConfig(**d)


def bell_cfg(**kw):
    """Dense-community config whose cost model commits the Pallas bell
    kernel — the quarantine target."""
    d = dict(model="gin", sampler="cluster", comm_size=64,
             clusters_per_batch=2, reorder="bfs", inter_buckets=2)
    d.update(kw)
    return gnn.GNNConfig(**d)


def run_result_equal(a, b):
    assert a.losses == b.losses
    assert a.hit_history == b.hit_history
    assert a.plans == b.plans


# -- crash-safe checkpoint / resume ------------------------------------------

@pytest.mark.parametrize("prefetch", [0, 3], ids=["sync", "async"])
def test_crash_resume_bit_identical(prefetch):
    """Inject a crash mid-epoch, resume from the checkpoint directory, and
    demand the full loss curve, hit history, committed plans, and cache
    counters match the uninterrupted run exactly — the ISSUE 7 acceptance
    bar.  (n_traces is NOT compared: restored plans re-trace lazily on the
    resumed side.)"""
    g = small_graph(n=160, e=1400)
    cfg = base_cfg(prefetch_depth=prefetch,
                   pipeline_workers=2 if prefetch else 0)
    ref = gnn_steps.train_minibatch(g, cfg, steps=10, eval_batches=2)
    with tempfile.TemporaryDirectory() as d:
        ck = dataclasses.replace(cfg, checkpoint_dir=d, checkpoint_every=3)
        fp = ft.FaultPlan(crash_at=7)
        with pytest.raises(ft.SimulatedCrash):
            gnn_steps.train_minibatch(g, ck, steps=10, eval_batches=0,
                                      fault_plan=fp)
        assert not pipeline_threads()   # the crash didn't leak workers
        res = gnn_steps.train_minibatch(
            g, dataclasses.replace(ck, resume_from=d), steps=10,
            eval_batches=2)
    # crash at batch 7 -> last snapshot is the one after batch 6 % 3 == 0
    assert res.faults["resumed_at"] == 6
    run_result_equal(res, ref)
    assert res.cache["hits"] == ref.cache["hits"]
    assert res.cache["misses"] == ref.cache["misses"]
    assert res.cache["near_hits"] == ref.cache["near_hits"]
    assert res.accuracy == ref.accuracy


def test_resume_at_checkpoint_free_index_replays_everything():
    # crash before the first checkpoint lands: resume warns and replays
    # from scratch — which IS the bit-identical resume for that cursor
    g = small_graph()
    cfg = base_cfg()
    ref = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
    with tempfile.TemporaryDirectory() as d:
        ck = dataclasses.replace(cfg, checkpoint_dir=d, checkpoint_every=4,
                                 resume_from=d)
        fp = ft.FaultPlan(crash_at=2)
        with pytest.raises(ft.SimulatedCrash):
            gnn_steps.train_minibatch(g, dataclasses.replace(
                ck, resume_from=""), steps=6, eval_batches=0, fault_plan=fp)
        with pytest.warns(UserWarning, match="no valid checkpoint"):
            res = gnn_steps.train_minibatch(g, ck, steps=6, eval_batches=1)
    assert res.faults["resumed_at"] == -1
    run_result_equal(res, ref)


def test_checkpoint_counters_and_cursor():
    g = small_graph()
    with tempfile.TemporaryDirectory() as d:
        cfg = base_cfg(checkpoint_dir=d, checkpoint_every=2)
        res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=0)
        assert res.faults["checkpoints"] == 3    # after batches 1, 3, 5
        from repro.distributed import checkpoint as ckpt_mod
        mgr = ckpt_mod.CheckpointManager(d)
        assert mgr.latest_valid_step() == 6
        aux = mgr.load_aux()
        assert aux["cursor"] == 6
        assert aux["losses"] == res.losses
        assert aux["hit_history"] == res.hit_history
        assert [p.layers for p in aux["plans"]] == res.plans


# -- transient retry ----------------------------------------------------------

def test_transient_worker_faults_retried_bit_identically():
    """Two injected transient faults on one batch: the pipeline absorbs
    them with backoff and the training outcome is indistinguishable from
    the fault-free run (injection precedes the skeleton build, so caches
    never see the aborted attempts)."""
    g = small_graph(n=160, e=1400)
    cfg = base_cfg(prefetch_depth=3, pipeline_workers=2)
    ref = gnn_steps.train_minibatch(g, cfg, steps=8, eval_batches=1)
    fcfg = dataclasses.replace(cfg, retry_max=3, retry_base_delay_s=0.0)
    fp = ft.FaultPlan(worker_faults={2: 2})
    res = gnn_steps.train_minibatch(g, fcfg, steps=8, eval_batches=1,
                                    fault_plan=fp)
    assert res.faults["retries"] == 2
    assert fp.injected_worker == 2
    assert res.pipeline["retries"] == 2      # surfaced for bench JSON
    run_result_equal(res, ref)


def test_retries_exhausted_propagates_the_fault():
    g = small_graph()
    cfg = base_cfg(prefetch_depth=2, pipeline_workers=2, retry_max=2,
                   retry_base_delay_s=0.0)
    fp = ft.FaultPlan(worker_faults={1: 5})  # more faults than retries
    with pytest.raises(ft.InjectedWorkerFault):
        gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=0,
                                  fault_plan=fp)
    assert not pipeline_threads()


def test_fatal_fault_fails_fast_despite_retry_budget():
    g = small_graph()
    cfg = base_cfg(prefetch_depth=2, pipeline_workers=2, retry_max=5,
                   retry_base_delay_s=10.0)   # a retry would hang the test
    fp = ft.FaultPlan(fatal_at={1})
    t0 = time.perf_counter()
    with pytest.raises(ValueError, match="fatal"):
        gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=0,
                                  fault_plan=fp)
    assert time.perf_counter() - t0 < 5.0    # no backoff ladder was paid
    assert fp.injected_fatal == 1
    assert not pipeline_threads()


def test_sync_path_retries_too():
    g = small_graph()
    cfg = base_cfg(retry_max=3, retry_base_delay_s=0.0)
    ref = gnn_steps.train_minibatch(g, base_cfg(), steps=6, eval_batches=1)
    fp = ft.FaultPlan(worker_faults={0: 1, 3: 1})
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1,
                                    fault_plan=fp)
    assert res.faults["retries"] == 2
    run_result_equal(res, ref)


def test_shutdown_under_retry_joins_promptly():
    """close() mid-backoff must interrupt the retry ladder, not sleep it
    out: the cancel event doubles as the backoff timer."""
    def work(idx, ticket):
        raise ft.TransientError(f"flaky {idx}")

    counter = iter(range(100))
    pipe = BatchPipeline(lambda: next(counter), work, n_items=8,
                         prefetch_depth=2, workers=2,
                         retry=ft.RetryPolicy(max_retries=50,
                                              base_delay_s=30.0),
                         retryable=ft.default_transient)
    time.sleep(0.1)          # let workers enter their first backoff
    t0 = time.perf_counter()
    pipe.close()
    assert time.perf_counter() - t0 < 5.0
    assert not pipeline_threads()


# -- kernel quarantine --------------------------------------------------------

@pytest.mark.parametrize("mode", ["compile", "execute"])
def test_kernel_fault_quarantines_and_degrades(mode):
    """A Pallas kernel that fails to compile (or execute) is quarantined
    for its signature and the cache re-selects next-best; training
    completes, every loss is finite, and the no-retrace contract holds —
    the failed plan's single trace is memoized, never repeated."""
    g = dense_community_graph()
    cfg = bell_cfg()
    ref = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=0)
    used = {k for plan in ref.plans for layer in plan for k in layer}
    assert "bell" in used                    # the fault target is selected
    fp = ft.FaultPlan(kernel_faults={"bell": mode})
    with fp.activate():
        res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1,
                                        fault_plan=fp)
    assert fp.kernel_trips >= 1
    assert res.faults["quarantined"] >= 1
    assert res.faults["recoveries"] >= 1
    assert res.cache["quarantined"] >= 1
    assert len(res.losses) == 6 and np.isfinite(res.losses).all()
    assert res.n_traces == len(res.plans)
    # post-recovery batches never dispatch the broken kernel again
    later = {k for plan in res.plans[1:] for layer in plan for k in layer}
    assert "bell" not in later


def test_kernel_fault_async_pipeline_degrades():
    g = dense_community_graph()
    cfg = bell_cfg(prefetch_depth=3, pipeline_workers=2)
    fp = ft.FaultPlan(kernel_faults={"bell": "compile"})
    with fp.activate():
        res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1,
                                        fault_plan=fp)
    assert res.faults["recoveries"] >= 1
    assert len(res.losses) == 6 and np.isfinite(res.losses).all()
    assert res.n_traces == len(res.plans)
    assert res.pipeline["quarantined"] == res.faults["quarantined"]
    assert not pipeline_threads()


def test_unattributable_failure_reraises():
    """Failures that implicate no Pallas kernel must NOT degrade — real
    bugs fail fast.  A fault injected into a config whose plans are
    all-XLA (csr_fused on the sparse small graph) never trips, and a
    synthetic non-kernel error in the step propagates."""
    g = small_graph()
    cfg = base_cfg()
    ref = gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=0)
    used = {k for plan in ref.plans for layer in plan for k in layer}
    assert all(not k.startswith(("block_diag", "bell")) for k in used)
    fp = ft.FaultPlan(kernel_faults={"bell": "compile"})
    with fp.activate():   # patched but never dispatched -> no-op
        res = gnn_steps.train_minibatch(g, cfg, steps=4, eval_batches=0,
                                        fault_plan=fp)
    assert res.faults["quarantined"] == 0
    assert res.losses == ref.losses


# -- PlanCache quarantine bookkeeping ----------------------------------------

def test_plan_cache_quarantine_purges_and_excludes():
    g = dense_community_graph()
    res = gnn_steps.train_minibatch(g, bell_cfg(), steps=6, eval_batches=0)
    cache = res.plan_cache
    sd = cache.state_dict()
    assert sd["entries"], "training should have cached at least one plan"
    sig, plan, _anchor = sd["entries"][0]
    used = {k for layer in plan.layers for k in layer}
    assert "bell" in used
    n_before = len(sd["entries"])
    fresh = cache.quarantine(sig, {"bell", "coo"})
    assert fresh == {"bell"}                 # the XLA floor is untouchable
    assert cache.quarantined_for(sig) == {"bell"}
    assert len(cache.state_dict()["entries"]) == n_before - 1  # purged
    assert cache.quarantine(sig, {"bell"}) == set()   # idempotent
    assert cache.stats["quarantined"] == 1


def test_plan_cache_state_dict_roundtrip_is_stable():
    g = small_graph(n=160, e=1400)
    res = gnn_steps.train_minibatch(g, base_cfg(), steps=8, eval_batches=0)
    cache = res.plan_cache
    sd1 = cache.state_dict()
    blob = pickle.dumps(sd1)                 # must survive the aux pickle
    cache.load_state_dict(pickle.loads(blob))
    sd2 = cache.state_dict()
    assert sd1 == sd2
    assert cache.stats["hits"] == res.cache["hits"]


# -- non-finite guard ---------------------------------------------------------

def test_nonfinite_guard_skips_and_counts():
    """A NaN batch contributes a NaN loss sample but no parameter update:
    training after the poisoned batch continues from the pre-batch params
    and every later loss is finite."""
    g = small_graph()
    cfg = base_cfg()
    ref = gnn_steps.train_minibatch(g, cfg, steps=8, eval_batches=1)
    fp = ft.FaultPlan(nonfinite_at=[3])
    res = gnn_steps.train_minibatch(g, cfg, steps=8, eval_batches=1,
                                    fault_plan=fp)
    assert fp.injected_nonfinite == 1
    assert res.faults["nonfinite_skips"] == 1
    assert res.losses[:3] == ref.losses[:3]
    assert not np.isfinite(res.losses[3])
    assert np.isfinite(res.losses[4:]).all()


def test_nonfinite_without_guard_poisons_params():
    g = small_graph()
    cfg = base_cfg(nonfinite_guard=False)
    fp = ft.FaultPlan(nonfinite_at=[2])
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=0,
                                    fault_plan=fp)
    assert res.faults["nonfinite_skips"] == 0
    # NaN grads flowed into Adam: everything after the hit is NaN
    assert not np.isfinite(res.losses[2:]).any()


# -- FaultPlan harness --------------------------------------------------------

def test_fault_plan_is_reusable_state_machine():
    fp = ft.FaultPlan(worker_faults={4: 2}, nonfinite_at=[1])
    batch = None
    with pytest.raises(ft.InjectedWorkerFault):
        fp.on_built(4, batch)
    with pytest.raises(ft.InjectedWorkerFault):
        fp.on_built(4, batch)
    assert fp.on_built(4, batch) is batch    # budget spent -> clean
    assert fp.injected_worker == 2
    fp.on_committed(3)                       # no crash configured
    assert fp.injected_fatal == 0


def test_fault_kernel_attribution_walks_cause_chain():
    inner = ft.KernelFault("__fault_kernel__:bell injected")
    try:
        try:
            raise inner
        except ft.KernelFault as k:
            raise RuntimeError("jit wrapped") from k
    except RuntimeError as outer:
        assert ft.fault_kernel_from(outer) == "bell"
    assert ft.fault_kernel_from(RuntimeError("unrelated")) is None

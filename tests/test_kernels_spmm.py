"""Per-kernel shape/dtype sweeps: Pallas kernels (interpret mode) vs the
pure-jnp ref.py oracles, forward and backward."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import formats
from repro.kernels import ops, ref
from repro.kernels.block_diag_spmm import block_diag_spmm
from repro.kernels.bell_spmm import bell_spmm


DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dt):
    return dict(atol=1e-4, rtol=1e-4) if dt == jnp.float32 else \
        dict(atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("nb,B,F", [(1, 8, 16), (4, 16, 64), (7, 32, 128),
                                    (2, 128, 256), (3, 8, 512)])
@pytest.mark.parametrize("dt", DTYPES)
def test_block_diag_sweep(rng, nb, B, F, dt):
    blocks = jnp.asarray(rng.standard_normal((nb, B, B)), dt)
    x = jnp.asarray(rng.standard_normal((nb * B, F)), dt)
    ft = min(128, F)
    y = block_diag_spmm(blocks, x, f_tile=ft, interpret=True)
    y_ref = ref.block_diag_spmm(blocks, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol(dt))


@pytest.mark.parametrize("nbr,K,B,F", [(2, 1, 8, 16), (4, 3, 16, 64),
                                       (3, 5, 32, 128), (2, 2, 128, 256)])
@pytest.mark.parametrize("dt", DTYPES)
def test_bell_sweep(rng, nbr, K, B, F, dt):
    nbc = nbr + 2
    blocks = jnp.asarray(rng.standard_normal((nbr, K, B, B)), dt)
    col_idx = jnp.asarray(rng.integers(0, nbc, (nbr, K)), jnp.int32)
    x = jnp.asarray(rng.standard_normal((nbc * B, F)), dt)
    ft = min(128, F)
    y = bell_spmm(blocks, col_idx, x, f_tile=ft, interpret=True)
    y_ref = ref.bell_spmm(blocks, col_idx, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol(dt))


def test_block_diag_grad(rng):
    nb, B, F = 3, 16, 32
    blocks = jnp.asarray(rng.standard_normal((nb, B, B)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((nb * B, F)), jnp.float32)
    g = jax.grad(lambda x: (ops.block_diag_matvec(blocks, x) ** 2).sum())(x)
    g_ref = jax.grad(lambda x: (ref.block_diag_spmm(blocks, x) ** 2).sum())(x)
    np.testing.assert_allclose(g, g_ref, atol=1e-3, rtol=1e-3)


def test_bell_grad(rng):
    n, B = 64, 8
    r = rng.integers(0, n, 150).astype(np.int32)
    c = rng.integers(0, n, 150).astype(np.int32)
    v = rng.standard_normal(150).astype(np.float32)
    coo = formats.coo_from_edges(n, n, r, c, v)
    coo_t = formats.coo_from_edges(n, n, c, r, v)
    bell = formats.coo_to_bell(coo, B)
    bell_t = formats.coo_to_bell(coo_t, B)
    x = jnp.asarray(rng.standard_normal((bell.n_cols, 24)), jnp.float32)
    g = jax.grad(lambda x: (ops.bell_matvec(bell, bell_t, x) ** 2).sum())(x)
    g_ref = jax.grad(
        lambda x: (ref.bell_spmm(bell.blocks, bell.col_idx, x) ** 2).sum())(x)
    np.testing.assert_allclose(g, g_ref, atol=1e-3, rtol=1e-3)


def test_odd_feature_padding(rng):
    """ops wrappers must handle non-128-multiple feature dims."""
    nb, B = 2, 16
    blocks = jnp.asarray(rng.standard_normal((nb, B, B)), jnp.float32)
    for F in (1, 29, 100, 130, 500):
        x = jnp.asarray(rng.standard_normal((nb * B, F)), jnp.float32)
        y = ops.block_diag_matvec(blocks, x)
        assert y.shape == (nb * B, F)
        np.testing.assert_allclose(y, ref.block_diag_spmm(blocks, x),
                                   atol=1e-4, rtol=1e-4)


def test_coo_segment_matches_dense(rng):
    n = 50
    r = rng.integers(0, n, 120).astype(np.int32)
    c = rng.integers(0, n, 120).astype(np.int32)
    v = rng.standard_normal(120).astype(np.float32)
    coo = formats.coo_from_edges(n, n, r, c, v)
    x = jnp.asarray(rng.standard_normal((n, 13)), jnp.float32)
    y = ops.coo_matvec(coo, x)
    y_ref = ref.coo_spmm_dense_ref(coo.rows, coo.cols, coo.vals, x, n)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)

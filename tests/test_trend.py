"""benchmarks/trend.py: history accumulation semantics + markdown render."""
from benchmarks import trend


ROWS_A = {"bench/row1": 100.0, "bench/row2": 50.0}
ROWS_B = {"bench/row1": 130.0, "bench/row3": 10.0}


def test_accumulate_appends_replaces_and_caps():
    h = trend.accumulate({"entries": []}, "aaa", ROWS_A, now=0)
    h = trend.accumulate(h, "bbb", ROWS_B, now=1)
    assert [e["commit"] for e in h["entries"]] == ["aaa", "bbb"]
    # a CI re-run of an old commit replaces its entry IN PLACE: the
    # chronology (and thus the delta columns) must not reorder
    h = trend.accumulate(h, "aaa", {"bench/row1": 90.0}, now=2)
    assert [e["commit"] for e in h["entries"]] == ["aaa", "bbb"]
    assert h["entries"][0]["rows"] == {"bench/row1": 90.0}
    # cap keeps the newest
    h = trend.accumulate(h, "ccc", ROWS_A, max_entries=2, now=3)
    assert [e["commit"] for e in h["entries"]] == ["bbb", "ccc"]
    # non-finite rows dropped
    h2 = trend.accumulate({"entries": []}, "x",
                          {"ok": 1.0, "bad": float("nan")}, now=0)
    assert set(h2["entries"][0]["rows"]) == {"ok"}


def test_markdown_table_shows_delta_and_missing_rows():
    h = trend.accumulate({"entries": []}, "aaa1aaa1a", ROWS_A, now=0)
    h = trend.accumulate(h, "bbb2bbb2b", ROWS_B, now=1)
    md = trend.markdown_table(h)
    assert "| aaa1aaa1a | bbb2bbb2b |" in md
    assert "| bench/row1 | 100 | 130 (+30%) |" in md
    assert "| bench/row2 | 50 | - |" in md       # gone in newest commit
    assert "| bench/row3 | - | 10 |" in md       # new in newest commit
    assert trend.markdown_table({"entries": []}).startswith("(no perf")

"""Mamba selective-scan Pallas kernel vs the sequential oracle."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.mamba_scan import mamba_scan


def make_inputs(rng, B, T, di, ds, dt_scale=0.1):
    x = jnp.asarray(rng.standard_normal((B, T, di)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, T, di))) * dt_scale,
                     jnp.float32)
    Bc = jnp.asarray(rng.standard_normal((B, T, ds)), jnp.float32)
    Cc = jnp.asarray(rng.standard_normal((B, T, ds)), jnp.float32)
    A = -jnp.asarray(np.abs(rng.standard_normal((di, ds))) + 0.1, jnp.float32)
    D = jnp.asarray(rng.standard_normal((di,)), jnp.float32)
    return x, dt, Bc, Cc, A, D


@pytest.mark.parametrize("B,T,di,ds,chunk,d_tile", [
    (1, 16, 8, 2, 8, 8), (2, 64, 32, 4, 16, 16), (1, 128, 64, 8, 32, 32),
    (2, 32, 16, 16, 32, 8),
])
def test_mamba_scan_vs_oracle(rng, B, T, di, ds, chunk, d_tile):
    x, dt, Bc, Cc, A, D = make_inputs(rng, B, T, di, ds)
    y = mamba_scan(x, dt, Bc, Cc, A, D, chunk=chunk, d_tile=d_tile,
                   interpret=True)
    y_ref = ref.mamba_ssm(x, dt, A, Bc, Cc, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(t_chunks=st.integers(1, 4), chunk=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_property_mamba_chunk_invariance(t_chunks, chunk, seed):
    rng = np.random.default_rng(seed)
    T = t_chunks * 16
    x, dt, Bc, Cc, A, D = make_inputs(rng, 1, T, 8, 4)
    y_ref = ref.mamba_ssm(x, dt, A, Bc, Cc, D)
    for c in (8, 16):
        if T % c:
            continue
        y = mamba_scan(x, dt, Bc, Cc, A, D, chunk=c, d_tile=8,
                       interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)


def test_model_pallas_core_matches_xla(rng):
    """mamba_core='pallas' through a jamba block == baseline xla scan."""
    import dataclasses
    import jax
    from repro import configs
    from repro.models import lm
    cfg0 = configs.get_config("jamba_v0_1_52b", reduced=True)
    toks = jnp.asarray(rng.integers(0, cfg0.vocab, (2, 16)), jnp.int32)
    batch = dict(tokens=toks, labels=jnp.roll(toks, -1, 1))
    p = lm.init_params(jax.random.PRNGKey(0), cfg0)
    outs = {}
    for core in ("xla", "pallas"):
        cfg = dataclasses.replace(cfg0, mamba_core=core)
        loss, _ = lm.loss_fn(p, cfg, batch)
        outs[core] = float(loss)
    assert abs(outs["xla"] - outs["pallas"]) < 1e-4, outs

"""Decomposition invariants (paper §3.3): the intra/inter split is a
partition of the edges; intra edges live on diagonal blocks; the reorder is
a permutation; aggregate(decomposed) == aggregate(original) — for any
number of inter density buckets."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adaptgear, decompose
from repro.graphs import graph as G
from repro.kernels.registry import REGISTRY


@pytest.fixture
def g():
    return G.synth_dataset("cora", scale=0.2, seed=0)


@pytest.mark.parametrize("method", ["bfs", "louvain"])
def test_perm_is_permutation(g, method):
    dec = decompose.decompose(g, comm_size=16, method=method)
    perm = np.asarray(dec.perm)
    assert sorted(perm.tolist()) == list(range(g.n))
    inv = np.asarray(dec.inv_perm)
    assert np.array_equal(perm[inv], np.arange(g.n))


@pytest.mark.parametrize("k", [1, 2, 4])
def test_edge_partition_complete(g, k):
    dec = decompose.decompose(g, comm_size=16, method="bfs", inter_buckets=k)
    s = dec.stats
    assert s["intra_edges"] + s["inter_edges"] == g.n_edges
    # every subgraph's nnz sums back to the edge count
    assert sum(sub.stats["nnz"] for sub in dec.subgraphs) == g.n_edges
    B = dec.block_size
    # intra edges on the diagonal blocks
    r = np.asarray(dec.intra.formats["coo"].rows)
    c = np.asarray(dec.intra.formats["coo"].cols)
    assert np.all(r // B == c // B)
    # inter edges strictly off the diagonal blocks, in every bucket
    for sub in dec.inters:
        r = np.asarray(sub.formats["coo"].rows)
        c = np.asarray(sub.formats["coo"].cols)
        assert np.all(r // B != c // B)


def test_inter_buckets_split_by_block_row_density(g):
    dec = decompose.decompose(g, comm_size=16, method="bfs", inter_buckets=2)
    assert len(dec.inters) == 2
    B = dec.block_size

    def mean_row_nnz(sub):
        rows = np.asarray(sub.formats["coo"].rows)
        nnz = np.bincount(rows // B, minlength=dec.n_pad // B)
        return nnz[nnz > 0].mean()

    # buckets are ordered sparsest -> densest by block-row occupancy
    assert mean_row_nnz(dec.inters[0]) < mean_row_nnz(dec.inters[1])


def test_aggregate_equals_undecomposed(g, rng):
    dec = decompose.decompose(g, comm_size=16, method="bfs")
    x = rng.standard_normal((g.n, 11)).astype(np.float32)
    xr = adaptgear.to_reordered(dec, jnp.asarray(x))
    y = adaptgear.aggregate(dec, xr, ("block_diag", "bell"))
    y = adaptgear.from_reordered(dec, y)
    # direct segment-sum on the original (unreordered) graph
    msgs = x[g.senders]
    y_ref = np.zeros((g.n, 11), np.float32)
    np.add.at(y_ref, g.receivers, msgs)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)


def test_reorder_improves_intra_density():
    src, dst = G.community_graph(512, 4096, comm_size=16, intra_frac=0.8, seed=0)
    g = G.Graph(512, src, dst, np.zeros((512, 4), np.float32),
                np.zeros(512, np.int32), 2)
    dec_no = decompose.decompose(g, comm_size=16, reorder=False)
    dec_yes = decompose.decompose(g, comm_size=16, method="louvain")
    frac_no = dec_no.stats["intra_edges"] / g.n_edges
    frac_yes = dec_yes.stats["intra_edges"] / g.n_edges
    assert frac_yes > frac_no, (frac_yes, frac_no)


def test_metis_substitution_warns_and_records(g):
    decompose._warned_substitutions.clear()
    with pytest.warns(UserWarning, match="metis"):
        dec = decompose.decompose(g, comm_size=16, method="metis")
    assert dec.stats["method"] == "metis"
    assert dec.stats["effective_method"] == "louvain"
    # one-time: a second call stays silent
    import warnings as _w
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        decompose.decompose(g, comm_size=16, method="metis")
    assert not [w for w in caught if "metis" in str(w.message)]


@settings(max_examples=15, deadline=None)
@given(n=st.integers(32, 200), e=st.integers(32, 600),
       b=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_property_decompose_preserves_spmm(n, e, b, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    g = G.Graph(n, src, dst, np.zeros((n, 3), np.float32),
                np.zeros(n, np.int32), 2)
    dec = decompose.decompose(g, comm_size=b, method="bfs",
                              inter_buckets=int(seed) % 3 + 1)
    x = rng.standard_normal((n, 3)).astype(np.float32)
    xr = adaptgear.to_reordered(dec, jnp.asarray(x))
    for ik in REGISTRY.candidates("diag"):
        for ek in REGISTRY.candidates("offdiag"):
            y = adaptgear.from_reordered(
                dec, adaptgear.aggregate(dec, xr, (ik.name, ek.name)))
            y_ref = np.zeros((n, 3), np.float32)
            np.add.at(y_ref, dst, x[src])
            np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3,
                                       rtol=1e-3, err_msg=f"{ik.name}/{ek.name}")


def test_aggregate_max_and_mean(g, rng):
    dec = decompose.decompose(g, comm_size=16, method="bfs")
    x = rng.standard_normal((g.n, 7)).astype(np.float32)
    xr = adaptgear.to_reordered(dec, jnp.asarray(x))

    # dense references on the original graph
    max_ref = np.zeros((g.n, 7), np.float32)
    has_nbr = np.zeros(g.n, bool)
    acc = np.full((g.n, 7), -np.inf, np.float32)
    np.maximum.at(acc, g.receivers, x[g.senders])
    has_nbr[g.receivers] = True
    max_ref[has_nbr] = acc[has_nbr]

    y = adaptgear.from_reordered(dec, adaptgear.aggregate_max(dec, xr))
    np.testing.assert_allclose(np.asarray(y), max_ref, atol=1e-5)

    deg = np.bincount(g.receivers, minlength=g.n).astype(np.float32)
    inv = 1.0 / np.maximum(deg, 1.0)
    inv_r = np.zeros(dec.n_pad, np.float32)
    inv_r[np.asarray(dec.perm)] = inv
    sum_ref = np.zeros((g.n, 7), np.float32)
    np.add.at(sum_ref, g.receivers, x[g.senders])
    mean_ref = sum_ref * inv[:, None]
    ym = adaptgear.from_reordered(
        dec, adaptgear.aggregate_mean(dec, xr, jnp.asarray(inv_r),
                                      ("block_diag", "bell")))
    np.testing.assert_allclose(np.asarray(ym), mean_ref, atol=1e-4,
                               rtol=1e-4)

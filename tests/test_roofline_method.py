"""Validates the roofline methodology itself: the HLO collective parser and
the scan-correction composition (small-probe linear composition must equal a
direct full-depth unrolled lowering)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[256,4096] all-gather(bf16[16,4096] %x), dimensions={0}
  %ar = f32[1024] all-reduce(f32[1024] %y), to_apply=%sum
  %rs = f32[64,128] reduce-scatter(f32[1024,128] %z), dimensions={0}
  %cp = s32[8] collective-permute(s32[8] %w)
  %dot = f32[128,128] dot(f32[128,64] %a, f32[64,128] %b)
"""
    out = collective_bytes(hlo)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1, "collective-permute": 1}
    assert out["all-gather"] == 256 * 4096 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 64 * 128 * 4
    assert out["collective-permute"] == 8 * 4
    assert out["total"] == sum(out[k] for k in out["counts"])


def test_flash_model_path_matches_softmax():
    """attn_core='flash' (Pallas fwd + recompute bwd) must be numerically
    identical to the XLA softmax path, through the full loss/grad."""
    import jax.numpy as jnp
    from repro.models import lm
    rng = np.random.default_rng(0)
    cfg0 = configs.get_config("internlm2_1_8b", reduced=True)
    B, S = 2, 128
    toks = jnp.asarray(rng.integers(0, cfg0.vocab, (B, S)), jnp.int32)
    batch = dict(tokens=toks, labels=jnp.roll(toks, -1, 1))
    p = lm.init_params(jax.random.PRNGKey(0), cfg0)
    ref = None
    for core in ("softmax", "flash"):
        cfg = dataclasses.replace(cfg0, attn_core=core)
        loss, _ = lm.loss_fn(p, cfg, batch)
        g = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(p)
        gn = float(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g))) ** 0.5
        if ref is None:
            ref = (float(loss), gn)
        else:
            assert abs(float(loss) - ref[0]) < 1e-4
            assert abs(gn - ref[1]) / ref[1] < 1e-4


@pytest.mark.slow
def test_scan_correction_composes_exactly(monkeypatch):
    """corrected_costs' small-probe composition == direct unrolled lowering
    at full depth (uniform-decoder and first-k-dense MoE families)."""
    from benchmarks.roofline import corrected_costs, _probe

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    monkeypatch.setitem(configs.SHAPES, "tiny",
                        dict(seq=32, batch=2, mode="train"))

    for arch, depth_field in [("internlm2_1_8b", None),
                              ("deepseek_moe_16b", None)]:
        reduced = configs.get_config(arch, reduced=True)
        monkeypatch.setattr(configs, "get_config",
                            lambda name, reduced_=False, _r=reduced: _r)
        composed = corrected_costs(arch, "tiny", mesh)
        direct = _probe(arch, "tiny", mesh, dict(n_layers=reduced.n_layers))
        monkeypatch.undo()
        monkeypatch.setitem(configs.SHAPES, "tiny",
                            dict(seq=32, batch=2, mode="train"))
        for k in ("flops", "coll"):
            np.testing.assert_allclose(composed[k], direct[k], rtol=0.02,
                                       err_msg=f"{arch}:{k}")
        np.testing.assert_allclose(composed["bytes"], direct["bytes"],
                                   rtol=0.10, err_msg=f"{arch}:bytes")

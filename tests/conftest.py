import os
import sys

# tests see exactly 1 CPU device (the dry-run, and only the dry-run, forces
# 512); make sure no leaked XLA_FLAGS changes that.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)

"""Generalized epilogue fusion (GIN's MLP, SAGE's dual weights) + the
spill/probe feedback satellites.

Property tests (hypothesis, f32/bf16): the epilogue-fused GIN/SAGE layers
— weight pushed through the aggregation, self terms seeding the threaded
accumulator, dual stripes in the Pallas kernel — must match the legacy
unfused dense reference for forward AND grads over k in {1, 2, 4} bucket
counts and over budget-capped blocked-ELL payloads with real spill.  Plus:
the no-retrace contract for fused-epilogue mini-batch plans, free-transform
selection honesty, budget-K autotuning from observed spill, adaptive probe
widening, and the cluster-tuple skeleton cache.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adaptgear, decompose, epilogue as ep_mod, gnn
from repro.core import selector as sel_mod
from repro.graphs import graph as G
from repro.kernels.registry import REGISTRY
from repro.sampling.plan_cache import MB_KERNELS, PlanCache
from repro.train import gnn_steps


def make_graph(n=180, e=1400, nf=5, nc=3, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, nf)).astype(np.float32)
    labels = rng.integers(0, nc, n).astype(np.int32)
    return G.Graph(n, src, dst, feats, labels, nc)


@functools.lru_cache(maxsize=None)
def cached(model, k):
    g = make_graph()
    cfg = gnn.GNNConfig(model=model, comm_size=8, reorder="bfs",
                        inter_buckets=k, hidden=8)
    dec = gnn.prepare(g, cfg)        # bakes SAGE's mean norm into the vals
    a = np.zeros((g.n, g.n), np.float32)
    a[g.receivers, g.senders] = 1.0
    if model == "sage":
        deg = np.bincount(g.receivers, minlength=g.n).astype(np.float32)
        a = a / np.maximum(deg, 1.0)[:, None]
    return g, a, dec, cfg


def dense_layer(model, layer, a, x):
    """Legacy unfused reference for one conv layer (float64-free f32)."""
    if model == "gin":
        h = (1.0 + np.asarray(layer["eps"])) * x + a @ x
        h = np.maximum(h @ np.asarray(layer["w1"]) + np.asarray(layer["b1"]),
                       0.0)
        return h @ np.asarray(layer["w2"]) + np.asarray(layer["b2"])
    agg = a @ x                        # a already carries the mean norm
    return (x @ np.asarray(layer["w_self"])
            + agg @ np.asarray(layer["w_neigh"]) + np.asarray(layer["b"]))


def tol(dt):
    return dict(atol=1e-4, rtol=1e-4) if dt == jnp.float32 else \
        dict(atol=2e-1, rtol=3e-1)


PLANS = [("block_diag_fused", "bell_fused"),
         ("block_diag_fused", "csr_fused"),
         ("block_diag", "bell_fused")]
MODELS = ["gin", "sage"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), mi=st.integers(0, 1),
       ki=st.integers(0, 2), pi=st.integers(0, len(PLANS) - 1),
       bf16=st.booleans())
def test_fused_epilogue_matches_dense_fwd_and_grad(seed, mi, ki, pi, bf16):
    """Fused GIN/SAGE forward + grads (wrt inputs AND every epilogue
    parameter) == the unfused dense reference, f32 and bf16, any bucket
    count, any fused plan shape."""
    dt = jnp.bfloat16 if bf16 else jnp.float32
    model, k = MODELS[mi], [1, 2, 4][ki]
    g, a, dec, cfg = cached(model, k)
    rng = np.random.default_rng(seed)
    params = [jax.tree.map(lambda v: jnp.asarray(
        rng.standard_normal(v.shape) * 0.5, dt), layer)
        for layer in gnn.init_model(jax.random.PRNGKey(0), cfg,
                                    5, g.n_classes)][:1]
    layer = params[0]
    x = jnp.asarray(rng.standard_normal((g.n, 5)), dt)
    cot = rng.standard_normal((g.n, a.shape[0]))  # unused cols sliced below
    conv = adaptgear.gin_conv if model == "gin" else adaptgear.sage_conv

    def fused(layer, x):
        xr = adaptgear.to_reordered(dec, x)
        return adaptgear.from_reordered(dec, conv(layer, dec, xr, PLANS[pi]))

    y = np.asarray(fused(layer, x), np.float32)
    xf = np.asarray(x, np.float32)
    layer_f = jax.tree.map(lambda v: np.asarray(v, np.float32), layer)
    y_ref = dense_layer(model, layer_f, a, xf)
    np.testing.assert_allclose(y, y_ref, **tol(dt),
                               err_msg=f"{model} k={k} plan={PLANS[pi]} fwd")

    cot = jnp.asarray(cot[:, : y.shape[-1]], jnp.float32)
    grads = jax.grad(lambda l, x: jnp.sum(
        fused(l, x).astype(jnp.float32) * cot), argnums=(0, 1))(layer, x)

    def ref_loss(layer, x):
        xr = adaptgear.to_reordered(dec, x)
        names = ("block_diag", "bell")        # unfused registry reference
        return jnp.sum(adaptgear.from_reordered(
            dec, conv(layer, dec, xr, names)).astype(jnp.float32) * cot)

    grads_ref = jax.grad(ref_loss, argnums=(0, 1))(layer, x)
    for (ga, gb) in zip(jax.tree.leaves(grads), jax.tree.leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(ga, np.float32),
                                   np.asarray(gb, np.float32), **tol(dt),
                                   err_msg=f"{model} k={k} plan={PLANS[pi]}")


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("ik,ek", PLANS)
@pytest.mark.parametrize("k", [1, 2, 4])
def test_fused_epilogue_matches_dense_deterministic(model, ik, ek, k, rng):
    """Non-hypothesis twin of the property test (runs on machines without
    hypothesis): forward + full grads, f32."""
    g, a, dec, cfg = cached(model, k)
    layer = gnn.init_model(jax.random.PRNGKey(1), cfg, 5, g.n_classes)[0]
    x = jnp.asarray(rng.standard_normal((g.n, 5)), jnp.float32)
    conv = adaptgear.gin_conv if model == "gin" else adaptgear.sage_conv

    def fused(layer, x):
        xr = adaptgear.to_reordered(dec, x)
        return adaptgear.from_reordered(dec, conv(layer, dec, xr, (ik, ek)))

    y = np.asarray(fused(layer, x), np.float32)
    y_ref = dense_layer(model, jax.tree.map(np.asarray, layer), a,
                        np.asarray(x))
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)

    cot = jnp.asarray(rng.standard_normal(y.shape), jnp.float32)
    loss = lambda f: lambda l, x: jnp.sum(  # noqa: E731
        f(l, x).astype(jnp.float32) * cot)

    def unfused(layer, x):
        xr = adaptgear.to_reordered(dec, x)
        return adaptgear.from_reordered(
            dec, conv(layer, dec, xr, ("block_diag", "bell")))

    grads = jax.grad(loss(fused), argnums=(0, 1))(layer, x)
    grads_ref = jax.grad(loss(unfused), argnums=(0, 1))(layer, x)
    for ga, gb in zip(jax.tree.leaves(grads), jax.tree.leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                   atol=1e-3, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), mi=st.integers(0, 1),
       bf16=st.booleans())
def test_fused_epilogue_over_capped_bell_with_spill(seed, mi, bf16):
    """The mini-batch payload shape: budget-capped blocked-ELL whose cap
    actually spills edges to the in-payload COO.  Fused GIN/SAGE forward +
    grads must stay exact — pad + spill decompose the same matrix."""
    dt = jnp.bfloat16 if bf16 else jnp.float32
    model = MODELS[mi]
    rng = np.random.default_rng(seed)
    n, B = 128, 8
    # hub-heavy: one dense destination block-row spanning many far blocks
    hub_dst = rng.integers(0, B, 300)
    hub_src = rng.integers(0, n, 300)
    base_src = rng.integers(0, n, 200)
    base_dst = rng.integers(0, n, 200)
    src = np.concatenate([hub_src, base_src]).astype(np.int32)
    dst = np.concatenate([hub_dst, base_dst]).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    feats = rng.standard_normal((n, 6)).astype(np.float32)
    g = G.Graph(n, src, dst, feats, rng.integers(0, 3, n).astype(np.int32), 3)
    vals = (G.mean_norm_values(n, src, dst) if model == "sage" else None)
    dec = decompose.decompose(
        g, comm_size=B, method="bfs", edge_vals=vals, inter_buckets=2,
        keep_empty_buckets=True, edge_budget=len(src),
        kernels=MB_KERNELS)
    spills = [s.formats["bell"][2].nnz for s in dec.inters
              if "bell" in s.formats]
    assert any(sp > 0 for sp in spills), "profile must exercise the spill"

    a = np.zeros((n, n), np.float32)
    a[dst, src] = 1.0
    if model == "sage":
        deg = np.bincount(dst, minlength=n).astype(np.float32)
        a = a / np.maximum(deg, 1.0)[:, None]
    cfg = gnn.GNNConfig(model=model, comm_size=B, hidden=8)
    layer = jax.tree.map(
        lambda v: jnp.asarray(np.asarray(v, np.float32), dt),
        gnn.init_model(jax.random.PRNGKey(0), cfg, 6, 3)[0])
    x = jnp.asarray(rng.standard_normal((n, 6)), dt)
    conv = adaptgear.gin_conv if model == "gin" else adaptgear.sage_conv
    names = ("block_diag_fused", "bell_fused", "bell_fused")

    def fused(layer, x):
        xr = adaptgear.to_reordered(dec, x)
        return adaptgear.from_reordered(dec, conv(layer, dec, xr, names))

    y = np.asarray(fused(layer, x), np.float32)
    y_ref = dense_layer(model, jax.tree.map(
        lambda v: np.asarray(v, np.float32), layer), a, np.asarray(x, np.float32))
    np.testing.assert_allclose(y, y_ref, **tol(dt), err_msg=f"{model} spill")

    g_x = jax.grad(lambda x: jnp.sum(fused(layer, x).astype(jnp.float32)))(x)
    assert np.isfinite(np.asarray(g_x, np.float32)).all()


def test_minibatch_sage_cost_model_commits_fused_at_one_trace():
    """Acceptance bar: on the dense-inter profile the cost model commits
    the fused dual-weight plan for mini-batch SAGE, the jitted step
    compiles exactly once, and training is finite."""
    from test_sampling import dense_community_graph
    g = dense_community_graph()
    cfg = gnn.GNNConfig(model="sage", sampler="cluster", comm_size=64,
                        clusters_per_batch=2, reorder="bfs",
                        inter_buckets=2)
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
    used = {k for plan in res.plans for layer in plan for k in layer}
    assert "bell_fused" in used or "block_diag_fused" in used, res.plans
    assert res.n_traces == 1
    assert np.isfinite(res.losses).all()


def test_minibatch_gin_fused_plan_at_one_trace():
    """Mini-batch GIN dispatching a fully fused epilogue plan (fixed
    selector pins it) compiles once and trains finitely — the dispatch
    path is plan-agnostic even where the cost model prefers unfused."""
    g = make_graph(n=128, e=1200)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, reorder="bfs",
                        inter_buckets=2, selector="fixed",
                        fixed_kernels=("block_diag_fused", "bell_fused"))
    res = gnn_steps.train_minibatch(g, cfg, steps=5, eval_batches=1)
    assert res.n_traces == 1
    expect = ("block_diag_fused",) + ("bell_fused",) * 2
    assert res.plans == [(expect,) * cfg.n_layers]
    assert np.isfinite(res.losses).all()


def test_dual_weight_kernel_hook_equivalence(rng):
    """The dual-stripe Pallas kernel (both weight stripes in VMEM,
    ``fused_dual_matvec``/``_acc``) == the seed path, forward and grads,
    with and without the threaded bias.  ``acc=True`` forces the hook on
    (its backend default keeps it TPU-only — in interpret mode the extra
    per-grid-step matmul is slower than the XLA seed it replaces)."""
    g, a, dec, cfg = cached("sage", 2)
    xr = adaptgear.to_reordered(dec, jnp.asarray(
        rng.standard_normal((g.n, 5)), jnp.float32))
    wn = jnp.asarray(rng.standard_normal((5, 7)), jnp.float32)
    ws = jnp.asarray(rng.standard_normal((5, 7)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(7), jnp.float32)
    names = ("block_diag_fused", "bell_fused", "bell_fused")
    assert REGISTRY.get(names[0]).fused_dual_matvec is not None

    for bias in (b, None):
        hook = lambda xr, wn, ws: adaptgear.aggregate_transform_dual(  # noqa
            dec, xr, wn, ws, names, bias=bias, acc=True)
        seed = lambda xr, wn, ws: adaptgear.aggregate_transform_dual(  # noqa
            dec, xr, wn, ws, names, bias=bias, acc=False)
        np.testing.assert_allclose(np.asarray(hook(xr, wn, ws)),
                                   np.asarray(seed(xr, wn, ws)),
                                   atol=1e-5, rtol=1e-5)
        g_h = jax.grad(lambda *a: jnp.sum(hook(*a) ** 2), (0, 1, 2))(xr, wn, ws)
        g_s = jax.grad(lambda *a: jnp.sum(seed(*a) ** 2), (0, 1, 2))(xr, wn, ws)
        for p, q in zip(g_h, g_s):
            np.testing.assert_allclose(np.asarray(p), np.asarray(q),
                                       atol=1e-3, rtol=1e-3)
    # bias grad through the acc-threaded broadcast
    db = jax.grad(lambda b: jnp.sum(adaptgear.aggregate_transform_dual(
        dec, xr, wn, ws, names, bias=b, acc=True)))(b)
    np.testing.assert_allclose(np.asarray(db),
                               np.full((7,), dec.n_pad, np.float32),
                               atol=1e-3, rtol=1e-4)


def test_gin_free_transform_zeroes_unfused_surcharge():
    """The MLP epilogue's shared transform is free to unfused candidates
    (the self term computes S = X W1 regardless): with it, an unfused
    candidate's whole-layer cost must equal its bare kernel cost, while
    the linear (GCN) epilogue surcharges the transform share."""
    g, _, dec, _ = cached("gin", 2)
    hw = sel_mod.default_hw()
    mlp = ep_mod.EpilogueSpec(kind="mlp", activation="relu", out_dim=3)
    sub = dec.inters[0]
    share_lin = sel_mod._transform_share(dec, 8, np.float32, hw, 16)
    share_mlp = sel_mod._transform_share(dec, 8, np.float32, hw, 16, mlp)
    assert share_lin > 0.0 and share_mlp == 0.0
    bare = sel_mod.candidate_cost(sub, "bell", 8, hw=hw)
    assert sel_mod.candidate_cost(sub, "bell", 8, hw=hw, in_dim=16,
                                  transform_share=share_mlp) == bare
    # under TPU constants (memory-bound) a narrow-input wide-hidden GIN
    # layer still picks fused kernels on MXU-scale dense blocks, even
    # with the unfused side uncharged — fusion wins on bandwidth alone
    src, dst = G.aligned_community_graph(2048, 30000, block=128,
                                         intra_frac=0.9, seed=0)
    gb = G.Graph(2048, src, dst, np.zeros((2048, 4), np.float32),
                 np.zeros(2048, np.int32), 2)
    decb = decompose.decompose(gb, comm_size=128, method="bfs",
                               reorder=False, inter_buckets=1)
    choice = sel_mod.select_by_cost_model(decb, 512, hw=sel_mod.HwModel(),
                                          in_dim=64, epilogue=mlp)
    assert any(REGISTRY.get(k).fused for k in choice), choice


def test_plan_layer_cost_includes_epilogue_terms():
    """Dense epilogue terms (dual self matmul, MLP second layer) enter the
    whole-layer totals the bucket autotuner compares."""
    g, _, dec, _ = cached("sage", 1)
    hw = sel_mod.default_hw()
    base = sel_mod.plan_layer_cost(dec, 8, hw=hw, in_dim=16)
    dual = sel_mod.plan_layer_cost(dec, 8, hw=hw, in_dim=16,
                                   epilogue=ep_mod.EpilogueSpec(kind="dual"))
    mlp = sel_mod.plan_layer_cost(
        dec, 8, hw=hw, in_dim=16,
        epilogue=ep_mod.EpilogueSpec(kind="mlp", out_dim=3))
    assert dual > base
    assert mlp > base
    assert ep_mod.epilogue_cost(None, dec.n_pad, 16, 8, hw=hw) == 0.0


def test_plan_carries_epilogues():
    """EpilogueSpecs thread from gnn through select_plan into the
    KernelPlan (both selector modes and the mini-batch PlanCache)."""
    g, _, dec, cfg = cached("sage", 2)
    pairs = gnn.agg_width_pairs(cfg, 5, g.n_classes)
    eps = gnn.layer_epilogues(cfg, 5, g.n_classes)
    assert all(e.kind == "dual" and e.mean_norm for e in eps)
    assert all(fin is not None for fin, _ in pairs)
    plan, _ = gnn.select_plan(dec, cfg, pairs, epilogues=eps)
    assert plan.epilogues == tuple(eps)
    assert plan.epilogue_for_layer(0).kind == "dual"
    cache = PlanCache(pairs, epilogues=eps)
    skel_plan = cache.select(dec)
    assert skel_plan.epilogues == tuple(eps)
    # gin structure rule: the narrow input layer aggregates raw features
    # (aggregate-first, pair (None, fin)); hidden-width layers keep the
    # transform-first rewrite and aggregate at the MLP hidden width
    cfg_gin = gnn.GNNConfig(model="gin", hidden=8)
    gpairs = gnn.agg_width_pairs(cfg_gin, 5, 3)
    assert gpairs == [(None, 5), (8, 8)]
    geps = gnn.layer_epilogues(cfg_gin, 5, 3)
    assert [e.out_dim for e in geps] == [8, 3]
    assert [e.structure for e in geps] == ["aggregate_first",
                                           "transform_first"]
    assert geps[0].hidden == 8 and not geps[0].free_transform
    assert geps[1].free_transform
    # wide input (hidden <= in_dim): transform-first everywhere, as before
    wpairs = gnn.agg_width_pairs(cfg_gin, 16, 3)
    assert wpairs == [(16, 8), (8, 8)]
    assert all(e.free_transform
               for e in gnn.layer_epilogues(cfg_gin, 16, 3))


def test_budget_k_adapts_from_observed_spill():
    """PlanCache budget-K autotuning: committed capped-bell payloads that
    spill beyond the target step the slack up the ladder, the adapted
    slack keys the signature, and rebuilding with it shrinks the spill."""
    rng = np.random.default_rng(0)
    n, B = 256, 8
    # hub row-block fanning out to many distinct far blocks: the budget
    # cap is too tight at the default slack
    hub_dst = rng.integers(0, B, 400)
    hub_src = rng.integers(0, n, 400)
    src = np.concatenate([hub_src, rng.integers(0, n, 100)]).astype(np.int32)
    dst = np.concatenate([hub_dst, rng.integers(0, n, 100)]).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    g = G.Graph(n, src, dst, np.zeros((n, 4), np.float32),
                np.zeros(n, np.int32), 2)
    budget = len(src)

    def build(slack):
        skel = decompose.decompose_skeleton(
            g, comm_size=B, reorder=False, inter_buckets=1,
            keep_empty_buckets=True, edge_budget=budget, bell_slack=slack)
        return skel.materialize(("bell",))

    cache = PlanCache([(4, 8)], adapt_budget_k=True, bell_slack=1.0,
                      spill_min_obs=2)
    sig0 = cache.signature(build(cache.bell_slack))
    assert ("bell_slack", 1.0) in sig0
    spill0 = None
    for _ in range(4):
        dec = build(cache.bell_slack)
        sp = sum(s.formats["bell"][2].nnz for s in dec.subgraphs
                 if "bell" in s.formats)
        spill0 = sp if spill0 is None else spill0
        cache.observe_bell(dec)
    assert spill0 > 0, "profile must spill at the initial slack"
    assert cache.bell_slack > 1.0
    assert cache.stats["slack_changes"] >= 1
    assert cache.stats["spill_nnz"] > 0
    # adapted slack -> larger K -> less spill, and a fresh signature
    dec2 = build(cache.bell_slack)
    spill2 = sum(s.formats["bell"][2].nnz for s in dec2.subgraphs
                 if "bell" in s.formats)
    assert spill2 < spill0
    assert cache.signature(dec2) != sig0

    # near-hit aliasing must not bridge a slack step: a statistically
    # identical batch decomposed under the NEW slack misses (forcing
    # re-selection under the new K) instead of reusing the plan priced
    # for the old cap.  The signature reads the slack baked into the
    # decomposition's own tier stats — not the cache's current slack —
    # so a batch built BEFORE the step (old-slack payload shapes, e.g.
    # one in flight on a pipeline worker) still hits the entry that
    # matches its shapes rather than shearing to the new key
    cache2 = PlanCache([(4, 8)], adapt_budget_k=True, bell_slack=1.0,
                       spill_min_obs=2)
    dec_old = decompose.decompose(
        g, comm_size=B, reorder=False, inter_buckets=1,
        keep_empty_buckets=True, edge_budget=budget,
        bell_slack=cache2.bell_slack, kernels=MB_KERNELS)
    _, hit = cache2.plan_for(dec_old)
    assert not hit
    assert cache2.lookup(dec_old) is not None    # resident at old slack
    cache2._bell_slack = 2.0                     # a slack step
    dec_new = decompose.decompose(
        g, comm_size=B, reorder=False, inter_buckets=1,
        keep_empty_buckets=True, edge_budget=budget,
        bell_slack=cache2.bell_slack, kernels=MB_KERNELS)
    assert cache2.lookup(dec_new) is None        # no cross-slack aliasing
    # in-flight old-slack batch: keyed by its own baked slack, still hits
    assert cache2.lookup(dec_old) is not None


def test_adaptive_probe_topk_widens_within_margin():
    """probe_topk widens past top-2 when the modeled margin sits inside
    the error band, and a zero wall-time budget degrades gracefully to
    the modeled choice."""
    g = make_graph(n=96, e=700)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, reorder="bfs")
    sampler = gnn_steps.make_sampler(g, cfg)
    dec, _ = gnn_steps.prepare_batch(sampler.sample(), cfg)
    pairs = [tuple(p) for p in
             gnn.agg_width_pairs(cfg, g.features.shape[-1], g.n_classes)]

    errs_narrow, errs_wide = [], []
    sel_mod.probe_topk(dec, pairs[:1], k=2, iters=1, errs=errs_narrow)
    sel_mod.probe_topk(dec, pairs[:1], k=2, k_max=5, margin=100.0, iters=1,
                       errs=errs_wide)
    assert len(errs_wide) > len(errs_narrow)   # the frontier widened

    # exhausted budget: nothing timed, modeled ranking decides
    layers = sel_mod.probe_topk(dec, pairs[:1], k=2, iters=1,
                                time_budget_s=0.0)
    modeled = sel_mod.select_by_cost_model(dec, pairs[0][1],
                                           in_dim=pairs[0][0],
                                           hw=sel_mod.default_hw())
    assert layers[0] == modeled

    # the cache's error band starts unknown and is measured from probes
    cache = PlanCache(pairs, probe_every=1, probe_iters=1)
    assert cache.probe_margin() is None
    cache.plan_for(dec)
    assert cache.stats["probes"] == 1
    assert len(cache._probe_errs) >= 2
    if cache.probe_margin() is not None:
        assert 0.05 <= cache.probe_margin() <= 1.0


def test_skeleton_cache_reuses_cluster_tuples():
    """Repeated cluster tuples skip decompose_skeleton entirely; the
    cached-skeleton run matches the uncached run exactly (same batches,
    same plans, same losses)."""
    g = make_graph(n=64, e=500)
    # 8 clusters, 8 per batch: every epoch redraws the same (full) tuple,
    # so every step past the first must hit the skeleton cache
    base = dict(model="gin", sampler="cluster", comm_size=8,
                clusters_per_batch=8, reorder="bfs", inter_buckets=2)
    res = gnn_steps.train_minibatch(
        g, gnn.GNNConfig(**base, skeleton_cache_entries=64),
        steps=8, eval_batches=1)
    assert res.skeleton_misses == 1
    assert res.skeleton_hits >= 7
    res_off = gnn_steps.train_minibatch(
        g, gnn.GNNConfig(**base, skeleton_cache_entries=0),
        steps=8, eval_batches=1)
    assert res_off.skeleton_hits == 0
    np.testing.assert_allclose(res.losses, res_off.losses, rtol=1e-6)
    assert res.plans == res_off.plans


def test_skeleton_cache_key_rules():
    """The cache key is the drawn cluster tuple (+ the adapted bell
    slack); truncated batches (random edge subset) and non-cluster
    batches never cache."""
    from repro.sampling.sampler import SampledBatch
    mk = lambda meta: SampledBatch(  # noqa: E731
        n=4, nodes=np.zeros(4, np.int32), node_mask=np.ones(4, bool),
        senders=np.zeros(2, np.int32), receivers=np.zeros(2, np.int32),
        edge_mask=np.ones(2, bool), features=np.zeros((4, 2), np.float32),
        labels=np.zeros(4, np.int32), target_mask=np.ones(4, bool),
        meta=meta)
    Key = gnn_steps.SkeletonCache.key
    assert Key(mk(dict(clusters=[1, 3], dropped_edges=0)), None) == \
        ((1, 3), None)
    assert Key(mk(dict(clusters=[1, 3], dropped_edges=0)), 2.0) != \
        Key(mk(dict(clusters=[1, 3], dropped_edges=0)), 1.5)
    assert Key(mk(dict(clusters=[1, 3], dropped_edges=5)), None) is None
    assert Key(mk(dict(seeds=4)), None) is None     # neighbor sampler
    # LRU bound
    cache = gnn_steps.SkeletonCache(max_entries=2)
    for i in range(3):
        cache.put(((i,), None), (i, i))
    assert len(cache._entries) == 2
    assert cache.get(((0,), None)) is None      # evicted
    assert cache.get(((2,), None)) == (2, 2)


def test_gin_structure_equivalence(rng):
    """Aggregate-first and transform-first GIN layers are the same
    function (linearity of aggregation): forward and grads match on real
    decomposed kernels, and both match the dense reference."""
    g, a, dec, _ = cached("gin", 2)
    x = rng.standard_normal((g.n, 5)).astype(np.float32)
    xr = adaptgear.to_reordered(dec, jnp.asarray(x))
    layer = adaptgear.init_gin_conv(jax.random.PRNGKey(3), 5, 8, 7)
    names = ("block_diag", "bell", "bell")

    def run(structure):
        return adaptgear.gin_conv(layer, dec, xr, names,
                                  structure=structure)

    y_tf, y_af = run("transform_first"), run("aggregate_first")
    np.testing.assert_allclose(np.asarray(y_af), np.asarray(y_tf),
                               atol=1e-4, rtol=1e-4)
    ref = dense_layer("gin", layer, a, x)
    back = np.asarray(y_af)[np.asarray(dec.perm)]
    np.testing.assert_allclose(back, ref, atol=1e-4, rtol=1e-4)
    g_tf = jax.grad(lambda p: jnp.sum(adaptgear.gin_conv(
        p, dec, xr, names, structure="transform_first") ** 2))(layer)
    g_af = jax.grad(lambda p: jnp.sum(adaptgear.gin_conv(
        p, dec, xr, names, structure="aggregate_first") ** 2))(layer)
    for k in g_tf:
        np.testing.assert_allclose(np.asarray(g_af[k]), np.asarray(g_tf[k]),
                                   atol=2e-3, rtol=2e-3)


def test_gin_aggregate_first_fused_names_fall_back(rng):
    """A plan that pinned fused kernel names implies transform-first —
    the aggregate-first spec defers to it instead of crashing (fused
    kernels have no raw-aggregation matvec)."""
    g, _, dec, _ = cached("gin", 2)
    xr = adaptgear.to_reordered(
        dec, jnp.asarray(rng.standard_normal((g.n, 5)), jnp.float32))
    layer = adaptgear.init_gin_conv(jax.random.PRNGKey(3), 5, 8, 7)
    fused = ("block_diag_fused", "bell_fused", "bell_fused")
    y = adaptgear.gin_conv(layer, dec, xr, fused,
                           structure="aggregate_first")
    y_tf = adaptgear.gin_conv(layer, dec, xr, fused,
                              structure="transform_first")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_tf))


def test_gin_structure_priced_selection():
    """layer_plan_inputs prices aggregate-first vs transform-first with
    the decomposition in hand: the narrow-input layer flips to
    aggregate-first (pair (None, fin), hidden on the spec), hidden-width
    layers stay transform-first, and the dec-free rule agrees here."""
    g, _, dec, cfg = cached("gin", 2)
    pairs, eps = gnn.layer_plan_inputs(cfg, 5, g.n_classes, dec=dec)
    assert pairs[0] == (None, 5)
    assert eps[0].structure == "aggregate_first" and eps[0].hidden == 8
    assert pairs[1:] == [(8, 8)]
    assert all(e.structure == "transform_first" for e in eps[1:])
    # priced totals really differ: the af layer aggregates at width 5
    hw = sel_mod.default_hw()
    tf_cost = sel_mod.plan_layer_cost(
        dec, 8, hw=hw, in_dim=5,
        epilogue=ep_mod.gin_layer_spec(5, 8, 8, "transform_first"))
    af_cost = sel_mod.plan_layer_cost(
        dec, 5, hw=hw, in_dim=None,
        epilogue=ep_mod.gin_layer_spec(5, 8, 8, "aggregate_first"))
    assert af_cost < tf_cost
    # dec-free path (mini-batch): same structures without pricing
    fpairs, feps = gnn.layer_plan_inputs(cfg, 5, g.n_classes)
    assert fpairs == pairs
    assert [e.structure for e in feps] == [e.structure for e in eps]


def test_epilogue_cost_aggregate_first_prices_whole_mlp():
    """The aggregate-first mlp spec bypasses the fin-None guard: the whole
    MLP (first matmul at the raw width, second at hidden) is priced, with
    the same dense flops as the transform-first split, so plan_layer_cost
    comparisons are carried by the sparse pass alone."""
    hw = sel_mod.HwModel()
    n, fin, hid, out = 4096, 16, 64, 8
    af = ep_mod.gin_layer_spec(fin, hid, out, "aggregate_first")
    tf = ep_mod.gin_layer_spec(fin, hid, out, "transform_first")
    c_af = ep_mod.epilogue_cost(af, n, None, fin, hw=hw)
    c_tf = ep_mod.epilogue_cost(tf, n, fin, hid, hw=hw)
    assert c_af > 0.0 and c_tf > 0.0
    # flops identical (2 n fin hid + 2 n hid out) -> compute-bound costs
    # agree; bandwidth terms differ only in elementwise traffic
    assert abs(c_af - c_tf) < max(c_af, c_tf) * 0.5
    # legacy guard intact for non-mlp specs with no input width
    assert ep_mod.epilogue_cost(
        ep_mod.EpilogueSpec(kind="dual"), n, None, fin, hw=hw) == 0.0


def test_gin_minibatch_aggregate_first_trains():
    """End-to-end mini-batch GIN with a narrow input (in_dim < hidden):
    the first layer runs aggregate-first via the PlanCache-carried
    epilogues, trains finitely, and still compiles once."""
    g = make_graph(n=128, e=1200, nf=4)
    cfg = gnn.GNNConfig(model="gin", sampler="cluster", comm_size=8,
                        clusters_per_batch=4, reorder="bfs", hidden=16,
                        inter_buckets=2, selector="cost_model")
    pairs = gnn.agg_width_pairs(cfg, 4, g.n_classes)
    assert pairs[0] == (None, 4)
    res = gnn_steps.train_minibatch(g, cfg, steps=6, eval_batches=1)
    assert res.n_traces == 1
    assert np.isfinite(res.losses).all()

"""End-to-end behaviour tests for the paper's system (AdaptGear)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import adaptgear, decompose, gnn
from repro.graphs import graph as G
from repro.kernels.registry import REGISTRY


@pytest.fixture(scope="module")
def citeseer():
    return G.synth_dataset("citeseer", scale=0.15, seed=0)


@pytest.mark.parametrize("model", ["gcn", "gin", "gat", "sage"])
def test_training_learns(citeseer, model):
    cfg = gnn.GNNConfig(model=model, selector="fixed",
                        fixed_kernels=("block_diag", "ell"), hidden=16)
    res = gnn.train(citeseer, cfg, steps=25)
    assert res.losses[-1] < res.losses[0] * 0.9, res.losses
    assert np.isfinite(res.losses).all()
    assert res.accuracy > 1.5 / citeseer.n_classes  # beats chance


def test_all_kernel_pairs_same_loss_curve(citeseer):
    """AdaptGear invariant: the kernel choice changes *speed*, never the
    math — every (intra, inter) pair must produce the same training curve."""
    curves = {}
    for ik in REGISTRY.candidates("diag"):
        for ek in REGISTRY.candidates("offdiag"):
            cfg = gnn.GNNConfig(model="gcn", selector="fixed",
                                fixed_kernels=(ik.name, ek.name), hidden=8)
            res = gnn.train(citeseer, cfg, steps=5)
            curves[(ik.name, ek.name)] = res.losses
    base = curves[("block_diag", "bell")]
    for k, c in curves.items():
        # different kernels sum edges in different orders; the fp drift is
        # amplified by Adam across steps — exactness holds per-aggregation
        # (test_decompose), curves agree to ~1%
        np.testing.assert_allclose(c, base, atol=5e-3, rtol=1e-2,
                                   err_msg=str(k))


def test_feedback_selector_runs(citeseer):
    cfg = gnn.GNNConfig(model="gcn", selector="feedback", warmup_iters=1)
    res = gnn.train(citeseer, cfg, steps=5)
    assert len(res.kernels) == cfg.n_layers   # per-layer selection
    dec = gnn.prepare(citeseer, cfg)
    n_cand = 0
    for i, sub in enumerate(dec.subgraphs):
        # GCN is transform-first, so fused candidates compete in the probe
        cands = [s.name for s in REGISTRY.candidates_for(sub,
                                                         include_fused=True)]
        n_cand += len(cands)
        for layer in res.kernels:
            assert layer[i] in cands
    assert len(res.probe_times) >= n_cand


def test_cost_model_selector_runs(citeseer):
    cfg = gnn.GNNConfig(model="gcn", selector="cost_model")
    res = gnn.train(citeseer, cfg, steps=5)
    assert np.isfinite(res.losses).all()


def test_preprocessing_overhead_small(citeseer):
    """Paper §6.3: preprocessing is a one-off, small vs training."""
    cfg = gnn.GNNConfig(model="gcn", selector="fixed")
    res = gnn.train(citeseer, cfg, steps=10)
    assert res.preprocess_seconds < 30.0


def test_memory_overhead_topology(citeseer):
    """Paper Fig. 12: subgraph topology storage is small vs features."""
    from repro.kernels.registry import payload_nbytes
    dec = decompose.decompose(citeseer, comm_size=16, method="bfs")
    topo_bytes = sum(payload_nbytes(payload)
                     for sub in dec.subgraphs
                     for payload in sub.formats.values())
    feat_bytes = citeseer.features.size * 4
    # all candidate formats together stay bounded; the *selected* pair alone
    # is what the paper's 4.47% number refers to (see benchmarks)
    assert topo_bytes < 50 * feat_bytes


def test_lm_moe_adaptgear_hook():
    """The MoE dispatch selector must route big-E configs to the sparse
    path (DESIGN.md §4)."""
    from repro import configs
    from repro.models import blocks as B
    moe16 = configs.get_config("deepseek_moe_16b").moe_cfg()
    assert B.choose_moe_path(moe16, n_tokens=1 << 20) == "sparse"
    v3 = configs.get_config("deepseek_v3_671b").moe_cfg()
    assert B.choose_moe_path(v3, n_tokens=1 << 20) == "sparse"

"""Fused transform+aggregate subsystem.

Covers: fused kernels vs the unfused dense reference (forward AND grads wrt
inputs and params, per-dtype tolerances) for GCN over every bucket count;
accumulation-mode equivalence; per-bucket blocked-ELL tiling; the _f_tile
divisor fix; selector integration (fused candidates competing in both
modes); and bucket-count autotuning."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import adaptgear, decompose, formats, gnn, selector
from repro.core.plan import KernelPlan
from repro.graphs import graph as G
from repro.kernels import ops
from repro.kernels.registry import REGISTRY


def make_graph(n=180, e=1400, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    key = src.astype(np.int64) * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    vals = rng.standard_normal(len(src)).astype(np.float32)
    g = G.Graph(n, src, dst, np.zeros((n, 3), np.float32),
                np.zeros(n, np.int32), 2)
    return g, vals


@functools.lru_cache(maxsize=None)
def cached(k):
    g, vals = make_graph()
    a = np.zeros((g.n, g.n), np.float32)
    a[g.receivers, g.senders] = vals
    dec = decompose.decompose(g, comm_size=8, method="bfs", edge_vals=vals,
                              inter_buckets=k)
    return g, a, dec


def tol(dt):
    # bf16 has ~3 significant digits; grads through two chained bf16
    # matmuls legitimately wobble at the 1e-1 scale on O(10) values
    return dict(atol=1e-4, rtol=1e-4) if dt == jnp.float32 else \
        dict(atol=2e-1, rtol=3e-1)


PLANS = [("block_diag_fused", "bell_fused"),   # fully fused
         ("block_diag_fused", "bell"),         # mixed: H materialized
         ("block_diag", "bell_fused")]


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("ik,ek", PLANS)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_fused_gcn_matches_dense_fwd_and_grad(ik, ek, k, dt, rng):
    """A (X W) + b through fused/mixed plans == the dense reference, for
    outputs and for grads wrt x, w, and b."""
    g, a, dec = cached(k)
    x = jnp.asarray(rng.standard_normal((g.n, 5)), dt)
    w = jnp.asarray(rng.standard_normal((5, 7)), dt)
    b = jnp.asarray(rng.standard_normal(7), dt)
    cot = jnp.asarray(rng.standard_normal((g.n, 7)), jnp.float32)

    def fused(x, w, b):
        xr = adaptgear.to_reordered(dec, x)
        y = adaptgear.aggregate_transform(dec, xr, w, (ik, ek), bias=b)
        return adaptgear.from_reordered(dec, y)

    def ref(x, w, b):
        af = jnp.asarray(a).astype(jnp.float32)
        return (af @ (x.astype(jnp.float32) @ w.astype(jnp.float32))
                + b.astype(jnp.float32))

    y = np.asarray(fused(x, w, b), np.float32)
    y_ref = np.asarray(ref(x, w, b))
    np.testing.assert_allclose(y, y_ref, **tol(dt),
                               err_msg=f"{ik}/{ek} k={k} fwd")

    loss = lambda f: lambda x, w, b: jnp.sum(  # noqa: E731
        f(x, w, b).astype(jnp.float32) * cot)
    grads = jax.grad(loss(fused), argnums=(0, 1, 2))(x, w, b)
    grads_ref = jax.grad(loss(ref), argnums=(0, 1, 2))(x, w, b)
    for gv, gr, name in zip(grads, grads_ref, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(gv, np.float32),
                                   np.asarray(gr, np.float32), **tol(dt),
                                   err_msg=f"{ik}/{ek} k={k} {name}")


@pytest.mark.parametrize("k", [1, 2, 4])
def test_accumulation_mode_equivalence(k, rng):
    """aggregate(acc=True) == aggregate(acc=False), and likewise for the
    fused transform path, including grads through the threaded buffer."""
    g, a, dec = cached(k)
    x = jnp.asarray(rng.standard_normal((g.n, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(4), jnp.float32)
    xr = adaptgear.to_reordered(dec, x)

    y_acc = adaptgear.aggregate(dec, xr, ("block_diag", "bell"), acc=True)
    y_sum = adaptgear.aggregate(dec, xr, ("block_diag", "bell"), acc=False)
    np.testing.assert_allclose(np.asarray(y_acc), np.asarray(y_sum),
                               atol=1e-5, rtol=1e-5)

    for names in (("block_diag_fused", "bell_fused"), ("block_diag", "bell")):
        f_acc = lambda xr, w, b: adaptgear.aggregate_transform(  # noqa: E731
            dec, xr, w, names, bias=b, acc=True)
        f_sum = lambda xr, w, b: adaptgear.aggregate_transform(  # noqa: E731
            dec, xr, w, names, bias=b, acc=False)
        np.testing.assert_allclose(np.asarray(f_acc(xr, w, b)),
                                   np.asarray(f_sum(xr, w, b)),
                                   atol=1e-5, rtol=1e-5, err_msg=str(names))
        g_acc = jax.grad(lambda *a: jnp.sum(f_acc(*a) ** 2), (0, 1, 2))(xr, w, b)
        g_sum = jax.grad(lambda *a: jnp.sum(f_sum(*a) ** 2), (0, 1, 2))(xr, w, b)
        for p, q in zip(g_acc, g_sum):
            np.testing.assert_allclose(np.asarray(p), np.asarray(q),
                                       atol=1e-3, rtol=1e-3, err_msg=str(names))


def test_fused_plan_through_training(rng):
    """End-to-end GCN training with a fixed fully-fused plan converges to
    the same curve as the unfused plan (the fused path is a pure speed
    change, never a math change)."""
    g = G.synth_dataset("cora", scale=0.1, seed=0)
    curves = {}
    for pair in (("block_diag", "bell"), ("block_diag_fused", "bell_fused")):
        cfg = gnn.GNNConfig(model="gcn", selector="fixed",
                            fixed_kernels=pair, hidden=8)
        curves[pair] = gnn.train(g, cfg, steps=5).losses
    np.testing.assert_allclose(curves[("block_diag_fused", "bell_fused")],
                               curves[("block_diag", "bell")],
                               atol=5e-3, rtol=1e-2)


def test_fused_selectable_by_both_selector_modes():
    """Fused kernels must be reachable through the KernelPlan machinery in
    both selector modes: the cost model (TPU constants, where the saved HBM
    round-trip dominates) and the committed feedback argmin."""
    # MXU-scale aligned communities: the regime fusion targets (B=128
    # diagonal blocks, expanding layer width)
    src, dst = G.aligned_community_graph(2048, 30000, block=128,
                                         intra_frac=0.9, seed=0)
    gb = G.Graph(2048, src, dst, np.zeros((2048, 4), np.float32),
                 np.zeros(2048, np.int32), 2)
    decb = decompose.decompose(gb, comm_size=128, method="bfs",
                               reorder=False, inter_buckets=1)
    choice = selector.select_by_cost_model(decb, 512, hw=selector.HwModel(),
                                           in_dim=64)
    plan = KernelPlan.make(decb, choice, n_layers=1)  # validates dispatch
    assert any(REGISTRY.get(k).fused for k in plan.for_layer(0)), choice
    g, _, dec = cached(1)
    # feedback: synthetic observations make the fused kernels fastest
    sel = selector.AdaptiveSelector(dec, warmup_iters=1, include_fused=True)
    for sub in dec.subgraphs:
        for spec in REGISTRY.candidates_for(sub, include_fused=True):
            t = 1e-6 if spec.fused else 1e-3
            sel.observe(sub.name, spec.name, t, width=8)
    committed = sel.choice(8)
    assert all(REGISTRY.get(k).fused for k in committed), committed
    KernelPlan.make(dec, committed, n_layers=2)


def test_feedback_choices_keyed_by_width_pair():
    """Two layers sharing an output width but differing in input width sit
    on opposite sides of the fused recompute crossover — their observations
    and committed choices must not pool."""
    g, _, dec = cached(1)
    sel = selector.AdaptiveSelector(dec, warmup_iters=1, include_fused=True)
    for sub in dec.subgraphs:
        for spec in REGISTRY.candidates_for(sub, include_fused=True):
            # narrow input: fused fastest; wide input: ell fastest
            sel.observe(sub.name, spec.name,
                        1e-6 if spec.fused else 1e-3, width=(4, 8))
            sel.observe(sub.name, spec.name,
                        1e-6 if spec.name == "ell" else 1e-3, width=(64, 8))
    narrow = sel.choice((4, 8))
    wide = sel.choice((64, 8))
    assert all(REGISTRY.get(k).fused for k in narrow), narrow
    assert all(k == "ell" for k in wide), wide
    # committed choices stay sticky per pair
    sel.observe("intra", "coo", 1e-9, width=(4, 8))
    assert sel.choice((4, 8)) == narrow


def test_cost_model_without_in_dim_excludes_fused():
    g, _, dec = cached(2)
    choice = selector.select_by_cost_model(dec, 64)
    assert not any(REGISTRY.get(k).fused for k in choice)
    with pytest.raises(ValueError):
        selector.candidate_cost(dec.intra, "block_diag_fused", 64)


def test_f_tile_picks_largest_divisor():
    """_f_tile must return the largest lane-multiple divisor of the padded
    width <= cap — and never hang or degrade on non-lane-multiple caps."""
    assert ops._f_tile(512) == 512
    assert ops._f_tile(512, cap=256) == 256
    assert ops._f_tile(768) == 384          # 512 does not divide 768
    assert ops._f_tile(1280) == 256         # old walk-down also found this
    assert ops._f_tile(640) == 128          # only 128 divides 640 under 512
    assert ops._f_tile(100) == 128          # pads to one lane tile
    # non-lane-multiple caps (per-bucket tiling): pick the divisor below
    assert ops._f_tile(256, cap=200) == 128
    assert ops._f_tile(1024, cap=1000) == 512
    assert ops._f_tile(512, cap=1) == 128


def test_bell_per_bucket_tiling():
    """Buckets whose stored blocks collapse under merging get a fatter tile;
    scattered buckets keep the community-size block."""
    n = 64
    # aligned cluster: every block-row's edges hit 8-blocks {4, 5}, which
    # form one aligned 16-block -> K halves when Bb doubles
    rows = np.repeat(np.arange(0, n, 8, dtype=np.int32), 2)
    cols = np.tile(np.asarray([32, 40], np.int32), n // 8)
    coo = formats.coo_from_edges(n, n, rows, cols)
    from repro.kernels.registry import _bell_pick_block
    assert _bell_pick_block(coo, 8) > 8
    # scattered: one edge per block-row to a far column -> K stays 1 and
    # merging only grows padding
    rows = np.arange(0, n, 8, dtype=np.int32)
    cols = (rows * 3 + 17) % n
    coo = formats.coo_from_edges(n, n, rows, cols)
    assert _bell_pick_block(coo, 8) == 8
    # payloads carry their own block size and stay numerically exact
    g, vals = make_graph(n=240, e=3000, seed=5)
    dec = decompose.decompose(g, comm_size=8, method="bfs", edge_vals=vals,
                              inter_buckets=2)
    for sub in dec.inters:
        bl = sub.formats["bell"][0]
        assert bl.block_size % 8 == 0 and dec.n_pad % bl.block_size == 0
        assert bl.f_tile_cap >= 128


def test_bucket_count_autotune():
    """inter_buckets=0 decomposes at k in {1,2,4}, totals the cost model
    over the model's layers, and commits the cheapest."""
    g = G.synth_dataset("cora", scale=0.08, seed=0)
    cfg = gnn.GNNConfig(model="gcn", selector="cost_model", inter_buckets=0)
    dec = gnn.prepare(g, cfg)
    tuned = dec.stats["bucket_autotune"]
    assert set(tuned) == {1, 2, 4}
    best_k = min(tuned, key=tuned.get)
    assert dec.stats["inter_buckets"] <= best_k
    # the committed decomposition trains
    res = gnn.train(g, cfg, steps=3)
    assert np.isfinite(res.losses).all()


def test_csr_one_file_kernel_matches_dense(rng):
    """The one-file CSR registration: registered for both kinds, exact
    against the dense reference, natively differentiable."""
    spec = REGISTRY.get("csr")
    assert spec.applies_to("diag") and spec.applies_to("offdiag")
    g, a, dec = cached(2)
    x = jnp.asarray(rng.standard_normal((g.n, 5)), jnp.float32)

    def agg(x):
        xr = adaptgear.to_reordered(dec, x)
        return adaptgear.from_reordered(
            dec, adaptgear.aggregate(dec, xr, ("csr", "csr")))

    np.testing.assert_allclose(np.asarray(agg(x)), a @ np.asarray(x),
                               atol=1e-4, rtol=1e-4)
    w = rng.standard_normal((g.n, 5)).astype(np.float32)
    grad = jax.grad(lambda x: jnp.sum(agg(x) * w))(x)
    np.testing.assert_allclose(np.asarray(grad), a.T @ w, atol=1e-4,
                               rtol=1e-4)


def test_fused_payload_aliasing_saves_memory():
    """Fused specs alias their unfused counterpart's payload: nothing extra
    is materialized, and the plan validator accepts the alias."""
    g, _, dec = cached(1)
    for sub in dec.subgraphs:
        assert "block_diag_fused" not in sub.formats
        assert "bell_fused" not in sub.formats
    KernelPlan.make(dec, ("block_diag_fused", "bell_fused"), n_layers=1)
    # restricting materialization to a fused kernel builds its base payload
    g2, vals = make_graph(seed=7)
    dec2 = decompose.decompose(g2, comm_size=8, method="bfs", edge_vals=vals,
                               kernels=("block_diag_fused", "bell_fused"))
    assert set(dec2.intra.formats) == {"block_diag"}
    assert set(dec2.inters[0].formats) == {"bell"}
    KernelPlan.make(dec2, ("block_diag_fused", "bell_fused"), n_layers=1)

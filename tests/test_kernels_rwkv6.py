"""RWKV-6 chunked kernel vs sequential oracle, shape/dtype/chunk sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.rwkv6_chunked import rwkv6_chunked, rwkv6_chunked_pallas


def make_inputs(rng, B, H, T, dh, dt=jnp.float32):
    r, k, v = (jnp.asarray(rng.standard_normal((B, H, T, dh)), dt)
               for _ in range(3))
    rate = np.clip(rng.standard_normal((B, H, T, dh)), -20, 0.405)
    w = jnp.asarray(np.exp(-np.exp(rate)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, dh)), jnp.float32)
    return r, k, v, w, u


@pytest.mark.parametrize("B,H,T,dh,chunk", [
    (1, 1, 32, 8, 8), (2, 3, 64, 16, 16), (2, 2, 128, 64, 32),
    (1, 4, 256, 32, 64),
])
def test_chunked_vs_sequential(rng, B, H, T, dh, chunk):
    r, k, v, w, u = make_inputs(rng, B, H, T, dh)
    o_seq = ref.rwkv6_linear_attention(r, k, v, w, u)
    o_chk, S = rwkv6_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_seq),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("B,H,T,dh,chunk", [
    (1, 2, 64, 16, 16), (2, 2, 128, 64, 32),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_pallas_vs_sequential(rng, B, H, T, dh, chunk, dt):
    r, k, v, w, u = make_inputs(rng, B, H, T, dh, dt)
    o_seq = ref.rwkv6_linear_attention(r, k, v, w, u)
    o_pal = rwkv6_chunked_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    tol = dict(atol=5e-4, rtol=1e-3) if dt == jnp.float32 else \
        dict(atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_seq, np.float32), **tol)


def test_state_carry_matches(rng):
    """Chunked with an initial state == running the oracle on the full seq."""
    B, H, T, dh = 1, 2, 64, 16
    r, k, v, w, u = make_inputs(rng, B, H, T, dh)
    o_full = ref.rwkv6_linear_attention(r, k, v, w, u)
    half = T // 2
    o1, S = rwkv6_chunked(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                          w[:, :, :half], u, chunk=16)
    o2, _ = rwkv6_chunked(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                          w[:, :, half:], u, chunk=16, state=S)
    o = jnp.concatenate([o1, o2], axis=2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               atol=5e-4, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(t_chunks=st.integers(1, 6), chunk=st.sampled_from([8, 16, 32]),
       dh=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1))
def test_property_chunk_invariance(t_chunks, chunk, dh, seed):
    """Property: the output must not depend on the chunk size."""
    rng = np.random.default_rng(seed)
    T = t_chunks * 32
    r, k, v, w, u = make_inputs(rng, 1, 1, T, dh)
    o_seq = ref.rwkv6_linear_attention(r, k, v, w, u)
    for c in {8, 16, 32}:
        if T % c:
            continue
        o_c, _ = rwkv6_chunked(r, k, v, w, u, chunk=c)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_seq),
                                   atol=1e-3, rtol=2e-3)


def test_model_wkv_pallas_core_matches_xla():
    """wkv_core='pallas' through the rwkv6 model == the chunked XLA core."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import lm
    rng = np.random.default_rng(0)
    cfg0 = configs.get_config("rwkv6_7b", reduced=True)
    toks = jnp.asarray(rng.integers(0, cfg0.vocab, (2, 32)), jnp.int32)
    batch = dict(tokens=toks, labels=jnp.roll(toks, -1, 1))
    p = lm.init_params(jax.random.PRNGKey(0), cfg0)
    outs = {}
    for core in ("xla", "pallas"):
        cfg = dataclasses.replace(cfg0, wkv_core=core)
        loss, _ = lm.loss_fn(p, cfg, batch)
        outs[core] = float(loss)
    assert abs(outs["xla"] - outs["pallas"]) < 1e-4, outs

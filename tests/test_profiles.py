"""Optimized execution profiles (§Perf findings as launcher rules)."""
import pytest

from repro import configs
from repro.launch import profiles


def test_head_padding_rules():
    qwen = configs.get_config("qwen2_5_14b")
    pad = profiles.padded_heads(qwen, 16)
    assert pad == dict(n_heads=48, kv_heads=16)
    # already divisible: no padding
    dsm = configs.get_config("deepseek_moe_16b")
    assert profiles.padded_heads(dsm, 16) == {}
    # MLA: untouched
    v3 = configs.get_config("deepseek_v3_671b")
    assert profiles.padded_heads(v3, 16) == {}
    # gqa divisibility preserved
    ilm = configs.get_config("internlm2_1_8b")
    pad = profiles.padded_heads(ilm, 16)
    nh = pad.get("n_heads", ilm.n_heads)
    kv = pad.get("kv_heads", ilm.kv_heads)
    assert nh % kv == 0 and nh % 16 == 0 and kv % 16 == 0


def test_zero1_size_rule():
    assert profiles.weights_fit_zero1(configs.get_config("internlm2_1_8b"), 16)
    assert profiles.weights_fit_zero1(configs.get_config("qwen2_5_14b"), 16)
    assert not profiles.weights_fit_zero1(
        configs.get_config("deepseek_v3_671b"), 16)
    assert not profiles.weights_fit_zero1(
        configs.get_config("mistral_large_123b"), 16)


def test_optimized_overrides_shapes():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        mo, ro = profiles.optimized_overrides(cfg, "train", 16)
        if "n_heads" in mo:
            assert mo["n_heads"] % 16 == 0
        if cfg.layer_pattern == "jamba":
            assert mo.get("mamba_core") == "pallas"
            assert ro is None            # v3 refutation: keep FSDP
        if arch == "deepseek_v3_671b":
            assert ro is None            # 671B needs FSDP

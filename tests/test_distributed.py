"""Sharding resolution, checkpointing, fault tolerance, elastic scaling,
data pipeline determinism."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.data import pipeline as data_mod
from repro.distributed import checkpoint as ckpt_mod
from repro.distributed import compression, elastic, fault_tolerance as ft
from repro.launch import sharding


class FakeMesh:
    """Duck-typed mesh for spec resolution tests (no 512 devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_divisible():
    rules = sharding.default_rules(MESH)
    s = sharding.spec_for((1024, 4096), ("embed", "mlp"), rules, MESH)
    assert s == P("data", "model")


def test_spec_fallback_on_indivisible():
    rules = sharding.default_rules(MESH)
    # kv dim 8*128=1024 divisible, but a raw kv_heads=8 dim is not
    s = sharding.spec_for((8, 128), ("kv", None), rules, MESH)
    assert s == P(None, None)


def test_spec_no_axis_reuse():
    rules = sharding.default_rules(MESH)
    s = sharding.spec_for((256, 512), ("mlp", "qkv"), rules, MESH)
    # both want "model"; only the first gets it
    assert s == P("model", None)


def test_spec_multi_pod_batch():
    rules = sharding.default_rules(MESH3)
    s = sharding.spec_for((256, 4096), ("batch", None), rules, MESH3)
    assert s == P(("pod", "data"), None)


def test_fallback_diagnostics():
    rules = sharding.default_rules(MESH)
    shapes = dict(w=jax.ShapeDtypeStruct((8, 128), jnp.float32))
    logical = dict(w=("kv", "embed"))
    notes = sharding.count_unsharded_fallbacks(shapes, logical, MESH, rules)
    assert any("kv=8" in n for n in notes)


# -- checkpointing -------------------------------------------------------------

def tree():
    return dict(a=jnp.arange(12.0).reshape(3, 4),
                nested=dict(b=jnp.ones((5,), jnp.int32)))


def test_checkpoint_roundtrip(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    t = tree()
    mgr.save(3, t, blocking=True)
    restored, step = mgr.restore(t)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], t["a"])
    np.testing.assert_array_equal(restored["nested"]["b"], t["nested"]["b"])


def test_checkpoint_corruption_falls_back(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    t = tree()
    mgr.save(1, t, blocking=True)
    mgr.save(2, jax.tree.map(lambda x: x + 1, t), blocking=True)
    # corrupt step 2
    with open(os.path.join(str(tmp_path), "step_000000000002",
                           "arrays.npz"), "ab") as f:
        f.write(b"garbage")
    assert mgr.latest_valid_step() == 1
    restored, step = mgr.restore(t)
    assert step == 1
    np.testing.assert_array_equal(restored["a"], t["a"])


def test_checkpoint_gc_keeps_k(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in range(5):
        mgr.save(s, tree(), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(7, tree())
    mgr.wait()
    assert mgr.latest_valid_step() == 7


# -- fault tolerance ------------------------------------------------------------

def test_heartbeat_dead_host():
    hb = ft.HeartbeatMonitor(timeout_s=10)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(0, now=8.0)
    assert hb.dead_hosts(now=15.0) == [1]
    assert hb.alive_hosts(now=15.0) == [0]


def test_straggler_detection():
    det = ft.StragglerDetector(threshold=1.5, min_samples=3)
    for _ in range(5):
        for h in range(4):
            det.observe(h, 1.0 if h != 2 else 3.0)
    assert det.stragglers() == [2]


def test_reassign_deterministic_and_complete():
    m1 = ft.reassign_shards(16, [0, 1, 3])
    m2 = ft.reassign_shards(16, [3, 0, 1])   # order must not matter
    assert m1 == m2
    covered = sorted(s for ss in m1.values() for s in ss)
    assert covered == list(range(16))


def test_retry_policy():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    pol = ft.RetryPolicy(max_retries=5, base_delay_s=0)
    assert pol.run(flaky, _sleep=lambda s: None) == "ok"
    assert len(calls) == 3


# -- elastic ---------------------------------------------------------------------

def test_best_mesh_shapes():
    assert elastic.best_mesh_shape(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert elastic.best_mesh_shape(256) == ((16, 16), ("data", "model"))
    assert elastic.best_mesh_shape(240) == ((15, 16), ("data", "model"))
    shape, axes = elastic.best_mesh_shape(8)
    assert np.prod(shape) <= 8


def test_plan_rescale_keeps_batch_when_divisible():
    plan = elastic.plan_rescale(256, 128, global_batch=256)
    assert plan["global_batch"] == 256
    plan = elastic.plan_rescale(256, 240, global_batch=256)
    assert plan["global_batch"] % (np.prod(plan["mesh_shape"]) //
                                   plan["mesh_shape"][-1]) == 0


def test_elastic_restore_roundtrip(tmp_path):
    """Checkpoint saved under one layout restores bit-exact under another."""
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    t = dict(w=jnp.arange(64.0).reshape(8, 8))
    mgr.save(1, t, blocking=True)
    restored, _ = mgr.restore(t)   # same host, new placement is a no-op here
    np.testing.assert_array_equal(restored["w"], t["w"])


# -- data pipeline -----------------------------------------------------------------

def test_pipeline_deterministic_and_restart_stable():
    p = data_mod.TokenPipeline(vocab=100, seq=8, global_batch=4, n_shards=2)
    b1 = p.batch(step=5, shard=1)
    b2 = p.batch(step=5, shard=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    full = data_mod.TokenPipeline(vocab=100, seq=8, global_batch=4,
                                  n_shards=2).batch(5, 1)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_pipeline_shards_disjoint():
    p = data_mod.TokenPipeline(vocab=1000, seq=16, global_batch=8, n_shards=4)
    rows = [p.batch(0, s)["tokens"] for s in range(4)]
    flat = np.stack([r.reshape(-1) for r in rows])
    # different shards see different data (overwhelmingly)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(flat[i], flat[j])


def test_shard_takeover_consistency():
    """Host B taking over shard 2 sees exactly what host A would have."""
    p = data_mod.TokenPipeline(vocab=100, seq=8, global_batch=8, n_shards=4)
    before = p.batch(step=9, shard=2)
    after = p.batch(step=9, shard=2)   # recomputed anywhere, any time
    np.testing.assert_array_equal(before["tokens"], after["tokens"])


# -- compression ---------------------------------------------------------------------

def test_compression_modes(rng):
    g = dict(w=jnp.asarray(rng.standard_normal((32, 32)), jnp.float32))
    out, _ = compression.compress(g, "none")
    np.testing.assert_array_equal(out["w"], g["w"])
    out, _ = compression.compress(g, "bf16")
    assert np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max() < 1e-1
    ef = compression.init_error_feedback(g)
    out, ef2 = compression.compress(g, "topk_ef", ef, topk_frac=0.1)
    nz = (np.asarray(out["w"]) != 0).mean()
    assert nz <= 0.15
    # error feedback carries the residual
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(ef2["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_elastic_restore_onto_new_mesh_layout(tmp_path):
    """Train-state checkpoint restores bit-exact onto a different mesh
    factorization (the elastic re-mesh path end-to-end on one host)."""
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    t = dict(w=jnp.arange(64.0).reshape(8, 8),
             m=jnp.ones((8, 8)) * 0.5)
    mgr.save(5, t, blocking=True)
    # "new fleet": a (1,1) mesh with different axis naming
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = dict(w=NamedSharding(mesh, P("data", "model")),
              m=NamedSharding(mesh, P(None, "model")))
    restored, step = mgr.restore(t, shardings=sh)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]


# -- fault-tolerance additions (crash-safe resume + quarantine support) ----------

def test_heartbeat_prune_after_report():
    """A long-dead host must not be re-reported on every poll: prune=True
    gives report-once semantics, and forget() drops a handled host."""
    hb = ft.HeartbeatMonitor(timeout_s=10)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(2, now=0.0)
    assert hb.dead_hosts(now=20.0, prune=True) == [0, 1, 2]
    assert hb.dead_hosts(now=25.0) == []         # pruned, not re-reported
    hb.beat(1, now=26.0)                         # re-registers fresh
    assert hb.alive_hosts(now=27.0) == [1]
    hb.forget(1)
    assert hb.dead_hosts(now=100.0) == []
    assert hb.alive_hosts(now=27.0) == []


def test_checkpoint_stale_tmp_ignored_and_gced(tmp_path):
    """A crash mid-write leaves step_<N>.tmp/ behind: it must never be a
    restore candidate, and a fresh manager GCs it on startup."""
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, tree(), blocking=True)
    stale = os.path.join(str(tmp_path), "step_000000000009.tmp")
    os.makedirs(stale)
    with open(os.path.join(stale, "arrays.npz"), "wb") as f:
        f.write(b"partial write")
    assert mgr.all_steps() == [1]                # tmp is not a step
    assert mgr.latest_valid_step() == 1
    mgr2 = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    assert not os.path.exists(stale)             # GC'd on init
    assert mgr2.latest_valid_step() == 1         # real steps untouched


def test_checkpoint_gc_keep_holds_with_aux(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in range(5):
        mgr.save(s, tree(), aux=dict(cursor=s), blocking=True)
    assert mgr.all_steps() == [3, 4]
    assert mgr.load_aux() == dict(cursor=4)
    assert mgr.load_aux(step=3) == dict(cursor=3)


def test_checkpoint_aux_roundtrip_and_none(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    aux = dict(cursor=7, losses=[1.0, 0.5], state=(1, 2, ("x",)))
    mgr.save(7, tree(), aux=aux, blocking=True)
    assert mgr.load_aux() == aux
    mgr.save(8, tree(), blocking=True)           # no aux on this one
    assert mgr.load_aux(step=8) is None


def test_checkpoint_aux_corruption_falls_back(tmp_path):
    """A corrupted aux payload invalidates the whole step (params without
    the cursor/cache state cannot resume bit-identically), falling back to
    the previous step."""
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, tree(), aux=dict(cursor=1), blocking=True)
    mgr.save(2, tree(), aux=dict(cursor=2), blocking=True)
    with open(os.path.join(str(tmp_path), "step_000000000002",
                           "aux.pkl"), "ab") as f:
        f.write(b"garbage")
    assert mgr.latest_valid_step() == 1
    assert mgr.load_aux() == dict(cursor=1)
    restored, step = mgr.restore(tree())
    assert step == 1


def test_retry_policy_fatal_fails_fast():
    calls = []

    def broken():
        calls.append(1)
        raise ValueError("deterministic bug")

    pol = ft.RetryPolicy(max_retries=5, base_delay_s=0)
    with pytest.raises(ValueError):
        pol.run(broken, _sleep=lambda s: None,
                retryable=ft.default_transient)
    assert len(calls) == 1                       # no retry burned


def test_retry_policy_cancel_interrupts_backoff():
    """close() during a backoff must not sleep out the delay ladder: the
    cancel event doubles as the timer and re-raises promptly."""
    import threading as th
    import time as _t
    cancel = th.Event()

    def flaky():
        cancel.set()                             # "close() arrives" mid-run
        raise ft.TransientError("flaky")

    pol = ft.RetryPolicy(max_retries=10, base_delay_s=30.0)
    t0 = _t.perf_counter()
    with pytest.raises(ft.TransientError):
        pol.run(flaky, cancel=cancel, retryable=ft.default_transient)
    assert _t.perf_counter() - t0 < 5.0

"""REQUIRED per-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, asserting output shapes + no NaNs; plus a decode
step for every decoder arch."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import lm
from repro.optim import adamw
from repro.train import steps as steps_mod

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = dict(labels=jnp.roll(toks, -1, 1))
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        batch["tokens"] = toks
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
        if cfg.mrope_sections is not None:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    else:
        batch["tokens"] = toks
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = lm.init_params(KEY, cfg)
    batch = make_batch(cfg)
    logits, aux = jax.jit(lambda p, b: lm.forward(p, cfg, b))(params, batch)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = lm.init_params(KEY, cfg)
    opt_state = adamw.init_state(params)
    opt_cfg = adamw.OptConfig(lr=5e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
    batch = make_batch(cfg)
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), arch
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", [a for a in configs.ARCHS])
def test_decode_step_smoke(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = lm.init_params(KEY, cfg)
    B, S_max = 2, 8
    caches = lm.init_cache(cfg, B, S_max)
    if cfg.input_mode == "embeds":
        tok = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(steps_mod.make_serve_step(cfg))
    nxt, logits, caches = step(params, caches, tok, jnp.int32(0))
    assert nxt.shape == (B, 1)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert (np.asarray(nxt) < cfg.vocab).all()
    # second step with updated cache
    nxt2, _, caches = step(params, caches, tok, jnp.int32(1))
    assert np.isfinite(np.asarray(nxt2, np.float32)).all()


@pytest.mark.parametrize("arch", ["jamba_v0_1_52b", "rwkv6_7b"])
def test_subquadratic_flag(arch):
    cfg = configs.get_config(arch)
    assert cfg.subquadratic
    ok, _ = configs.shape_applicable(cfg, "long_500k")
    assert ok


def test_quadratic_archs_skip_long():
    cfg = configs.get_config("qwen2_5_14b")
    ok, reason = configs.shape_applicable(cfg, "long_500k")
    assert not ok and reason


DECODER_TOKEN_ARCHS = [a for a in configs.ARCHS
                       if configs.get_config(a, reduced=True).family ==
                       "decoder"
                       and configs.get_config(a, reduced=True).input_mode ==
                       "tokens"]


@pytest.mark.parametrize("arch", DECODER_TOKEN_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Cache-producing prefill hands off to decode with teacher-forced
    logits identical to the full forward pass."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_config(arch, reduced=True),
                              mtp=False)
    rng = np.random.default_rng(0)
    B, P, S = 2, 8, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    params = lm.init_params(KEY, cfg)
    full_logits, _ = lm.forward(params, cfg, dict(tokens=toks))
    logits_pre, caches = lm.prefill(params, cfg, dict(tokens=toks[:, :P]),
                                    s_max=S)
    np.testing.assert_allclose(np.asarray(logits_pre, np.float32),
                               np.asarray(full_logits[:, :P], np.float32),
                               atol=1e-3, rtol=1e-3)
    for t in range(P, S):
        lg, _, caches = lm.decode_step(params, cfg, caches, toks[:, t:t + 1], t)
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full_logits[:, t], np.float32),
                                   atol=1e-3, rtol=1e-3, err_msg=f"{arch}@{t}")

"""LM block unit tests: decode-vs-forward consistency, GQA vs oracle, MoE
path equivalence, numerical hygiene."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.models import blocks as B

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def x(rng):
    return jnp.asarray(rng.standard_normal((2, 12, 64)), jnp.float32)


@pytest.fixture
def pos():
    return jnp.broadcast_to(jnp.arange(12)[None], (2, 12))


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_gqa_matches_oracle(rng, x, pos, hq, hkv):
    cfg = B.AttnConfig(d_model=64, n_heads=hq, kv_heads=hkv,
                       head_dim=64 // hq, use_rope=False)
    p = B.init_attention(KEY, cfg)
    y = B.attention_apply(p, cfg, x, pos)
    # manual oracle
    q = (x @ p["wq"]).reshape(2, 12, hq, cfg.head_dim).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(2, 12, hkv, cfg.head_dim).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(2, 12, hkv, cfg.head_dim).transpose(0, 2, 1, 3)
    o = ref.mha(q, k, v, causal=True)
    y_ref = o.transpose(0, 2, 1, 3).reshape(2, 12, -1) @ p["wo"]
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kind", ["attn", "mla", "mamba", "rwkv"])
def test_decode_matches_forward(rng, kind):
    x = jnp.asarray(rng.standard_normal((2, 8, 64)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    if kind == "attn":
        cfg = B.AttnConfig(d_model=64, n_heads=4, kv_heads=2, head_dim=16)
        p = B.init_attention(KEY, cfg)
        y_full = B.attention_apply(p, cfg, x, pos)
        cache = B.init_attn_cache(cfg, 2, 8, jnp.float32)
        step = lambda xt, c, t: B.attention_decode(p, cfg, xt, c, t)
    elif kind == "mla":
        cfg = B.MLAConfig(d_model=64, n_heads=4, q_lora_rank=32,
                          kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                          v_dim=16)
        p = B.init_mla(KEY, cfg)
        y_full = B.mla_apply(p, cfg, x, pos)
        cache = B.init_mla_cache(cfg, 2, 8, jnp.float32)
        step = lambda xt, c, t: B.mla_decode(p, cfg, xt, c, t, absorbed=True)
    elif kind == "mamba":
        cfg = B.MambaConfig(d_model=64, d_inner=128, d_state=4)
        p = B.init_mamba(KEY, cfg)
        y_full = B.mamba_apply(p, cfg, x)
        cache = B.init_mamba_cache(cfg, 2, jnp.float32)
        step = lambda xt, c, t: B.mamba_decode(p, cfg, xt, c)
    else:
        cfg = B.RWKV6Config(d_model=64, head_dim=16, chunk=4)
        p = B.init_rwkv6(KEY, cfg)
        y_full, _ = B.rwkv6_time_mix(p, cfg, x)
        cache = dict(x_prev=jnp.zeros((2, 1, 64)), S=None)

        def step(xt, c, t):
            y, (xp, S) = B.rwkv6_time_mix(p, cfg, xt, x_prev=c["x_prev"],
                                          state=c["S"], use_chunked=False)
            return y, dict(x_prev=xp, S=S)

    ys = []
    for t in range(8):
        y, cache = step(x[:, t:t + 1], cache, t)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_full, atol=2e-5, rtol=1e-4)


def test_moe_dense_equals_sparse_no_drops(rng):
    cfg = B.MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0)
    p = B.init_moe(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    yd, _ = B.moe_apply_dense(p, cfg, x)
    ys, _ = B.moe_apply_sparse(p, cfg, x)
    np.testing.assert_allclose(yd, ys, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_bounded(rng):
    """With capacity 1.0 some tokens may drop, but outputs stay finite and
    dropped tokens produce exactly zero (plus shared-expert path if any)."""
    cfg = B.MoEConfig(d_model=16, n_experts=4, top_k=1, d_ff_expert=32,
                      capacity_factor=0.5)
    p = B.init_moe(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    y, aux = B.moe_apply_sparse(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))


def test_moe_adaptive_path_rule():
    dense_cfg = B.MoEConfig(d_model=8, n_experts=2, top_k=1, d_ff_expert=8)
    sparse_cfg = B.MoEConfig(d_model=8, n_experts=256, top_k=8, d_ff_expert=8)
    assert B.choose_moe_path(dense_cfg, n_tokens=10_000) == "dense"
    assert B.choose_moe_path(sparse_cfg, n_tokens=10_000) == "sparse"


def test_rwkv_decay_clamp(rng):
    """Extreme LoRA outputs must not produce w outside the fp32-safe band."""
    cfg = B.RWKV6Config(d_model=64, head_dim=16)
    p = B.init_rwkv6(KEY, cfg)
    p = dict(p, w0=jnp.full((64,), 50.0))   # absurd decay request
    x = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    y, _ = B.rwkv6_time_mix(p, cfg, x, use_chunked=False)
    assert np.isfinite(np.asarray(y)).all()


def test_mrope_reduces_to_rope_for_text():
    from repro.layers import rope
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 6, 3, 16)), jnp.float32)
    pos1 = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos1[None], (3, 2, 6))
    y_rope = rope.apply_rope(x, pos1)
    y_mrope = rope.apply_mrope(x, pos3, sections=(2, 3, 3))
    np.testing.assert_allclose(y_rope, y_mrope, atol=1e-5)


def test_rope_preserves_norm(rng):
    from repro.layers import rope
    x = jnp.asarray(rng.standard_normal((1, 5, 2, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(5)[None], (1, 5))
    y = rope.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), atol=1e-4,
                               rtol=1e-4)


from _hypothesis_compat import given, settings, st


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 64), e=st.integers(2, 8), k=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_property_moe_dispatch_invariants(n, e, k, seed):
    """MoE sparse dispatch invariants: finite outputs; zero input -> zero
    routed output; combine weights are a convex combination (sum to 1 over
    the selected experts) so outputs are bounded by expert output norms."""
    if k > e:
        return
    rng = np.random.default_rng(seed)
    cfg = B.MoEConfig(d_model=8, n_experts=e, top_k=k, d_ff_expert=16,
                      capacity_factor=8.0)
    p = B.init_moe(jax.random.PRNGKey(seed % 1000), cfg)
    x = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    y, aux = B.moe_apply_sparse(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))
    y0, _ = B.moe_apply_sparse(p, cfg, jnp.zeros((n, 8)))
    assert np.abs(np.asarray(y0)).max() < 1e-5
    # with no drops, sparse == dense (the invariant AdaptGear relies on:
    # execution path changes speed, not math)
    yd, _ = B.moe_apply_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd), atol=1e-3,
                               rtol=1e-3)

"""Optimizer + train-step machinery."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import adamw


def test_adamw_minimizes_quadratic():
    params = dict(w=jnp.asarray([5.0, -3.0]))
    state = adamw.init_state(params)
    cfg = adamw.OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000, min_lr_frac=1.0)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = adamw.update(params, grads, state, cfg)
    assert np.abs(np.asarray(params["w"])).max() < 1e-2


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.int32(0)))
    lr10 = float(adamw.schedule(cfg, jnp.int32(10)))
    lr100 = float(adamw.schedule(cfg, jnp.int32(100)))
    assert lr0 < 0.05
    assert abs(lr10 - 1.0) < 0.05
    assert abs(lr100 - 0.1) < 0.02


def test_clip_by_global_norm():
    g = dict(a=jnp.asarray([3.0, 4.0]))
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               atol=1e-5)


def test_grad_accumulation_matches_full_batch():
    """accum=4 over a batch == accum=1 on the same batch (same grads up to
    fp error), for a model whose loss is a mean over examples."""
    from repro import configs
    from repro.models import lm
    from repro.train import steps as steps_mod

    cfg = configs.get_config("internlm2_1_8b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.OptConfig(lr=1e-3)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)
    batch = dict(tokens=toks, labels=jnp.roll(toks, -1, 1))

    out = {}
    for accum in (1, 4):
        step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg,
                                                 accum_steps=accum))
        p2, _, m = step(params, adamw.init_state(params), batch)
        out[accum] = (float(m["loss"]), p2)
    assert abs(out[1][0] - out[4][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(out[1][1]), jax.tree.leaves(out[4][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_train_with_compression_runs():
    from repro.launch import train as train_mod
    res = train_mod.train("internlm2_1_8b", steps=3, seq=16, global_batch=4,
                          grad_compression="bf16", verbose=False)
    assert np.isfinite(res["final_loss"])


def test_train_checkpoint_resume(tmp_path):
    from repro.launch import train as train_mod
    d = str(tmp_path / "ck")
    r1 = train_mod.train("internlm2_1_8b", steps=6, seq=16, global_batch=4,
                         ckpt_dir=d, ckpt_every=3, verbose=False)
    # resume: runs only the remaining steps from the checkpoint
    r2 = train_mod.train("internlm2_1_8b", steps=9, seq=16, global_batch=4,
                         ckpt_dir=d, ckpt_every=3, verbose=False)
    assert len(r2["losses"]) == 3  # resumed at step 6
    assert np.isfinite(r2["final_loss"])

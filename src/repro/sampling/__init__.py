"""Mini-batch sampled-subgraph training (beyond-paper subsystem).

Full-batch AdaptGear exercises kernel selection against one static density
profile.  Sampling makes every training step a fresh density distribution —
exactly the regime where the paper's §4 dynamic selection has to be
*amortized* rather than recomputed:

  graphs.Graph
      |  sampling.sampler (ClusterSampler | NeighborSampler)
      v
  SampledBatch -- fixed-shape padded node/edge budgets (masked loss), so
      |            every batch shares one pytree structure and the jitted
      |            step compiles once
      |  core.decompose.decompose(reorder=False, keep_empty_buckets=True)
      v
  Decomposed (per batch)
      |  sampling.plan_cache.PlanCache -- quantized density signature ->
      |  memoized KernelPlan (cost-model selection on miss, reuse on hit)
      v
  train.gnn_steps.make_sampled_step -- jit step(params, opt, dec, batch)
"""
from repro.sampling.sampler import (ClusterSampler, NeighborSampler,
                                    SampledBatch)
from repro.sampling.plan_cache import (MB_KERNELS, PlanCache,
                                       density_signature, fix_shapes,
                                       plan_payload_keys)

__all__ = ["ClusterSampler", "NeighborSampler", "SampledBatch",
           "PlanCache", "MB_KERNELS", "density_signature", "fix_shapes",
           "plan_payload_keys"]

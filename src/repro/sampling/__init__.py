"""Mini-batch sampled-subgraph training (beyond-paper subsystem).

Full-batch AdaptGear exercises kernel selection against one static density
profile.  Sampling makes every training step a fresh density distribution —
exactly the regime where the paper's §4 dynamic selection has to be
*amortized* rather than recomputed:

  graphs.Graph
      |  sampling.sampler (ClusterSampler | NeighborSampler)
      v
  SampledBatch -- fixed-shape padded node/edge budgets (masked loss), so
      |            every batch shares one pytree structure and the jitted
      |            step compiles once
      |  core.decompose.decompose_skeleton(reorder=False,
      |  keep_empty_buckets=True, edge_budget=...)  [one partition pass]
      v
  DecomposeSkeleton (per batch)
      |  sampling.plan_cache.PlanCache -- quantized density signature read
      |  off the skeleton -> memoized KernelPlan (cost-model selection on
      |  miss, probe-on-Nth-miss pinning, reuse on hit); then
      |  skel.materialize(plan_payload_keys(plan)) builds only the
      |  committed payloads
      v
  train.gnn_steps.make_sampled_step -- jit step(params, opt, dec, batch)
"""
from repro.sampling.sampler import (ClusterSampler, NeighborSampler,
                                    SampledBatch)
from repro.sampling.plan_cache import (MB_KERNELS, PlanCache,
                                       density_signature, fix_shapes,
                                       plan_payload_keys)

__all__ = ["ClusterSampler", "NeighborSampler", "SampledBatch",
           "PlanCache", "MB_KERNELS", "density_signature", "fix_shapes",
           "plan_payload_keys"]

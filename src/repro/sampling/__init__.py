"""Mini-batch sampled-subgraph training (beyond-paper subsystem).

Full-batch AdaptGear exercises kernel selection against one static density
profile.  Sampling makes every training step a fresh density distribution —
exactly the regime where the paper's §4 dynamic selection has to be
*amortized* rather than recomputed:

  graphs.Graph
      |  sampling.sampler (ClusterSampler | NeighborSampler)
      v
  SampledBatch -- fixed-shape padded node/edge budgets (masked loss), so
      |            every batch shares one pytree structure and the jitted
      |            step compiles once
      |  core.decompose.decompose_skeleton(reorder=False,
      |  keep_empty_buckets=True, edge_budget=...)  [one partition pass]
      v
  DecomposeSkeleton (per batch)
      |  sampling.plan_cache.PlanCache -- quantized density signature read
      |  off the skeleton -> memoized KernelPlan (cost-model selection on
      |  miss, probe-on-Nth-miss pinning, reuse on hit); then
      |  skel.materialize(plan_payload_keys(plan)) builds only the
      |  committed payloads
      v
  train.gnn_steps.make_sampled_step -- jit step(params, opt, dec, batch)

The whole host column runs either inline (cfg.prefetch_depth=0) or on
train.pipeline.BatchPipeline worker threads (prefetch_depth>0): samplers
split drawing into a cheap sequential draw() -> DrawTicket and a pure,
thread-safe build(ticket) whose randomness is a function of (seed, ticket
index), so the async batch stream is bit-identical to the sync one; the
PlanCache serializes lookup/selection/probing/budget-K bookkeeping behind
one lock so concurrent workers preserve its hit rate and counters.
"""
from repro.sampling.sampler import (ClusterSampler, DrawTicket,
                                    NeighborSampler, SampledBatch)
from repro.sampling.plan_cache import (MB_KERNELS, PlanCache,
                                       density_signature, fix_shapes,
                                       plan_payload_keys)

__all__ = ["ClusterSampler", "DrawTicket", "NeighborSampler",
           "SampledBatch", "PlanCache", "MB_KERNELS", "density_signature",
           "fix_shapes", "plan_payload_keys"]

"""Mini-batch samplers emitting fixed-shape padded :class:`SampledBatch`es.

Two samplers, both host-side numpy (sampling is preprocessing, like the
paper's §3.3 decomposition) and both deterministic under a fixed seed:

* :class:`ClusterSampler` — Cluster-GCN-style community-block sampling.
  The full graph is reordered once with the same community orderings
  ``decompose`` uses (``REORDERERS``); a *cluster* is one ``block``-sized
  slice of the reordered id space, i.e. exactly one diagonal block of the
  full-graph decomposition.  A batch is the induced subgraph over ``q``
  randomly drawn clusters (epoch-shuffled without replacement, Chiang et
  al.'s stochastic multiple partitions), laid out so cluster ``j`` occupies
  local rows ``[j*block, (j+1)*block)`` — the per-batch
  ``decompose(reorder=False)`` then lands intra-cluster edges on the
  diagonal for free.

* :class:`NeighborSampler` — layer-wise neighbor sampling (GraphSAGE):
  seed nodes plus up to ``fanout[l]`` sampled in-neighbors per node per
  layer.  Only the seeds carry loss (``target_mask``).  Sampled nodes are
  sorted by the precomputed community ordering so the per-batch
  decomposition still finds what little block structure a neighbor-sampled
  subgraph has; the degree profile it produces is the scale-free skew the
  sell-C-sigma kernel targets.

Every batch is padded to a fixed ``node_budget`` x ``edge_budget`` (zero
features / masked rows / dropped-edge accounting), so the downstream jitted
train step never retraces: same ShapeDtypeStructs batch after batch.

Async pipeline contract (train/pipeline.py): ``sample()`` is split into a
cheap, lock-protected :meth:`draw` that consumes the *sequential* epoch
state and pins batch ``index``'s cluster/seed set in a :class:`DrawTicket`,
and a pure, thread-safe :meth:`build` that does the heavy work (induced
edges, feature gather, padding).  All randomness inside ``build`` comes
from a per-batch stream that is a pure function of (sampler seed, batch
index) — epoch permutations likewise key off (seed, epoch number) — so
pipeline workers can build batches out of order and the stream stays
bit-identical to sequential ``sample()`` calls under the same seed.
"""
from __future__ import annotations

import threading
import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.core.decompose import REORDERERS, resolve_method
from repro.graphs.graph import Graph

# stream tags keep the per-epoch and per-batch child streams disjoint
_EPOCH_TAG = 0x9E3779B9
_BATCH_TAG = 0x85EBCA6B


def _stream_rng(entropy: int, tag: int, index: int) -> np.random.Generator:
    """Deterministic child stream: a pure function of (sampler seed, stream
    tag, index).  Batch i's randomness no longer depends on how many draws
    preceded it, which is what lets pipeline workers build batches on any
    thread in any order yet bit-identical to the sequential path."""
    return np.random.default_rng(
        np.random.SeedSequence((entropy, tag, index)))


@dataclass(frozen=True)
class DrawTicket:
    """Snapshot of one sequential draw: everything :meth:`build` needs to
    construct batch ``index`` deterministically on any thread."""
    index: int           # 0-based position in the sampler's batch stream
    chosen: np.ndarray   # clusters (ClusterSampler) | seeds (NeighborSampler)


@dataclass
class SampledBatch:
    """One fixed-shape mini-batch (host numpy; device transfer happens in
    the train step).  All arrays are padded to the sampler's budgets.

    ``nodes[i]`` is the original graph id of local row ``i`` (-1 where
    padded); edges are in *local* ids with the aggregation convention of
    the rest of the system (receivers = dst rows, senders = src cols).
    """
    n: int                     # node budget (== len(nodes))
    nodes: np.ndarray          # (n,) int32 original ids, -1 padding
    node_mask: np.ndarray      # (n,) bool, True where a real node sits
    senders: np.ndarray        # (edge_budget,) int32 local src, 0 padding
    receivers: np.ndarray      # (edge_budget,) int32 local dst, 0 padding
    edge_mask: np.ndarray      # (edge_budget,) bool
    features: np.ndarray       # (n, F) float32, 0 where padded
    labels: np.ndarray         # (n,) int32, 0 where padded
    target_mask: np.ndarray    # (n,) bool — rows that carry loss
    meta: dict = field(default_factory=dict)

    @property
    def n_real_nodes(self) -> int:
        return int(self.node_mask.sum())

    @property
    def n_real_edges(self) -> int:
        return int(self.edge_mask.sum())

    def real_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """(senders, receivers) restricted to real (unpadded) edges."""
        m = self.edge_mask
        return self.senders[m], self.receivers[m]


def _pack_edges(src: np.ndarray, dst: np.ndarray, edge_budget: int,
                meta: dict, rng: np.random.Generator | None = None
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Truncate to the budget and pad with masked (0, 0) entries.

    Over-budget batches keep a *random* subset (drawn from the sampler's
    seeded rng, so runs stay reproducible): a deterministic prefix cut
    would drop the same structural edges every time a batch recurs,
    silently biasing training.  The dropped count lands in ``meta``."""
    n_e = len(src)
    dropped = max(n_e - edge_budget, 0)
    if dropped:
        warnings.warn(
            f"sampled batch exceeds edge budget ({n_e} > {edge_budget}); "
            f"dropping a random {dropped}-edge subset — raise the budget "
            "to train on every induced edge", UserWarning, stacklevel=3)
        if rng is not None:
            keep = np.sort(rng.choice(n_e, edge_budget, replace=False))
        else:
            keep = np.arange(edge_budget)
        src, dst = src[keep], dst[keep]
        n_e = edge_budget
    s = np.zeros(edge_budget, np.int32)
    d = np.zeros(edge_budget, np.int32)
    m = np.zeros(edge_budget, bool)
    s[:n_e], d[:n_e], m[:n_e] = src, dst, True
    meta["dropped_edges"] = dropped
    return s, d, m


def _gather_node_arrays(graph: Graph, nodes: np.ndarray,
                        node_mask: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
    feats = np.zeros((len(nodes), graph.features.shape[-1]), np.float32)
    labels = np.zeros(len(nodes), np.int32)
    real = node_mask.nonzero()[0]
    feats[real] = graph.features[nodes[real]]
    labels[real] = graph.labels[nodes[real]]
    return feats, labels


class ClusterSampler:
    """Community-block (Cluster-GCN) sampler over precomputed orderings.

    ``node_budget`` is implied: ``clusters_per_batch * block`` (each drawn
    cluster owns its full block of local rows, partially-filled clusters
    padded in place so the per-batch block-diagonal split stays aligned).
    """

    def __init__(self, graph: Graph, block: int = 16,
                 clusters_per_batch: int = 8, method: str = "louvain",
                 edge_budget: int | None = None, seed: int = 0):
        self.graph = graph
        self.block = block
        self.q = min(clusters_per_batch,
                     max((graph.n + block - 1) // block, 1))
        self.node_budget = self.q * block
        # one reordering for the whole run — the same community structure
        # decompose() would compute, reused across every batch
        self.perm = REORDERERS[resolve_method(method)](
            graph.n, graph.senders, graph.receivers, block)
        self.n_clusters = (graph.n + block - 1) // block
        # members[c] = original ids of cluster c, in reordered order
        order = np.argsort(self.perm, kind="stable")   # old id of new id
        self.members = [order[c * block: (c + 1) * block]
                        for c in range(self.n_clusters)]
        frac = self.node_budget / max(graph.n, 1)
        self.edge_budget = (int(edge_budget) if edge_budget else
                            max(1024, int(4 * graph.n_edges * frac)))
        self.seed = int(seed)
        self._entropy = self.seed & ((1 << 63) - 1)
        self._lock = threading.Lock()
        self._epoch: list[int] = []
        self._epoch_no = 0
        self._n_drawn = 0

    def _draw_clusters(self) -> np.ndarray:
        # epoch-shuffled without replacement; when a batch straddles an
        # epoch boundary, an id already drawn for *this batch* is deferred
        # to later in the fresh epoch (not dropped — it must still get its
        # draw) so a batch never contains a duplicate cluster, which would
        # duplicate its nodes and double-count them in the masked loss.
        # Epoch e's permutation keys off (seed, e), not a mutating rng, so
        # the stream is reproducible from the draw count alone.
        out: list[int] = []
        while len(out) < self.q:
            if not self._epoch:
                self._epoch = _stream_rng(
                    self._entropy, _EPOCH_TAG, self._epoch_no).permutation(
                        self.n_clusters).tolist()[::-1]
                self._epoch_no += 1
            c = self._epoch.pop()
            if c in out:
                self._epoch.insert(0, c)
            else:
                out.append(c)
        return np.asarray(sorted(out))

    def draw(self) -> DrawTicket:
        """Consume the sequential epoch stream (thread-safe, cheap — a few
        list pops) and pin batch ``index``'s cluster set.  The pipeline
        calls this under its dispatch lock in index order; the heavy
        :meth:`build` then runs on any worker thread."""
        with self._lock:
            idx = self._n_drawn
            self._n_drawn += 1
            chosen = self._draw_clusters()
        return DrawTicket(idx, chosen)

    def fast_forward(self, n: int) -> None:
        """Advance the sequential draw state to draw number ``n`` (resume
        path: the next :meth:`draw` returns the ticket batch ``n`` of the
        uninterrupted stream would have).  The epoch state is a pure
        function of the draw count, so replaying the draws — a few list
        pops each, no batch builds — reproduces it exactly."""
        if n < self._n_drawn:
            raise ValueError(f"cannot rewind sampler: {n} < {self._n_drawn} "
                             "draws already consumed")
        while self._n_drawn < n:
            self.draw()

    def build(self, ticket: DrawTicket) -> SampledBatch:
        """Materialize the ticket's batch: pure given the ticket (per-batch
        randomness streams off (seed, ticket.index)), so it is thread-safe
        and order-independent."""
        chosen = ticket.chosen
        B, nb = self.block, self.node_budget
        nodes = np.full(nb, -1, np.int64)
        node_mask = np.zeros(nb, bool)
        local_of = np.full(self.graph.n, -1, np.int64)
        for j, c in enumerate(chosen):
            mem = self.members[c]
            nodes[j * B: j * B + len(mem)] = mem
            node_mask[j * B: j * B + len(mem)] = True
            local_of[mem] = j * B + np.arange(len(mem))
        # induced edges: both endpoints inside the drawn clusters
        ls = local_of[self.graph.senders]
        lr = local_of[self.graph.receivers]
        keep = (ls >= 0) & (lr >= 0)
        meta = dict(clusters=chosen.tolist())
        s, d, m = _pack_edges(ls[keep].astype(np.int32),
                              lr[keep].astype(np.int32),
                              self.edge_budget, meta,
                              rng=_stream_rng(self._entropy, _BATCH_TAG,
                                              ticket.index))
        feats, labels = _gather_node_arrays(self.graph,
                                            nodes.astype(np.int64),
                                            node_mask)
        return SampledBatch(
            n=nb, nodes=nodes.astype(np.int32), node_mask=node_mask,
            senders=s, receivers=d, edge_mask=m, features=feats,
            labels=labels, target_mask=node_mask.copy(), meta=meta)

    def sample(self) -> SampledBatch:
        return self.build(self.draw())


class NeighborSampler:
    """Layer-wise in-neighbor sampling: ``batch_nodes`` loss-carrying seeds,
    expanded by ``fanouts`` rounds of up-to-``f`` sampled in-neighbors.

    Budgets are the construction worst case (fixed, so shapes never vary):
    ``node_budget = batch_nodes * (1 + f1 + f1*f2 + ...)`` and
    ``edge_budget = batch_nodes * (f1 + f1*f2 + ...)``, each clamped to
    what the graph can actually supply (distinct nodes <= n, distinct
    edges <= n_edges — without the clamp a small graph would pad every
    batch larger than the graph itself).
    """

    def __init__(self, graph: Graph, batch_nodes: int = 128,
                 fanouts: tuple = (8, 4), method: str = "louvain",
                 block: int = 16, seed: int = 0):
        self.graph = graph
        self.batch_nodes = min(batch_nodes, graph.n)
        self.fanouts = tuple(int(f) for f in fanouts)
        widths = [self.batch_nodes]
        for f in self.fanouts:
            widths.append(min(widths[-1] * f, graph.n_edges))
        self.node_budget = (-(-min(sum(widths), graph.n) // block) * block)
        self.edge_budget = max(min(sum(widths[1:]), graph.n_edges), 1)
        # in-neighbor CSR (aggregation gathers from in-neighbors)
        order = np.argsort(graph.receivers, kind="stable")
        self._srt_src = graph.senders[order]
        counts = np.bincount(graph.receivers, minlength=graph.n)
        self._indptr = np.zeros(graph.n + 1, np.int64)
        np.cumsum(counts, out=self._indptr[1:])
        # community order used to lay sampled nodes out in blocks
        self.perm = REORDERERS[resolve_method(method)](
            graph.n, graph.senders, graph.receivers, block)
        self.seed = int(seed)
        self._entropy = self.seed & ((1 << 63) - 1)
        self._lock = threading.Lock()
        self._epoch: list[int] = []
        self._epoch_no = 0
        self._n_drawn = 0

    def _draw_seeds(self) -> np.ndarray:
        # same epoch-boundary defer-dedup as ClusterSampler._draw_clusters:
        # a duplicate seed would emit its sampled in-edges twice
        out: list[int] = []
        seen: set[int] = set()
        while len(out) < self.batch_nodes:
            if not self._epoch:
                self._epoch = _stream_rng(
                    self._entropy, _EPOCH_TAG, self._epoch_no).permutation(
                        self.graph.n).tolist()[::-1]
                self._epoch_no += 1
            v = self._epoch.pop()
            if v in seen:
                self._epoch.insert(0, v)
            else:
                seen.add(v)
                out.append(v)
        return np.asarray(out, np.int64)

    def _sample_neighbors(self, v: int, fanout: int,
                          rng: np.random.Generator) -> np.ndarray:
        lo, hi = self._indptr[v], self._indptr[v + 1]
        deg = hi - lo
        if deg <= fanout:
            return self._srt_src[lo:hi]
        pick = rng.choice(deg, size=fanout, replace=False)
        return self._srt_src[lo + np.sort(pick)]

    def draw(self) -> DrawTicket:
        """Consume the sequential seed-epoch stream (thread-safe, cheap);
        the fanout expansion happens in :meth:`build` off the ticket's
        per-batch rng stream."""
        with self._lock:
            idx = self._n_drawn
            self._n_drawn += 1
            seeds = self._draw_seeds()
        return DrawTicket(idx, seeds)

    def fast_forward(self, n: int) -> None:
        """Advance the sequential draw state to draw number ``n`` by
        replaying draws (see :meth:`ClusterSampler.fast_forward`)."""
        if n < self._n_drawn:
            raise ValueError(f"cannot rewind sampler: {n} < {self._n_drawn} "
                             "draws already consumed")
        while self._n_drawn < n:
            self.draw()

    def ego_ticket(self, seeds, index: int) -> DrawTicket:
        """Ticket for an *ego-net query* (serving): expand the caller's own
        seed set instead of consuming the training epoch stream.

        Seeds are validated, deduped and sorted — :meth:`build` assumes a
        duplicate-free seed set (a duplicate would emit its sampled
        in-edges twice and overflow the edge budget), and sorting makes
        the batch a pure function of the seed *set*, not the caller's
        ordering.  ``index`` picks the per-query rng stream, so the same
        (seeds, index) pair reproduces the same :class:`SampledBatch`
        bit-for-bit on any thread — the property the micro-batcher's
        retries rely on.  At most ``batch_nodes`` seeds fit one batch
        (fewer is fine: padding absorbs the slack)."""
        seeds = np.unique(np.asarray(seeds, np.int64))
        if seeds.size == 0:
            raise ValueError("ego_ticket needs at least one seed node")
        if seeds[0] < 0 or seeds[-1] >= self.graph.n:
            raise ValueError(
                f"seed ids must lie in [0, {self.graph.n}); got "
                f"[{seeds[0]}, {seeds[-1]}]")
        if seeds.size > self.batch_nodes:
            raise ValueError(
                f"{seeds.size} seeds exceed batch_nodes={self.batch_nodes}")
        return DrawTicket(int(index), seeds)

    def build(self, ticket: DrawTicket) -> SampledBatch:
        """Fanout expansion + padding for one ticket: thread-safe (reads
        only the immutable CSR/ordering arrays; randomness streams off
        (seed, ticket.index))."""
        rng = _stream_rng(self._entropy, _BATCH_TAG, ticket.index)
        seeds = ticket.chosen
        in_batch = set(seeds.tolist())
        frontier = seeds
        edges_s: list[np.ndarray] = []
        edges_d: list[np.ndarray] = []
        for f in self.fanouts:
            nxt: list[int] = []
            for v in frontier:
                nbr = self._sample_neighbors(int(v), f, rng)
                if len(nbr) == 0:
                    continue
                edges_s.append(nbr)
                edges_d.append(np.full(len(nbr), v, np.int64))
                for u in nbr.tolist():
                    if u not in in_batch:
                        in_batch.add(u)
                        nxt.append(u)
            frontier = np.asarray(nxt, np.int64)
        batch_nodes = np.fromiter(in_batch, np.int64, len(in_batch))
        # community order: the per-batch decomposition inherits whatever
        # block structure the full-graph ordering gives these nodes
        batch_nodes = batch_nodes[np.argsort(self.perm[batch_nodes],
                                             kind="stable")]
        nb = self.node_budget
        nodes = np.full(nb, -1, np.int64)
        node_mask = np.zeros(nb, bool)
        nodes[: len(batch_nodes)] = batch_nodes
        node_mask[: len(batch_nodes)] = True
        local_of = np.full(self.graph.n, -1, np.int64)
        local_of[batch_nodes] = np.arange(len(batch_nodes))
        src = local_of[np.concatenate(edges_s) if edges_s
                       else np.zeros(0, np.int64)]
        dst = local_of[np.concatenate(edges_d) if edges_d
                       else np.zeros(0, np.int64)]
        meta = dict(seeds=len(seeds), sampled_nodes=len(batch_nodes))
        s, d, m = _pack_edges(src.astype(np.int32), dst.astype(np.int32),
                              self.edge_budget, meta, rng=rng)
        feats, labels = _gather_node_arrays(self.graph, nodes, node_mask)
        target = np.zeros(nb, bool)
        target[local_of[seeds]] = True
        return SampledBatch(
            n=nb, nodes=nodes.astype(np.int32), node_mask=node_mask,
            senders=s, receivers=d, edge_mask=m, features=feats,
            labels=labels, target_mask=target, meta=meta)

    def sample(self) -> SampledBatch:
        return self.build(self.draw())

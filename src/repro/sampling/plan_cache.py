"""PlanCache: amortized per-batch kernel selection + fixed-shape payloads.

Every sampled batch is a fresh graph, so the paper's dynamic selection
(§4) would re-run per step.  Two observations make it amortizable:

* Batches drawn from one sampler are *statistically* alike: quantizing
  each tier's density statistics (log2-bucketed nnz, binned block-row
  occupancy) collapses the stream of per-batch decompositions onto a
  handful of :func:`density_signature` keys.  :class:`PlanCache` memoizes
  the cost-model-selected :class:`KernelPlan` per key — selection runs on
  a miss, steady-state steps reuse the committed plan (LRU-bounded).

* The jitted train step must not retrace, so the per-batch ``Decomposed``
  it consumes must present one pytree structure: :func:`fix_shapes` pads
  every COO/CSR payload to the sampler's edge budget (zero-valued edges
  in the last row keep the math and the sorted-segment invariant intact)
  and scrubs the per-batch ``stats`` dicts out of the static metadata
  (they differ per batch and are unhashable, either of which would force
  a retrace).  Only budget-paddable formats are materialized per batch —
  ``MB_KERNELS`` — which is why the mini-batch hot loop partitions each
  batch once into a ``decompose_skeleton(keep_empty_buckets=True,
  edge_budget=...)`` and materializes payloads from it (the full
  ``MB_KERNELS`` candidate set only when selection runs on a miss, the
  committed plan's per-tier payload keys on a hit).
"""
from __future__ import annotations

import dataclasses
import math
import os
import pickle
import threading
import warnings
import zlib
from collections import OrderedDict

import numpy as np

from repro.core import formats, selector as sel_mod
from repro.core.decompose import Decomposed
from repro.core.plan import KernelPlan
from repro.kernels import tcgnn_tile
from repro.kernels.registry import REGISTRY
from repro.obs import Telemetry

# the cache's published counters; each is a registry Counter surfaced as a
# same-named attribute (plan_cache.<name>) so `self.hits += 1` style code
# and the stats view read/write one system of record
_COUNTERS = ("hits", "near_hits", "misses", "evictions", "probes",
             "quarantined", "slack_changes")


def _counter_attr(key: str):
    """Attribute <-> registry-counter bridge: reads return the counter's
    value, writes (including ``+=``) land in the counter.  Lost-update
    safety comes from the cache's own RLock, which every mutating path
    already holds."""
    def fget(self):
        return self._counters[key].value

    def fset(self, v):
        self._counters[key].set(v)

    return property(fget, fset)

# Kernels admitted to the mini-batch path.  Membership rule: a kernel is
# admissible iff its payload has a *fixed pytree shape at the edge budget* —
# every array dim a function of (budget, node budget, block size) alone,
# nothing data-dependent.  BlockDiag is (n/B, B, B) for any batch, COO/CSR
# pad to the edge budget, and blocked-ELL qualifies through its
# budget-padded variant: decomposing with an ``edge_budget`` caps the
# stored-block count at K = bell_budget_k(budget, n_pad, B), pads block
# payloads to that cap with masked zero-blocks, and spills overflow edges
# to an in-payload COO tier (padded to the budget like any other COO).
# ELL stays out (max-degree width is data-dependent).  The condensed-tile
# kernel (tcgnn_tile) qualifies the same way bell does: its column cap
# C = tcgnn_budget_c(budget, n_pad, B) is a function of the budget alone,
# block rows keep their densest C columns, and overflow edges spill to the
# in-payload COO (padded to the budget like any other COO).  Fused kernels
# alias their unfused payload, so transform-first layers keep them — GCN
# natively, GIN/SAGE through the epilogue rewrite (core.epilogue); the
# fused CSR path (per-edge gathered transform) rides the CSR payload.
MB_KERNELS = ("block_diag", "block_diag_fused", "coo", "csr", "csr_fused",
              "bell", "bell_fused", "tcgnn_tile", "tcgnn_tile_fused")


# ---------------------------------------------------------------------------
# Fixed-shape padding
# ---------------------------------------------------------------------------

def _padded(arr, budget: int, fill) -> np.ndarray:
    """Host-side pad-to-budget (numpy on purpose: a jnp.concatenate here
    would compile one executable per novel nnz, every batch).  Each region
    is written exactly once (empty + copy + fill-tail, not full + copy):
    this runs per payload array per batch on the hot path."""
    a = formats._np(arr)
    out = np.empty((budget,), a.dtype)
    out[: len(a)] = a
    out[len(a):] = fill
    return out


def _pad_coo(coo: formats.COO, budget: int) -> formats.COO:
    nnz = int(coo.rows.shape[0])
    if nnz > budget:
        raise ValueError(f"COO nnz {nnz} exceeds edge budget {budget}")
    if nnz == budget:
        return coo
    # padded edges live in the last row (keeps rows sorted for the cheap
    # segment_sum mode) with val 0 (keeps the sum exact)
    return formats.COO(coo.n_rows, coo.n_cols,
                       _padded(coo.rows, budget, coo.n_rows - 1),
                       _padded(coo.cols, budget, 0),
                       _padded(coo.vals, budget, 0.0))


def _pad_csr(csr: formats.CSR, budget: int) -> formats.CSR:
    nnz = int(csr.indices.shape[0])
    if nnz > budget:
        raise ValueError(f"CSR nnz {nnz} exceeds edge budget {budget}")
    if nnz == budget:
        return csr
    # bump only the terminal pointer: the pad entries land in the last
    # row's segment, where their zero vals vanish
    indptr = formats._np(csr.indptr).copy()
    indptr[-1] = budget
    return formats.CSR(csr.n_rows, csr.n_cols, indptr,
                       _padded(csr.indices, budget, 0),
                       _padded(csr.vals, budget, 0.0))


def _pad_payload(name: str, payload, budget: int):
    if isinstance(payload, formats.COO):
        return _pad_coo(payload, budget)
    if isinstance(payload, formats.CSR):
        return _pad_csr(payload, budget)
    if isinstance(payload, formats.BlockDiag):
        return payload                      # shape fixed by (n_pad, B)
    if (isinstance(payload, tuple) and len(payload) == 3
            and all(isinstance(b, formats.BlockELL) and b.budgeted
                    for b in payload[:2])):
        # budget-padded blocked-ELL (bell, bell_t, spill): the bells are
        # already shape-fixed by construction (K from the edge budget),
        # only the spill COO needs the budget pad
        return payload[:2] + (_pad_coo(payload[2], budget),)
    if (isinstance(payload, tuple) and len(payload) == 3
            and all(isinstance(b, tcgnn_tile.TcgnnTile) and b.budgeted
                    for b in payload[:2])):
        # budget-capped condensed tiles (tc, tc_t, spill): C is a function
        # of the edge budget (tcgnn_budget_c), only the spill COO pads
        return payload[:2] + (_pad_coo(payload[2], budget),)
    raise TypeError(
        f"payload {name!r} ({type(payload).__name__}) has no fixed-shape "
        f"padding; mini-batch decomposition must use kernels={MB_KERNELS} "
        f"and pass the sampler's edge_budget to decompose (budget-capped "
        f"blocked-ELL only)")


def fix_shapes(dec: Decomposed, edge_budget: int,
               keep: frozenset | set | None = None,
               stats: tuple | None = None) -> Decomposed:
    """Pad every payload to the edge budget and scrub per-batch stats.

    The result is safe to pass *as an argument* to a jitted step: across
    batches from one sampler it always has the same treedef, the same
    static metadata, and the same leaf ShapeDtypeStructs.

    ``keep`` optionally restricts to the payload keys a committed plan
    dispatches (see :func:`plan_payload_keys`) so unused candidate formats
    are not padded and shipped through the jit boundary every step: either
    one set applied to every subgraph, or a per-subgraph sequence of sets
    (the plan_payload_keys form — tier i keeps only what some layer
    dispatches *on tier i*).  It must be derived from the plan alone, so
    batches sharing a step function keep one treedef.

    ``stats`` optionally replaces the scrub with a *hashable* summary —
    the quantized :func:`density_signature` bins of the plan that the step
    was compiled for, so debugging a cached plan doesn't require
    re-deriving them from raw payloads.  It is static jit metadata: the
    caller must pass the same value for every batch sharing a step
    function (canonicalize per plan, never per batch — a per-batch value
    would retrace every step).  The per-subgraph dicts are still scrubbed
    (unhashable); their bins live inside the signature tuple.
    """
    if isinstance(keep, (tuple, list)):
        if len(keep) != len(dec.subgraphs):
            raise ValueError(
                f"per-subgraph keep has {len(keep)} entries for "
                f"{len(dec.subgraphs)} subgraphs (one set per subgraph; "
                f"wrap a single shared key set in frozenset, not tuple)")
        if any(isinstance(k, str) for k in keep):
            raise TypeError(
                "keep entries must be collections of payload keys, not "
                "strings (a tuple of names would filter by substring)")
        keeps = keep
    else:
        keeps = [keep] * len(dec.subgraphs)
    subs = tuple(
        dataclasses.replace(
            s, stats=None,
            formats={k: _pad_payload(k, p, edge_budget)
                     for k, p in s.formats.items()
                     if ki is None or k in ki})
        for s, ki in zip(dec.subgraphs, keeps))
    return dataclasses.replace(dec, subgraphs=subs, stats=stats)


def plan_payload_keys(plan) -> tuple[frozenset, ...]:
    """Per-subgraph payload keys a KernelPlan actually dispatches (fused
    kernels alias their unfused payload) — the ``keep`` sets for
    :func:`fix_shapes` and the per-tier kernel lists for
    ``DecomposeSkeleton.materialize``.  Tier i's set covers only the
    kernels some layer assigns to tier i, so a format another tier picked
    is neither built nor padded nor shipped for this one."""
    return tuple(
        frozenset(REGISTRY.get(layer[i]).payload_key for layer in plan.layers)
        for i in range(len(plan.subgraph_names)))


# ---------------------------------------------------------------------------
# Density signature + cache
# ---------------------------------------------------------------------------

def density_signature(dec, nnz_log2_step: float = 2.0,
                      occ_bins: int = 2) -> tuple:
    """Quantized per-tier density histogram — the PlanCache key.  ``dec``
    is anything exposing ``n_pad`` / ``block_size`` / ``subgraphs`` with
    per-tier ``kind`` + ``stats`` (a Decomposed or a DecomposeSkeleton).

    Per tier: (kind, round(log2(nnz+1)/step), ceil(occupancy * bins),
    ceil(col_occupancy * bins)).  The fourth element bins the tier's
    column occupancy (distinct condensed columns per edge —
    decompose._tier_stats) so tile-condensability is visible to lookup:
    two batches alike in nnz and block-row occupancy but unlike in
    condensability select different condensed-tile (tcgnn) costs and must
    not share a plan.  Decompositions predating the stat bin to 0, a value
    a real tier never produces (any edge gives col_occupancy > 0), so old
    persisted signatures cannot alias new ones.
    Coarse on purpose: batches from one sampler differ by sampling noise,
    not by regime, and the cost-model argmin is flat across a density
    decade — finer keys only manufacture misses (hit rate is the product
    being bought; tighten the steps if a workload's crossovers are sharp).
    """
    tiers = tuple(
        (s.kind,
         int(round(math.log2(s.stats["nnz"] + 1) / nnz_log2_step)),
         int(math.ceil(s.stats.get("brow_occupancy", 0.0) * occ_bins)),
         int(math.ceil(s.stats.get("col_occupancy", 0.0) * occ_bins)))
        for s in dec.subgraphs)
    return (dec.n_pad, dec.block_size, tiers)


class PlanCache:
    """signature -> KernelPlan memo with cost-model selection on miss.

    ``width_pairs`` are the per-layer ``(in_dim, agg_dim)`` pairs from
    :func:`repro.core.gnn.agg_width_pairs` (ints accepted, meaning no
    transform-first fusion); they are fixed per cache instance, so they
    are part of the cache's identity rather than of each key.

    Lookup is two-stage.  The quantized signature is the exact key; on a
    key miss, cached *anchors* (the raw per-tier stats that minted each
    entry) are scanned for a batch within half a quantization cell on
    every tier — batches straddling a cell boundary flap between two
    signatures forever, and without this they would re-run selection on
    every flap.  A near-match reuses the anchor's plan and aliases the
    new signature to it, so either stage skips selection (both count
    toward ``hit_rate``); only a genuine miss selects.

    Thread safety (the async pipeline's contract): every stateful entry
    point — ``lookup`` / ``plan_for`` / ``observe_bell`` / ``stats`` —
    holds one re-entrant lock, so concurrent resolution is *safe*:
    ``plan_for`` is atomic (lookup + select + store under the lock), and
    two workers racing the same fresh signature cost exactly one miss —
    the loser blocks, then hits.  Atomicity alone is not *deterministic*,
    though: cross-signature ordering still matters, because a later batch
    can hit (or near-hit) an entry an earlier batch minted, and the
    near-hit anchor scan and LRU order are insertion-order dependent — so
    the pipeline additionally serializes all lookup/plan_for/observe_bell
    calls in batch-index order (``BatchPipeline``'s resolve turnstile),
    which makes every counter, alias, and eviction bit-identical to
    single-threaded training.  Probes serialize behind the same lock, one
    wall-clock measurement at a time, so a probe's timing is never
    polluted by another probe's device work (with the pipeline the
    consumer's step can still overlap a probe; probing defaults off in
    pipeline mode — ``cfg.probe_every = 0``).
    """

    def __init__(self, width_pairs, dtype=np.float32,
                 hw: sel_mod.HwModel | None = None,
                 nnz_log2_step: float = 2.0, occ_bins: int = 2,
                 max_entries: int = 128, probe_every: int = 0,
                 probe_iters: int = 2, edge_budget: int | None = None,
                 epilogues=None, probe_k_max: int = 4,
                 probe_budget_s: float | None = 2.0,
                 adapt_budget_k: bool = False,
                 bell_slack: float = 2.0, spill_target: float = 0.05,
                 slack_ladder: tuple = (1.0, 1.5, 2.0, 3.0, 4.0),
                 spill_min_obs: int = 8,
                 max_slack_changes: int | None = None,
                 telemetry: Telemetry | None = None):
        # telemetry first: the counter attributes below are properties
        # over registry counters, so the registry must exist before any
        # `self.hits = 0` style assignment runs
        self.tele = telemetry if telemetry is not None else Telemetry()
        self._counters = {k: self.tele.metrics.counter(f"plan_cache.{k}")
                          for k in _COUNTERS}
        self.pairs = [(None, w) if isinstance(w, int) else tuple(w)
                      for w in width_pairs]
        # per-layer EpilogueSpecs aligned with the pairs: selection and
        # probing price the dense epilogue honestly (free transform for
        # GIN's MLP, flat self-matmul for SAGE's dual weights)
        self.epilogues = (tuple(epilogues) if epilogues is not None
                          else (None,) * len(self.pairs))
        self.dtype = dtype
        self.hw = hw or sel_mod.default_hw()
        self.nnz_log2_step = nnz_log2_step
        self.occ_bins = occ_bins
        self.max_entries = max_entries
        # feedback probing: on every ``probe_every``-th miss, wall-clock the
        # cost model's top-2 candidates per (layer, subgraph) and pin the
        # measured winner in the cached entry (0 = cost model only).  The
        # probe compiles its candidates, so the cost amortizes across the
        # cache's lifetime the way full-batch warmup amortizes over steps.
        self.probe_every = probe_every
        self.probe_iters = probe_iters
        # adaptive probe widening: the probe widens past top-2 (up to
        # probe_k_max) when the modeled margin between candidates sits
        # inside the model's observed relative-error band, accumulated
        # from this cache's own probe measurements; probe_budget_s caps
        # one miss's probe wall time, compiles included
        self.probe_k_max = probe_k_max
        self.probe_budget_s = probe_budget_s
        self._probe_errs: list[tuple] = []      # (modeled_s, measured_s)
        # the sampler's padded edge-slot count: probes time candidates on
        # payloads padded to it, because that is what the step executes
        self.edge_budget = edge_budget
        # budget-K autotuning: committed capped-bell plans report their
        # spill nnz + slot utilization per signature; once enough batches
        # are observed the blocked-ELL budget slack steps along the ladder
        # (more slack when spill exceeds ``spill_target`` of the tier's
        # edges, less when nothing spills and most padded slots are waste).
        # The current slack keys the signature, so plans selected under
        # one K never serve another K's payload shapes.
        self.adapt_budget_k = adapt_budget_k
        self.spill_target = spill_target
        self.spill_min_obs = spill_min_obs
        self._slack_ladder = tuple(sorted(set(slack_ladder) | {bell_slack}))
        self._bell_slack = bell_slack
        self._spill_by_sig: dict[tuple, list] = {}   # sig -> [spill, stored]
        self._spill_window: list[tuple] = []    # (spill_frac, slot_util)
        self.slack_changes = 0
        # every slack step changes the capped-bell payload shapes, which
        # costs one recompile of each affected step function; the cap
        # bounds total adaptive-K recompiles per run (None = unbounded)
        self.max_slack_changes = max_slack_changes
        # one re-entrant lock over all mutable state: pipeline workers
        # resolve plans concurrently, probes serialize behind it
        self._lock = threading.RLock()
        # signature -> (plan, anchor); anchor = raw (kind, log2 nnz, occ)
        # per tier of the decomposition that minted (or aliased) the entry
        self._entries: OrderedDict[tuple, tuple] = OrderedDict()
        # kernel quarantine: signature -> set of kernel names whose compile
        # or execution failed under that signature's payload shapes.  A
        # quarantined (kernel, signature) pair is struck from selection and
        # from near-hit aliasing, so a bad Pallas kernel degrades the plan
        # to the next-best candidate instead of killing the run (the XLA
        # reference path, coo, is never quarantined — the floor always
        # selects).
        self._quarantine: dict[tuple, set] = {}
        self.hits = 0
        self.near_hits = 0
        self.misses = 0
        self.evictions = 0
        self.probes = 0
        self.quarantined = 0    # (kernel, signature) pairs quarantined

    # registry-backed counters (see _counter_attr): the same numbers the
    # stats view reports are what the run's metrics snapshot exports
    hits = _counter_attr("hits")
    near_hits = _counter_attr("near_hits")
    misses = _counter_attr("misses")
    evictions = _counter_attr("evictions")
    probes = _counter_attr("probes")
    quarantined = _counter_attr("quarantined")
    slack_changes = _counter_attr("slack_changes")

    def attach_telemetry(self, telemetry: Telemetry) -> None:
        """Re-home this cache's instruments into a run's shared Telemetry
        (the driver calls this when handed a pre-built cache): audit and
        tracer swap to the run's, and the counters migrate into the run's
        registry carrying their current values, so the metrics snapshot
        and the legacy stats view stay one system of record."""
        with self._lock:
            self.tele = telemetry
            moved = {}
            for key, c in self._counters.items():
                nc = telemetry.metrics.counter(c.name)
                if nc is not c:
                    nc.set(c.value)
                moved[key] = nc
            self._counters = moved

    def _dec_slack(self, dec) -> float:
        """The slack this decomposition was *built* with (baked into its
        tier stats by ``decompose_skeleton(bell_slack=...)``), falling back
        to the cache's current slack for decompositions that never threaded
        one.  Reading the built value keeps signature/anchor a pure
        function of the batch: a pipeline worker stepping the ladder
        mid-flight can't shear another batch's cache key away from the
        payload shapes it actually carries."""
        for s in dec.subgraphs:
            st = getattr(s, "stats", None)
            if st and "bell_slack" in st:
                return float(st["bell_slack"])
        return self._bell_slack

    def signature(self, dec) -> tuple:
        sig = density_signature(dec, self.nnz_log2_step, self.occ_bins)
        if self.adapt_budget_k:
            # the slack determines the capped-bell K and with it every bell
            # candidate's cost and payload shape: fold it into the key so a
            # slack step cleanly re-selects instead of serving stale plans
            sig = sig + (("bell_slack", self._dec_slack(dec)),)
        return sig

    # -- budget-K autotuning from observed spill (ROADMAP) ------------------

    @property
    def bell_slack(self) -> float:
        """Slack factor for ``formats.bell_budget_k`` — callers thread it
        into ``decompose_skeleton(bell_slack=...)`` so per-batch capped
        builds use the adapted K."""
        with self._lock:
            return self._bell_slack

    def observe_bell(self, dec) -> None:
        """Record spill/utilization of every committed budget-capped bell
        payload in ``dec`` and step the slack when the evidence is in.

        Called by the mini-batch loop after materializing a committed
        plan's payloads, so only plans that actually dispatch bell feed
        the autotuner (a tier the selector routed to COO says nothing
        about the cap)."""
        if not self.adapt_budget_k:
            return
        with self._lock:
            self._observe_bell_locked(dec)

    def _observe_bell_locked(self, dec) -> None:
        for sub in dec.subgraphs:
            p = sub.formats.get("bell")
            if not (isinstance(p, tuple) and len(p) == 3
                    and getattr(p[0], "budgeted", False)):
                continue
            spill = int(p[2].nnz)
            stored = int((sub.stats or {}).get("nnz", 0)) - spill
            acc = self._spill_by_sig.setdefault(
                (sub.name, p[0].max_blocks), [0, 0])
            acc[0] += spill
            acc[1] += max(stored, 0)
            spill_frac = spill / max(spill + stored, 1)
            # fraction of padded block slots holding a real block: low
            # utilization with zero spill means the cap is pure waste
            slot_util = (float(formats._np(p[0].n_valid).sum())
                         / max(p[0].n_brow * p[0].max_blocks, 1))
            self._spill_window.append((spill_frac, slot_util))
        self._maybe_step_slack()

    def _maybe_step_slack(self) -> None:
        if len(self._spill_window) < self.spill_min_obs:
            return
        if (self.max_slack_changes is not None
                and self.slack_changes >= self.max_slack_changes):
            # recompile budget exhausted: hold the ladder where it is (each
            # step re-shapes the capped-bell payloads and costs one jit
            # recompile per affected step function)
            self._spill_window.clear()
            return
        window = self._spill_window[-self.spill_min_obs:]
        spill = float(np.mean([s for s, _ in window]))
        util = float(np.mean([u for _, u in window]))
        ladder = self._slack_ladder
        i = ladder.index(self._bell_slack)
        nxt = None
        if spill > self.spill_target and i + 1 < len(ladder):
            nxt = ladder[i + 1]         # hub-heavy: grow K, spill less
        elif spill == 0.0 and util < 0.25 and i > 0:
            nxt = ladder[i - 1]         # nothing spills, slots mostly pad
        if nxt is not None:
            self._bell_slack = nxt
            self.slack_changes += 1
            self._spill_window.clear()

    def _anchor(self, dec) -> tuple:
        """(minting slack, raw per-tier stats).  The slack rides along so
        near-hit aliasing never bridges a budget-K slack step — a slack
        change alters every bell candidate's K (cost and payload shape),
        and the whole point of folding it into the signature is to force
        re-selection rather than serve plans priced for the old cap."""
        tiers = tuple((s.kind, math.log2(s.stats["nnz"] + 1),
                       s.stats.get("brow_occupancy", 0.0),
                       s.stats.get("col_occupancy", 0.0))
                      for s in dec.subgraphs)
        return (self._dec_slack(dec) if self.adapt_budget_k else None, tiers)

    def _near(self, a: tuple, b: tuple) -> bool:
        """Same minting slack, within half a quantization cell per tier.

        Length-tolerant per tier: anchors minted before the column-
        occupancy stat carry 3-element tier tuples (persisted snapshots —
        state_dict/save round-trip them verbatim), and a legacy anchor
        compares on the stats it has, so pre-upgrade entries keep serving
        their plans instead of going permanently cold."""
        if a[0] != b[0] or len(a[1]) != len(b[1]):
            return False
        for ta, tb in zip(a[1], b[1]):
            if ta[0] != tb[0]:
                return False
            if abs(ta[1] - tb[1]) > self.nnz_log2_step / 2:
                return False
            if abs(ta[2] - tb[2]) > 0.5 / self.occ_bins:
                return False
            if (len(ta) > 3 and len(tb) > 3
                    and abs(ta[3] - tb[3]) > 0.5 / self.occ_bins):
                return False
        return True

    def select(self, dec: Decomposed,
               exclude: frozenset | None = None) -> KernelPlan:
        """Uncached cost-model selection (what every step would pay
        without the cache — the benchmark's 'uncached' row).  ``exclude``
        defaults to the quarantine set for the batch's signature."""
        if exclude is None:
            with self._lock:
                exclude = frozenset(
                    self._quarantine.get(self.signature(dec), ()))
        layers = [sel_mod.select_by_cost_model(dec, fout, self.dtype,
                                               hw=self.hw, in_dim=fin,
                                               epilogue=ep, exclude=exclude)
                  for (fin, fout), ep in zip(self.pairs, self.epilogues)]
        return KernelPlan.make(dec, layers, epilogues=self.epilogues)

    # -- kernel quarantine (fault tolerance; train/gnn_steps.py) ------------

    @staticmethod
    def _plan_kernels(plan: KernelPlan) -> set:
        return {k for layer in plan.layers for k in layer}

    def quarantine(self, sig: tuple, kernels) -> set:
        """Strike ``kernels`` from signature ``sig``'s candidate set and
        purge any cached entry dispatching them, so the next lookup
        re-selects around the failure.  ``coo`` (the XLA segment-sum floor
        that every subgraph kind admits) is never quarantined — graceful
        degradation must terminate at a plan that always runs.  Returns
        the names newly quarantined."""
        with self._lock:
            q = self._quarantine.setdefault(sig, set())
            fresh = {str(k) for k in kernels} - {"coo"} - q
            q.update(fresh)
            self.quarantined += len(fresh)
            if fresh:
                self.tele.audit.quarantine(sig=sig, kernels=fresh)
                self.tele.tracer.instant("quarantine", cat="cache",
                                         kernels=sorted(fresh))
            if fresh and sig in self._entries:
                plan, _ = self._entries[sig]
                if self._plan_kernels(plan) & q:
                    del self._entries[sig]
            return fresh

    def quarantined_for(self, sig: tuple) -> frozenset:
        with self._lock:
            return frozenset(self._quarantine.get(sig, ()))

    # -- checkpoint state (distributed.checkpoint aux payload) --------------

    def state_dict(self) -> dict:
        """Picklable snapshot of every piece of mutable state the resume
        contract covers: entries (plans + anchors, in LRU order), all
        counters, the probe error band, the budget-K ladder position and
        its evidence windows, and the quarantine map.  Restoring this via
        :meth:`load_state_dict` and replaying the remaining batches is
        bit-identical to never having stopped (signatures, plans, and
        anchors are plain tuples/dataclasses of primitives)."""
        with self._lock:
            return dict(
                entries=[(sig, plan, anchor)
                         for sig, (plan, anchor) in self._entries.items()],
                hits=self.hits, near_hits=self.near_hits,
                misses=self.misses, evictions=self.evictions,
                probes=self.probes, quarantined=self.quarantined,
                quarantine={sig: sorted(ks)
                            for sig, ks in self._quarantine.items()},
                probe_errs=list(self._probe_errs),
                bell_slack=self._bell_slack,
                slack_changes=self.slack_changes,
                spill_by_sig=[(k, list(v))
                              for k, v in self._spill_by_sig.items()],
                spill_window=list(self._spill_window))

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            self._entries = OrderedDict(
                (sig, (plan, anchor))
                for sig, plan, anchor in state["entries"])
            self.hits = state["hits"]
            self.near_hits = state["near_hits"]
            self.misses = state["misses"]
            self.evictions = state["evictions"]
            self.probes = state["probes"]
            self.quarantined = state["quarantined"]
            self._quarantine = {sig: set(ks)
                                for sig, ks in state["quarantine"].items()}
            self._probe_errs = [tuple(e) for e in state["probe_errs"]]
            self._bell_slack = state["bell_slack"]
            self.slack_changes = state["slack_changes"]
            self._spill_by_sig = {k: list(v)
                                  for k, v in state["spill_by_sig"]}
            self._spill_window = [tuple(w) for w in state["spill_window"]]

    # -- disk persistence (serving warm start; launch/serve.py) -------------

    _SAVE_MAGIC = b"PLANCACHE1\n"

    def save(self, path: str) -> None:
        """Persist the full :meth:`state_dict` — signatures, committed
        plans, anchors, counters, quarantine, ladder position — so a later
        process (the inference server's cold start) can skip selection
        *and* reproduce this run's plans identically.  Write is atomic and
        crc-checked, matching the CheckpointManager idioms: serialize to
        ``path + '.tmp'`` with a magic + crc32 header, fsync, then
        ``os.replace`` into place — a crash mid-write never leaves a
        half-written cache where a warm start would find it."""
        with self._lock:
            blob = pickle.dumps(self.state_dict(),
                                protocol=pickle.HIGHEST_PROTOCOL)
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(tmp, "wb") as f:
            f.write(self._SAVE_MAGIC)
            f.write(zlib.crc32(blob).to_bytes(4, "big"))
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def load(self, path: str) -> bool:
        """Restore a :meth:`save`d snapshot; returns True on success.
        Any failure — missing file, bad magic, crc mismatch, unpicklable
        payload — warns and leaves the cache untouched (corruption falls
        back to a cold start, never to a crash or a half-loaded cache)."""
        try:
            with open(path, "rb") as f:
                magic = f.read(len(self._SAVE_MAGIC))
                if magic != self._SAVE_MAGIC:
                    raise ValueError(f"bad magic {magic!r}")
                crc = int.from_bytes(f.read(4), "big")
                blob = f.read()
            if zlib.crc32(blob) != crc:
                raise ValueError("crc mismatch")
            state = pickle.loads(blob)
        except FileNotFoundError:
            return False
        except Exception as exc:           # corrupt file: cold start
            warnings.warn(f"PlanCache.load({path!r}): {exc}; "
                          "starting cold", stacklevel=2)
            return False
        self.load_state_dict(state)
        return True

    def _store(self, sig: tuple, plan: KernelPlan, anchor: tuple) -> None:
        self._entries[sig] = (plan, anchor)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def lookup(self, dec) -> KernelPlan | None:
        """Resident plan for the batch's density signature, or None.

        Works on a *stats-only* decomposition (``decompose(kernels=())``)
        or directly on a :class:`~repro.core.decompose.DecomposeSkeleton`:
        both the signature and the anchor read per-tier stats, never
        payloads — so the hot loop checks the cache straight off the
        skeleton and on a hit materializes only the committed plan's
        payloads.  Counts hits/near-hits; a failed lookup is not yet a
        miss (the caller decides whether to select).
        """
        with self._lock:
            sig = self.signature(dec)
            q = self._quarantine.get(sig)
            entry = self._entries.get(sig)
            if entry is not None:
                # a quarantine after the entry was minted purges it in
                # quarantine(); this guards aliased entries stored since
                if q and self._plan_kernels(entry[0]) & q:
                    del self._entries[sig]
                else:
                    self.hits += 1
                    self._entries.move_to_end(sig)
                    return entry[0]
            anchor = self._anchor(dec)
            for plan, a in reversed(self._entries.values()):  # newest first
                if q and self._plan_kernels(plan) & q:
                    continue    # never alias onto a quarantined kernel
                if self._near(anchor, a):
                    self.near_hits += 1
                    self._store(sig, plan, a)   # alias the boundary cell
                    return plan
            return None

    def plan_for(self, dec: Decomposed) -> tuple[KernelPlan, bool]:
        """(plan, hit): memoized plan for the batch's density signature;
        ``hit`` is True whenever selection was skipped.  ``dec`` must
        carry candidate payloads (selection validates against them, and a
        scheduled probe times them) — the two-phase hot path uses
        :meth:`lookup` first instead.  Atomic under the cache lock: two
        pipeline workers racing one fresh signature pay exactly one miss
        (the second blocks, then hits the entry the first minted)."""
        with self._lock:
            plan = self.lookup(dec)
            if plan is not None:
                return plan, True
            self.misses += 1
            sig = self.signature(dec)
            exclude = frozenset(self._quarantine.get(sig, ()))
            plan = self.select(dec, exclude=exclude)
            source = "cost_model"
            if self.probe_every and self.misses % self.probe_every == 0:
                probed = self._probe_pin(dec)
                # the probe frontier doesn't know the quarantine; keep the
                # cost-model fallback if it re-pinned a struck kernel
                if not (self._plan_kernels(probed) & exclude):
                    plan = probed
                    source = "probe"
            if self.tele.audit.enabled:
                # every committed plan leaves a receipt: per-(layer, tier)
                # kernel choices with the modeled seconds selection compared
                modeled = sel_mod.plan_modeled_costs(
                    dec, plan.layers, self.pairs, self.dtype, hw=self.hw,
                    epilogues=self.epilogues)
                self.tele.audit.plan(
                    sig=sig, layers=plan.layers,
                    tiers=[s.name for s in dec.subgraphs],
                    modeled_s=modeled, source=source,
                    bell_slack=(self._bell_slack if self.adapt_budget_k
                                else None))
            self._store(sig, plan, self._anchor(dec))
            return plan, False

    def probe_margin(self) -> float | None:
        """The cost model's observed relative-error band, from this cache's
        own probe measurements: the median |measured - modeled| / modeled
        over recent probes (None until enough evidence).  Two candidates
        whose modeled costs differ by less than this are indistinguishable
        to the model — the probe widens to let the wall clock decide."""
        with self._lock:
            if len(self._probe_errs) < 4:
                return None
            rel = [abs(meas - mod) / max(mod, 1e-12)
                   for mod, meas in self._probe_errs[-64:]]
        return float(np.clip(np.median(rel), 0.05, 1.0))

    def _probe_pin(self, dec: Decomposed) -> KernelPlan:
        """Feedback probing through the cache (ROADMAP probe-on-Nth-miss):
        wall-clock-time the cost model's cheapest candidates per
        (layer, subgraph) and pin the measured winner — closing the loop
        the way full-batch warmup does, amortized over every future hit on
        this signature.  The frontier is top-2 until the cache has probe
        evidence, then widens (up to ``probe_k_max``) to every candidate
        inside the model's own error band (:meth:`probe_margin`), with
        ``probe_budget_s`` capping one miss's probe wall time.  With an
        ``edge_budget`` the timing runs on the budget-padded payload twin
        (the shapes the jitted step executes — a real-nnz COO would
        underprice its padded runtime cost); the cost-model ranking still
        reads the real stats."""
        self.probes += 1
        time_dec = (fix_shapes(dec, self.edge_budget)
                    if self.edge_budget else None)
        timings = {} if self.tele.audit.enabled else None
        with self.tele.tracer.span("probe", cat="cache"):
            layers = sel_mod.probe_topk(dec, self.pairs, self.dtype,
                                        hw=self.hw,
                                        iters=self.probe_iters,
                                        time_dec=time_dec,
                                        epilogues=self.epilogues,
                                        k_max=self.probe_k_max,
                                        margin=self.probe_margin(),
                                        time_budget_s=self.probe_budget_s,
                                        errs=self._probe_errs,
                                        timings=timings)
        for (tier, kernel, fin, fout), (mod, meas) in sorted(
                (timings or {}).items()):
            self.tele.audit.probe(tier=tier, kernel=kernel, modeled_s=mod,
                                  measured_s=meas, in_dim=fin or None,
                                  agg_dim=fout)
        return KernelPlan.make(dec, layers, epilogues=self.epilogues)

    @property
    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.near_hits + self.misses
            out = dict(hits=self.hits, near_hits=self.near_hits,
                       misses=self.misses, entries=len(self._entries),
                       evictions=self.evictions, probes=self.probes,
                       quarantined=self.quarantined,
                       hit_rate=(self.hits + self.near_hits) / max(total, 1))
            if self.adapt_budget_k:
                spill = sum(a[0] for a in self._spill_by_sig.values())
                stored = sum(a[1] for a in self._spill_by_sig.values())
                out.update(bell_slack=self._bell_slack,
                           slack_changes=self.slack_changes,
                           spill_nnz=spill,
                           spill_frac=spill / max(spill + stored, 1))
            if self._probe_errs:
                out["probe_margin"] = self.probe_margin()
            return out

"""PlanCache: amortized per-batch kernel selection + fixed-shape payloads.

Every sampled batch is a fresh graph, so the paper's dynamic selection
(§4) would re-run per step.  Two observations make it amortizable:

* Batches drawn from one sampler are *statistically* alike: quantizing
  each tier's density statistics (log2-bucketed nnz, binned block-row
  occupancy) collapses the stream of per-batch decompositions onto a
  handful of :func:`density_signature` keys.  :class:`PlanCache` memoizes
  the cost-model-selected :class:`KernelPlan` per key — selection runs on
  a miss, steady-state steps reuse the committed plan (LRU-bounded).

* The jitted train step must not retrace, so the per-batch ``Decomposed``
  it consumes must present one pytree structure: :func:`fix_shapes` pads
  every COO/CSR payload to the sampler's edge budget (zero-valued edges
  in the last row keep the math and the sorted-segment invariant intact)
  and scrubs the per-batch ``stats`` dicts out of the static metadata
  (they differ per batch and are unhashable, either of which would force
  a retrace).  Only budget-paddable formats are materialized per batch —
  ``MB_KERNELS`` — which is why the mini-batch hot loop partitions each
  batch once into a ``decompose_skeleton(keep_empty_buckets=True,
  edge_budget=...)`` and materializes payloads from it (the full
  ``MB_KERNELS`` candidate set only when selection runs on a miss, the
  committed plan's per-tier payload keys on a hit).
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict

import numpy as np

from repro.core import formats, selector as sel_mod
from repro.core.decompose import Decomposed
from repro.core.plan import KernelPlan
from repro.kernels.registry import REGISTRY

# Kernels admitted to the mini-batch path.  Membership rule: a kernel is
# admissible iff its payload has a *fixed pytree shape at the edge budget* —
# every array dim a function of (budget, node budget, block size) alone,
# nothing data-dependent.  BlockDiag is (n/B, B, B) for any batch, COO/CSR
# pad to the edge budget, and blocked-ELL qualifies through its
# budget-padded variant: decomposing with an ``edge_budget`` caps the
# stored-block count at K = bell_budget_k(budget, n_pad, B), pads block
# payloads to that cap with masked zero-blocks, and spills overflow edges
# to an in-payload COO tier (padded to the budget like any other COO).
# ELL stays out (max-degree width is data-dependent).  Fused kernels alias
# their unfused payload, so GCN's transform-first layers keep them.
MB_KERNELS = ("block_diag", "block_diag_fused", "coo", "csr", "bell",
              "bell_fused")


# ---------------------------------------------------------------------------
# Fixed-shape padding
# ---------------------------------------------------------------------------

def _padded(arr, budget: int, fill) -> np.ndarray:
    """Host-side pad-to-budget (numpy on purpose: a jnp.concatenate here
    would compile one executable per novel nnz, every batch).  Each region
    is written exactly once (empty + copy + fill-tail, not full + copy):
    this runs per payload array per batch on the hot path."""
    a = formats._np(arr)
    out = np.empty((budget,), a.dtype)
    out[: len(a)] = a
    out[len(a):] = fill
    return out


def _pad_coo(coo: formats.COO, budget: int) -> formats.COO:
    nnz = int(coo.rows.shape[0])
    if nnz > budget:
        raise ValueError(f"COO nnz {nnz} exceeds edge budget {budget}")
    if nnz == budget:
        return coo
    # padded edges live in the last row (keeps rows sorted for the cheap
    # segment_sum mode) with val 0 (keeps the sum exact)
    return formats.COO(coo.n_rows, coo.n_cols,
                       _padded(coo.rows, budget, coo.n_rows - 1),
                       _padded(coo.cols, budget, 0),
                       _padded(coo.vals, budget, 0.0))


def _pad_csr(csr: formats.CSR, budget: int) -> formats.CSR:
    nnz = int(csr.indices.shape[0])
    if nnz > budget:
        raise ValueError(f"CSR nnz {nnz} exceeds edge budget {budget}")
    if nnz == budget:
        return csr
    # bump only the terminal pointer: the pad entries land in the last
    # row's segment, where their zero vals vanish
    indptr = formats._np(csr.indptr).copy()
    indptr[-1] = budget
    return formats.CSR(csr.n_rows, csr.n_cols, indptr,
                       _padded(csr.indices, budget, 0),
                       _padded(csr.vals, budget, 0.0))


def _pad_payload(name: str, payload, budget: int):
    if isinstance(payload, formats.COO):
        return _pad_coo(payload, budget)
    if isinstance(payload, formats.CSR):
        return _pad_csr(payload, budget)
    if isinstance(payload, formats.BlockDiag):
        return payload                      # shape fixed by (n_pad, B)
    if (isinstance(payload, tuple) and len(payload) == 3
            and all(isinstance(b, formats.BlockELL) and b.budgeted
                    for b in payload[:2])):
        # budget-padded blocked-ELL (bell, bell_t, spill): the bells are
        # already shape-fixed by construction (K from the edge budget),
        # only the spill COO needs the budget pad
        return payload[:2] + (_pad_coo(payload[2], budget),)
    raise TypeError(
        f"payload {name!r} ({type(payload).__name__}) has no fixed-shape "
        f"padding; mini-batch decomposition must use kernels={MB_KERNELS} "
        f"and pass the sampler's edge_budget to decompose (budget-capped "
        f"blocked-ELL only)")


def fix_shapes(dec: Decomposed, edge_budget: int,
               keep: frozenset | set | None = None,
               stats: tuple | None = None) -> Decomposed:
    """Pad every payload to the edge budget and scrub per-batch stats.

    The result is safe to pass *as an argument* to a jitted step: across
    batches from one sampler it always has the same treedef, the same
    static metadata, and the same leaf ShapeDtypeStructs.

    ``keep`` optionally restricts to the payload keys a committed plan
    dispatches (see :func:`plan_payload_keys`) so unused candidate formats
    are not padded and shipped through the jit boundary every step: either
    one set applied to every subgraph, or a per-subgraph sequence of sets
    (the plan_payload_keys form — tier i keeps only what some layer
    dispatches *on tier i*).  It must be derived from the plan alone, so
    batches sharing a step function keep one treedef.

    ``stats`` optionally replaces the scrub with a *hashable* summary —
    the quantized :func:`density_signature` bins of the plan that the step
    was compiled for, so debugging a cached plan doesn't require
    re-deriving them from raw payloads.  It is static jit metadata: the
    caller must pass the same value for every batch sharing a step
    function (canonicalize per plan, never per batch — a per-batch value
    would retrace every step).  The per-subgraph dicts are still scrubbed
    (unhashable); their bins live inside the signature tuple.
    """
    if isinstance(keep, (tuple, list)):
        if len(keep) != len(dec.subgraphs):
            raise ValueError(
                f"per-subgraph keep has {len(keep)} entries for "
                f"{len(dec.subgraphs)} subgraphs (one set per subgraph; "
                f"wrap a single shared key set in frozenset, not tuple)")
        if any(isinstance(k, str) for k in keep):
            raise TypeError(
                "keep entries must be collections of payload keys, not "
                "strings (a tuple of names would filter by substring)")
        keeps = keep
    else:
        keeps = [keep] * len(dec.subgraphs)
    subs = tuple(
        dataclasses.replace(
            s, stats=None,
            formats={k: _pad_payload(k, p, edge_budget)
                     for k, p in s.formats.items()
                     if ki is None or k in ki})
        for s, ki in zip(dec.subgraphs, keeps))
    return dataclasses.replace(dec, subgraphs=subs, stats=stats)


def plan_payload_keys(plan) -> tuple[frozenset, ...]:
    """Per-subgraph payload keys a KernelPlan actually dispatches (fused
    kernels alias their unfused payload) — the ``keep`` sets for
    :func:`fix_shapes` and the per-tier kernel lists for
    ``DecomposeSkeleton.materialize``.  Tier i's set covers only the
    kernels some layer assigns to tier i, so a format another tier picked
    is neither built nor padded nor shipped for this one."""
    return tuple(
        frozenset(REGISTRY.get(layer[i]).payload_key for layer in plan.layers)
        for i in range(len(plan.subgraph_names)))


# ---------------------------------------------------------------------------
# Density signature + cache
# ---------------------------------------------------------------------------

def density_signature(dec, nnz_log2_step: float = 2.0,
                      occ_bins: int = 2) -> tuple:
    """Quantized per-tier density histogram — the PlanCache key.  ``dec``
    is anything exposing ``n_pad`` / ``block_size`` / ``subgraphs`` with
    per-tier ``kind`` + ``stats`` (a Decomposed or a DecomposeSkeleton).

    Per tier: (kind, round(log2(nnz+1)/step), ceil(occupancy * bins)).
    Coarse on purpose: batches from one sampler differ by sampling noise,
    not by regime, and the cost-model argmin is flat across a density
    decade — finer keys only manufacture misses (hit rate is the product
    being bought; tighten the steps if a workload's crossovers are sharp).
    """
    tiers = tuple(
        (s.kind,
         int(round(math.log2(s.stats["nnz"] + 1) / nnz_log2_step)),
         int(math.ceil(s.stats.get("brow_occupancy", 0.0) * occ_bins)))
        for s in dec.subgraphs)
    return (dec.n_pad, dec.block_size, tiers)


class PlanCache:
    """signature -> KernelPlan memo with cost-model selection on miss.

    ``width_pairs`` are the per-layer ``(in_dim, agg_dim)`` pairs from
    :func:`repro.core.gnn.agg_width_pairs` (ints accepted, meaning no
    transform-first fusion); they are fixed per cache instance, so they
    are part of the cache's identity rather than of each key.

    Lookup is two-stage.  The quantized signature is the exact key; on a
    key miss, cached *anchors* (the raw per-tier stats that minted each
    entry) are scanned for a batch within half a quantization cell on
    every tier — batches straddling a cell boundary flap between two
    signatures forever, and without this they would re-run selection on
    every flap.  A near-match reuses the anchor's plan and aliases the
    new signature to it, so either stage skips selection (both count
    toward ``hit_rate``); only a genuine miss selects.
    """

    def __init__(self, width_pairs, dtype=np.float32,
                 hw: sel_mod.HwModel | None = None,
                 nnz_log2_step: float = 2.0, occ_bins: int = 2,
                 max_entries: int = 128, probe_every: int = 0,
                 probe_iters: int = 2, edge_budget: int | None = None):
        self.pairs = [(None, w) if isinstance(w, int) else tuple(w)
                      for w in width_pairs]
        self.dtype = dtype
        self.hw = hw or sel_mod.default_hw()
        self.nnz_log2_step = nnz_log2_step
        self.occ_bins = occ_bins
        self.max_entries = max_entries
        # feedback probing: on every ``probe_every``-th miss, wall-clock the
        # cost model's top-2 candidates per (layer, subgraph) and pin the
        # measured winner in the cached entry (0 = cost model only).  The
        # probe compiles its candidates, so the cost amortizes across the
        # cache's lifetime the way full-batch warmup amortizes over steps.
        self.probe_every = probe_every
        self.probe_iters = probe_iters
        # the sampler's padded edge-slot count: probes time candidates on
        # payloads padded to it, because that is what the step executes
        self.edge_budget = edge_budget
        # signature -> (plan, anchor); anchor = raw (kind, log2 nnz, occ)
        # per tier of the decomposition that minted (or aliased) the entry
        self._entries: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.near_hits = 0
        self.misses = 0
        self.evictions = 0
        self.probes = 0

    def signature(self, dec) -> tuple:
        return density_signature(dec, self.nnz_log2_step, self.occ_bins)

    @staticmethod
    def _anchor(dec) -> tuple:
        return tuple((s.kind, math.log2(s.stats["nnz"] + 1),
                      s.stats.get("brow_occupancy", 0.0))
                     for s in dec.subgraphs)

    def _near(self, a: tuple, b: tuple) -> bool:
        """Within half a quantization cell on every tier."""
        if len(a) != len(b):
            return False
        return all(ka == kb
                   and abs(la - lb) <= self.nnz_log2_step / 2
                   and abs(oa - ob) <= 0.5 / self.occ_bins
                   for (ka, la, oa), (kb, lb, ob) in zip(a, b))

    def select(self, dec: Decomposed) -> KernelPlan:
        """Uncached cost-model selection (what every step would pay
        without the cache — the benchmark's 'uncached' row)."""
        layers = [sel_mod.select_by_cost_model(dec, fout, self.dtype,
                                               hw=self.hw, in_dim=fin)
                  for fin, fout in self.pairs]
        return KernelPlan.make(dec, layers)

    def _store(self, sig: tuple, plan: KernelPlan, anchor: tuple) -> None:
        self._entries[sig] = (plan, anchor)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def lookup(self, dec) -> KernelPlan | None:
        """Resident plan for the batch's density signature, or None.

        Works on a *stats-only* decomposition (``decompose(kernels=())``)
        or directly on a :class:`~repro.core.decompose.DecomposeSkeleton`:
        both the signature and the anchor read per-tier stats, never
        payloads — so the hot loop checks the cache straight off the
        skeleton and on a hit materializes only the committed plan's
        payloads.  Counts hits/near-hits; a failed lookup is not yet a
        miss (the caller decides whether to select).
        """
        sig = self.signature(dec)
        entry = self._entries.get(sig)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(sig)
            return entry[0]
        anchor = self._anchor(dec)
        for plan, a in reversed(self._entries.values()):   # newest first
            if self._near(anchor, a):
                self.near_hits += 1
                self._store(sig, plan, a)   # alias the boundary cell
                return plan
        return None

    def plan_for(self, dec: Decomposed) -> tuple[KernelPlan, bool]:
        """(plan, hit): memoized plan for the batch's density signature;
        ``hit`` is True whenever selection was skipped.  ``dec`` must
        carry candidate payloads (selection validates against them, and a
        scheduled probe times them) — the two-phase hot path uses
        :meth:`lookup` first instead."""
        plan = self.lookup(dec)
        if plan is not None:
            return plan, True
        self.misses += 1
        plan = self.select(dec)
        if self.probe_every and self.misses % self.probe_every == 0:
            plan = self._probe_pin(dec)
        self._store(self.signature(dec), plan, self._anchor(dec))
        return plan, False

    def _probe_pin(self, dec: Decomposed) -> KernelPlan:
        """Feedback probing through the cache (ROADMAP probe-on-Nth-miss):
        wall-clock-time the cost model's two cheapest candidates per
        (layer, subgraph) and pin the measured winner — closing the loop
        the way full-batch warmup does, amortized over every future hit on
        this signature.  With an ``edge_budget`` the timing runs on the
        budget-padded payload twin (the shapes the jitted step executes —
        a real-nnz COO would underprice its padded runtime cost); the
        cost-model ranking still reads the real stats."""
        self.probes += 1
        time_dec = (fix_shapes(dec, self.edge_budget)
                    if self.edge_budget else None)
        layers = sel_mod.probe_topk(dec, self.pairs, self.dtype, hw=self.hw,
                                    iters=self.probe_iters,
                                    time_dec=time_dec)
        return KernelPlan.make(dec, layers)

    @property
    def stats(self) -> dict:
        total = self.hits + self.near_hits + self.misses
        return dict(hits=self.hits, near_hits=self.near_hits,
                    misses=self.misses, entries=len(self._entries),
                    evictions=self.evictions, probes=self.probes,
                    hit_rate=(self.hits + self.near_hits) / max(total, 1))

"""Transformer-family building blocks: GQA/MLA attention, dense & MoE FFN,
Mamba (selective SSM), RWKV-6 time/channel mix.

Every block provides:
  init_X(key, ...) -> params          (dict of arrays)
  spec_X(...)      -> logical specs   (same tree, tuples of logical axes)
  X_apply(params, x, ...)             (full-sequence / training mode)
  X_decode(params, x, cache, pos)     (single-token with cache) where relevant

All matmul-heavy math runs in the model dtype with fp32 accumulation
(preferred_element_type), softmax/norm statistics in fp32.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.layers import nn, rope as rope_mod
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _dense(key, shape, dtype):
    return nn.lecun_normal(key, shape).astype(dtype)


def einsum(s, *xs):
    return jnp.einsum(s, *xs, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Attention (MHA / GQA, optional QKV bias, optional M-RoPE)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope_sections: tuple | None = None   # qwen2-vl
    causal: bool = True
    use_rope: bool = True
    # "softmax": XLA unfused attention (baseline); "identity": zero-cost
    # stand-in used by the roofline's attention-core isolation probes
    # (§Perf flash substitution); the Pallas flash kernel is the TPU path.
    attn_core: str = "softmax"


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    H, KV, dh, d = cfg.n_heads, cfg.kv_heads, cfg.head_dim, cfg.d_model
    p = dict(
        wq=_dense(ks[0], (d, H * dh), dtype),
        wk=_dense(ks[1], (d, KV * dh), dtype),
        wv=_dense(ks[2], (d, KV * dh), dtype),
        wo=_dense(ks[3], (H * dh, d), dtype),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((KV * dh,), dtype)
        p["bv"] = jnp.zeros((KV * dh,), dtype)
    return p


def spec_attention(cfg: AttnConfig):
    s = dict(wq=("embed", "qkv"), wk=("embed", "kv"), wv=("embed", "kv"),
             wo=("qkv", "embed"))
    if cfg.qkv_bias:
        s.update(bq=("qkv",), bk=("kv",), bv=("kv",))
    return s


def _qkv(params, cfg: AttnConfig, x, positions):
    B, S, _ = x.shape
    q = einsum("bsd,dh->bsh", x, params["wq"]).astype(x.dtype)
    k = einsum("bsd,dh->bsh", x, params["wk"]).astype(x.dtype)
    v = einsum("bsd,dh->bsh", x, params["wv"]).astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.kv_heads, cfg.head_dim)
    if cfg.use_rope:
        if cfg.mrope_sections is not None:
            q = rope_mod.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = rope_mod.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = rope_mod.apply_rope(q, positions, cfg.rope_theta)
            k = rope_mod.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(params, cfg: AttnConfig, x, positions,
                    kv_override=None):
    """Full-sequence attention. positions: (B,S) or (3,B,S) for M-RoPE.
    kv_override: (k, v) for cross-attention (whisper decoder)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, cfg, x, positions)
    if kv_override is not None:
        k, v = kv_override
    if cfg.attn_core == "identity":
        g = cfg.n_heads // cfg.kv_heads
        vm = jnp.mean(v, axis=1, keepdims=True)          # (B,1,Hkv,dh)
        out = jnp.broadcast_to(jnp.repeat(vm, g, axis=2),
                               (B, S, cfg.n_heads, v.shape[-1]))
        out = out.reshape(B, S, -1)
    elif (cfg.attn_core == "flash" and cfg.causal and kv_override is None
          and S % 128 == 0):
        from repro.kernels.flash_attention import flash_attention_trainable
        out = flash_attention_trainable(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    else:
        out = kref.mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                       v.transpose(0, 2, 1, 3), causal=cfg.causal)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return einsum("bsh,hd->bsd", out, params["wo"]).astype(x.dtype)


def attention_decode(params, cfg: AttnConfig, x, cache, pos):
    """Single-step decode. x: (B, 1, d); cache: {k, v: (B, Smax, KV, dh)};
    pos: scalar int32 current position."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k_new, v_new = _qkv(params, cfg, x, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    Smax = k.shape[1]
    mask = (jnp.arange(Smax) <= pos)[None, None, None, :]     # (1,1,1,Smax)
    qh = q.transpose(0, 2, 1, 3)                              # (B,H,1,dh)
    kh = k.transpose(0, 2, 1, 3).astype(x.dtype)
    vh = v.transpose(0, 2, 1, 3).astype(x.dtype)
    H, KV = cfg.n_heads, cfg.kv_heads
    g = H // KV
    qg = qh.reshape(B, KV, g, 1, cfg.head_dim)
    logits = einsum("bhgqd,bhtd->bhgqt", qg.astype(jnp.float32),
                    kh.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    logits = jnp.where(mask[:, :, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = einsum("bhgqt,bhtd->bhgqd", p, vh.astype(jnp.float32))
    out = out.reshape(B, H, 1, cfg.head_dim).transpose(0, 2, 1, 3)
    out = out.reshape(B, 1, H * cfg.head_dim).astype(x.dtype)
    y = einsum("bsh,hd->bsd", out, params["wo"]).astype(x.dtype)
    return y, dict(k=k, v=v)


def init_attn_cache(cfg: AttnConfig, batch: int, s_max: int, dtype):
    shp = (batch, s_max, cfg.kv_heads, cfg.head_dim)
    return dict(k=jnp.zeros(shp, dtype), v=jnp.zeros(shp, dtype))


def spec_attn_cache(cfg: AttnConfig):
    return dict(k=("batch", "kv_seq", "kv", None),
                v=("batch", "kv_seq", "kv", None))


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2/V3)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 1e4
    attn_core: str = "softmax"    # see AttnConfig.attn_core

    @property
    def qk_dim(self):
        return self.qk_nope_dim + self.qk_rope_dim


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    return dict(
        wq_a=_dense(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype),
        q_norm=jnp.ones((cfg.q_lora_rank,), dtype),
        wq_b=_dense(ks[1], (cfg.q_lora_rank, H * cfg.qk_dim), dtype),
        wkv_a=_dense(ks[2], (cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype),
        kv_norm=jnp.ones((cfg.kv_lora_rank,), dtype),
        wkv_b=_dense(ks[3], (cfg.kv_lora_rank, H * (cfg.qk_nope_dim + cfg.v_dim)), dtype),
        wo=_dense(ks[4], (H * cfg.v_dim, cfg.d_model), dtype),
    )


def spec_mla(cfg: MLAConfig):
    return dict(wq_a=("embed", None), q_norm=(None,), wq_b=(None, "qkv"),
                wkv_a=("embed", None), kv_norm=(None,), wkv_b=(None, "qkv"),
                wo=("qkv", "embed"))


def _mla_qkv(params, cfg: MLAConfig, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = nn.rms_norm(einsum("bsd,dr->bsr", x, params["wq_a"]).astype(x.dtype),
                     params["q_norm"])
    q = einsum("bsr,rh->bsh", cq, params["wq_b"]).astype(x.dtype)
    q = q.reshape(B, S, H, cfg.qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = rope_mod.apply_rope(q_rope, positions, cfg.rope_theta)

    kv = einsum("bsd,dr->bsr", x, params["wkv_a"]).astype(x.dtype)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = nn.rms_norm(c_kv, params["kv_norm"])
    k_rope = rope_mod.apply_rope(k_rope[:, :, None, :], positions,
                                 cfg.rope_theta)    # (B,S,1,rope)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params, cfg: MLAConfig, c_kv):
    """Naive (paper-faithful baseline) expansion of latent cache to full
    per-head K_nope/V.  The absorbed variant (beyond-paper §Perf) folds
    wkv_b into the query/output projections instead."""
    B, S, _ = c_kv.shape
    H = cfg.n_heads
    kv = einsum("bsr,rh->bsh", c_kv, params["wkv_b"]).astype(c_kv.dtype)
    kv = kv.reshape(B, S, H, cfg.qk_nope_dim + cfg.v_dim)
    return jnp.split(kv, [cfg.qk_nope_dim], axis=-1)     # k_nope, v


def mla_apply(params, cfg: MLAConfig, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope, v = _mla_expand_kv(params, cfg, c_kv)
    if cfg.attn_core == "identity":
        vm = jnp.mean(v, axis=1, keepdims=True)
        out = jnp.broadcast_to(vm, (B, S, H, cfg.v_dim))
    elif cfg.attn_core == "flash" and S % 128 == 0:
        from repro.kernels.flash_attention import flash_attention_trainable
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (B, S, H, cfg.qk_rope_dim))], axis=-1)
        out = flash_attention_trainable(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, scale=cfg.qk_dim ** -0.5)
        out = out.transpose(0, 2, 1, 3)
    else:
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (B, S, H, cfg.qk_rope_dim))], axis=-1)
        out = kref.mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                       v.transpose(0, 2, 1, 3), causal=True,
                       scale=cfg.qk_dim ** -0.5)
        out = out.transpose(0, 2, 1, 3)
    out = out.reshape(B, S, H * cfg.v_dim)
    return einsum("bsh,hd->bsd", out, params["wo"]).astype(x.dtype)


def init_mla_cache(cfg: MLAConfig, batch: int, s_max: int, dtype):
    return dict(c_kv=jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
                k_rope=jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype))


def spec_mla_cache(cfg: MLAConfig):
    return dict(c_kv=("batch", "kv_seq", None), k_rope=("batch", "kv_seq", None))


def mla_decode(params, cfg: MLAConfig, x, cache, pos, absorbed: bool = False):
    """Single-step MLA decode against the compressed latent cache."""
    B = x.shape[0]
    H = cfg.n_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, cfg, x, positions)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, :, 0, :].astype(cache["k_rope"].dtype),
        pos, axis=1)
    Smax = c_kv.shape[1]
    mask = (jnp.arange(Smax) <= pos)[None, None, None, :]
    scale = cfg.qk_dim ** -0.5
    if absorbed:
        # Absorb wkv_b into q and out: logits_nope = (q_nope W_k^T) . c_kv
        wkv = params["wkv_b"].reshape(cfg.kv_lora_rank, H,
                                      cfg.qk_nope_dim + cfg.v_dim)
        w_k = wkv[:, :, : cfg.qk_nope_dim]           # (r, H, nope)
        w_v = wkv[:, :, cfg.qk_nope_dim:]            # (r, H, v)
        q_lat = einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))       # (B,1,H,r)
        logits = (einsum("bqhr,btr->bhqt", q_lat, c_kv.astype(jnp.float32))
                  + einsum("bqhn,btn->bhqt", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
        logits = jnp.where(mask, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        ctx = einsum("bhqt,btr->bqhr", p, c_kv.astype(jnp.float32))
        out = einsum("bqhr,rhv->bqhv", ctx, w_v.astype(jnp.float32))
    else:
        k_nope, v = _mla_expand_kv(params, cfg, c_kv.astype(x.dtype))
        logits = (einsum("bqhn,bthn->bhqt", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
                  + einsum("bqhn,btn->bhqt", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
        logits = jnp.where(mask, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = einsum("bhqt,bthv->bqhv", p, v.astype(jnp.float32))
    out = out.reshape(B, 1, H * cfg.v_dim).astype(x.dtype)
    y = einsum("bsh,hd->bsd", out, params["wo"]).astype(x.dtype)
    return y, dict(c_kv=c_kv, k_rope=k_rope)


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32,
             gated: bool = True):
    ks = jax.random.split(key, 3)
    p = dict(w_up=_dense(ks[0], (d_model, d_ff), dtype),
             w_down=_dense(ks[1], (d_ff, d_model), dtype))
    if gated:
        p["w_gate"] = _dense(ks[2], (d_model, d_ff), dtype)
    return p


def spec_mlp(gated: bool = True):
    s = dict(w_up=("embed", "mlp"), w_down=("mlp", "embed"))
    if gated:
        s["w_gate"] = ("embed", "mlp")
    return s


def mlp_apply(params, x, gated: bool = True):
    up = einsum("bsd,df->bsf", x, params["w_up"]).astype(x.dtype)
    if gated:
        gate = einsum("bsd,df->bsf", x, params["w_gate"]).astype(x.dtype)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return einsum("bsf,fd->bsd", h, params["w_down"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (DeepSeek-style: shared experts + routed top-k, capacity dispatch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared experts (DeepSeekMoE)
    d_ff_shared: int = 0         # total shared width (n_shared * d_ff_expert typically)
    capacity_factor: float = 1.25
    # AdaptGear hook: "dense" computes every expert for every token (the
    # dense-block kernel analogue; wins when E is tiny / density high),
    # "sparse" does capacity sort-scatter dispatch, "adaptive" picks by the
    # analytic density rule (top_k/E), mirroring core/selector.py.
    dispatch: str = "adaptive"


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ek = jax.random.split(ks[0], 3)
    p = dict(
        router=_dense(ks[1], (d, E), jnp.float32),     # router kept fp32
        w_gate=_dense(ek[0], (E, d, f), dtype),
        w_up=_dense(ek[1], (E, d, f), dtype),
        w_down=_dense(ek[2], (E, f, d), dtype),
    )
    if cfg.n_shared:
        p["shared"] = init_mlp(ks[2], d, cfg.d_ff_shared, dtype)
    return p


def spec_moe(cfg: MoEConfig):
    s = dict(router=("embed", None),
             w_gate=("expert", "embed", None),
             w_up=("expert", "embed", None),
             w_down=("expert", None, "embed"))
    if cfg.n_shared:
        s["shared"] = spec_mlp()
    return s


def moe_density(cfg: MoEConfig) -> float:
    return cfg.top_k / cfg.n_experts


def choose_moe_path(cfg: MoEConfig, n_tokens: int) -> str:
    """AdaptGear cost-model rule for MoE: dense path FLOPs scale with E,
    sparse path with top_k + dispatch overhead.  Dense wins only when the
    token-expert 'adjacency' is dense (few experts) or the token count is
    too small to amortize sort/scatter."""
    if cfg.dispatch != "adaptive":
        return cfg.dispatch
    dense_cost = float(cfg.n_experts)
    sparse_cost = cfg.top_k + 0.5 + 1e4 / max(n_tokens, 1)  # dispatch overhead
    return "dense" if dense_cost <= sparse_cost else "sparse"


def _moe_gates(params, cfg: MoEConfig, x2d):
    logits = einsum("nd,de->ne", x2d.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, cfg.top_k)        # (N, k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    me = gates.mean(0)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0 / top_idx.size)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return top_vals, top_idx, aux


def moe_apply_dense(params, cfg: MoEConfig, x2d):
    """Dense path: every expert for every token, masked combine."""
    top_vals, top_idx, aux = _moe_gates(params, cfg, x2d)
    N = x2d.shape[0]
    combine = jnp.zeros((N, cfg.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(N)[:, None], top_idx].add(top_vals)
    gate = einsum("nd,edf->enf", x2d, params["w_gate"]).astype(x2d.dtype)
    up = einsum("nd,edf->enf", x2d, params["w_up"]).astype(x2d.dtype)
    h = jax.nn.silu(gate) * up
    y = einsum("enf,efd->end", h, params["w_down"])
    out = einsum("end,ne->nd", y, combine).astype(x2d.dtype)
    return out, aux


def moe_apply_sparse(params, cfg: MoEConfig, x2d):
    """Sort-based capacity dispatch (token-choice, dropping).

    N*k assignments are sorted by expert id; position-in-expert comes from
    the sorted rank minus the expert's start offset; tokens beyond capacity
    C are dropped (standard GShard/Switch semantics)."""
    N, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    top_vals, top_idx, aux = _moe_gates(params, cfg, x2d)
    C = max(int(math.ceil(N * k / E * cfg.capacity_factor)), 1)

    e_flat = top_idx.reshape(-1)                       # (N*k,)
    t_flat = jnp.repeat(jnp.arange(N), k)              # (N*k,)
    w_flat = top_vals.reshape(-1)

    order = jnp.argsort(e_flat)                        # stable
    e_sorted = e_flat[order]
    # start offset of each expert within the sorted list
    starts = jnp.searchsorted(e_sorted, jnp.arange(E))  # (E,)
    pos = jnp.arange(N * k) - starts[e_sorted]          # rank within expert
    keep = pos < C

    # scatter tokens into the (E, C, d) dispatch buffer
    buf = jnp.zeros((E, C, d), x2d.dtype)
    src = x2d[t_flat[order]]
    buf = buf.at[e_sorted, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], src, 0))

    gate = einsum("ecd,edf->ecf", buf, params["w_gate"]).astype(x2d.dtype)
    up = einsum("ecd,edf->ecf", buf, params["w_up"]).astype(x2d.dtype)
    h = jax.nn.silu(gate) * up
    y = einsum("ecf,efd->ecd", h, params["w_down"]).astype(x2d.dtype)

    # gather back + weighted combine
    out_e = y[e_sorted, jnp.where(keep, pos, 0)]        # (N*k, d)
    out_e = jnp.where(keep[:, None], out_e, 0) * w_flat[order][:, None]
    out = jnp.zeros((N, d), jnp.float32).at[t_flat[order]].add(
        out_e.astype(jnp.float32))
    return out.astype(x2d.dtype), aux


def moe_apply(params, cfg: MoEConfig, x):
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    path = choose_moe_path(cfg, B * S)
    if path == "dense":
        out, aux = moe_apply_dense(params, cfg, x2d)
    else:
        out, aux = moe_apply_sparse(params, cfg, x2d)
    if cfg.n_shared:
        out = out + mlp_apply(params["shared"], x).reshape(B * S, d)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba (selective SSM; Jamba's recurrent layer)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int          # expansion * d_model (Jamba: 2x)
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0      # 0 -> ceil(d_model/16)
    # "xla": associative_scan baseline; "identity": roofline isolation
    # stand-in (skip the recurrence); "pallas": VMEM-resident scan kernel
    scan_core: str = "xla"

    @property
    def rank(self):
        return self.dt_rank or -(-self.d_model // 16)


def init_mamba(key, cfg: MambaConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    di, ds, r = cfg.d_inner, cfg.d_state, cfg.rank
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return dict(
        in_proj=_dense(ks[0], (cfg.d_model, 2 * di), dtype),
        conv_w=_dense(ks[1], (cfg.d_conv, di), dtype),
        conv_b=jnp.zeros((di,), dtype),
        x_proj=_dense(ks[2], (di, r + 2 * ds), dtype),
        dt_proj=_dense(ks[3], (r, di), dtype),
        dt_bias=jnp.zeros((di,), dtype),
        A_log=jnp.log(A),
        D=jnp.ones((di,), jnp.float32),
        out_proj=_dense(ks[4], (di, cfg.d_model), dtype),
    )


def spec_mamba(cfg: MambaConfig):
    return dict(in_proj=("embed", "mlp"), conv_w=(None, "mlp"),
                conv_b=("mlp",), x_proj=("mlp", None), dt_proj=(None, "mlp"),
                dt_bias=("mlp",), A_log=("mlp", None), D=("mlp",),
                out_proj=("mlp", "embed"))


def _mamba_inner(params, cfg: MambaConfig, xz, conv_state=None):
    """Shared pre-scan compute. xz: (B, T, 2*d_inner)."""
    x, z = jnp.split(xz, 2, axis=-1)
    B, T, di = x.shape
    # causal depthwise conv1d
    if conv_state is None:
        pad = jnp.zeros((B, cfg.d_conv - 1, di), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([conv_state, x], axis=1)
    new_conv_state = xp[:, -(cfg.d_conv - 1):, :]
    x = sum(xp[:, i:i + T, :] * params["conv_w"][i] for i in range(cfg.d_conv))
    x = jax.nn.silu(x + params["conv_b"])
    proj = einsum("btd,dr->btr", x, params["x_proj"]).astype(x.dtype)
    dt, Bc, Cc = jnp.split(proj, [cfg.rank, cfg.rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(
        einsum("btr,rd->btd", dt, params["dt_proj"]) + params["dt_bias"])
    return x, z, dt.astype(jnp.float32), Bc, Cc, new_conv_state


def mamba_apply(params, cfg: MambaConfig, x, return_state: bool = False):
    """Full-sequence selective scan via associative_scan (baseline; the
    Pallas VMEM-resident kernel is scan_core="pallas").  With
    ``return_state`` also returns the decode cache (final h + conv tail)."""
    xz = einsum("btd,de->bte", x, params["in_proj"]).astype(x.dtype)
    xs, z, dt, Bc, Cc, conv_state = _mamba_inner(params, cfg, xz)
    A = -jnp.exp(params["A_log"])                          # (di, ds)
    if cfg.scan_core == "identity":
        # roofline isolation: everything but the recurrence
        y = xs.astype(jnp.float32) * params["D"]
    elif cfg.scan_core == "pallas":
        from repro.kernels.mamba_scan import mamba_scan_trainable
        y = mamba_scan_trainable(xs.astype(jnp.float32), dt,
                                 Bc.astype(jnp.float32),
                                 Cc.astype(jnp.float32), A, params["D"])
        y = y.astype(jnp.float32)
    else:
        dA = jnp.exp(dt[..., None] * A)                    # (B,T,di,ds)
        dBx = (dt * xs.astype(jnp.float32))[..., None] * \
            Bc.astype(jnp.float32)[:, :, None, :]

        def combine(a, b):
            a1, b1 = a
            a2, b2 = b
            return a2 * a1, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        y = einsum("btds,bts->btd", hs, Cc.astype(jnp.float32))
        y = y + xs.astype(jnp.float32) * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = einsum("btd,de->bte", y, params["out_proj"]).astype(x.dtype)
    if not return_state:
        return out
    # final recurrent state for decode handoff (recomputed sequentially for
    # the pallas/identity cores; exact for the xla core)
    if cfg.scan_core == "xla":
        h_last = hs[:, -1]
    else:
        A_ = -jnp.exp(params["A_log"])
        dA_ = jnp.exp(dt[..., None] * A_)
        dBx_ = (dt * xs.astype(jnp.float32))[..., None] *             Bc.astype(jnp.float32)[:, :, None, :]

        def comb(a, b):
            a1, b1 = a
            a2, b2 = b
            return a2 * a1, a2 * b1 + b2

        _, hs_ = jax.lax.associative_scan(comb, (dA_, dBx_), axis=1)
        h_last = hs_[:, -1]
    return out, dict(h=h_last, conv=conv_state.astype(x.dtype))


def init_mamba_cache(cfg: MambaConfig, batch: int, dtype):
    return dict(h=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
                conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype))


def spec_mamba_cache(cfg: MambaConfig):
    return dict(h=("batch", "mlp", None), conv=("batch", None, "mlp"))


def mamba_decode(params, cfg: MambaConfig, x, cache):
    """Single-token recurrent step. x: (B, 1, d)."""
    xz = einsum("btd,de->bte", x, params["in_proj"]).astype(x.dtype)
    xs, z, dt, Bc, Cc, new_conv = _mamba_inner(params, cfg, xz, cache["conv"])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)                   # (B,di,ds)
    dBx = (dt[:, 0] * xs[:, 0].astype(jnp.float32))[..., None] * \
        Bc[:, 0].astype(jnp.float32)[:, None, :]
    h = dA * cache["h"] + dBx
    y = einsum("bds,bs->bd", h, Cc[:, 0].astype(jnp.float32))
    y = y + xs[:, 0].astype(jnp.float32) * params["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = einsum("bd,de->be", y, params["out_proj"]).astype(x.dtype)
    return out[:, None, :], dict(h=h, conv=new_conv.astype(cache["conv"].dtype))


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): time-mix with data-dependent decay + channel-mix
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    d_ff: int = 0                 # channel-mix width (3.5x d_model default)
    lora_rank: int = 64           # decay LoRA rank
    chunk: int = 64               # chunked-parallel block length
    # "xla": chunked pure-jnp; "pallas": VMEM-resident kernel;
    # "identity": roofline isolation stand-in (skip the WKV recurrence)
    wkv_core: str = "xla"

    @property
    def n_heads(self):
        return self.d_model // self.head_dim


def init_rwkv6(key, cfg: RWKV6Config, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return dict(
        # token-shift interpolation weights (static; the full RWKV6 uses
        # data-dependent token-shift — we keep per-channel static mu)
        mu_r=jnp.full((d,), 0.5, dtype), mu_k=jnp.full((d,), 0.5, dtype),
        mu_v=jnp.full((d,), 0.5, dtype), mu_w=jnp.full((d,), 0.5, dtype),
        mu_g=jnp.full((d,), 0.5, dtype),
        wr=_dense(ks[0], (d, d), dtype),
        wk=_dense(ks[1], (d, d), dtype),
        wv=_dense(ks[2], (d, d), dtype),
        wg=_dense(ks[3], (d, d), dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + lora(x)))
        w0=jnp.zeros((d,), jnp.float32),
        w_lora_a=_dense(ks[4], (d, cfg.lora_rank), dtype),
        w_lora_b=_dense(ks[5], (cfg.lora_rank, d), dtype),
        u=nn.trunc_normal(ks[6], (H, dh)).astype(jnp.float32),   # bonus
        ln_x=jnp.ones((d,), dtype),                               # group-norm scale
        wo=_dense(ks[7], (d, d), dtype),
    )


def spec_rwkv6(cfg: RWKV6Config):
    return dict(mu_r=(None,), mu_k=(None,), mu_v=(None,), mu_w=(None,),
                mu_g=(None,),
                wr=("embed", "mlp"), wk=("embed", "mlp"), wv=("embed", "mlp"),
                wg=("embed", "mlp"), w0=(None,), w_lora_a=("embed", None),
                w_lora_b=(None, "mlp"), u=(None, None), ln_x=(None,),
                wo=("mlp", "embed"))


def _rwkv6_rkvwg(params, cfg: RWKV6Config, x, x_prev):
    """Token-shift mixes x_t with x_{t-1}; x_prev: (B,1,d) last token of the
    previous segment (zeros at sequence start)."""
    B, T, d = x.shape
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)     # shifted
    def mix(mu):
        return x + (xs - x) * mu
    r = einsum("btd,de->bte", mix(params["mu_r"]), params["wr"]).astype(x.dtype)
    k = einsum("btd,de->bte", mix(params["mu_k"]), params["wk"]).astype(x.dtype)
    v = einsum("btd,de->bte", mix(params["mu_v"]), params["wv"]).astype(x.dtype)
    g = einsum("btd,de->bte", mix(params["mu_g"]), params["wg"]).astype(x.dtype)
    lora = einsum("btd,dr->btr", jnp.tanh(
        einsum("btd,dr->btr", mix(params["mu_w"]), params["w_lora_a"]).astype(x.dtype)),
        params["w_lora_b"])
    # decay rate clamped to exp(0.405)=1.5 => w >= exp(-1.5): keeps the
    # chunked kernel's e^{+-c} factors fp32-safe for chunk<=64 (see
    # kernels/rwkv6_chunked.py docstring).
    rate = jnp.clip(params["w0"] + lora.astype(jnp.float32), -20.0, 0.405)
    w = jnp.exp(-jnp.exp(rate))                                   # (B,T,d) in (0,1)
    H, dh = cfg.n_heads, cfg.head_dim
    shp = (B, H, T, dh)
    resh = lambda a: a.reshape(B, T, H, dh).transpose(0, 2, 1, 3)
    return resh(r), resh(k), resh(v), resh(w.astype(jnp.float32)), g


def rwkv6_time_mix(params, cfg: RWKV6Config, x, x_prev=None, state=None,
                   use_chunked: bool = True):
    """Full-sequence RWKV6 attention-free mixing.  Returns (out, (x_last,
    S_last)) so segments/decode can be chained."""
    B, T, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, d), x.dtype)
    r, k, v, w, g = _rwkv6_rkvwg(params, cfg, x, x_prev)
    if cfg.wkv_core == "identity" and use_chunked:
        # roofline isolation: everything but the recurrence
        o = v.astype(jnp.float32)
        S = state if state is not None else jnp.zeros(
            (B, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32)
    elif (cfg.wkv_core == "pallas" and use_chunked and state is None
          and T % cfg.chunk == 0 and T > cfg.chunk):
        from repro.kernels.rwkv6_chunked import rwkv6_chunked_pallas
        o = rwkv6_chunked_pallas(r, k, v, w, params["u"], chunk=cfg.chunk,
                                 interpret=jax.default_backend() != "tpu")
        o = o.astype(jnp.float32)
        S = jnp.zeros((B, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                      jnp.float32)
    elif use_chunked and T % cfg.chunk == 0 and T > cfg.chunk:
        from repro.kernels.rwkv6_chunked import rwkv6_chunked
        o, S = rwkv6_chunked(r, k, v, w, params["u"],
                             chunk=cfg.chunk, state=state)
    else:
        o, S = _rwkv6_sequential(r, k, v, w, params["u"], state)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, d)
    # per-head group norm
    H, dh = cfg.n_heads, cfg.head_dim
    oh = o.reshape(B, T, H, dh).astype(jnp.float32)
    mu = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    o = ((oh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, T, d)
    o = (o * params["ln_x"]).astype(x.dtype)
    o = o * jax.nn.silu(g)
    out = einsum("btd,de->bte", o, params["wo"]).astype(x.dtype)
    return out, (x[:, -1:], S)


def _rwkv6_sequential(r, k, v, w, u, state):
    B, H, T, dh = r.shape
    if state is None:
        state = jnp.zeros((B, H, dh, dh), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", rt,
                         S + u.astype(jnp.float32)[:, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    inputs = tuple(jnp.moveaxis(a.astype(jnp.float32), 2, 0)
                   for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, state, inputs)
    return jnp.moveaxis(outs, 0, 2), S


def init_rwkv6_cm(key, cfg: RWKV6Config, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    ff = cfg.d_ff or int(3.5 * d)
    return dict(mu_k=jnp.full((d,), 0.5, dtype), mu_r=jnp.full((d,), 0.5, dtype),
                wk=_dense(ks[0], (d, ff), dtype), wv=_dense(ks[1], (ff, d), dtype),
                wr=_dense(jax.random.fold_in(ks[0], 1), (d, d), dtype))


def spec_rwkv6_cm(cfg: RWKV6Config):
    return dict(mu_k=(None,), mu_r=(None,), wk=("embed", "mlp"),
                wv=("mlp", "embed"), wr=("embed", "mlp"))


def rwkv6_channel_mix(params, x, x_prev=None):
    B, T, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, d), x.dtype)
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xk = x + (xs - x) * params["mu_k"]
    xr = x + (xs - x) * params["mu_r"]
    kk = einsum("btd,df->btf", xk, params["wk"]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(kk))
    vv = einsum("btf,fd->btd", kk, params["wv"]).astype(x.dtype)
    rr = jax.nn.sigmoid(einsum("btd,de->bte", xr, params["wr"]).astype(x.dtype))
    return rr * vv, x[:, -1:]

"""Unified LM-family model: decoder-only (dense / MoE / MLA / hybrid /
attention-free) and encoder-decoder (whisper), with scan-over-layers,
configurable remat, and logical sharding specs for every parameter.

A model is a sequence of homogeneous *layer groups*; each group is
scan-stacked (params carry a leading layer dim) so the HLO stays small for
61-layer models and the stacked dim doubles as a pipeline-stage axis.

Layer kinds:
  attn_mlp / attn_moe : GQA attention + dense or MoE FFN   (pre-RMSNorm)
  mla_mlp  / mla_moe  : multi-head latent attention variant
  rwkv                : RWKV-6 time-mix + channel-mix
  jamba_period        : 8-layer Jamba period (7x mamba + 1x attn,
                        alternating MLP/MoE)
  enc / dec           : whisper encoder / decoder layers (LayerNorm + GELU)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers import nn, rope as rope_mod
from repro.models import blocks as blk

Params = Any


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder"          # decoder | encdec
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    vocab: int = 1000
    vocab_pad_to: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = True
    dtype: str = "float32"
    norm_eps: float = 1e-6

    attn_type: str = "gqa"           # gqa | mla
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "adaptive"   # AdaptGear hook
    aux_loss_coef: float = 0.01

    # hybrid / attention-free
    layer_pattern: str = "uniform"   # uniform | jamba | rwkv
    mamba_d_state: int = 16
    mamba_expand: int = 2

    # modality / structure
    input_mode: str = "tokens"       # tokens | embeds (vlm & audio stubs)
    mrope_sections: tuple | None = None
    encoder_layers: int = 0
    encoder_seq: int = 1500

    # deepseek-v3 multi-token prediction
    mtp: bool = False
    mtp_weight: float = 0.3

    # execution
    attn_core: str = "softmax"       # softmax | flash | identity
    mamba_core: str = "xla"          # xla | pallas | identity
    wkv_core: str = "xla"            # xla | pallas | identity
    remat: str = "dots"              # none | full | dots
    scan_layers: bool = True
    subquadratic: bool = False       # eligible for long_500k
    rwkv_chunk: int = 32

    @property
    def jdtype(self):
        return dict(float32=jnp.float32, bfloat16=jnp.bfloat16,
                    float16=jnp.float16)[self.dtype]

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    def attn_cfg(self, causal=True, use_rope=True) -> blk.AttnConfig:
        return blk.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, kv_heads=self.kv_heads,
            head_dim=self.head_dim, qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta, mrope_sections=self.mrope_sections,
            causal=causal, use_rope=use_rope, attn_core=self.attn_core)

    def mla_cfg(self) -> blk.MLAConfig:
        return blk.MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            q_lora_rank=self.q_lora_rank, kv_lora_rank=self.kv_lora_rank,
            qk_nope_dim=self.qk_nope_dim, qk_rope_dim=self.qk_rope_dim,
            v_dim=self.v_head_dim, rope_theta=self.rope_theta,
            attn_core=self.attn_core)

    def moe_cfg(self) -> blk.MoEConfig:
        return blk.MoEConfig(
            d_model=self.d_model, n_experts=self.n_experts, top_k=self.top_k,
            d_ff_expert=self.d_ff_expert, n_shared=self.n_shared_experts,
            d_ff_shared=self.n_shared_experts * self.d_ff_expert,
            capacity_factor=self.capacity_factor, dispatch=self.moe_dispatch)

    def mamba_cfg(self) -> blk.MambaConfig:
        return blk.MambaConfig(d_model=self.d_model,
                               d_inner=self.mamba_expand * self.d_model,
                               d_state=self.mamba_d_state,
                               scan_core=self.mamba_core)

    def rwkv_cfg(self) -> blk.RWKV6Config:
        return blk.RWKV6Config(d_model=self.d_model, head_dim=64,
                               d_ff=self.d_ff, chunk=self.rwkv_chunk,
                               wkv_core=self.wkv_core)

    def layer_groups(self) -> list[tuple[str, int]]:
        """[(kind, n_layers_in_group), ...] in execution order."""
        if self.family == "encdec":
            return [("enc", self.encoder_layers), ("dec", self.n_layers)]
        if self.layer_pattern == "rwkv":
            return [("rwkv", self.n_layers)]
        if self.layer_pattern == "jamba":
            assert self.n_layers % 8 == 0
            return [("jamba_period", self.n_layers // 8)]
        mixer = "mla" if self.attn_type == "mla" else "attn"
        if self.n_experts:
            groups = []
            if self.first_k_dense:
                groups.append((f"{mixer}_mlp", self.first_k_dense))
            groups.append((f"{mixer}_moe", self.n_layers - self.first_k_dense))
            return groups
        return [(f"{mixer}_mlp", self.n_layers)]


# ---------------------------------------------------------------------------
# per-layer init / spec / apply
# ---------------------------------------------------------------------------

def _norm_init(d, dtype, with_bias=False):
    p = dict(scale=jnp.ones((d,), dtype))
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def _norm_spec(with_bias=False):
    s = dict(scale=(None,))
    if with_bias:
        s["bias"] = (None,)
    return s


def _norm_apply(p, x, eps):
    if "bias" in p:
        return nn.layer_norm(x, p["scale"], p["bias"], eps)
    return nn.rms_norm(x, p["scale"], eps)


def init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    dt = cfg.jdtype
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if kind in ("attn_mlp", "attn_moe", "mla_mlp", "mla_moe"):
        mixer, ffn = kind.split("_")
        p = dict(norm1=_norm_init(d, dt), norm2=_norm_init(d, dt))
        if mixer == "attn":
            p["attn"] = blk.init_attention(ks[0], cfg.attn_cfg(), dt)
        else:
            p["attn"] = blk.init_mla(ks[0], cfg.mla_cfg(), dt)
        if ffn == "mlp":
            p["ffn"] = blk.init_mlp(ks[1], d, cfg.d_ff, dt)
        else:
            p["ffn"] = blk.init_moe(ks[1], cfg.moe_cfg(), dt)
        return p
    if kind == "rwkv":
        rc = cfg.rwkv_cfg()
        return dict(norm1=_norm_init(d, dt), norm2=_norm_init(d, dt),
                    tm=blk.init_rwkv6(ks[0], rc, dt),
                    cm=blk.init_rwkv6_cm(ks[1], rc, dt))
    if kind == "jamba_period":
        mc, moec = cfg.mamba_cfg(), cfg.moe_cfg()
        sub = {}
        for i in range(8):
            kk = jax.random.split(ks[i % 8], 4)
            mix = ("attn" if i == 3 else "mamba")
            layer = dict(norm1=_norm_init(d, dt), norm2=_norm_init(d, dt))
            if mix == "attn":
                layer["mixer"] = blk.init_attention(kk[0], cfg.attn_cfg(), dt)
            else:
                layer["mixer"] = blk.init_mamba(kk[0], mc, dt)
            if i % 2 == 1:
                layer["ffn"] = blk.init_moe(kk[1], moec, dt)
            else:
                layer["ffn"] = blk.init_mlp(kk[1], d, cfg.d_ff, dt)
            sub[f"l{i}"] = layer
        return sub
    if kind == "enc":
        return dict(norm1=_norm_init(d, dt, True), norm2=_norm_init(d, dt, True),
                    attn=blk.init_attention(ks[0], cfg.attn_cfg(causal=False, use_rope=False), dt),
                    ffn=blk.init_mlp(ks[1], d, cfg.d_ff, dt, gated=False))
    if kind == "dec":
        return dict(norm1=_norm_init(d, dt, True), norm2=_norm_init(d, dt, True),
                    norm3=_norm_init(d, dt, True),
                    attn=blk.init_attention(ks[0], cfg.attn_cfg(causal=True, use_rope=False), dt),
                    cross=blk.init_attention(ks[1], cfg.attn_cfg(causal=False, use_rope=False), dt),
                    ffn=blk.init_mlp(ks[2], d, cfg.d_ff, dt, gated=False))
    raise ValueError(kind)


def spec_layer(cfg: ModelConfig, kind: str):
    if kind in ("attn_mlp", "attn_moe", "mla_mlp", "mla_moe"):
        mixer, ffn = kind.split("_")
        return dict(
            norm1=_norm_spec(), norm2=_norm_spec(),
            attn=(blk.spec_attention(cfg.attn_cfg()) if mixer == "attn"
                  else blk.spec_mla(cfg.mla_cfg())),
            ffn=(blk.spec_mlp() if ffn == "mlp" else blk.spec_moe(cfg.moe_cfg())),
        )
    if kind == "rwkv":
        rc = cfg.rwkv_cfg()
        return dict(norm1=_norm_spec(), norm2=_norm_spec(),
                    tm=blk.spec_rwkv6(rc), cm=blk.spec_rwkv6_cm(rc))
    if kind == "jamba_period":
        sub = {}
        for i in range(8):
            layer = dict(norm1=_norm_spec(), norm2=_norm_spec())
            layer["mixer"] = (blk.spec_attention(cfg.attn_cfg()) if i == 3
                              else blk.spec_mamba(cfg.mamba_cfg()))
            layer["ffn"] = (blk.spec_moe(cfg.moe_cfg()) if i % 2 == 1
                            else blk.spec_mlp())
            sub[f"l{i}"] = layer
        return sub
    if kind == "enc":
        return dict(norm1=_norm_spec(True), norm2=_norm_spec(True),
                    attn=blk.spec_attention(cfg.attn_cfg(causal=False)),
                    ffn=blk.spec_mlp(gated=False))
    if kind == "dec":
        return dict(norm1=_norm_spec(True), norm2=_norm_spec(True),
                    norm3=_norm_spec(True),
                    attn=blk.spec_attention(cfg.attn_cfg()),
                    cross=blk.spec_attention(cfg.attn_cfg(causal=False)),
                    ffn=blk.spec_mlp(gated=False))
    raise ValueError(kind)


def layer_apply(params, cfg: ModelConfig, kind: str, x, positions,
                enc_out=None, rwkv_carry=None):
    """Full-sequence layer. Returns (x, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_moe", "mla_mlp", "mla_moe"):
        mixer, ffn = kind.split("_")
        h = _norm_apply(params["norm1"], x, eps)
        if mixer == "attn":
            h = blk.attention_apply(params["attn"], cfg.attn_cfg(), h, positions)
        else:
            h = blk.mla_apply(params["attn"], cfg.mla_cfg(), h, positions)
        x = x + h
        h = _norm_apply(params["norm2"], x, eps)
        if ffn == "mlp":
            h = blk.mlp_apply(params["ffn"], h)
        else:
            h, aux = blk.moe_apply(params["ffn"], cfg.moe_cfg(), h)
        return x + h, aux
    if kind == "rwkv":
        rc = cfg.rwkv_cfg()
        h = _norm_apply(params["norm1"], x, eps)
        h, _ = blk.rwkv6_time_mix(params["tm"], rc, h)
        x = x + h
        h = _norm_apply(params["norm2"], x, eps)
        h, _ = blk.rwkv6_channel_mix(params["cm"], h)
        return x + h, aux
    if kind == "jamba_period":
        total_aux = aux
        for i in range(8):
            lp = params[f"l{i}"]
            h = _norm_apply(lp["norm1"], x, eps)
            if i == 3:
                h = blk.attention_apply(lp["mixer"], cfg.attn_cfg(), h, positions)
            else:
                h = blk.mamba_apply(lp["mixer"], cfg.mamba_cfg(), h)
            x = x + h
            h = _norm_apply(lp["norm2"], x, eps)
            if i % 2 == 1:
                h, a = blk.moe_apply(lp["ffn"], cfg.moe_cfg(), h)
                total_aux = total_aux + a
            else:
                h = blk.mlp_apply(lp["ffn"], h)
            x = x + h
        return x, total_aux
    if kind == "enc":
        h = _norm_apply(params["norm1"], x, eps)
        h = blk.attention_apply(params["attn"], cfg.attn_cfg(causal=False, use_rope=False),
                                h, positions)
        x = x + h
        h = _norm_apply(params["norm2"], x, eps)
        return x + blk.mlp_apply(params["ffn"], h, gated=False), aux
    if kind == "dec":
        acfg = cfg.attn_cfg(causal=True, use_rope=False)
        ccfg = cfg.attn_cfg(causal=False, use_rope=False)
        h = _norm_apply(params["norm1"], x, eps)
        h = blk.attention_apply(params["attn"], acfg, h, positions)
        x = x + h
        h = _norm_apply(params["norm2"], x, eps)
        kx = blk.einsum("bsd,dh->bsh", enc_out, params["cross"]["wk"]).astype(x.dtype)
        vx = blk.einsum("bsd,dh->bsh", enc_out, params["cross"]["wv"]).astype(x.dtype)
        Bb, Se, _ = enc_out.shape
        kx = kx.reshape(Bb, Se, cfg.kv_heads, cfg.head_dim)
        vx = vx.reshape(Bb, Se, cfg.kv_heads, cfg.head_dim)
        if ccfg.qkv_bias:
            kx = kx + params["cross"]["bk"].reshape(cfg.kv_heads, cfg.head_dim)
            vx = vx + params["cross"]["bv"].reshape(cfg.kv_heads, cfg.head_dim)
        h = blk.attention_apply(params["cross"], ccfg, h, positions,
                                kv_override=(kx, vx))
        x = x + h
        h = _norm_apply(params["norm3"], x, eps)
        return x + blk.mlp_apply(params["ffn"], h, gated=False), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model init / spec / forward
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    dt = cfg.jdtype
    keys = jax.random.split(key, 8)
    V = cfg.padded_vocab
    p = dict(embed=nn.trunc_normal(keys[0], (V, cfg.d_model)).astype(dt),
             final_norm=_norm_init(cfg.d_model, dt,
                                   with_bias=(cfg.family == "encdec")))
    if not cfg.tie_embeddings:
        p["lm_head"] = nn.trunc_normal(keys[1], (cfg.d_model, V)).astype(dt)
    groups = []
    for gi, (kind, n) in enumerate(cfg.layer_groups()):
        gkey = jax.random.fold_in(keys[2], gi)
        if cfg.scan_layers:
            stack = jax.vmap(lambda k: init_layer(k, cfg, kind))(
                jax.random.split(gkey, n))
        else:
            stack = [init_layer(k, cfg, kind)
                     for k in jax.random.split(gkey, n)]
        groups.append(stack)  # kind/n derivable from cfg.layer_groups()
    p["groups"] = groups
    if cfg.family == "encdec":
        p["enc_final_norm"] = _norm_init(cfg.d_model, dt, with_bias=True)
    if cfg.mtp:
        p["mtp"] = dict(norm=_norm_init(cfg.d_model, dt),
                        proj=nn.lecun_normal(keys[3],
                                             (2 * cfg.d_model, cfg.d_model)).astype(dt),
                        block=init_layer(keys[4], cfg, "attn_mlp"
                                         if cfg.attn_type == "gqa" else "mla_mlp"))
    return p


def param_specs(cfg: ModelConfig):
    s = dict(embed=("vocab", "embed"), final_norm=_norm_spec(cfg.family == "encdec"))
    if not cfg.tie_embeddings:
        s["lm_head"] = ("embed", "vocab")
    groups = []
    for kind, n in cfg.layer_groups():
        ls = spec_layer(cfg, kind)
        if cfg.scan_layers:
            ls = jax.tree.map(lambda t: ("layer",) + t, ls,
                              is_leaf=lambda t: isinstance(t, tuple))
        else:
            ls = [ls] * n
        groups.append(ls)
    s["groups"] = groups
    if cfg.family == "encdec":
        s["enc_final_norm"] = _norm_spec(True)
    if cfg.mtp:
        mkind = "attn_mlp" if cfg.attn_type == "gqa" else "mla_mlp"
        s["mtp"] = dict(norm=_norm_spec(), proj=("embed", "embed"),
                        block=spec_layer(cfg, mkind))
    return s


def _run_group(group_params, cfg: ModelConfig, kind: str, x, positions,
               enc_out=None):
    """Scan (or loop) a homogeneous layer group."""
    def body_fn(x, layer_params):
        y, aux = layer_apply(layer_params, cfg, kind, x, positions, enc_out)
        return y, aux

    if cfg.remat == "full":
        body_fn = jax.checkpoint(body_fn)
    elif cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if cfg.scan_layers:
        x, auxs = jax.lax.scan(body_fn, x, group_params)
        return x, auxs.sum()
    aux_total = jnp.zeros((), jnp.float32)
    for lp in group_params:
        x, aux = body_fn(x, lp)
        aux_total += aux
    return x, aux_total


def _logits(params, cfg: ModelConfig, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return blk.einsum("bsd,dv->bsv", h, head).astype(cfg.jdtype)


def forward(params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    """Training/prefill forward pass.  batch keys by input_mode/family:
      tokens mode : tokens (B,S) [+ positions (3,B,S) for M-RoPE]
      embeds mode : embeds (B,S,d)
      encdec      : enc_embeds (B,Se,d) + tokens (B,S)
    Returns (logits (B,S,Vp), aux dict)."""
    dt = cfg.jdtype
    if cfg.input_mode == "tokens":
        x = nn.embed_lookup(params["embed"], batch["tokens"]).astype(dt)
        B, S = batch["tokens"].shape
    else:
        x = batch["embeds"].astype(dt)
        B, S = x.shape[:2]
    if cfg.mrope_sections is not None:
        positions = batch.get("positions")
        if positions is None:
            base = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            positions = jnp.broadcast_to(base[None], (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    enc_out = None
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        enc = batch["enc_embeds"].astype(dt)
        Se = enc.shape[1]
        enc = enc + rope_mod.sinusoidal_positions(Se, cfg.d_model).astype(dt)
        enc_positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
        x_dec = nn.embed_lookup(params["embed"], batch["tokens"]).astype(dt)
        x_dec = x_dec + rope_mod.sinusoidal_positions(S, cfg.d_model).astype(dt)
        for g, (kind, n) in zip(params["groups"], cfg.layer_groups()):
            if kind == "enc":
                enc, aux = _run_group(g, cfg, kind, enc, enc_positions)
                aux_total += aux
                enc = _norm_apply(params["enc_final_norm"], enc, cfg.norm_eps)
                enc_out = enc
            else:
                x_dec, aux = _run_group(g, cfg, kind, x_dec, positions, enc_out)
                aux_total += aux
        h = _norm_apply(params["final_norm"], x_dec, cfg.norm_eps)
        return _logits(params, cfg, h), dict(aux_loss=aux_total)

    for g, (kind, n) in zip(params["groups"], cfg.layer_groups()):
        x, aux = _run_group(g, cfg, kind, x, positions)
        aux_total += aux
    h = _norm_apply(params["final_norm"], x, cfg.norm_eps)
    out = dict(aux_loss=aux_total)
    if cfg.mtp and "tokens" in batch:
        # DeepSeek-V3-style multi-token prediction: one extra block over
        # [norm(h_t); norm(embed(tok_{t+1}))] predicting token t+2.
        nxt = jnp.roll(batch["tokens"], -1, axis=1)
        e2 = nn.embed_lookup(params["embed"], nxt).astype(dt)
        hm = jnp.concatenate([_norm_apply(params["mtp"]["norm"], x, cfg.norm_eps),
                              e2], axis=-1)
        hm = blk.einsum("bsd,de->bse", hm, params["mtp"]["proj"]).astype(dt)
        mkind = "attn_mlp" if cfg.attn_type == "gqa" else "mla_mlp"
        hm, _ = layer_apply(params["mtp"]["block"], cfg, mkind, hm, positions)
        hm = _norm_apply(params["final_norm"], hm, cfg.norm_eps)
        out["mtp_logits"] = _logits(params, cfg, hm)
    return _logits(params, cfg, h), out


def loss_fn(params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    logits, out = forward(params, cfg, batch)
    labels = batch["labels"]
    mask = batch.get("mask")
    # mask out the padded vocab tail
    loss = nn.softmax_cross_entropy(logits[..., : cfg.vocab], labels, mask)
    total = loss + cfg.aux_loss_coef * out["aux_loss"]
    metrics = dict(ce=loss, aux=out["aux_loss"])
    if cfg.mtp and "mtp_logits" in out:
        l2 = jnp.roll(labels, -1, axis=1)
        mtp_loss = nn.softmax_cross_entropy(out["mtp_logits"][..., : cfg.vocab], l2, mask)
        total = total + cfg.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    return total, metrics


# ---------------------------------------------------------------------------
# decode (serving) path
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int):
    dt = cfg.jdtype
    if kind in ("attn_mlp", "attn_moe"):
        return blk.init_attn_cache(cfg.attn_cfg(), batch, s_max, dt)
    if kind in ("mla_mlp", "mla_moe"):
        return blk.init_mla_cache(cfg.mla_cfg(), batch, s_max, dt)
    if kind == "rwkv":
        rc = cfg.rwkv_cfg()
        return dict(S=jnp.zeros((batch, rc.n_heads, rc.head_dim, rc.head_dim),
                                jnp.float32),
                    x_tm=jnp.zeros((batch, 1, cfg.d_model), dt),
                    x_cm=jnp.zeros((batch, 1, cfg.d_model), dt))
    if kind == "jamba_period":
        sub = {}
        for i in range(8):
            if i == 3:
                sub[f"l{i}"] = blk.init_attn_cache(cfg.attn_cfg(), batch, s_max, dt)
            else:
                sub[f"l{i}"] = blk.init_mamba_cache(cfg.mamba_cfg(), batch, dt)
        return sub
    if kind == "dec":
        c = blk.init_attn_cache(cfg.attn_cfg(), batch, s_max, dt)
        kv_shape = (batch, cfg.encoder_seq, cfg.kv_heads, cfg.head_dim)
        c["cross_k"] = jnp.zeros(kv_shape, dt)
        c["cross_v"] = jnp.zeros(kv_shape, dt)
        return c
    if kind == "enc":
        return None
    raise ValueError(kind)


def spec_layer_cache(cfg: ModelConfig, kind: str):
    if kind in ("attn_mlp", "attn_moe"):
        return blk.spec_attn_cache(cfg.attn_cfg())
    if kind in ("mla_mlp", "mla_moe"):
        return blk.spec_mla_cache(cfg.mla_cfg())
    if kind == "rwkv":
        return dict(S=("batch", "heads", None, None), x_tm=("batch", None, None),
                    x_cm=("batch", None, None))
    if kind == "jamba_period":
        return {f"l{i}": (blk.spec_attn_cache(cfg.attn_cfg()) if i == 3
                          else blk.spec_mamba_cache(cfg.mamba_cfg()))
                for i in range(8)}
    if kind == "dec":
        s = blk.spec_attn_cache(cfg.attn_cfg())
        s["cross_k"] = ("batch", None, "kv", None)
        s["cross_v"] = ("batch", None, "kv", None)
        return s
    if kind == "enc":
        return None
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    caches = []
    for kind, n in cfg.layer_groups():
        if kind == "enc":
            caches.append(None)
            continue
        one = init_layer_cache(cfg, kind, batch, s_max)
        if cfg.scan_layers:
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one))
        else:
            caches.append([init_layer_cache(cfg, kind, batch, s_max)
                           for _ in range(n)])
    return caches


def cache_specs(cfg: ModelConfig):
    out = []
    for kind, n in cfg.layer_groups():
        if kind == "enc":
            out.append(None)
            continue
        s = spec_layer_cache(cfg, kind)
        if cfg.scan_layers:
            s = jax.tree.map(lambda t: (None,) + t, s,
                             is_leaf=lambda t: isinstance(t, tuple))
        else:
            s = [s] * n
        out.append(s)
    return out


def layer_decode(params, cfg: ModelConfig, kind: str, x, cache, pos):
    eps = cfg.norm_eps
    if kind in ("attn_mlp", "attn_moe", "mla_mlp", "mla_moe"):
        mixer, ffn = kind.split("_")
        h = _norm_apply(params["norm1"], x, eps)
        if mixer == "attn":
            h, cache = blk.attention_decode(params["attn"], cfg.attn_cfg(), h,
                                            cache, pos)
        else:
            h, cache = blk.mla_decode(params["attn"], cfg.mla_cfg(), h, cache,
                                      pos, absorbed=True)
        x = x + h
        h = _norm_apply(params["norm2"], x, eps)
        if ffn == "mlp":
            h = blk.mlp_apply(params["ffn"], h)
        else:
            h, _ = blk.moe_apply(params["ffn"], cfg.moe_cfg(), h)
        return x + h, cache
    if kind == "rwkv":
        rc = cfg.rwkv_cfg()
        h = _norm_apply(params["norm1"], x, eps)
        h_out, (x_tm, S) = blk.rwkv6_time_mix(params["tm"], rc, h,
                                              x_prev=cache["x_tm"],
                                              state=cache["S"],
                                              use_chunked=False)
        x = x + h_out
        h = _norm_apply(params["norm2"], x, eps)
        h_out, x_cm = blk.rwkv6_channel_mix(params["cm"], h,
                                            x_prev=cache["x_cm"])
        return x + h_out, dict(S=S, x_tm=x_tm.astype(cache["x_tm"].dtype),
                               x_cm=x_cm.astype(cache["x_cm"].dtype))
    if kind == "jamba_period":
        new = {}
        for i in range(8):
            lp = params[f"l{i}"]
            h = _norm_apply(lp["norm1"], x, eps)
            if i == 3:
                h, new[f"l{i}"] = blk.attention_decode(lp["mixer"],
                                                       cfg.attn_cfg(), h,
                                                       cache[f"l{i}"], pos)
            else:
                h, new[f"l{i}"] = blk.mamba_decode(lp["mixer"], cfg.mamba_cfg(),
                                                   h, cache[f"l{i}"])
            x = x + h
            h = _norm_apply(lp["norm2"], x, eps)
            if i % 2 == 1:
                h, _ = blk.moe_apply(lp["ffn"], cfg.moe_cfg(), h)
            else:
                h = blk.mlp_apply(lp["ffn"], h)
            x = x + h
        return x, new
    if kind == "dec":
        acfg = cfg.attn_cfg(causal=True, use_rope=False)
        ccfg = cfg.attn_cfg(causal=False, use_rope=False)
        h = _norm_apply(params["norm1"], x, eps)
        self_cache = dict(k=cache["k"], v=cache["v"])
        h, self_cache = blk.attention_decode(params["attn"], acfg, h,
                                             self_cache, pos)
        x = x + h
        h = _norm_apply(params["norm2"], x, eps)
        B = x.shape[0]
        positions = jnp.zeros((B, 1), jnp.int32)
        h = blk.attention_apply(params["cross"], ccfg, h, positions,
                                kv_override=(cache["cross_k"],
                                             cache["cross_v"]))
        x = x + h
        h = _norm_apply(params["norm3"], x, eps)
        x = x + blk.mlp_apply(params["ffn"], h, gated=False)
        return x, dict(k=self_cache["k"], v=self_cache["v"],
                       cross_k=cache["cross_k"], cross_v=cache["cross_v"])
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, caches, tokens, pos):
    """One decode step.  tokens: (B, 1) int32 (or embeds (B,1,d) in embeds
    mode); pos: scalar int32 position of the new token.  Returns
    (logits (B, 1, Vp), next_token (B, 1), new caches)."""
    dt = cfg.jdtype
    if cfg.input_mode == "tokens":
        x = nn.embed_lookup(params["embed"], tokens).astype(dt)
    else:
        x = tokens.astype(dt)
    if cfg.family == "encdec":
        if cfg.scan_layers:
            s_max = caches[-1]["k"].shape[2]
        else:
            s_max = caches[-1][0]["k"].shape[1]
        x = x + jax.lax.dynamic_slice_in_dim(
            rope_mod.sinusoidal_positions(s_max, cfg.d_model).astype(dt),
            pos, 1, axis=0)

    new_caches = []
    for g, cache, (kind, n) in zip(params["groups"], caches,
                                   cfg.layer_groups()):
        if kind == "enc":
            new_caches.append(None)
            continue
        if cfg.scan_layers:
            def body_fn(x, inp):
                lp, lc = inp
                y, nc = layer_decode(lp, cfg, kind, x, lc, pos)
                return y, nc
            x, new_c = jax.lax.scan(body_fn, x, (g, cache))
        else:
            new_c = []
            for lp, lc in zip(g, cache):
                x, nc = layer_decode(lp, cfg, kind, x, lc, pos)
                new_c.append(nc)
        new_caches.append(new_c)
    h = _norm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, h)
    next_tok = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    return logits, next_tok, new_caches


# ---------------------------------------------------------------------------
# cache-producing prefill (serving: prompt pass that hands off to decode)
# ---------------------------------------------------------------------------

def _pad_cache_seq(arr, s_max):
    pad = s_max - arr.shape[1]
    if pad <= 0:
        return arr[:, :s_max]
    return jnp.pad(arr, ((0, 0), (0, pad)) + ((0, 0),) * (arr.ndim - 2))


def layer_prefill(params, cfg: ModelConfig, kind: str, x, positions, s_max,
                  enc_out=None):
    """Full-sequence layer that also emits its decode cache."""
    eps = cfg.norm_eps
    dt = cfg.jdtype
    B, S, _ = x.shape
    if kind in ("attn_mlp", "attn_moe"):
        acfg = cfg.attn_cfg()
        h = _norm_apply(params["norm1"], x, eps)
        q, k, v = blk._qkv(params["attn"], acfg, h, positions)
        o = blk.kref.mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
        h = blk.einsum("bsh,hd->bsd", o, params["attn"]["wo"]).astype(x.dtype)
        x = x + h
        cache = dict(k=_pad_cache_seq(k.astype(dt), s_max),
                     v=_pad_cache_seq(v.astype(dt), s_max))
        h = _norm_apply(params["norm2"], x, eps)
        if kind.endswith("mlp"):
            h = blk.mlp_apply(params["ffn"], h)
        else:
            h, _ = blk.moe_apply(params["ffn"], cfg.moe_cfg(), h)
        return x + h, cache
    if kind in ("mla_mlp", "mla_moe"):
        mcfg = cfg.mla_cfg()
        h = _norm_apply(params["norm1"], x, eps)
        q_nope, q_rope, c_kv, k_rope = blk._mla_qkv(params["attn"], mcfg, h,
                                                    positions)
        cache = dict(c_kv=_pad_cache_seq(c_kv.astype(dt), s_max),
                     k_rope=_pad_cache_seq(k_rope[:, :, 0, :].astype(dt),
                                           s_max))
        h2 = blk.mla_apply(params["attn"], mcfg, _norm_apply(params["norm1"],
                                                             x, eps),
                           positions)
        x = x + h2
        h = _norm_apply(params["norm2"], x, eps)
        if kind.endswith("mlp"):
            h = blk.mlp_apply(params["ffn"], h)
        else:
            h, _ = blk.moe_apply(params["ffn"], cfg.moe_cfg(), h)
        return x + h, cache
    if kind == "rwkv":
        rc = cfg.rwkv_cfg()
        h = _norm_apply(params["norm1"], x, eps)
        h_out, (x_tm, S_state) = blk.rwkv6_time_mix(
            params["tm"], rc, h, use_chunked=(cfg.wkv_core != "pallas"))
        x = x + h_out
        h = _norm_apply(params["norm2"], x, eps)
        h_out, x_cm = blk.rwkv6_channel_mix(params["cm"], h)
        x = x + h_out
        return x, dict(S=S_state, x_tm=x_tm.astype(dt), x_cm=x_cm.astype(dt))
    if kind == "jamba_period":
        caches = {}
        for i in range(8):
            lp = params[f"l{i}"]
            h = _norm_apply(lp["norm1"], x, eps)
            if i == 3:
                acfg = cfg.attn_cfg()
                q, k, v = blk._qkv(lp["mixer"], acfg, h, positions)
                o = blk.kref.mha(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), causal=True)
                o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
                h = blk.einsum("bsh,hd->bsd", o,
                               lp["mixer"]["wo"]).astype(x.dtype)
                caches[f"l{i}"] = dict(k=_pad_cache_seq(k.astype(dt), s_max),
                                       v=_pad_cache_seq(v.astype(dt), s_max))
            else:
                h, caches[f"l{i}"] = blk.mamba_apply(lp["mixer"],
                                                     cfg.mamba_cfg(), h,
                                                     return_state=True)
            x = x + h
            h = _norm_apply(lp["norm2"], x, eps)
            if i % 2 == 1:
                h, _ = blk.moe_apply(lp["ffn"], cfg.moe_cfg(), h)
            else:
                h = blk.mlp_apply(lp["ffn"], h)
            x = x + h
        return x, caches
    raise ValueError(f"prefill unsupported for kind {kind}")


def prefill(params, cfg: ModelConfig, batch: dict, s_max: int):
    """Prompt pass producing (logits, caches) for decode handoff.
    Decoder-only families (token or embeds mode)."""
    assert cfg.family == "decoder"
    dt = cfg.jdtype
    if cfg.input_mode == "tokens":
        x = nn.embed_lookup(params["embed"], batch["tokens"]).astype(dt)
        B, S = batch["tokens"].shape
    else:
        x = batch["embeds"].astype(dt)
        B, S = x.shape[:2]
    if cfg.mrope_sections is not None:
        positions = batch.get("positions")
        if positions is None:
            base = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            positions = jnp.broadcast_to(base[None], (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    caches = []
    for g, (kind, n) in zip(params["groups"], cfg.layer_groups()):
        if cfg.scan_layers:
            def body_fn(x, lp):
                y, c = layer_prefill(lp, cfg, kind, x, positions, s_max)
                return y, c
            x, cache = jax.lax.scan(body_fn, x, g)
        else:
            cache = []
            for lp in g:
                x, c = layer_prefill(lp, cfg, kind, x, positions, s_max)
                cache.append(c)
        caches.append(cache)
    h = _norm_apply(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, h), caches

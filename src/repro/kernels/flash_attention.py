"""Pallas TPU kernel: blockwise-softmax (Flash) causal attention.

The roofline baseline (EXPERIMENTS.md §Roofline) shows every *_4k/32k
attention cell is memory- or collective-bound because XLA's unfused
attention writes the (B, H, S, S) score tensor to HBM.  This kernel keeps
the score block in VMEM: HBM traffic drops from O(S^2) to O(S * d) streams
of Q, K, V, O — the classic FlashAttention result (arXiv:2205.14135),
retiled for the TPU MXU (block sizes multiples of 128 lanes).

Grid: (B * Hq, Sq / blk_q, Skv / blk_k) with the KV dim innermost
("arbitrary"); running (max, sum, acc) live in VMEM scratch across KV steps.
Causal masking is handled per-block: fully-masked blocks still execute (no
data-dependent control flow) but contribute zero; a production mosaic build
would skip them via the grid order — we note the 2x causal win in the
analytic model instead.

GQA: the index map sends q-head h to kv-head h // (Hq // Hkv).

Supports forward (serving / prefill).  For training, the wrapper installs a
custom VJP whose backward recomputes attention blockwise through the
pure-jnp path (flash-style recompute; see ops note in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, blk_q: int, blk_k: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)          # (blk_q, d)
    k = k_ref[...].astype(jnp.float32)          # (blk_k, d)
    v = v_ref[...].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        q_pos = pl.program_id(1) * blk_q + \
            jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        k_pos = kv_i * blk_k + \
            jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                          # (blk_q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + \
        jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret", "scale"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool = True,
                    scale: float | None = None):
    """q: (B, Hq, Sq, d); k: (B, Hkv, Skv, d); v: (B, Hkv, Skv, dv)
    -> (B, Hq, Sq, dv).  Sq % blk_q == 0 and Skv % blk_k == 0 (pad
    upstream); dv may differ from d (MLA)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    dv = v.shape[-1]
    g = Hq // Hkv
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    assert Sq % blk_q == 0 and Skv % blk_k == 0
    scale = (d ** -0.5) if scale is None else scale

    qf = q.reshape(B * Hq, Sq, d)
    kf = k.reshape(B * Hkv, Skv, d)
    vf = v.reshape(B * Hkv, Skv, dv)

    def kv_index(i, qi, ki):
        # flat q index i = b * Hq + h  ->  kv index b * Hkv + h // g
        b = i // Hq
        h = i % Hq
        return (b * Hkv + h // g, ki, 0)

    grid = (B * Hq, Sq // blk_q, Skv // blk_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          blk_q=blk_q, blk_k=blk_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, blk_q, d), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((None, blk_k, d), kv_index),
            pl.BlockSpec((None, blk_k, dv), kv_index),
        ],
        out_specs=pl.BlockSpec((None, blk_q, dv), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((blk_q, 1), jnp.float32),
                        pltpu.VMEM((blk_q, 1), jnp.float32),
                        pltpu.VMEM((blk_q, dv), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, dv)


# ---------------------------------------------------------------------------
# trainable wrapper: flash forward + flash-style recompute backward
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _trainable(causal: bool, scale: float | None):
    from repro.kernels import ref

    @jax.custom_vjp
    def f(q, k, v):
        interp = jax.default_backend() != "tpu"
        return flash_attention(q, k, v, causal=causal, interpret=interp,
                               scale=scale)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, do):
        # flash-style recompute: rerun attention under vjp of the oracle
        # (no saved S^2 tensors cross fwd->bwd; the recompute itself is the
        # Pallas bwd kernel on TPU — here the oracle stands in, DESIGN.md)
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q, k, v: ref.mha(q, k, v, causal=causal, scale=scale),
            q, k, v)
        return vjp(do)

    f.defvjp(fwd, bwd)
    return f


def flash_attention_trainable(q, k, v, *, causal: bool = True,
                              scale: float | None = None):
    """Differentiable flash attention: Pallas forward, recompute backward."""
    return _trainable(causal, scale)(q, k, v)


def flash_hbm_bytes(B, Hq, Hkv, Sq, Skv, d, bytes_el=2, blk_q=512) -> int:
    """Analytic HBM traffic of the kernel: Q and O streamed once; K and V
    streamed once per q-block row (the KV loop rereads them).  blk_q=512
    keeps the VMEM working set ~1 MiB while cutting KV rereads 4x vs the
    128 default (a tuning noted in EXPERIMENTS.md §Perf)."""
    q_o = 2 * B * Hq * Sq * d * bytes_el
    n_qblk = max(Sq // blk_q, 1)
    kv = 2 * B * Hkv * Skv * d * bytes_el * n_qblk
    return q_o + kv


def flash_flops(B, Hq, Sq, Skv, d, causal=True) -> float:
    """2 matmuls of S_q x S_kv x d per head; causal halves the live blocks."""
    f = 2.0 * 2.0 * B * Hq * Sq * Skv * d
    return f / 2 if causal else f

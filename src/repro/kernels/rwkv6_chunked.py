"""Chunked-parallel RWKV-6 linear recurrence (Finch, arXiv:2404.05892).

The sequential recurrence
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;   o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
is O(T) steps of rank-1 updates -- hostile to the MXU.  The chunked form
(borrowed from the GLA family) turns it into per-chunk matmuls:

with in-chunk cumulative log-decay  c_t = sum_{s<=t} log w_s  (c_0 = 0 at the
chunk start):
    intra:  o_t += sum_{j<t} (r_t e^{c_{t-1}-c_j}) . k_j  v_j  +  (r_t.(u*k_t)) v_t
    inter:  o_t += (r_t e^{c_{t-1}}) S_prev
    carry:  S'   = e^{c_C} (x)_k S_prev + sum_j e^{c_C - c_j} k_j v_j^T

All exponents are differences c_a - c_b with a >= b, hence <= 0: every factor
is in (0, 1] and fp32-safe as long as |c| stays < ~80 within one chunk.  The
model clamps the per-step decay rate (blocks.py) so chunk<=64 is safe.

Two implementations:
  * rwkv6_chunked       -- pure-jnp (oracle-adjacent; used for autodiff)
  * rwkv6_chunked_pallas -- Pallas TPU kernel: grid (B*H, T/C) with the chunk
    dim sequential ("arbitrary") and the (dh, dh) state held in VMEM scratch
    across grid steps.  Forward-only (inference/serving path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_math(r, k, v, lw, u, S):
    """One chunk for all (B, H). r/k/v/lw: (B,H,C,dh) fp32; S: (B,H,dh,dh)."""
    C = r.shape[2]
    c_inc = jnp.cumsum(lw, axis=2)                      # c_t (inclusive)
    c_exc = c_inc - lw                                  # c_{t-1} (exclusive)
    r_dec = r * jnp.exp(c_exc)                          # r_t e^{c_{t-1}}
    k_dec = k * jnp.exp(c_inc[:, :, -1:, :] - c_inc)    # k_j e^{c_C - c_j}

    # intra-chunk: A[t, j] = (r_t e^{c_{t-1}}) . (k_j e^{-c_j}), j < t.
    # Using the safe factorization (r_t e^{c_{t-1}-c_C'}) with c at chunk end
    # would distort the strict lower triangle; instead compute pairwise with
    # k_j e^{c_{t-1}-c_j} via the two decayed tensors sharing e^{c_C}:
    #   r_dec . (k_dec e^{-c_C}) = r_t k_j e^{c_{t-1} - c_j}   (exact)
    # and e^{-c_C} folds into a single broadcast (safe: applied after the
    # masked matmul where every surviving term already carries e^{c_C-c_j}).
    A = jnp.einsum("bhtd,bhjd->bhtj", r_dec,
                   k * jnp.exp(-c_inc))                 # may be large individually
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    A = jnp.where(mask, A, 0.0)
    diag = jnp.einsum("bhtd,bhtd->bht", r, u[None, :, None, :] * k)
    o = jnp.einsum("bhtj,bhjd->bhtd", A, v)
    o += diag[..., None] * v
    o += jnp.einsum("bhtd,bhde->bhte", r_dec, S)
    S_new = jnp.exp(c_inc[:, :, -1, :])[..., None] * S + \
        jnp.einsum("bhjd,bhje->bhde", k_dec, v)
    return o, S_new


def rwkv6_chunked(r, k, v, w, u, *, chunk: int = 32, state=None):
    """Pure-jnp chunked evaluation.  r,k,v,w: (B,H,T,dh); u: (H,dh).
    Returns (o: (B,H,T,dh) fp32->input dtype, S: (B,H,dh,dh) fp32)."""
    B, H, T, dh = r.shape
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    if state is None:
        state = jnp.zeros((B, H, dh, dh), jnp.float32)

    def to_chunks(a):
        return a.reshape(B, H, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, lc = map(to_chunks, (rf, kf, vf, lw))

    def step(S, inp):
        rr, kk, vv, ll = inp
        o, S = _chunk_math(rr, kk, vv, ll, u.astype(jnp.float32), S)
        return S, o

    S, os = jax.lax.scan(step, state, (rc, kc, vc, lc))
    o = os.transpose(1, 2, 0, 3, 4).reshape(B, H, T, dh)
    return o.astype(r.dtype), S


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _pallas_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)       # (C, dh)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)       # (1, dh)
    S = s_ref[...]                           # (dh, dh)

    C = r.shape[0]
    c_inc = jnp.cumsum(lw, axis=0)
    c_exc = c_inc - lw
    r_dec = r * jnp.exp(c_exc)
    k_idec = k * jnp.exp(-c_inc)
    A = jnp.dot(r_dec, k_idec.T, preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(jj < ii, A, 0.0)
    diag = jnp.sum(r * (u * k), axis=-1)     # (C,)
    o = jnp.dot(A, v, preferred_element_type=jnp.float32)
    o += diag[:, None] * v
    o += jnp.dot(r_dec, S, preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)

    k_dec = k * jnp.exp(c_inc[-1:, :] - c_inc)
    s_ref[...] = jnp.exp(c_inc[-1, :])[:, None] * S + \
        jnp.dot(k_dec.T, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked_pallas(r, k, v, w, u, *, chunk: int = 32,
                         interpret: bool = True):
    """Forward-only Pallas evaluation. Shapes as rwkv6_chunked; state starts
    at zero (serving prefill).  Grid: (B*H parallel, T/C sequential)."""
    B, H, T, dh = r.shape
    assert T % chunk == 0
    n_chunks = T // chunk
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))

    def flat(a):
        return a.reshape(B * H, T, dh)

    rf, kf, vf, lwf = flat(r), flat(k), flat(v), flat(lw)
    uf = jnp.broadcast_to(u[None, :, None, :], (B, H, 1, dh)).reshape(B * H, 1, dh)

    grid = (B * H, n_chunks)
    out = pl.pallas_call(
        _pallas_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, dh), lambda i, t: (i, t, 0)),
            pl.BlockSpec((None, chunk, dh), lambda i, t: (i, t, 0)),
            pl.BlockSpec((None, chunk, dh), lambda i, t: (i, t, 0)),
            pl.BlockSpec((None, chunk, dh), lambda i, t: (i, t, 0)),
            pl.BlockSpec((None, 1, dh), lambda i, t: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, dh), lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
    )(rf, kf, vf, lwf, uf)
    return out.reshape(B, H, T, dh)


def rwkv6_hbm_bytes(B, H, T, dh, bytes_el: int = 4) -> int:
    """Streaming floor of the Pallas kernel: r/k/v/w in + o out, once."""
    return 5 * B * H * T * dh * bytes_el


def rwkv6_flops(B, H, T, dh, chunk: int = 32) -> float:
    """Per chunk: two (C,C)x(C,dh)-class matmuls + two (C,dh)x(dh,dh) state
    ops => 2*C^2*dh + 4*C*dh^2 flops; T/C chunks."""
    per_chunk = 2.0 * chunk * chunk * dh + 4.0 * chunk * dh * dh
    return B * H * (T // chunk) * per_chunk

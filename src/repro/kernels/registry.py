"""Kernel registry: the single place an aggregation kernel is defined.

Every sparse/dense aggregation kernel registers one :class:`KernelSpec`
bundling everything the rest of the system needs to use it:

  name       -- dispatch key (stored in KernelPlans, printed by benchmarks)
  kinds      -- which subgraph kinds the kernel applies to: ``"diag"`` (the
                block-diagonal intra-community subgraph) and/or ``"offdiag"``
                (inter-community density buckets)
  build      -- host-side format materializer run once during decomposition:
                ``build(coo, coo_t, block_size, stats) -> payload``.  The
                payload is an arbitrary pytree (a single format container, or
                a tuple such as blocked-ELL forward + transpose for the VJP).
                ``coo_t`` is only constructed (and non-None) when
                ``needs_transpose`` is set.  ``stats`` carries the subgraph's
                density statistics so a builder can pick per-bucket tiling
                (the blocked-ELL builder chooses its block size and
                feature-tile cap from them).
  matvec     -- device function ``matvec(payload, x) -> A @ x``
  matvec_acc -- optional accumulating variant ``matvec_acc(payload, x, y_in)
                -> y_in + A @ x``; aggregate() threads one output buffer
                through the subgraph list instead of materializing a partial
                per density bucket (the Pallas kernels seed their VMEM
                scratch from y_in).
  fused_matvec / fused_matvec_acc
             -- fused transform+aggregate entry points
                ``(payload, x, w[, y_in]) -> A @ (x @ w) [+ y_in]``.  A spec
                providing these is a *fused* kernel: it is selected through
                the same KernelPlan machinery but dispatched by
                ``aggregate_transform`` with the raw features and weight.
  payload_of -- name of another kernel whose format payload this spec reuses
                (fused kernels alias their unfused counterpart's payload, so
                no extra device memory is materialized).
  cost       -- analytic roofline estimate consumed by the cost-model
                selector: ``cost(sub, feat_dim, dtype, hw) -> seconds`` for
                unfused kernels, where ``feat_dim`` is the aggregated width;
                for fused kernels ``feat_dim`` is the ``(in_dim, out_dim)``
                pair since the in-kernel transform prices both.  ``hw`` is
                any object with ``peak_flops / hbm_bw / launch_overhead_s /
                gather_eff / scatter_eff / mxu_eff(B)`` (core/selector.HwModel).

Adding a kernel (CSR, sell-C-sigma, another fused variant, ...) is one
``register()`` call in one file — see kernels/csr.py for the one-file
template; decomposition, both selector modes, aggregation dispatch, and the
benchmarks pick it up automatically.  Registration order is meaningful:
``candidates()`` preserves it, and the selectors break cost ties in favor of
earlier registrations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from repro.core import formats
from repro.kernels import ops

DIAG = "diag"          # intra-community subgraph (block-diagonal)
OFFDIAG = "offdiag"    # inter-community subgraph / density bucket


@dataclass(frozen=True)
class KernelSpec:
    name: str
    kinds: frozenset
    build: Callable[[formats.COO, formats.COO, int, dict], Any] | None
    matvec: Callable[[Any, jax.Array], jax.Array] | None
    cost: Callable[[Any, Any, Any, Any], float]
    # build consumes coo_t (for the VJP); a callable form decides from the
    # tier stats so budget-capped builds (which derive their own transpose
    # from the stored-edge subset) don't force a full transpose COO
    needs_transpose: Any = False    # bool | Callable[[dict], bool]

    def wants_transpose(self, stats: dict | None) -> bool:
        if callable(self.needs_transpose):
            return bool(self.needs_transpose(stats or {}))
        return bool(self.needs_transpose)
    matvec_acc: Callable[[Any, jax.Array, jax.Array], jax.Array] | None = None
    fused_matvec: Callable[..., jax.Array] | None = None
    fused_matvec_acc: Callable[..., jax.Array] | None = None
    # dual-weight epilogue hooks (SAGE): ``(payload, x, w_neigh, w_self
    # [, y_in]) -> x @ w_self + A @ (x @ w_neigh) [+ y_in]``.  Optional even
    # for fused specs; aggregate_transform_dual uses them on the tier that
    # owns the self term (the diagonal tier, whose row block is its own
    # source block) and falls back to seeding the accumulator with the
    # dense self term otherwise.
    fused_dual_matvec: Callable[..., jax.Array] | None = None
    fused_dual_matvec_acc: Callable[..., jax.Array] | None = None
    payload_of: str | None = None   # alias another kernel's format payload
    # Pallas-compiled device code (vs. XLA-native gather/segment ops).  The
    # quarantine path (sampling.plan_cache / train.gnn_steps) uses this to
    # attribute a compile/execute failure it cannot pin to one kernel: the
    # XLA reference kernels (coo/csr) always succeed, so only pallas specs
    # are quarantine candidates by default.
    pallas: bool = False
    doc: str = ""

    def applies_to(self, kind: str) -> bool:
        return kind in self.kinds

    @property
    def fused(self) -> bool:
        return self.fused_matvec is not None

    @property
    def payload_key(self) -> str:
        """Key into Subgraph.formats holding this kernel's payload."""
        return self.payload_of or self.name


class KernelRegistry:
    """Ordered name -> KernelSpec mapping with per-subgraph-kind views."""

    def __init__(self):
        self._specs: dict[str, KernelSpec] = {}

    def register(self, spec: KernelSpec) -> KernelSpec:
        if spec.name in self._specs:
            raise ValueError(f"kernel {spec.name!r} already registered")
        if spec.payload_of is not None and spec.payload_of not in self._specs:
            raise ValueError(
                f"kernel {spec.name!r} aliases unregistered payload "
                f"{spec.payload_of!r}")
        self._specs[spec.name] = spec
        return spec

    def get(self, name: str) -> KernelSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown kernel {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> tuple[str, ...]:
        return tuple(self._specs)

    def candidates(self, kind: str, include_fused: bool = False
                   ) -> tuple[KernelSpec, ...]:
        """Specs applicable to a subgraph kind, in registration order.

        Fused specs are opt-in: they require the transform operand ``w`` at
        dispatch time, so only transform-first call sites (GCN) enumerate
        them."""
        return tuple(s for s in self._specs.values()
                     if s.applies_to(kind) and (include_fused or not s.fused))

    def candidates_for(self, sub, include_fused: bool = False
                       ) -> tuple[KernelSpec, ...]:
        """Specs whose format payload is materialized on this subgraph."""
        return tuple(s for s in self.candidates(sub.kind, include_fused)
                     if s.payload_key in sub.formats)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self):
        return iter(self._specs.values())


REGISTRY = KernelRegistry()


def payload_nbytes(payload) -> int:
    """Device bytes of a format payload (any pytree of arrays)."""
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(payload)
               if hasattr(a, "size"))


def _bytes_el(dtype) -> int:
    return np.dtype(dtype).itemsize


def _lane_pad(F: int) -> int:
    return ((F + 127) // 128) * 128


# ---------------------------------------------------------------------------
# Per-bucket blocked-ELL tiling (chosen at build time from density stats)
# ---------------------------------------------------------------------------

def _bell_pick_block(coo: formats.COO, base_block: int) -> int:
    """Blocked-ELL block size for one density bucket.

    Candidates are multiples of the community size that still divide the
    padded node count (so every bucket's output stays row-aligned with the
    rest of the decomposition).  Score per candidate: ``K * sqrt(Bb)`` —
    the geometric mean of the memory proxy (padded tile volume
    ``nbr * K * Bb^2 = n * K * Bb``) and the MXU-efficiency proxy (the same
    volume de-rated by the ``Bb/128`` sublane utilization, i.e. ``n*K*128``).
    Merging neighbors into a fatter tile wins exactly when the bucket's
    stored-block count collapses with it (dense block neighborhoods);
    scattered buckets keep the small base block since K barely drops while
    padding quadruples."""
    n_pad = coo.n_rows
    rows = formats._np(coo.rows)
    cols = formats._np(coo.cols)
    if len(rows) == 0:
        return base_block
    best, best_score = base_block, None
    for mult in (1, 2, 4):
        Bb = base_block * mult
        if n_pad % Bb:
            continue
        nbc = n_pad // Bb
        brow = rows // Bb
        keys = np.unique(brow.astype(np.int64) * nbc + cols // Bb)
        per_row = np.bincount(keys // nbc, minlength=n_pad // Bb)
        K = max(int(per_row.max()), 1)
        score = K * float(np.sqrt(Bb))
        if best_score is None or score < best_score:
            best, best_score = Bb, score
    return best


def _bell_f_cap(block_size: int) -> int:
    """Feature-tile cap keeping the kernel's double-buffered VMEM working
    set (adjacency tile + x tile + accumulator + output tile) near 4 MB."""
    budget_floats = (4 << 20) // 4 // 2
    cap = (budget_floats - block_size * block_size) // (3 * block_size)
    return int(max(128, min(1024, (cap // 128) * 128)))


def _bell_build(coo, coo_t, block_size, stats):
    """Blocked-ELL payload; two variants keyed by the subgraph stats.

    With ``stats['edge_budget']`` set (the mini-batch path) the payload is
    the *budget-padded* triple ``(bell, bell_t, spill)`` whose every array
    dim is a function of (budget, n_pad, B) — see :func:`_bell_build_capped`.
    Otherwise (full batch) it is the classic ``(bell, bell_t)`` pair with
    the data-dependent per-bucket block size and K."""
    budget = (stats or {}).get("edge_budget")
    if budget:
        return _bell_build_capped(coo, block_size, int(budget),
                                  slack=(stats or {}).get("bell_slack"))
    Bb = _bell_pick_block(coo, block_size)
    cap = _bell_f_cap(Bb)
    return (formats.coo_to_bell(coo, Bb, f_tile_cap=cap),
            formats.coo_to_bell(coo_t, Bb, f_tile_cap=cap))


def _np_edges(coo):
    return (formats._np(coo.rows), formats._np(coo.cols),
            formats._np(coo.vals))


def _bell_build_capped(coo, block_size, edge_budget, slack=None):
    """Budget-padded blocked-ELL payload ``(bell, bell_t, spill)``.

    The block size is pinned to the community size and K to
    :func:`formats.bell_budget_k` (a data-dependent block merge or K would
    change the pytree shape per batch and retrace the jitted step).
    ``slack`` overrides the budget cap's slack factor: the PlanCache's
    budget-K autotuner feeds observed spill rates back through the tier
    stats (``stats['bell_slack']``) so hub-heavy samplers trade padding
    waste against spill volume per workload.  The
    forward cap keeps each block-row's densest blocks; the transpose of the
    *stored* edges is capped again, and stored edges whose transposed block
    did not fit move to the spill alongside the forward overflow.  That
    makes ``bell_t`` exactly the transpose of ``bell``, so the existing
    blocked-ELL custom VJPs stay correct as-is, while every spilled edge
    flows through the natively-differentiable segment-sum path in both
    directions."""
    K = formats.bell_budget_k(edge_budget, coo.n_rows, block_size,
                              **({} if slack is None else dict(slack=slack)))
    cap = _bell_f_cap(block_size)
    _, spill_fwd, stored = formats.coo_to_bell_capped(
        coo, block_size, K, f_tile_cap=cap, build_blocks=False)
    sr, sc, sv = _np_edges(stored)
    coo_st = formats.coo_from_edges(stored.n_cols, stored.n_rows, sc, sr, sv)
    bell_t, spill_t, stored_t = formats.coo_to_bell_capped(
        coo_st, block_size, K, f_tile_cap=cap)
    # forward payload = exactly the transpose-capped survivors
    tr, tc, tv = _np_edges(stored_t)
    bell, leftover, _ = formats.coo_to_bell_capped(
        formats.coo_from_edges(coo.n_rows, coo.n_cols, tc, tr, tv),
        block_size, K, f_tile_cap=cap)
    assert leftover.nnz == 0  # a subset of a K-fitting edge set fits K
    fr, fc, fv = _np_edges(spill_fwd)
    xr, xc, xv = _np_edges(spill_t)      # transpose orientation: swap back
    spill = formats.coo_from_edges(
        coo.n_rows, coo.n_cols, np.concatenate([fr, xc]),
        np.concatenate([fc, xr]), np.concatenate([fv, xv]))
    return (bell, bell_t, spill)


# Dispatch shims shared by the two blocked-ELL payload layouts: the classic
# (bell, bell_t) pair and the budget-padded (bell, bell_t, spill) triple.
# The spill aggregates through the COO segment-sum path (unfused) or the
# per-edge gathered transform (fused — H is never materialized for it).

def _bell_mv(p, x):
    y = ops.bell_matvec(p[0], p[1], x)
    return y + ops.coo_matvec(p[2], x) if len(p) > 2 else y


def _bell_mv_acc(p, x, y_in):
    y = ops.bell_matvec_acc(p[0], p[1], x, y_in)
    return y + ops.coo_matvec(p[2], x) if len(p) > 2 else y


def _bell_fmv(p, x, w):
    y = ops.bell_fused_matvec(p[0], p[1], x, w)
    return y + ops.coo_transform_matvec(p[2], x, w) if len(p) > 2 else y


def _bell_fmv_acc(p, x, w, y_in):
    y = ops.bell_fused_matvec_acc(p[0], p[1], x, w, y_in)
    return y + ops.coo_transform_matvec(p[2], x, w) if len(p) > 2 else y


# ---------------------------------------------------------------------------
# Built-in kernels.  Cost formulae are the two-term roofline estimates that
# used to live inline in core/selector.candidate_cost (paper §3.3's analytic
# alternative to feedback probing).
# ---------------------------------------------------------------------------

def _block_diag_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    B = sub.block_size
    nb = sub.n_rows // B
    flops = 2.0 * nb * B * B * feat_dim
    bytes_ = nb * B * B * be + 2.0 * sub.n_rows * feat_dim * be
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    return t + hw.launch_overhead_s


def _bell_spill_cost(nnz, n_rows, feat_dim, dtype, hw) -> float:
    """Scatter-class seconds for the capped payload's spilled edges (same
    shape as the COO term; no extra launch — the spill rides the same
    dispatch).  Priced at the *real* spill nnz, matching the convention of
    the COO/CSR cost fns (padding to the edge budget executes zero-valued
    edges for every candidate alike)."""
    be = _bytes_el(dtype)
    flops = 2.0 * nnz * feat_dim
    bytes_ = nnz * (2 * feat_dim * be + 8) + n_rows * feat_dim * be
    return max(flops / hw.peak_flops, bytes_ / (hw.hbm_bw * hw.scatter_eff))


def _bell_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    p = sub.formats["bell"]
    bl = p[0]
    B = bl.block_size
    # padding-waste term is inherent: the kernel executes all n_brow * K
    # slots, so a budget-capped K prices its masked zero-blocks here
    nblk = bl.n_brow * bl.max_blocks
    flops = 2.0 * nblk * B * B * feat_dim
    bytes_ = nblk * (B * B * be + B * feat_dim * be) + sub.n_rows * feat_dim * be
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    if len(p) > 2 and p[2].nnz:          # budget-capped: spill-cost term
        t += _bell_spill_cost(p[2].nnz, sub.n_rows, feat_dim, dtype, hw)
    return t + hw.launch_overhead_s


def _ell_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    n = sub.n_rows
    K = sub.formats["ell"].max_deg
    flops = 2.0 * n * K * feat_dim
    bytes_ = n * K * (feat_dim * be + 4) + n * feat_dim * be
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.gather_eff)) + hw.launch_overhead_s


def _coo_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    nnz = sub.stats["nnz"]
    flops = 2.0 * nnz * feat_dim
    bytes_ = nnz * (2 * feat_dim * be + 8) + sub.n_rows * feat_dim * be
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.scatter_eff)) + hw.launch_overhead_s


# Fused transform+aggregate costs.  ``feat_dim`` is the (in_dim, out_dim)
# pair: the in-kernel transform prices the input width (the unfused
# aggregation only ever sees out_dim); the selector adds the shared dense
# transform's cost to *unfused* candidates when comparing (selector.py).

def _block_diag_fused_cost(sub, feat_dims, dtype, hw) -> float:
    fin, fout = feat_dims
    be = _bytes_el(dtype)
    B = sub.block_size
    nb = sub.n_rows // B
    ft = min(ops._fused_f_cap(B, _lane_pad(fin)), _lane_pad(fout))
    njt = max(1, -(-_lane_pad(fout) // ft))
    # transform runs once per row (same FLOPs as the standalone X @ W) plus
    # the block contraction; H never round-trips HBM
    flops = 2.0 * nb * B * (fin * fout + B * fout)
    bytes_ = (nb * B * B * be                     # adjacency blocks
              + sub.n_rows * fin * be * njt      # x re-read per output tile
              + nb * fin * fout * be             # weight stripe per block
              + sub.n_rows * fout * be)          # output
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    return t + hw.launch_overhead_s


def _bell_fused_cost(sub, feat_dims, dtype, hw) -> float:
    fin, fout = feat_dims
    be = _bytes_el(dtype)
    p = sub.formats["bell"]
    bl = p[0]
    B = bl.block_size
    nblk = bl.n_brow * bl.max_blocks     # includes budget-cap padding waste
    ft = min(bl.f_tile_cap, ops._fused_f_cap(B, _lane_pad(fin)),
             _lane_pad(fout))
    njt = max(1, -(-_lane_pad(fout) // ft))
    # the transform re-runs per *stored block* (recompute vs H round-trip
    # trade: a source block referenced k times is transformed k times)
    flops = 2.0 * nblk * B * (fin * fout + B * fout)
    bytes_ = (nblk * B * B * be
              + nblk * B * fin * be * njt        # gathered x per stored block
              + nblk * fin * fout * be           # weight stripe per step
              + sub.n_rows * fout * be)
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    if len(p) > 2 and p[2].nnz:
        # spilled edges transform their gathered source rows one-by-one
        # (coo_transform_matvec): E*fin*fout recompute + scatter-class bytes
        E = p[2].nnz
        flops_s = 2.0 * E * (fin * fout + fout)
        bytes_s = (E * (fin * be + fout * be + 8)
                   + sub.n_rows * fout * be)
        t += max(flops_s / hw.peak_flops,
                 bytes_s / (hw.hbm_bw * hw.scatter_eff))
    return t + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="block_diag",
    kinds=frozenset({DIAG}),
    build=lambda coo, coo_t, B, stats: formats.coo_to_blockdiag(coo, B),
    matvec=lambda bd, x: ops.block_diag_matvec(bd.blocks, x),
    matvec_acc=lambda bd, x, y: ops.block_diag_matvec_acc(bd.blocks, x, y),
    cost=_block_diag_cost,
    pallas=True,
    doc="dense (B,B) diagonal blocks on the MXU (paper's dense kernel)",
))

REGISTRY.register(KernelSpec(
    name="bell",
    kinds=frozenset({OFFDIAG}),
    build=_bell_build,
    matvec=_bell_mv,
    matvec_acc=_bell_mv_acc,
    cost=_bell_cost,
    # full-batch builds consume coo_t; the budget-capped build re-derives
    # its transpose from the stored-edge subset, so no coo_t is needed
    needs_transpose=lambda stats: not stats.get("edge_budget"),
    pallas=True,
    doc="blocked-ELL over per-bucket (B,B) tiles; transpose materialized "
        "for the VJP; budget-capped K + COO spill under an edge budget",
))

REGISTRY.register(KernelSpec(
    name="ell",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=lambda coo, coo_t, B, stats: formats.coo_to_ell(coo),
    matvec=lambda ell, x: ops.ell_matvec(ell, x),
    cost=_ell_cost,
    doc="padded-neighbor gather (vertex-parallel CSR analogue)",
))

REGISTRY.register(KernelSpec(
    name="coo",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=lambda coo, coo_t, B, stats: coo,
    matvec=lambda coo, x: ops.coo_matvec(coo, x),
    cost=_coo_cost,
    doc="edge-parallel segment-sum (scatter-add analogue)",
))

REGISTRY.register(KernelSpec(
    name="block_diag_fused",
    kinds=frozenset({DIAG}),
    build=None,
    payload_of="block_diag",
    matvec=None,
    fused_matvec=lambda bd, x, w: ops.block_diag_fused_matvec(bd.blocks, x, w),
    fused_matvec_acc=lambda bd, x, w, y:
        ops.block_diag_fused_matvec_acc(bd.blocks, x, w, y),
    fused_dual_matvec=lambda bd, x, w, ws:
        ops.block_diag_dual_matvec(bd.blocks, x, w, ws),
    fused_dual_matvec_acc=lambda bd, x, w, ws, y:
        ops.block_diag_dual_matvec_acc(bd.blocks, x, w, ws, y),
    cost=_block_diag_fused_cost,
    pallas=True,
    doc="fused A @ (X W): weight stripe in VMEM, transform consumed by the "
        "MXU block contraction without an HBM round-trip; the dual-weight "
        "hook adds a second (self) stripe for the SAGE epilogue",
))

REGISTRY.register(KernelSpec(
    name="bell_fused",
    kinds=frozenset({OFFDIAG}),
    build=None,
    payload_of="bell",
    matvec=None,
    fused_matvec=_bell_fmv,
    fused_matvec_acc=_bell_fmv_acc,
    cost=_bell_fused_cost,
    pallas=True,
    doc="fused blocked-ELL A @ (X W); trades per-stored-block transform "
        "recompute for the H round-trip",
))

# one-file kernel registrations (import side effect registers the spec)
from repro.kernels import csr  # noqa: E402,F401
from repro.kernels import sell_cs  # noqa: E402,F401
from repro.kernels import tcgnn_tile  # noqa: E402,F401

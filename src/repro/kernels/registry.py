"""Kernel registry: the single place an aggregation kernel is defined.

Every sparse/dense aggregation kernel registers one :class:`KernelSpec`
bundling everything the rest of the system needs to use it:

  name    -- dispatch key (stored in KernelPlans, printed by benchmarks)
  kinds   -- which subgraph kinds the kernel applies to: ``"diag"`` (the
             block-diagonal intra-community subgraph) and/or ``"offdiag"``
             (inter-community density buckets)
  build   -- host-side format materializer run once during decomposition:
             ``build(coo, coo_t, block_size) -> payload``.  The payload is
             an arbitrary pytree (a single format container, or a tuple such
             as blocked-ELL forward + transpose for the VJP).  ``coo_t`` is
             only constructed (and non-None) when ``needs_transpose`` is set.
  matvec  -- device function ``matvec(payload, x) -> A @ x``
  cost    -- analytic roofline estimate ``cost(sub, feat_dim, dtype, hw) ->
             seconds`` consumed by the cost-model selector; ``hw`` is any
             object with ``peak_flops / hbm_bw / launch_overhead_s /
             gather_eff / scatter_eff / mxu_eff(B)`` (see
             core/selector.HwModel).

Adding a kernel (CSR, sell-C-sigma, fused transform+aggregate, ...) is one
``register()`` call in one file; decomposition, both selector modes,
aggregation dispatch, and the benchmarks pick it up automatically.
Registration order is meaningful: ``candidates()`` preserves it, and the
selectors break cost ties in favor of earlier registrations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.core import formats
from repro.kernels import ops

DIAG = "diag"          # intra-community subgraph (block-diagonal)
OFFDIAG = "offdiag"    # inter-community subgraph / density bucket


@dataclass(frozen=True)
class KernelSpec:
    name: str
    kinds: frozenset
    build: Callable[[formats.COO, formats.COO, int], Any]
    matvec: Callable[[Any, jax.Array], jax.Array]
    cost: Callable[[Any, int, Any, Any], float]
    needs_transpose: bool = False   # build consumes coo_t (for the VJP)
    doc: str = ""

    def applies_to(self, kind: str) -> bool:
        return kind in self.kinds


class KernelRegistry:
    """Ordered name -> KernelSpec mapping with per-subgraph-kind views."""

    def __init__(self):
        self._specs: dict[str, KernelSpec] = {}

    def register(self, spec: KernelSpec) -> KernelSpec:
        if spec.name in self._specs:
            raise ValueError(f"kernel {spec.name!r} already registered")
        self._specs[spec.name] = spec
        return spec

    def get(self, name: str) -> KernelSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown kernel {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> tuple[str, ...]:
        return tuple(self._specs)

    def candidates(self, kind: str) -> tuple[KernelSpec, ...]:
        """Specs applicable to a subgraph kind, in registration order."""
        return tuple(s for s in self._specs.values() if s.applies_to(kind))

    def candidates_for(self, sub) -> tuple[KernelSpec, ...]:
        """Specs whose format payload is materialized on this subgraph."""
        return tuple(s for s in self.candidates(sub.kind)
                     if s.name in sub.formats)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self):
        return iter(self._specs.values())


REGISTRY = KernelRegistry()


def payload_nbytes(payload) -> int:
    """Device bytes of a format payload (any pytree of arrays)."""
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(payload)
               if hasattr(a, "size"))


def _bytes_el(dtype) -> int:
    return np.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# Built-in kernels.  Cost formulae are the two-term roofline estimates that
# used to live inline in core/selector.candidate_cost (paper §3.3's analytic
# alternative to feedback probing).
# ---------------------------------------------------------------------------

def _block_diag_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    B = sub.block_size
    nb = sub.n_rows // B
    flops = 2.0 * nb * B * B * feat_dim
    bytes_ = nb * B * B * be + 2.0 * sub.n_rows * feat_dim * be
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    return t + hw.launch_overhead_s


def _bell_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    B = sub.block_size
    bl = sub.formats["bell"][0]
    nblk = bl.n_brow * bl.max_blocks       # kernel executes padding too
    flops = 2.0 * nblk * B * B * feat_dim
    bytes_ = nblk * (B * B * be + B * feat_dim * be) + sub.n_rows * feat_dim * be
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    return t + hw.launch_overhead_s


def _ell_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    n = sub.n_rows
    K = sub.formats["ell"].max_deg
    flops = 2.0 * n * K * feat_dim
    bytes_ = n * K * (feat_dim * be + 4) + n * feat_dim * be
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.gather_eff)) + hw.launch_overhead_s


def _coo_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    nnz = sub.stats["nnz"]
    flops = 2.0 * nnz * feat_dim
    bytes_ = nnz * (2 * feat_dim * be + 8) + sub.n_rows * feat_dim * be
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.scatter_eff)) + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="block_diag",
    kinds=frozenset({DIAG}),
    build=lambda coo, coo_t, B: formats.coo_to_blockdiag(coo, B),
    matvec=lambda bd, x: ops.block_diag_matvec(bd.blocks, x),
    cost=_block_diag_cost,
    doc="dense (B,B) diagonal blocks on the MXU (paper's dense kernel)",
))

REGISTRY.register(KernelSpec(
    name="bell",
    kinds=frozenset({OFFDIAG}),
    build=lambda coo, coo_t, B: (formats.coo_to_bell(coo, B),
                                 formats.coo_to_bell(coo_t, B)),
    matvec=lambda p, x: ops.bell_matvec(p[0], p[1], x),
    cost=_bell_cost,
    needs_transpose=True,
    doc="blocked-ELL over (B,B) tiles; transpose materialized for the VJP",
))

REGISTRY.register(KernelSpec(
    name="ell",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=lambda coo, coo_t, B: formats.coo_to_ell(coo),
    matvec=lambda ell, x: ops.ell_matvec(ell, x),
    cost=_ell_cost,
    doc="padded-neighbor gather (vertex-parallel CSR analogue)",
))

REGISTRY.register(KernelSpec(
    name="coo",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=lambda coo, coo_t, B: coo,
    matvec=lambda coo, x: ops.coo_matvec(coo, x),
    cost=_coo_cost,
    doc="edge-parallel segment-sum (scatter-add analogue)",
))

"""Pallas TPU kernel: selective SSM scan (Mamba) with VMEM-resident state.

XLA's associative_scan over the full sequence materializes the (B, T,
d_inner, d_state) hidden tensor in HBM O(log T) times — the §Roofline
baseline shows this makes jamba's train cell memory-bound by a wide margin.
The original CUDA kernel (Gu & Dao, arXiv:2312.00752 'hardware-aware scan')
keeps the recurrent state in SRAM; the TPU analogue keeps the (d_tile,
d_state) state in VMEM scratch across a sequential chunk grid:

  grid = (B, d_inner/d_tile, T/chunk)   -- chunk dim sequential
  per step: within-chunk associative scan over (chunk, d_tile, d_state)
            entirely in VMEM; only x/dt/B/C stream in and y streams out.

HBM traffic drops from O(T * d_inner * d_state * log T) to
O(T * (2 d_inner + 2 d_state * d_tiles) + T * d_inner) — the streaming
floor.  d_tile=512, chunk=128 keeps the working set ~6 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)       # (C, dt_tile)
    dt = dt_ref[...].astype(jnp.float32)     # (C, dt_tile)
    Bc = b_ref[...].astype(jnp.float32)      # (C, ds)
    Cc = c_ref[...].astype(jnp.float32)      # (C, ds)
    A = a_ref[...].astype(jnp.float32)       # (dt_tile, ds)
    D = d_ref[...].astype(jnp.float32)       # (1, dt_tile)

    dA = jnp.exp(dt[:, :, None] * A[None])               # (C, d, ds)
    dBx = (dt * x)[:, :, None] * Bc[:, None, :]          # (C, d, ds)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a2 * a1, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (dA, dBx), axis=0)
    h0 = h_ref[...]                                      # (d, ds)
    hs = aa * h0[None] + bb                              # (C, d, ds)
    y = jnp.einsum("cds,cs->cd", hs, Cc) + x * D
    y_ref[...] = y.astype(y_ref.dtype)
    h_ref[...] = hs[-1]


@functools.partial(jax.jit, static_argnames=("chunk", "d_tile", "interpret"))
def mamba_scan(x, dt, Bc, Cc, A, D, *, chunk: int = 128, d_tile: int = 512,
               interpret: bool = True):
    """x, dt: (B, T, d_inner); Bc, Cc: (B, T, d_state);
    A: (d_inner, d_state); D: (d_inner,) -> y (B, T, d_inner).
    dt is post-softplus.  T % chunk == 0; d_inner % d_tile == 0."""
    B, T, di = x.shape
    ds = A.shape[-1]
    chunk = min(chunk, T)
    d_tile = min(d_tile, di)
    assert T % chunk == 0 and di % d_tile == 0
    grid = (B, di // d_tile, T // chunk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, d_tile), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((None, chunk, d_tile), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((None, chunk, ds), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((None, chunk, ds), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((d_tile, ds), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, d_tile), lambda b, d, t: (0, d)),
        ],
        out_specs=pl.BlockSpec((None, chunk, d_tile), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, T, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((d_tile, ds), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(x, dt, Bc, Cc, A, D.reshape(1, di))
    return out


def mamba_scan_hbm_bytes(B, T, di, ds, d_tile: int = 512,
                         bytes_el: int = 4) -> int:
    """Streaming floor: x/dt/y once; B/C rereads per d-tile; A/D once."""
    xy = 3 * B * T * di * bytes_el
    bc = 2 * B * T * ds * (di // d_tile) * bytes_el
    return xy + bc + di * ds * bytes_el


def mamba_scan_flops(B, T, di, ds) -> float:
    """exp + 3 muls + add per (t, d, s) for the recurrence, plus the C
    contraction and D skip: ~8 flops per state element."""
    return 8.0 * B * T * di * ds


# ---------------------------------------------------------------------------
# trainable wrapper: Pallas forward + recompute backward (oracle vjp)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _trainable():
    from repro.kernels import ref

    @jax.custom_vjp
    def f(x, dt, Bc, Cc, A, D):
        interp = jax.default_backend() != "tpu"
        return mamba_scan(x, dt, Bc, Cc, A, D, interpret=interp)

    def fwd(x, dt, Bc, Cc, A, D):
        return f(x, dt, Bc, Cc, A, D), (x, dt, Bc, Cc, A, D)

    def bwd(res, dy):
        x, dt, Bc, Cc, A, D = res
        _, vjp = jax.vjp(
            lambda x, dt, Bc, Cc, A, D: ref.mamba_ssm(x, dt, A, Bc, Cc, D),
            x, dt, Bc, Cc, A, D)
        return vjp(dy)

    f.defvjp(fwd, bwd)
    return f


def mamba_scan_trainable(x, dt, Bc, Cc, A, D):
    """Differentiable selective scan: Pallas forward, recompute backward."""
    return _trainable()(x, dt, Bc, Cc, A, D)

"""Pallas TPU kernel: blocked-ELL SpMM with scalar-prefetch block indices
(inter-community subgraph).

Paper mapping (§3.2 'CSR-based kernel' for low-density subgraphs): on CUDA a
CTA covers several destination vertices, threads walk the CSR neighbor lists
and gather source features from global memory.  A TPU has no per-thread
gather; the idiomatic equivalent is *block-level* indirection: store the
inter-community adjacency as a CSR over (B, B) tiles, pad each block-row to K
tiles (blocked-ELL), and let the BlockSpec index_map -- fed by scalar-prefetch
-- DMA exactly the (B, Ft) feature tile named by each stored block.

Grid = (block-rows, feature-tiles, K); K is the innermost reduction
("arbitrary") dimension accumulated in a VMEM scratch and flushed at k==K-1.
Padding tiles are all-zero and point at block-column 0, so no masking is
needed inside the kernel (no data-dependent control flow on TPU).

That zero-padding contract is what makes the *budget-padded* variant free
at kernel level: the mini-batch path caps K from the sampler's edge budget
(formats.bell_budget_k) and pads every block-row to exactly that many
slots, so this kernel runs an identical grid for every sampled batch — the
jitted step never retraces — while executing the masked zero-blocks as
ordinary (correct, zero-contributing) MXU tiles.  Overflow edges never
reach this kernel; they ride the COO spill tier of the payload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, a_ref, x_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_acc(idx_ref, a_ref, x_ref, y_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # accumulation mode: seed the VMEM scratch from the threaded-through
        # partial output instead of zeros — the separate partial-sum pass
        # (and its full-width HBM tensor) disappears
        acc_ref[...] = y_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def bell_spmm(blocks: jax.Array, col_idx: jax.Array, x: jax.Array,
              y_in: jax.Array | None = None, *,
              f_tile: int = 512, interpret: bool = True) -> jax.Array:
    """Y = A_bell @ x (+ y_in).

    blocks: (nbr, K, B, B); col_idx: (nbr, K) int32; x: (nbc*B, F);
    y_in: optional (nbr*B, F) accumulator input.  Returns (nbr*B, F).
    """
    nbr, K, B, _ = blocks.shape
    F = x.shape[-1]
    f_tile = min(f_tile, F)
    assert F % f_tile == 0, (F, f_tile)
    xb = x.reshape(-1, B, F)
    grid = (nbr, F // f_tile, K)
    in_specs = [
        pl.BlockSpec((None, None, B, B), lambda i, j, k, idx: (i, k, 0, 0)),
        pl.BlockSpec((None, B, f_tile), lambda i, j, k, idx: (idx[i, k], 0, j)),
    ]
    operands = [col_idx, blocks, xb]
    kernel = _kernel
    if y_in is not None:
        in_specs.append(
            pl.BlockSpec((None, B, f_tile), lambda i, j, k, idx: (i, 0, j)))
        operands.append(y_in.reshape(nbr, B, F))
        kernel = _kernel_acc
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j, k, idx: (i, 0, j)),
        scratch_shapes=[pltpu.VMEM((B, f_tile), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((nbr, B, F), x.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(nbr * B, F)

"""CSR (row-pointer gather+reduce) aggregation kernel — the registry's
"one-file kernel" validation: everything a kernel needs (matvec, cost model,
format builder binding) lives here as a single ``register()`` call; the
decomposition, both selector modes, dispatch, and the benchmarks pick it up
with no edits elsewhere.

Paper mapping (§2.1/§3.2): CSR is the vertex-parallel format — one worker
per destination row walks ``indices[indptr[i]:indptr[i+1]]``.  The TPU/XLA
analogue expands the row pointer back to per-edge row ids with a
``searchsorted`` over the (static-shape) edge range, gathers source
features, and reduces with a sorted segment-sum: gather-efficiency class
(like ELL) rather than scatter class (like COO), but with zero padding —
CSR stores exactly nnz entries where ELL pads every row to max degree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.kernels.registry import DIAG, OFFDIAG, REGISTRY, KernelSpec


def _edge_rows(csr: formats.CSR) -> jax.Array:
    """Expand the row pointer back to per-edge destination ids (sorted,
    static shape; budget-padded entries land in the last row's segment)."""
    nnz = csr.indices.shape[0]
    return jnp.searchsorted(csr.indptr, jnp.arange(nnz, dtype=jnp.int32),
                            side="right").astype(jnp.int32) - 1


def csr_matvec(csr: formats.CSR, x: jax.Array) -> jax.Array:
    """Y = A_csr @ x via row-pointer expansion + sorted segment reduce.
    Natively differentiable (gather transposes to scatter-add)."""
    msgs = x[csr.indices] * csr.vals[:, None]
    return jax.ops.segment_sum(msgs, _edge_rows(csr),
                               num_segments=csr.n_rows,
                               indices_are_sorted=True).astype(x.dtype)


def _csr_cost(sub, feat_dim, dtype, hw) -> float:
    be = np.dtype(dtype).itemsize
    nnz = sub.stats["nnz"]
    flops = 2.0 * nnz * feat_dim
    # exact-nnz gather (no ELL padding) + row-pointer stream + output
    bytes_ = nnz * (feat_dim * be + 4) + sub.n_rows * (feat_dim * be + 4)
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.gather_eff)) + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="csr",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=lambda coo, coo_t, B, stats: formats.coo_to_csr(coo),
    matvec=csr_matvec,
    cost=_csr_cost,
    doc="row-pointer gather+reduce (vertex-parallel, exact-nnz storage)",
))


# ---------------------------------------------------------------------------
# Fused epilogue path: Y = A_csr @ (x @ w) without materializing H = x @ w
# ---------------------------------------------------------------------------

def csr_transform_matvec(csr: formats.CSR, x: jax.Array,
                         w: jax.Array) -> jax.Array:
    """Per-edge gathered transform: each edge transforms only its gathered
    source row, ``(E, Fi) @ (Fi, Fo)``, then the sorted segment reduce — the
    (n, Fo)-wide ``H`` never round-trips HBM.  Wins exactly on sparse tiers
    (E below ~n/n_sub, where the per-edge recompute undercuts the unfused
    candidates' share of the shared transform).  Natively differentiable."""
    h_e = (x[csr.indices] @ w) * csr.vals[:, None]
    return jax.ops.segment_sum(h_e, _edge_rows(csr), num_segments=csr.n_rows,
                               indices_are_sorted=True).astype(x.dtype)


def _csr_fused_cost(sub, feat_dims, dtype, hw) -> float:
    fin, fout = feat_dims
    be = np.dtype(dtype).itemsize
    nnz = sub.stats["nnz"]
    # transform recompute per edge (a source row referenced k times is
    # transformed k times) + gather-class traffic on the narrow input side
    flops = 2.0 * nnz * (fin * fout + fout)
    bytes_ = (nnz * (fin * be + fout * be + 8)
              + sub.n_rows * (fout * be + 4))
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.gather_eff)) + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="csr_fused",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=None,
    payload_of="csr",
    matvec=None,
    fused_matvec=csr_transform_matvec,
    cost=_csr_fused_cost,
    doc="fused CSR A @ (X W): per-edge gathered transform, no (n, F) "
        "intermediate; trades per-edge recompute for the H round-trip",
))

"""Pallas TPU kernel: dense diagonal-block batched SpMM (intra-community).

Paper mapping (§3.2 'Dense-based kernel'): CUDA maps one CTA per community
adjacency block and runs a batched GEMM on Tensor Cores.  On TPU the analogue
is a pallas_call whose grid iterates (block, feature-tile); each step loads a
(B, B) adjacency block and the matching (B, Ft) feature tile into VMEM and
issues one MXU matmul.  B is padded to the 128-lane boundary by ops.py so the
MXU tiles are fully utilized.

VMEM working set per step: B*B + 2*B*Ft floats.  With B=128, Ft=512 that is
~0.6 MB -- far below the ~16 MB VMEM budget, leaving room for the pipelined
double buffering pallas inserts automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _kernel_acc(a_ref, x_ref, y_ref, o_ref):
    y = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (y_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def block_diag_spmm(blocks: jax.Array, x: jax.Array,
                    y_in: jax.Array | None = None, *,
                    f_tile: int = 512, interpret: bool = True) -> jax.Array:
    """Y = blockdiag(blocks) @ x (+ y_in).

    blocks: (nb, B, B); x: (nb*B, F) with F % f_tile == 0 (ops.py pads);
    y_in: optional (nb*B, F) accumulator input (aggregate's threaded output
    buffer, saving the separate partial-sum pass).
    """
    nb, B, _ = blocks.shape
    n, F = x.shape
    assert n == nb * B, (n, nb, B)
    f_tile = min(f_tile, F)
    assert F % f_tile == 0, (F, f_tile)
    xb = x.reshape(nb, B, F)
    grid = (nb, F // f_tile)
    in_specs = [
        pl.BlockSpec((None, B, B), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)),
    ]
    operands = [blocks, xb]
    kernel = _kernel
    if y_in is not None:
        in_specs.append(pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)))
        operands.append(y_in.reshape(nb, B, F))
        kernel = _kernel_acc
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nb, B, F), x.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(n, F)

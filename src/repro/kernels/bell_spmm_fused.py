"""Pallas TPU kernels: fused transform+aggregate over blocked-ELL
(inter-community subgraph), plus the shared dW reduction kernel.

``bell_spmm_fused`` computes Y = A_bell @ (X @ W) (+ Y_in) in one pass: the
(Fi, Ft) weight stripe lives in VMEM and each gathered (B, Fi) source-feature
block is transformed and immediately contracted against its stored (B, B)
adjacency block — the transformed feature matrix H never round-trips HBM.
Unlike the diagonal tier, the in-kernel transform re-runs per stored block
(a source block referenced by k stored blocks is transformed k times), so
fusion trades recompute FLOPs for the H write+read; the registry cost model
prices both and lets the selector decide per bucket.

``bell_spmm_dw`` is the backward weight kernel: dW = X^T (A^T dY), expressed
as a single blocked reduction sum_{i,k} x_i^T (A^T[i,k] @ dy[col_idx[i,k]])
over the materialized transpose payload — no (n, F) intermediate is ever
written.  The block-diagonal kernel reuses it with K=1 and identity block
columns (ops.py), so both fused VJPs share one Pallas reduction.

Under the mini-batch edge budget both kernels run on the budget-padded
payload (stored-block count capped at a budget-derived K, masked
zero-blocks padding, overflow edges spilled to an in-payload COO): the
grid shape is then batch-invariant, and the spilled edges transform their
gathered source rows per edge (ops.coo_transform_matvec) instead of
forcing an H = X W materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, a_ref, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32), h,
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_acc(idx_ref, a_ref, x_ref, w_ref, y_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # seed the VMEM scratch from the threaded-through partial instead of
        # zeros: the downstream "+" that would re-read both operands vanishes
        acc_ref[...] = y_ref[...].astype(jnp.float32)

    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32), h,
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def bell_spmm_fused(blocks: jax.Array, col_idx: jax.Array, x: jax.Array,
                    w: jax.Array, y_in: jax.Array | None = None, *,
                    f_tile: int = 512, interpret: bool = True) -> jax.Array:
    """Y = A_bell @ (x @ w) (+ y_in).

    blocks: (nbr, K, B, B); col_idx: (nbr, K) int32; x: (nbc*B, Fi);
    w: (Fi, Fo) with Fo % f_tile == 0; y_in: optional (nbr*B, Fo).
    Returns (nbr*B, Fo).
    """
    nbr, K, B, _ = blocks.shape
    Fi = x.shape[-1]
    Fo = w.shape[-1]
    f_tile = min(f_tile, Fo)
    assert Fo % f_tile == 0, (Fo, f_tile)
    xb = x.reshape(-1, B, Fi)
    grid = (nbr, Fo // f_tile, K)
    in_specs = [
        pl.BlockSpec((None, None, B, B), lambda i, j, k, idx: (i, k, 0, 0)),
        pl.BlockSpec((None, B, Fi), lambda i, j, k, idx: (idx[i, k], 0, 0)),
        pl.BlockSpec((Fi, f_tile), lambda i, j, k, idx: (0, j)),
    ]
    operands = [col_idx, blocks, xb, w]
    kernel = _kernel
    if y_in is not None:
        yb = y_in.reshape(nbr, B, Fo)
        in_specs.append(
            pl.BlockSpec((None, B, f_tile), lambda i, j, k, idx: (i, 0, j)))
        operands.append(yb)
        kernel = _kernel_acc
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j, k, idx: (i, 0, j)),
        scratch_shapes=[pltpu.VMEM((B, f_tile), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((nbr, B, Fo), x.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(nbr * B, Fo)


# ---------------------------------------------------------------------------
# dW reduction
# ---------------------------------------------------------------------------

def _dw_kernel(idx_ref, a_ref, x_ref, g_ref, o_ref, acc_ref):
    i = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when((i == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = jnp.dot(a_ref[...].astype(jnp.float32), g_ref[...],
                preferred_element_type=jnp.float32)          # (B, fo_tile)
    # x_i^T @ z without materializing the transpose: contract the B dims
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), z,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (fi_tile, fo_tile)

    @pl.when((i == pl.num_programs(2) - 1) & (k == pl.num_programs(3) - 1))
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("fi_tile", "fo_tile", "interpret"))
def bell_spmm_dw(blocks_t: jax.Array, col_idx_t: jax.Array, x: jax.Array,
                 g: jax.Array, *, fi_tile: int = 512, fo_tile: int = 512,
                 interpret: bool = True) -> jax.Array:
    """dW = X^T @ (A^T @ G), A^T given in blocked-ELL (transpose payload).

    blocks_t: (nbr, K, B, B); col_idx_t: (nbr, K) int32; x: (nbr*B, Fi);
    g: (nbc*B, Fo).  Returns (Fi, Fo) float32.
    """
    nbr, K, B, _ = blocks_t.shape
    Fi = x.shape[-1]
    Fo = g.shape[-1]
    fi_tile = min(fi_tile, Fi)
    fo_tile = min(fo_tile, Fo)
    assert Fi % fi_tile == 0 and Fo % fo_tile == 0, (Fi, fi_tile, Fo, fo_tile)
    xb = x.reshape(nbr, B, Fi)
    gb = g.reshape(-1, B, Fo)
    grid = (Fi // fi_tile, Fo // fo_tile, nbr, K)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, B, B),
                         lambda fi, fo, i, k, idx: (i, k, 0, 0)),
            pl.BlockSpec((None, B, fi_tile),
                         lambda fi, fo, i, k, idx: (i, 0, fi)),
            pl.BlockSpec((None, B, fo_tile),
                         lambda fi, fo, i, k, idx: (idx[i, k], 0, fo)),
        ],
        out_specs=pl.BlockSpec((fi_tile, fo_tile),
                               lambda fi, fo, i, k, idx: (fi, fo)),
        scratch_shapes=[pltpu.VMEM((fi_tile, fo_tile), jnp.float32)],
    )
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((Fi, Fo), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(col_idx_t, blocks_t, xb, gb)

"""TC-GNN-style column-condensed MXU tiles — one-file registration
following kernels/csr.py and kernels/sell_cs.py.

TC-GNN (Wang et al., PAPERS.md) condenses the *non-zero columns* of each
sparse block row into a contiguous dense tile and runs it on tensor cores;
Balog et al. make the same bet for MXU-class hardware.  This is the
registry's mid-density tier: blocked-ELL pays (B, B) padding per stored
block (waste grows as blocks get sparser), while dense materializes the
whole row.  Column condensation pays only per *distinct source column* of
a block row — between those two regimes it stores, gathers, and multiplies
strictly less.

Format: per block row ``i`` the builder ranks the distinct source columns,
packs their edge values into a dense ``(B, C)`` tile (``tiles[i, r, s]`` is
the weight of edge ``(i*B + r, gather_idx[i, s])``) and records the column
ids in ``gather_idx``.  ``C`` is lane-aligned (the "8x128" tile contract:
``B`` on the sublane axis, ``C`` a multiple of 128 on the lane axis); slots
past a row's real column count stay all-zero pointing at column 0, so the
kernel needs no mask.  The device pass is then a *row-level* XLA gather
``x[gather_idx] -> (nbr, C, F)`` followed by a batched dense contraction
``tiles @ x_g`` that the Pallas kernel runs through the MXU — block-level
BlockSpec indirection (bell's trick) cannot express a per-column gather,
so the gather stays in XLA and the FLOPs stay on the MXU.

Under the mini-batch edge budget the payload is the budget-capped triple
``(tc, tc_t, spill)`` — C capped by :func:`tcgnn_budget_c` from the edge
budget alone, each block row keeping its densest columns and the overflow
riding the COO spill tier — the same fixed-pytree-shape contract as the
capped blocked-ELL (``MB_KERNELS``).  The transpose of the *stored* edges
is capped again and the forward payload rebuilt from the survivors, so
``tc_t`` is exactly the transpose of ``tc`` and the custom VJPs stay
correct while every spilled edge flows through the natively-differentiable
segment-sum path in both directions.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats
from repro.kernels import ops
from repro.kernels.registry import (OFFDIAG, REGISTRY, KernelSpec,
                                    _bell_spill_cost, _bytes_el, _lane_pad)

LANE = ops.LANE
C_TILE_CAP = 512     # condensed-column tile per grid step (lane multiple)


@dataclass(frozen=True)
class TcgnnTile:
    """Column-condensed dense tiles + per-block-row gather index."""
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))
    n_cond: int = dataclasses.field(metadata=dict(static=True))  # C, lane-pad
    f_tile_cap: int = dataclasses.field(default=512,
                                        metadata=dict(static=True))
    budgeted: bool = dataclasses.field(default=False,
                                       metadata=dict(static=True))
    tiles: Any = None        # (n_brow, B, C) float32 condensed adjacency
    gather_idx: Any = None   # (n_brow, C) int32 source ids, 0 where padded

    @property
    def n_brow(self) -> int:
        return self.n_rows // self.block_size


jax.tree_util.register_dataclass(
    TcgnnTile, ["tiles", "gather_idx"],
    ["n_rows", "n_cols", "block_size", "n_cond", "f_tile_cap", "budgeted"])


# ---------------------------------------------------------------------------
# Host-side builders
# ---------------------------------------------------------------------------

def _np_edges(coo):
    return (formats._np(coo.rows), formats._np(coo.cols),
            formats._np(coo.vals))


def _cond_rank(rows: np.ndarray, cols: np.ndarray, n_cols: int,
               block_size: int):
    """Rank each block row's distinct source columns densest-first (ties
    toward the lower column id) — the column-granular twin of
    formats.coo_to_bell_capped's vectorized segmented block rank."""
    brow = (rows // block_size).astype(np.int64)
    key = brow * np.int64(n_cols) + cols.astype(np.int64)
    uniq, inv, counts = np.unique(key, return_inverse=True,
                                  return_counts=True)
    ubrow, ucol = uniq // n_cols, uniq % n_cols
    order = np.lexsort((ucol, -counts, ubrow))
    sorted_brow = ubrow[order]
    rank_sorted = (np.arange(len(uniq))
                   - np.searchsorted(sorted_brow, sorted_brow))
    slot = np.empty(len(uniq), np.int64)
    slot[order] = rank_sorted
    return brow, ubrow, ucol, slot, slot[inv]


def coo_to_tcgnn(coo: formats.COO, block_size: int,
                 f_tile_cap: int = 512) -> TcgnnTile:
    """Full-batch condensation: C = lane-rounded max distinct-column count
    over block rows (data-dependent; the budget-capped variant below pins
    it for the mini-batch path)."""
    B = block_size
    n_rpad = ((coo.n_rows + B - 1) // B) * B
    nbr = max(n_rpad // B, 1)
    rows, cols, vals = _np_edges(coo)
    if len(rows):
        brow, ubrow, ucol, slot, edge_slot = _cond_rank(
            rows, cols, coo.n_cols, B)
        C = _lane_pad(int(slot.max()) + 1)
    else:
        C = LANE
    tiles = np.zeros((nbr, B, C), np.float32)
    gather_idx = np.zeros((nbr, C), np.int32)
    if len(rows):
        gather_idx[ubrow, slot] = ucol
        tiles[brow, rows % B, edge_slot] = vals
    return TcgnnTile(n_rpad, coo.n_cols, B, C, f_tile_cap,
                     tiles=jnp.asarray(tiles),
                     gather_idx=jnp.asarray(gather_idx))


def tcgnn_budget_c(edge_budget: int, n_pad: int, block_size: int,
                   slack: float = 2.0) -> int:
    """Condensed-column cap C for the budget-padded payload.

    Derived from the sampler's *edge budget* alone — never a batch's
    actual edges — so every batch shares one (n_brow, B, C) shape.  The
    worst case is every stored edge owning its own distinct column, so C
    covers ``slack``x the per-block-row average edge count, lane-rounded;
    the (lane-padded) column count bounds it above — at that bound the cap
    is vacuous and nothing ever spills."""
    nbr = max(n_pad // block_size, 1)
    c = -(-int(slack * edge_budget) // nbr)
    c = -(-max(c, 1) // LANE) * LANE
    return int(max(LANE, min(c, _lane_pad(n_pad))))


def coo_to_tcgnn_capped(coo: formats.COO, block_size: int, c_max: int,
                        f_tile_cap: int = 512, build_tiles: bool = True
                        ) -> tuple[TcgnnTile | None, formats.COO,
                                   formats.COO]:
    """Condensed tiles with exactly ``c_max`` column slots per block row.

    Rows with more distinct columns keep their *densest* ``c_max`` (ties
    toward the lower column id); the remaining edges come back as a
    row-sorted *spill* COO and the stored edges as a third COO (what the
    transpose pass caps again — see :func:`_tcgnn_build_capped`).  Returns
    ``(tc, spill, stored)`` with ``tc.budgeted=True``; all three shapes
    are functions of ``(c_max, n_pad, B)`` and the edge count only.

    ``build_tiles=False`` skips the (n_brow, B, C) scatter and returns
    ``tc=None`` — for the capped builder's first partition pass, which
    only needs the stored/spill edge split."""
    B = block_size
    n_rpad = ((coo.n_rows + B - 1) // B) * B
    nbr = max(n_rpad // B, 1)
    C = int(max(LANE, -(-int(c_max) // LANE) * LANE))
    rows, cols, vals = _np_edges(coo)
    if build_tiles:
        tiles = np.zeros((nbr, B, C), np.float32)
        gather_idx = np.zeros((nbr, C), np.int32)
    if len(rows):
        brow, ubrow, ucol, slot, edge_slot = _cond_rank(
            rows, cols, coo.n_cols, B)
        stored_m = edge_slot < C
        if build_tiles:
            sb = np.flatnonzero(slot < C)
            gather_idx[ubrow[sb], slot[sb]] = ucol[sb]
            tiles[brow[stored_m], rows[stored_m] % B,
                  edge_slot[stored_m]] = vals[stored_m]
    else:
        stored_m = np.zeros(0, bool)
    tc = (TcgnnTile(n_rpad, coo.n_cols, B, C, f_tile_cap, budgeted=True,
                    tiles=jnp.asarray(tiles),
                    gather_idx=jnp.asarray(gather_idx))
          if build_tiles else None)
    spill = formats.coo_from_edges(n_rpad, coo.n_cols, rows[~stored_m],
                                   cols[~stored_m], vals[~stored_m])
    stored = formats.coo_from_edges(n_rpad, coo.n_cols, rows[stored_m],
                                    cols[stored_m], vals[stored_m])
    return tc, spill, stored


def _tcgnn_f_cap(block_size: int) -> int:
    """Feature-tile cap keeping one grid step's VMEM working set (tile +
    gathered-feature stripe + accumulator + output) near the same ~4 MB
    double-buffered budget the blocked-ELL kernels target."""
    budget_floats = (4 << 20) // 4 // 2
    cap = ((budget_floats - block_size * C_TILE_CAP)
           // (C_TILE_CAP + 2 * block_size))
    return int(max(LANE, min(1024, (cap // LANE) * LANE)))


def _tcgnn_build(coo, coo_t, block_size, stats):
    """Condensed-tile payload; two variants keyed by the subgraph stats.

    With ``stats['edge_budget']`` set (the mini-batch path) the payload is
    the budget-capped triple ``(tc, tc_t, spill)``; otherwise the classic
    ``(tc, tc_t)`` pair with the data-dependent C.  The budget slack is
    shared with blocked-ELL (``stats['bell_slack']``): both caps answer
    "how much padding buys how little spill", so the PlanCache's budget-K
    autotuner steers them together."""
    budget = (stats or {}).get("edge_budget")
    if budget:
        return _tcgnn_build_capped(coo, block_size, int(budget),
                                   slack=(stats or {}).get("bell_slack"))
    cap = _tcgnn_f_cap(block_size)
    return (coo_to_tcgnn(coo, block_size, f_tile_cap=cap),
            coo_to_tcgnn(coo_t, block_size, f_tile_cap=cap))


def _tcgnn_build_capped(coo, block_size, edge_budget, slack=None):
    """Budget-capped payload ``(tc, tc_t, spill)``.

    Same dance as the registry's capped blocked-ELL builder, at column
    granularity: cap the forward edges, cap the *transpose of the stored
    subset*, then rebuild the forward payload from the transpose-capped
    survivors — a subset of a C-fitting column set still fits C, so the
    rebuild never spills and ``tc_t`` is exactly ``tc`` transposed."""
    C = tcgnn_budget_c(edge_budget, coo.n_rows, block_size,
                       **({} if slack is None else dict(slack=slack)))
    cap = _tcgnn_f_cap(block_size)
    _, spill_fwd, stored = coo_to_tcgnn_capped(
        coo, block_size, C, build_tiles=False)
    sr, sc, sv = _np_edges(stored)
    coo_st = formats.coo_from_edges(stored.n_cols, stored.n_rows, sc, sr, sv)
    tc_t, spill_t, stored_t = coo_to_tcgnn_capped(
        coo_st, block_size, C, f_tile_cap=cap)
    tr, tcc, tv = _np_edges(stored_t)
    tc, leftover, _ = coo_to_tcgnn_capped(
        formats.coo_from_edges(coo.n_rows, coo.n_cols, tcc, tr, tv),
        block_size, C, f_tile_cap=cap)
    assert leftover.nnz == 0  # a subset of a C-fitting column set fits C
    fr, fc, fv = _np_edges(spill_fwd)
    xr, xc, xv = _np_edges(spill_t)      # transpose orientation: swap back
    spill = formats.coo_from_edges(
        coo.n_rows, coo.n_cols, np.concatenate([fr, xc]),
        np.concatenate([fc, xr]), np.concatenate([fv, xv]))
    return (tc, tc_t, spill)


# ---------------------------------------------------------------------------
# Pallas kernels: batched dense contraction over the condensed tiles.
# The per-row gather runs in XLA before the call (BlockSpec indirection is
# block-granular; a per-column gather needs row granularity), so the grid
# is plain (block-rows, feature-tiles, column-tiles) with no scalar
# prefetch — C is the innermost reduction accumulated in VMEM scratch.
# ---------------------------------------------------------------------------

def _mv_kernel(a_ref, xg_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], xg_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mv_kernel_acc(a_ref, xg_ref, y_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # accumulation mode: seed from the threaded-through partial output
        acc_ref[...] = y_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...], xg_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("f_tile", "c_tile", "interpret"))
def tcgnn_spmm(tiles: jax.Array, xg: jax.Array,
               y_in: jax.Array | None = None, *, f_tile: int = 512,
               c_tile: int = C_TILE_CAP, interpret: bool = True
               ) -> jax.Array:
    """Y = condensed contraction tiles @ xg (+ y_in).

    tiles: (nbr, B, C); xg: (nbr, C, F) gathered features; y_in: optional
    (nbr*B, F) accumulator input.  Returns (nbr*B, F).
    """
    nbr, B, C = tiles.shape
    F = xg.shape[-1]
    f_tile = min(f_tile, F)
    c_tile = min(c_tile, C)
    assert F % f_tile == 0 and C % c_tile == 0, (F, f_tile, C, c_tile)
    grid = (nbr, F // f_tile, C // c_tile)
    in_specs = [
        pl.BlockSpec((None, B, c_tile), lambda i, j, k: (i, 0, k)),
        pl.BlockSpec((None, c_tile, f_tile), lambda i, j, k: (i, k, j)),
    ]
    operands = [tiles, xg]
    kernel = _mv_kernel
    if y_in is not None:
        in_specs.append(
            pl.BlockSpec((None, B, f_tile), lambda i, j, k: (i, 0, j)))
        operands.append(y_in.reshape(nbr, B, F))
        kernel = _mv_kernel_acc
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j, k: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nbr, B, F), xg.dtype),
        scratch_shapes=[pltpu.VMEM((B, f_tile), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(nbr * B, F)


def _fmv_kernel(a_ref, xg_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = jnp.dot(xg_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32), h,
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fmv_kernel_acc(a_ref, xg_ref, w_ref, y_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = y_ref[...].astype(jnp.float32)

    h = jnp.dot(xg_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32), h,
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("f_tile", "c_tile", "interpret"))
def tcgnn_spmm_fused(tiles: jax.Array, xg: jax.Array, w: jax.Array,
                     y_in: jax.Array | None = None, *, f_tile: int = 512,
                     c_tile: int = C_TILE_CAP, interpret: bool = True
                     ) -> jax.Array:
    """Y = tiles @ (xg @ w) (+ y_in): the gathered (c_tile, Fi) feature
    stripe is transformed in VMEM and immediately contracted — H never
    round-trips HBM.  Unlike bell's fused kernel the transform runs once
    per *condensed column slot* (a source row gathered by k block rows is
    transformed k times; the cost model prices that recompute).

    tiles: (nbr, B, C); xg: (nbr, C, Fi); w: (Fi, Fo) with Fo % f_tile
    == 0; y_in: optional (nbr*B, Fo).  Returns (nbr*B, Fo).
    """
    nbr, B, C = tiles.shape
    Fi = xg.shape[-1]
    Fo = w.shape[-1]
    f_tile = min(f_tile, Fo)
    c_tile = min(c_tile, C)
    assert Fo % f_tile == 0 and C % c_tile == 0, (Fo, f_tile, C, c_tile)
    grid = (nbr, Fo // f_tile, C // c_tile)
    in_specs = [
        pl.BlockSpec((None, B, c_tile), lambda i, j, k: (i, 0, k)),
        pl.BlockSpec((None, c_tile, Fi), lambda i, j, k: (i, k, 0)),
        pl.BlockSpec((Fi, f_tile), lambda i, j, k: (0, j)),
    ]
    operands = [tiles, xg, w]
    kernel = _fmv_kernel
    if y_in is not None:
        in_specs.append(
            pl.BlockSpec((None, B, f_tile), lambda i, j, k: (i, 0, j)))
        operands.append(y_in.reshape(nbr, B, Fo))
        kernel = _fmv_kernel_acc
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j, k: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nbr, B, Fo), xg.dtype),
        scratch_shapes=[pltpu.VMEM((B, f_tile), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(nbr * B, Fo)


def _dw_kernel(a_ref, g_ref, x_ref, o_ref, acc_ref):
    i = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when((i == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = jnp.dot(a_ref[...].astype(jnp.float32), g_ref[...],
                preferred_element_type=jnp.float32)          # (B, fo_tile)
    # x_i^T @ z without materializing the transpose: contract the B dims
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), z,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (fi, fo)

    @pl.when((i == pl.num_programs(2) - 1) & (k == pl.num_programs(3) - 1))
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("fi_tile", "fo_tile",
                                             "c_tile", "interpret"))
def tcgnn_spmm_dw(tiles_t: jax.Array, gg: jax.Array, x: jax.Array, *,
                  fi_tile: int = 512, fo_tile: int = 512,
                  c_tile: int = C_TILE_CAP, interpret: bool = True
                  ) -> jax.Array:
    """dW = X^T @ (A^T @ G), A^T given as the condensed transpose payload,
    as a single blocked reduction sum_{i,k} x_i^T (tiles_t[i,k] @ gg[i,k])
    — no (n, F) intermediate is ever written.

    tiles_t: (nbr, B, C); gg: (nbr, C, Fo) gathered dY; x: (nbr*B, Fi).
    Returns (Fi, Fo) float32.
    """
    nbr, B, C = tiles_t.shape
    Fi = x.shape[-1]
    Fo = gg.shape[-1]
    fi_tile = min(fi_tile, Fi)
    fo_tile = min(fo_tile, Fo)
    c_tile = min(c_tile, C)
    assert Fi % fi_tile == 0 and Fo % fo_tile == 0 and C % c_tile == 0, (
        Fi, fi_tile, Fo, fo_tile, C, c_tile)
    xb = x.reshape(nbr, B, Fi)
    grid = (Fi // fi_tile, Fo // fo_tile, nbr, C // c_tile)
    return pl.pallas_call(
        _dw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, B, c_tile), lambda fi, fo, i, k: (i, 0, k)),
            pl.BlockSpec((None, c_tile, fo_tile),
                         lambda fi, fo, i, k: (i, k, fo)),
            pl.BlockSpec((None, B, fi_tile),
                         lambda fi, fo, i, k: (i, 0, fi)),
        ],
        out_specs=pl.BlockSpec((fi_tile, fo_tile),
                               lambda fi, fo, i, k: (fi, fo)),
        out_shape=jax.ShapeDtypeStruct((Fi, Fo), jnp.float32),
        scratch_shapes=[pltpu.VMEM((fi_tile, fo_tile), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(tiles_t, gg, xb)


# ---------------------------------------------------------------------------
# jit wrappers + custom VJPs (ops.py idiom: Y = A @ X is linear in X, so
# dX = A^T @ dY over the materialized transpose payload)
# ---------------------------------------------------------------------------

def _c_tile_of(C: int) -> int:
    return ops._f_tile(C, cap=C_TILE_CAP)


def _tc_gather(tc: TcgnnTile, xp: jax.Array) -> jax.Array:
    """Row-level XLA gather: (nbr, C, F) dense stripes the kernel streams.
    Padded slots gather row 0 against zero tile values — correct, unmasked."""
    return xp[tc.gather_idx]


def _tc_fwd_impl(tc: TcgnnTile, x, y_in=None):
    t = ops._f_tile(x.shape[-1], cap=tc.f_tile_cap)
    xp, F = ops._pad_feat(x, t)
    xp = ops._pad_rows(xp, tc.n_cols)
    yp = ops._pad_feat(y_in, t)[0] if y_in is not None else None
    y = tcgnn_spmm(tc.tiles, _tc_gather(tc, xp), yp, f_tile=t,
                   c_tile=_c_tile_of(tc.n_cond), interpret=ops._interpret())
    return y[:, :F]


@jax.custom_vjp
def tcgnn_matvec(tc: TcgnnTile, tc_t: TcgnnTile, x: jax.Array) -> jax.Array:
    return _tc_fwd_impl(tc, x)


def _tc_fwd(tc, tc_t, x):
    return _tc_fwd_impl(tc, x), (tc_t, x.shape[0])


def _tc_bwd(res, dy):
    tc_t, n = res
    dx = _tc_fwd_impl(tc_t, dy)[:n]
    return None, None, dx


tcgnn_matvec.defvjp(_tc_fwd, _tc_bwd)


@jax.custom_vjp
def tcgnn_matvec_acc(tc: TcgnnTile, tc_t: TcgnnTile, x: jax.Array,
                     y_in: jax.Array) -> jax.Array:
    """Y = A_tc @ x + y_in (accumulating dispatch mode)."""
    return _tc_fwd_impl(tc, x, y_in)


def _tc_acc_fwd(tc, tc_t, x, y_in):
    return _tc_fwd_impl(tc, x, y_in), (tc_t, x.shape[0])


def _tc_acc_bwd(res, dy):
    tc_t, n = res
    dx = _tc_fwd_impl(tc_t, dy)[:n]
    return None, None, dx, dy


tcgnn_matvec_acc.defvjp(_tc_acc_fwd, _tc_acc_bwd)


def _tc_fused_f_cap(block_size: int, c_tile: int, fin_padded: int) -> int:
    """Output-tile cap for the fused kernel from the VMEM budget: per grid
    step the working set is B*c (tile) + c*Fi (gathered stripe) + Fi*Ft
    (weight stripe) + 2*B*Ft (accumulator + output)."""
    budget_floats = (4 << 20) // 4 // 2
    cap = ((budget_floats - block_size * c_tile - c_tile * fin_padded)
           // (fin_padded + 2 * block_size))
    return int(max(LANE, min(1024, (cap // LANE) * LANE)))


def _tcf_impl(tc: TcgnnTile, x, w, y_in=None):
    xp, _ = ops._pad_feat(x, LANE)
    xp = ops._pad_rows(xp, tc.n_cols)
    Fo = w.shape[-1]
    ct = _c_tile_of(tc.n_cond)
    t = ops._f_tile(Fo, cap=min(tc.f_tile_cap,
                                _tc_fused_f_cap(tc.block_size, ct,
                                                xp.shape[-1])))
    wp = ops._pad_feat(w, t)[0]
    wp = jnp.pad(wp, ((0, xp.shape[-1] - wp.shape[0]), (0, 0)))
    yp = ops._pad_feat(y_in, t)[0] if y_in is not None else None
    y = tcgnn_spmm_fused(tc.tiles, _tc_gather(tc, xp), wp, yp, f_tile=t,
                         c_tile=ct, interpret=ops._interpret())
    return y[:, :Fo]


def _tc_dw_impl(tc_t: TcgnnTile, x, dy):
    """dW = X^T (A^T dY) over the condensed transpose payload."""
    xp, Fi = ops._pad_feat(x, LANE)
    xp = ops._pad_rows(xp, tc_t.n_rows)
    gp, Fo = ops._pad_feat(dy, LANE)
    gp = ops._pad_rows(gp, tc_t.n_cols)
    dw = tcgnn_spmm_dw(tc_t.tiles, _tc_gather(tc_t, gp), xp,
                       fi_tile=ops._f_tile(Fi), fo_tile=ops._f_tile(Fo),
                       c_tile=_c_tile_of(tc_t.n_cond),
                       interpret=ops._interpret())
    return dw[:Fi, :Fo]


@jax.custom_vjp
def tcgnn_fused_matvec(tc: TcgnnTile, tc_t: TcgnnTile, x: jax.Array,
                       w: jax.Array) -> jax.Array:
    """Y = A_tc @ (x @ w), one fused Pallas pass."""
    return _tcf_impl(tc, x, w)


def _tcf_fwd(tc, tc_t, x, w):
    return _tcf_impl(tc, x, w), (tc_t, x, w)


def _tcf_bwd(res, dy):
    tc_t, x, w = res
    dx = _tcf_impl(tc_t, dy, w.T)[: x.shape[0]].astype(x.dtype)
    dw = _tc_dw_impl(tc_t, x, dy).astype(w.dtype)
    return None, None, dx, dw


tcgnn_fused_matvec.defvjp(_tcf_fwd, _tcf_bwd)


@jax.custom_vjp
def tcgnn_fused_matvec_acc(tc: TcgnnTile, tc_t: TcgnnTile, x: jax.Array,
                           w: jax.Array, y_in: jax.Array) -> jax.Array:
    """Y = A_tc @ (x @ w) + y_in, one fused Pallas pass."""
    return _tcf_impl(tc, x, w, y_in)


def _tcf_acc_fwd(tc, tc_t, x, w, y_in):
    return _tcf_impl(tc, x, w, y_in), (tc_t, x, w)


def _tcf_acc_bwd(res, dy):
    tc_t, x, w = res
    dx = _tcf_impl(tc_t, dy, w.T)[: x.shape[0]].astype(x.dtype)
    dw = _tc_dw_impl(tc_t, x, dy).astype(w.dtype)
    return None, None, dx, dw, dy


tcgnn_fused_matvec_acc.defvjp(_tcf_acc_fwd, _tcf_acc_bwd)


# ---------------------------------------------------------------------------
# Dispatch shims shared by the two payload layouts: the classic (tc, tc_t)
# pair and the budget-capped (tc, tc_t, spill) triple (spill rides the COO
# segment-sum / per-edge gathered-transform paths, like bell's)
# ---------------------------------------------------------------------------

def _tc_mv(p, x):
    y = tcgnn_matvec(p[0], p[1], x)
    return y + ops.coo_matvec(p[2], x) if len(p) > 2 else y


def _tc_mv_acc(p, x, y_in):
    y = tcgnn_matvec_acc(p[0], p[1], x, y_in)
    return y + ops.coo_matvec(p[2], x) if len(p) > 2 else y


def _tc_fmv(p, x, w):
    y = tcgnn_fused_matvec(p[0], p[1], x, w)
    return y + ops.coo_transform_matvec(p[2], x, w) if len(p) > 2 else y


def _tc_fmv_acc(p, x, w, y_in):
    y = tcgnn_fused_matvec_acc(p[0], p[1], x, w, y_in)
    return y + ops.coo_transform_matvec(p[2], x, w) if len(p) > 2 else y


# ---------------------------------------------------------------------------
# Cost model: condensation occupancy vs. padding waste.  The kernel
# executes all n_brow * C slots, so a sparse tier whose distinct-column
# count sits far below the lane-rounded C prices its padding here, while a
# dense tier prices the (column-granular, not block-granular) volume that
# makes it beat bell exactly at mid densities.  The XLA row gather that
# feeds the kernel is priced gather-class at full feature width.
# ---------------------------------------------------------------------------

def _tcgnn_cost(sub, feat_dim, dtype, hw) -> float:
    be = _bytes_el(dtype)
    p = sub.formats["tcgnn_tile"]
    tc = p[0]
    B = tc.block_size
    nbr = tc.n_brow
    C = tc.n_cond
    flops = 2.0 * nbr * B * C * feat_dim
    gather_bytes = nbr * C * feat_dim * be     # (nbr, C, F) stripe volume
    bytes_ = (nbr * B * C * 4                  # condensed tiles (f32)
              + gather_bytes                   # kernel streams the stripes
              + sub.n_rows * feat_dim * be)    # output
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    # row-level gather materializing the stripes: gather-class read + write
    t += gather_bytes / (hw.hbm_bw * hw.gather_eff)
    if len(p) > 2 and p[2].nnz:                # budget-capped: spill term
        t += _bell_spill_cost(p[2].nnz, sub.n_rows, feat_dim, dtype, hw)
    return t + hw.launch_overhead_s


def _tcgnn_fused_cost(sub, feat_dims, dtype, hw) -> float:
    fin, fout = feat_dims
    be = _bytes_el(dtype)
    p = sub.formats["tcgnn_tile"]
    tc = p[0]
    B = tc.block_size
    nbr = tc.n_brow
    C = tc.n_cond
    ct = _c_tile_of(C)
    ft = min(tc.f_tile_cap, _tc_fused_f_cap(B, ct, _lane_pad(fin)),
             _lane_pad(fout))
    njt = max(1, -(-_lane_pad(fout) // ft))
    # the transform runs once per condensed slot (C per block row) — less
    # recompute than bell's per-stored-block K*B rows at equal coverage
    flops = 2.0 * nbr * C * (fin * fout + B * fout)
    gather_bytes = nbr * C * fin * be
    bytes_ = (nbr * B * C * 4
              + gather_bytes * njt             # stripe re-read per out tile
              + nbr * fin * fout * be          # weight stripe per block row
              + sub.n_rows * fout * be)
    t = max(flops / (hw.peak_flops * hw.mxu_eff(B)), bytes_ / hw.hbm_bw)
    t += gather_bytes / (hw.hbm_bw * hw.gather_eff)
    if len(p) > 2 and p[2].nnz:
        # spilled edges transform their gathered source rows one-by-one
        E = p[2].nnz
        flops_s = 2.0 * E * (fin * fout + fout)
        bytes_s = E * (fin * be + fout * be + 8) + sub.n_rows * fout * be
        t += max(flops_s / hw.peak_flops,
                 bytes_s / (hw.hbm_bw * hw.scatter_eff))
    return t + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="tcgnn_tile",
    kinds=frozenset({OFFDIAG}),
    build=_tcgnn_build,
    matvec=_tc_mv,
    matvec_acc=_tc_mv_acc,
    cost=_tcgnn_cost,
    # full-batch builds consume coo_t; the budget-capped build re-derives
    # its transpose from the stored-edge subset, so no coo_t is needed
    needs_transpose=lambda stats: not stats.get("edge_budget"),
    pallas=True,
    doc="TC-GNN-style column condensation: each block row's non-zero "
        "columns packed into dense 8x128-aligned MXU tiles + a gather "
        "index; budget-capped C + COO spill under an edge budget",
))

REGISTRY.register(KernelSpec(
    name="tcgnn_tile_fused",
    kinds=frozenset({OFFDIAG}),
    build=None,
    payload_of="tcgnn_tile",
    matvec=None,
    fused_matvec=_tc_fmv,
    fused_matvec_acc=_tc_fmv_acc,
    cost=_tcgnn_fused_cost,
    pallas=True,
    doc="fused column-condensed A @ (X W): gathered stripe transformed in "
        "VMEM and contracted immediately, no (n, F) intermediate",
))

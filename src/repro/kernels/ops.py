"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * feature-dim padding to lane-aligned tiles (128) and unpadding,
  * interpret-mode selection (interpret=True on CPU, compiled on TPU),
  * custom VJPs: aggregation Y = A @ X is linear in X, so dX = A^T @ dY.
    The transposed operand is either computed on the fly (block-diagonal:
    swap the last two axes) or passed in as a preprocessed format
    (blocked-ELL: the transpose is materialized once during decomposition,
    matching the paper's one-shot preprocessing stage).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import formats
from repro.kernels import ref
from repro.kernels.block_diag_spmm import block_diag_spmm
from repro.kernels.bell_spmm import bell_spmm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


LANE = 128


def _pad_feat(x: jax.Array, tile: int) -> tuple[jax.Array, int]:
    F = x.shape[-1]
    Fp = ((F + tile - 1) // tile) * tile
    if Fp != F:
        x = jnp.pad(x, ((0, 0), (0, Fp - F)))
    return x, F


def _f_tile(F: int, cap: int = 512) -> int:
    t = min(cap, ((F + LANE - 1) // LANE) * LANE)
    # pick the largest tile <= cap that divides the padded F
    Fp = ((F + LANE - 1) // LANE) * LANE
    while Fp % t:
        t -= LANE
    return max(t, LANE)


# --- block-diagonal (intra-community dense kernel) --------------------------

@jax.custom_vjp
def block_diag_matvec(blocks: jax.Array, x: jax.Array) -> jax.Array:
    return _bd_fwd_impl(blocks, x)


def _bd_fwd_impl(blocks, x):
    t = _f_tile(x.shape[-1])
    xp, F = _pad_feat(x, t)
    y = block_diag_spmm(blocks, xp, f_tile=t, interpret=_interpret())
    return y[:, :F]


def _bd_fwd(blocks, x):
    return _bd_fwd_impl(blocks, x), (blocks, x.shape)


def _bd_bwd(res, dy):
    blocks, _ = res
    dx = _bd_fwd_impl(jnp.swapaxes(blocks, -1, -2), dy)
    return None, dx  # graph topology is not trained


block_diag_matvec.defvjp(_bd_fwd, _bd_bwd)


# --- blocked-ELL (inter-community sparse kernel) -----------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=())
def bell_matvec(bell: formats.BlockELL, bell_t: formats.BlockELL,
                x: jax.Array) -> jax.Array:
    return _bell_fwd_impl(bell, x)


def _bell_fwd_impl(bell: formats.BlockELL, x):
    t = _f_tile(x.shape[-1])
    xp, F = _pad_feat(x, t)
    n_cpad = bell.n_cols
    if xp.shape[0] < n_cpad:
        xp = jnp.pad(xp, ((0, n_cpad - xp.shape[0]), (0, 0)))
    y = bell_spmm(bell.blocks, bell.col_idx, xp, f_tile=t,
                  interpret=_interpret())
    return y[:, :F]


def _bell_fwd(bell, bell_t, x):
    return _bell_fwd_impl(bell, x), (bell_t, x.shape[0])


def _bell_bwd(res, dy):
    bell_t, n = res
    dx = _bell_fwd_impl(bell_t, dy)[:n]
    return None, None, dx


bell_matvec.defvjp(_bell_fwd, _bell_bwd)


# --- ELL gather (XLA vertex-parallel path) -----------------------------------

def ell_matvec(ell: formats.ELL, x: jax.Array) -> jax.Array:
    """Pure-XLA padded-neighbor gather; natively differentiable (the gather
    transposes to a scatter-add, matching the CSR->COO duality)."""
    return ref.ell_spmm(ell.indices, ell.vals, x)


# --- COO segment-sum (edge-parallel / atomics analogue) ----------------------

def coo_matvec(coo: formats.COO, x: jax.Array) -> jax.Array:
    return ref.coo_spmm(coo.rows, coo.cols, coo.vals, x, coo.n_rows)

# Candidate enumeration lives in repro.kernels.registry (KernelSpec.kinds);
# this module only provides the matvec implementations the registry binds.

"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * feature-dim padding to lane-aligned tiles (128) and unpadding,
  * interpret-mode selection (interpret=True on CPU, compiled on TPU),
  * custom VJPs: aggregation Y = A @ X is linear in X, so dX = A^T @ dY.
    The transposed operand is either computed on the fly (block-diagonal:
    swap the last two axes) or passed in as a preprocessed format
    (blocked-ELL: the transpose is materialized once during decomposition,
    matching the paper's one-shot preprocessing stage).
  * fused transform+aggregate: Y = A @ (X W) (+ Y_in) in one Pallas pass.
    By associativity dX = A^T (dY W^T) is the *same* fused form over the
    transpose payload, and dW = X^T (A^T dY) is a single blocked reduction
    (bell_spmm_dw) — the backward never materializes an (n, F) intermediate.
  * dual-weight epilogue (SAGE): Y = A @ (X W) + X W_self (+ Y_in), both
    stripes in VMEM on the diagonal tier; dX gains the dense dY W_self^T
    term and dW_self = X^T dY is one dense matmul — the shared blocked
    reduction still produces dW.
  * accumulating (`*_acc`) variants that thread one output buffer through
    aggregate()'s subgraph loop (the kernels seed their VMEM scratch from
    y_in instead of zeros) so no per-bucket partial tensors are allocated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats
from repro.kernels import ref
from repro.kernels.block_diag_spmm import block_diag_spmm
from repro.kernels.bell_spmm import bell_spmm
from repro.kernels.block_diag_spmm_fused import (block_diag_spmm_dual,
                                                 block_diag_spmm_fused)
from repro.kernels.bell_spmm_fused import bell_spmm_fused, bell_spmm_dw


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


LANE = 128


def _pad_feat(x: jax.Array, tile: int) -> tuple[jax.Array, int]:
    F = x.shape[-1]
    Fp = ((F + tile - 1) // tile) * tile
    if Fp != F:
        x = jnp.pad(x, ((0, 0), (0, Fp - F)))
    return x, F


def _f_tile(F: int, cap: int = 512) -> int:
    """Largest lane-multiple tile <= cap that divides the lane-padded F.

    Picked by direct divisor scan: the old walk-down decremented from the
    cap in LANE steps, which degenerates (or diverges) whenever the cap is
    not itself a lane multiple — per-bucket tiling passes arbitrary caps.
    """
    Fp = ((F + LANE - 1) // LANE) * LANE
    hi = min(max(cap, LANE), Fp)
    best = LANE
    for t in range(LANE, hi + 1, LANE):
        if Fp % t == 0:
            best = t
    return best


def _pad_rows(x: jax.Array, n_rows: int) -> jax.Array:
    if x.shape[0] < n_rows:
        x = jnp.pad(x, ((0, n_rows - x.shape[0]), (0, 0)))
    return x


def _fused_f_cap(block_size: int, fin_padded: int, stripes: int = 1) -> int:
    """Output-tile cap for the fused kernels from the VMEM budget.

    Per grid step the fused working set is B*B (adjacency) + B*Fi (features)
    + stripes*Fi*Ft (weight stripes; the dual-weight epilogue carries two)
    + 2*B*Ft (accumulator + output); solving for Ft under a ~4 MB
    double-buffered budget lets narrow-input layers run much fatter output
    tiles (= fewer grid steps) than the unfused default."""
    budget_floats = (4 << 20) // 4 // 2
    cap = (budget_floats - block_size * block_size - block_size * fin_padded
           ) // (stripes * fin_padded + 2 * block_size)
    return int(max(LANE, min(1024, (cap // LANE) * LANE)))


# --- block-diagonal (intra-community dense kernel) --------------------------

@jax.custom_vjp
def block_diag_matvec(blocks: jax.Array, x: jax.Array) -> jax.Array:
    return _bd_fwd_impl(blocks, x)


def _bd_fwd_impl(blocks, x, y_in=None):
    t = _f_tile(x.shape[-1])
    xp, F = _pad_feat(x, t)
    yp = _pad_feat(y_in, t)[0] if y_in is not None else None
    y = block_diag_spmm(blocks, xp, yp, f_tile=t, interpret=_interpret())
    return y[:, :F]


def _bd_fwd(blocks, x):
    return _bd_fwd_impl(blocks, x), (blocks, x.shape)


def _bd_bwd(res, dy):
    blocks, _ = res
    dx = _bd_fwd_impl(jnp.swapaxes(blocks, -1, -2), dy)
    return None, dx  # graph topology is not trained


block_diag_matvec.defvjp(_bd_fwd, _bd_bwd)


@jax.custom_vjp
def block_diag_matvec_acc(blocks: jax.Array, x: jax.Array,
                          y_in: jax.Array) -> jax.Array:
    """Y = blockdiag(blocks) @ x + y_in (accumulating dispatch mode)."""
    return _bd_fwd_impl(blocks, x, y_in)


def _bd_acc_fwd(blocks, x, y_in):
    return _bd_fwd_impl(blocks, x, y_in), (blocks,)


def _bd_acc_bwd(res, dy):
    blocks, = res
    dx = _bd_fwd_impl(jnp.swapaxes(blocks, -1, -2), dy)
    return None, dx, dy


block_diag_matvec_acc.defvjp(_bd_acc_fwd, _bd_acc_bwd)


# --- blocked-ELL (inter-community sparse kernel) -----------------------------

@jax.custom_vjp
def bell_matvec(bell: formats.BlockELL, bell_t: formats.BlockELL,
                x: jax.Array) -> jax.Array:
    return _bell_fwd_impl(bell, x)


def _bell_fwd_impl(bell: formats.BlockELL, x, y_in=None):
    t = _f_tile(x.shape[-1], cap=bell.f_tile_cap)
    xp, F = _pad_feat(x, t)
    xp = _pad_rows(xp, bell.n_cols)
    yp = _pad_feat(y_in, t)[0] if y_in is not None else None
    y = bell_spmm(bell.blocks, bell.col_idx, xp, yp, f_tile=t,
                  interpret=_interpret())
    return y[:, :F]


def _bell_fwd(bell, bell_t, x):
    return _bell_fwd_impl(bell, x), (bell_t, x.shape[0])


def _bell_bwd(res, dy):
    bell_t, n = res
    dx = _bell_fwd_impl(bell_t, dy)[:n]
    return None, None, dx


bell_matvec.defvjp(_bell_fwd, _bell_bwd)


@jax.custom_vjp
def bell_matvec_acc(bell: formats.BlockELL, bell_t: formats.BlockELL,
                    x: jax.Array, y_in: jax.Array) -> jax.Array:
    """Y = A_bell @ x + y_in (accumulating dispatch mode)."""
    return _bell_fwd_impl(bell, x, y_in)


def _bell_acc_fwd(bell, bell_t, x, y_in):
    return _bell_fwd_impl(bell, x, y_in), (bell_t, x.shape[0])


def _bell_acc_bwd(res, dy):
    bell_t, n = res
    dx = _bell_fwd_impl(bell_t, dy)[:n]
    return None, None, dx, dy


bell_matvec_acc.defvjp(_bell_acc_fwd, _bell_acc_bwd)


# --- fused transform+aggregate: block-diagonal -------------------------------

def _bdf_impl(blocks, x, w, y_in=None):
    xp, _ = _pad_feat(x, LANE)
    Fo = w.shape[-1]
    t = _f_tile(Fo, cap=_fused_f_cap(blocks.shape[-1], xp.shape[-1]))
    wp = _pad_feat(w, t)[0]
    wp = jnp.pad(wp, ((0, xp.shape[-1] - wp.shape[0]), (0, 0)))
    yp = _pad_feat(y_in, t)[0] if y_in is not None else None
    y = block_diag_spmm_fused(blocks, xp, wp, yp, f_tile=t,
                              interpret=_interpret())
    return y[:, :Fo]


def _bd_dw_impl(blocks, x, dy):
    """dW = X^T (A^T dY) for the diagonal tier, via the shared blocked-ELL
    dW reduction with K=1 and identity block columns."""
    bt = jnp.swapaxes(blocks, -1, -2)[:, None]            # (nb, 1, B, B)
    idx = jnp.arange(blocks.shape[0], dtype=jnp.int32)[:, None]
    xp, Fi = _pad_feat(x, LANE)
    gp, Fo = _pad_feat(dy, LANE)
    dw = bell_spmm_dw(bt, idx, xp, gp,
                      fi_tile=_f_tile(Fi), fo_tile=_f_tile(Fo),
                      interpret=_interpret())
    return dw[:Fi, :Fo]


@jax.custom_vjp
def block_diag_fused_matvec(blocks: jax.Array, x: jax.Array,
                            w: jax.Array) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w), one fused Pallas pass."""
    return _bdf_impl(blocks, x, w)


def _bdf_fwd(blocks, x, w):
    return _bdf_impl(blocks, x, w), (blocks, x, w)


def _bdf_bwd(res, dy):
    blocks, x, w = res
    bt = jnp.swapaxes(blocks, -1, -2)
    dx = _bdf_impl(bt, dy, w.T).astype(x.dtype)       # A^T (dY W^T), fused
    dw = _bd_dw_impl(blocks, x, dy).astype(w.dtype)
    return None, dx, dw


block_diag_fused_matvec.defvjp(_bdf_fwd, _bdf_bwd)


@jax.custom_vjp
def block_diag_fused_matvec_acc(blocks: jax.Array, x: jax.Array,
                                w: jax.Array, y_in: jax.Array) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w) + y_in, one fused Pallas pass."""
    return _bdf_impl(blocks, x, w, y_in)


def _bdf_acc_fwd(blocks, x, w, y_in):
    return _bdf_impl(blocks, x, w, y_in), (blocks, x, w)


def _bdf_acc_bwd(res, dy):
    blocks, x, w = res
    bt = jnp.swapaxes(blocks, -1, -2)
    dx = _bdf_impl(bt, dy, w.T).astype(x.dtype)
    dw = _bd_dw_impl(blocks, x, dy).astype(w.dtype)
    return None, dx, dw, dy


block_diag_fused_matvec_acc.defvjp(_bdf_acc_fwd, _bdf_acc_bwd)


# --- fused dual-weight epilogue: block-diagonal (SAGE) -----------------------

def _bdd_impl(blocks, x, w, w_self, y_in=None):
    xp, _ = _pad_feat(x, LANE)
    Fo = w.shape[-1]
    t = _f_tile(Fo, cap=_fused_f_cap(blocks.shape[-1], xp.shape[-1],
                                     stripes=2))

    def _stripe(m):
        mp = _pad_feat(m, t)[0]
        return jnp.pad(mp, ((0, xp.shape[-1] - mp.shape[0]), (0, 0)))

    yp = _pad_feat(y_in, t)[0] if y_in is not None else None
    y = block_diag_spmm_dual(blocks, xp, _stripe(w), _stripe(w_self), yp,
                             f_tile=t, interpret=_interpret())
    return y[:, :Fo]


def _bdd_bwd_terms(blocks, x, w, w_self, dy):
    """Shared dual-epilogue backward: dx = A^T (dY W^T) + dY W_self^T
    (the first term is the fused pass over the transposed blocks, the
    second a dense matmul), dW = X^T (A^T dY) via the blocked reduction,
    dW_self = X^T dY (dense)."""
    bt = jnp.swapaxes(blocks, -1, -2)
    dx = (_bdf_impl(bt, dy, w.T)
          + dy @ w_self.T.astype(dy.dtype)).astype(x.dtype)
    dw = _bd_dw_impl(blocks, x, dy).astype(w.dtype)
    dws = jax.lax.dot_general(
        x.astype(jnp.float32), dy.astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(w_self.dtype)
    return dx, dw, dws


@jax.custom_vjp
def block_diag_dual_matvec(blocks: jax.Array, x: jax.Array, w: jax.Array,
                           w_self: jax.Array) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w) + x @ w_self, one fused Pallas pass
    with both weight stripes in VMEM (the dual-weight SAGE epilogue)."""
    return _bdd_impl(blocks, x, w, w_self)


def _bdd_fwd(blocks, x, w, w_self):
    return _bdd_impl(blocks, x, w, w_self), (blocks, x, w, w_self)


def _bdd_bwd(res, dy):
    dx, dw, dws = _bdd_bwd_terms(*res, dy)
    return None, dx, dw, dws


block_diag_dual_matvec.defvjp(_bdd_fwd, _bdd_bwd)


@jax.custom_vjp
def block_diag_dual_matvec_acc(blocks: jax.Array, x: jax.Array,
                               w: jax.Array, w_self: jax.Array,
                               y_in: jax.Array) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w) + x @ w_self + y_in."""
    return _bdd_impl(blocks, x, w, w_self, y_in)


def _bdd_acc_fwd(blocks, x, w, w_self, y_in):
    return _bdd_impl(blocks, x, w, w_self, y_in), (blocks, x, w, w_self)


def _bdd_acc_bwd(res, dy):
    dx, dw, dws = _bdd_bwd_terms(*res, dy)
    return None, dx, dw, dws, dy


block_diag_dual_matvec_acc.defvjp(_bdd_acc_fwd, _bdd_acc_bwd)


# --- fused transform+aggregate: blocked-ELL ----------------------------------

def _bellf_impl(bell: formats.BlockELL, x, w, y_in=None):
    xp, _ = _pad_feat(x, LANE)
    xp = _pad_rows(xp, bell.n_cols)
    Fo = w.shape[-1]
    t = _f_tile(Fo, cap=min(bell.f_tile_cap,
                            _fused_f_cap(bell.block_size, xp.shape[-1])))
    wp = _pad_feat(w, t)[0]
    wp = jnp.pad(wp, ((0, xp.shape[-1] - wp.shape[0]), (0, 0)))
    yp = _pad_feat(y_in, t)[0] if y_in is not None else None
    y = bell_spmm_fused(bell.blocks, bell.col_idx, xp, wp, yp, f_tile=t,
                        interpret=_interpret())
    return y[:, :Fo]


def _bell_dw_impl(bell_t: formats.BlockELL, x, dy):
    """dW = X^T (A^T dY) over the materialized transpose payload."""
    xp, Fi = _pad_feat(x, LANE)
    xp = _pad_rows(xp, bell_t.n_rows)
    gp, Fo = _pad_feat(dy, LANE)
    gp = _pad_rows(gp, bell_t.n_cols)
    dw = bell_spmm_dw(bell_t.blocks, bell_t.col_idx, xp, gp,
                      fi_tile=_f_tile(Fi), fo_tile=_f_tile(Fo),
                      interpret=_interpret())
    return dw[:Fi, :Fo]


@jax.custom_vjp
def bell_fused_matvec(bell: formats.BlockELL, bell_t: formats.BlockELL,
                      x: jax.Array, w: jax.Array) -> jax.Array:
    """Y = A_bell @ (x @ w), one fused Pallas pass."""
    return _bellf_impl(bell, x, w)


def _bellf_fwd(bell, bell_t, x, w):
    return _bellf_impl(bell, x, w), (bell_t, x, w)


def _bellf_bwd(res, dy):
    bell_t, x, w = res
    dx = _bellf_impl(bell_t, dy, w.T)[: x.shape[0]].astype(x.dtype)
    dw = _bell_dw_impl(bell_t, x, dy).astype(w.dtype)
    return None, None, dx, dw


bell_fused_matvec.defvjp(_bellf_fwd, _bellf_bwd)


@jax.custom_vjp
def bell_fused_matvec_acc(bell: formats.BlockELL, bell_t: formats.BlockELL,
                          x: jax.Array, w: jax.Array,
                          y_in: jax.Array) -> jax.Array:
    """Y = A_bell @ (x @ w) + y_in, one fused Pallas pass."""
    return _bellf_impl(bell, x, w, y_in)


def _bellf_acc_fwd(bell, bell_t, x, w, y_in):
    return _bellf_impl(bell, x, w, y_in), (bell_t, x, w)


def _bellf_acc_bwd(res, dy):
    bell_t, x, w = res
    dx = _bellf_impl(bell_t, dy, w.T)[: x.shape[0]].astype(x.dtype)
    dw = _bell_dw_impl(bell_t, x, dy).astype(w.dtype)
    return None, None, dx, dw, dy


bell_fused_matvec_acc.defvjp(_bellf_acc_fwd, _bellf_acc_bwd)


# --- ELL gather (XLA vertex-parallel path) -----------------------------------

def ell_matvec(ell: formats.ELL, x: jax.Array) -> jax.Array:
    """Pure-XLA padded-neighbor gather; natively differentiable (the gather
    transposes to a scatter-add, matching the CSR->COO duality)."""
    return ref.ell_spmm(ell.indices, ell.vals, x)


# --- COO segment-sum (edge-parallel / atomics analogue) ----------------------

def coo_matvec(coo: formats.COO, x: jax.Array) -> jax.Array:
    return ref.coo_spmm(coo.rows, coo.cols, coo.vals, x, coo.n_rows)


def coo_transform_matvec(coo: formats.COO, x: jax.Array,
                         w: jax.Array) -> jax.Array:
    """Y = A_coo @ (x @ w) without materializing H = x @ w: each edge
    transforms only its gathered source row, (E, Fi) @ (Fi, Fo).

    This is the spill path of the budget-padded fused blocked-ELL — E is
    the (small) overflow the stored-block cap rejected, so per-edge
    transform recompute beats an (n, Fo) H round-trip.  Natively
    differentiable (gather + matmul + sorted segment-sum)."""
    h_e = (x[coo.cols] @ w) * coo.vals[:, None]
    return jax.ops.segment_sum(h_e, coo.rows, num_segments=coo.n_rows,
                               indices_are_sorted=True).astype(x.dtype)

# Candidate enumeration lives in repro.kernels.registry (KernelSpec.kinds);
# this module only provides the matvec implementations the registry binds.

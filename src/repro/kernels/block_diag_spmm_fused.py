"""Pallas TPU kernel: fused transform+aggregate for the block-diagonal
(intra-community) subgraph: Y = blockdiag(blocks) @ (X @ W) [+ Y_in].

The unfused GCN path pays an HBM round-trip for H = X @ W: XLA writes H out,
the aggregation kernel reads it back.  Here the weight tile lives in VMEM and
the (B, Fi) @ (Fi, Ft) transform product is consumed immediately by the
(B, B) @ (B, Ft) block contraction — H never touches HBM (TC-GNN / MaxK-GNN's
fusion argument, mapped to the MXU).

Grid = (block, out-feature-tile).  Each step loads the (B, B) adjacency
block, the block's full-width (B, Fi) feature rows, and the (Fi, Ft) weight
stripe, then issues two chained MXU matmuls.  For the diagonal tier the
in-kernel transform does exactly the same FLOPs as the standalone X @ W
(every row transformed once), so fusion is a pure bandwidth/launch win.

The optional ``y_in`` operand turns the kernel into an accumulator
(o = y_in + A (X W)): aggregate() threads one output buffer through the
subgraph list instead of materializing one partial per density bucket.

VMEM working set per step: B*B + B*Fi + Fi*Ft + 2*B*Ft floats — with
B=128, Fi=1536, Ft=512 that is ~4.5 MB, inside the ~16 MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, w_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(a_ref[...].astype(jnp.float32), h,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel_acc(a_ref, x_ref, w_ref, y_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = jnp.dot(a_ref[...].astype(jnp.float32), h,
                preferred_element_type=jnp.float32)
    o_ref[...] = (y_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def block_diag_spmm_fused(blocks: jax.Array, x: jax.Array, w: jax.Array,
                          y_in: jax.Array | None = None, *,
                          f_tile: int = 512, interpret: bool = True
                          ) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w) (+ y_in).

    blocks: (nb, B, B); x: (nb*B, Fi); w: (Fi, Fo) with Fo % f_tile == 0
    (ops.py pads); y_in: optional (nb*B, Fo) accumulator input.
    """
    nb, B, _ = blocks.shape
    n, Fi = x.shape
    assert n == nb * B, (n, nb, B)
    Fo = w.shape[-1]
    f_tile = min(f_tile, Fo)
    assert Fo % f_tile == 0, (Fo, f_tile)
    xb = x.reshape(nb, B, Fi)
    grid = (nb, Fo // f_tile)
    in_specs = [
        pl.BlockSpec((None, B, B), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((None, B, Fi), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((Fi, f_tile), lambda i, j: (0, j)),
    ]
    operands = [blocks, xb, w]
    kernel = _kernel
    if y_in is not None:
        yb = y_in.reshape(nb, B, Fo)
        in_specs.append(pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)))
        operands.append(yb)
        kernel = _kernel_acc
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nb, B, Fo), x.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(n, Fo)

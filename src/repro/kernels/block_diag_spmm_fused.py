"""Pallas TPU kernel: fused transform+aggregate for the block-diagonal
(intra-community) subgraph: Y = blockdiag(blocks) @ (X @ W) [+ Y_in].

The unfused GCN path pays an HBM round-trip for H = X @ W: XLA writes H out,
the aggregation kernel reads it back.  Here the weight tile lives in VMEM and
the (B, Fi) @ (Fi, Ft) transform product is consumed immediately by the
(B, B) @ (B, Ft) block contraction — H never touches HBM (TC-GNN / MaxK-GNN's
fusion argument, mapped to the MXU).

Grid = (block, out-feature-tile).  Each step loads the (B, B) adjacency
block, the block's full-width (B, Fi) feature rows, and the (Fi, Ft) weight
stripe, then issues two chained MXU matmuls.  For the diagonal tier the
in-kernel transform does exactly the same FLOPs as the standalone X @ W
(every row transformed once), so fusion is a pure bandwidth/launch win.

The optional ``y_in`` operand turns the kernel into an accumulator
(o = y_in + A (X W)): aggregate() threads one output buffer through the
subgraph list instead of materializing one partial per density bucket.

``block_diag_spmm_dual`` is the dual-weight epilogue variant (SAGE:
Y = X W_self + A (X W_neigh) [+ Y_in]): a *second* weight stripe rides in
VMEM next to the neighbor stripe and the block's rows are transformed by
both — the self term never materializes as a separate (n, Fo) tensor.
Only the diagonal tier gets this (its row block *is* its source block);
off-diagonal tiers accumulate their neighbor terms on top via y_in.

VMEM working set per step: B*B + B*Fi + Fi*Ft + 2*B*Ft floats — with
B=128, Fi=1536, Ft=512 that is ~4.5 MB, inside the ~16 MB budget (the
dual variant adds one more Fi*Ft stripe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, w_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(a_ref[...].astype(jnp.float32), h,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel_acc(a_ref, x_ref, w_ref, y_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = jnp.dot(a_ref[...].astype(jnp.float32), h,
                preferred_element_type=jnp.float32)
    o_ref[...] = (y_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def block_diag_spmm_fused(blocks: jax.Array, x: jax.Array, w: jax.Array,
                          y_in: jax.Array | None = None, *,
                          f_tile: int = 512, interpret: bool = True
                          ) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w) (+ y_in).

    blocks: (nb, B, B); x: (nb*B, Fi); w: (Fi, Fo) with Fo % f_tile == 0
    (ops.py pads); y_in: optional (nb*B, Fo) accumulator input.
    """
    nb, B, _ = blocks.shape
    n, Fi = x.shape
    assert n == nb * B, (n, nb, B)
    Fo = w.shape[-1]
    f_tile = min(f_tile, Fo)
    assert Fo % f_tile == 0, (Fo, f_tile)
    xb = x.reshape(nb, B, Fi)
    grid = (nb, Fo // f_tile)
    in_specs = [
        pl.BlockSpec((None, B, B), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((None, B, Fi), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((Fi, f_tile), lambda i, j: (0, j)),
    ]
    operands = [blocks, xb, w]
    kernel = _kernel
    if y_in is not None:
        yb = y_in.reshape(nb, B, Fo)
        in_specs.append(pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)))
        operands.append(yb)
        kernel = _kernel_acc
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nb, B, Fo), x.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(n, Fo)


# ---------------------------------------------------------------------------
# Dual-weight epilogue variant (SAGE): Y = X W_self + A (X W_neigh) [+ Y_in]
# ---------------------------------------------------------------------------

def _kernel_dual(a_ref, x_ref, w_ref, ws_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = jnp.dot(a_ref[...].astype(jnp.float32), h,
                preferred_element_type=jnp.float32)
    y += jnp.dot(x_ref[...], ws_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _kernel_dual_acc(a_ref, x_ref, w_ref, ws_ref, y_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = jnp.dot(a_ref[...].astype(jnp.float32), h,
                preferred_element_type=jnp.float32)
    y += jnp.dot(x_ref[...], ws_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (y_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def block_diag_spmm_dual(blocks: jax.Array, x: jax.Array, w: jax.Array,
                         w_self: jax.Array, y_in: jax.Array | None = None, *,
                         f_tile: int = 512, interpret: bool = True
                         ) -> jax.Array:
    """Y = blockdiag(blocks) @ (x @ w) + x @ w_self (+ y_in).

    Same grid/tiling as :func:`block_diag_spmm_fused`; ``w_self`` is a
    second (Fi, Fo) stripe sharing ``w``'s BlockSpec.  The diagonal tier's
    row block is its own source block, so the self transform consumes the
    already-resident (B, Fi) feature rows — the dual epilogue costs one
    extra MXU matmul per step and zero extra HBM feature traffic.
    """
    nb, B, _ = blocks.shape
    n, Fi = x.shape
    assert n == nb * B, (n, nb, B)
    Fo = w.shape[-1]
    assert w_self.shape == w.shape, (w_self.shape, w.shape)
    f_tile = min(f_tile, Fo)
    assert Fo % f_tile == 0, (Fo, f_tile)
    xb = x.reshape(nb, B, Fi)
    grid = (nb, Fo // f_tile)
    w_spec = pl.BlockSpec((Fi, f_tile), lambda i, j: (0, j))
    in_specs = [
        pl.BlockSpec((None, B, B), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((None, B, Fi), lambda i, j: (i, 0, 0)),
        w_spec,
        w_spec,
    ]
    operands = [blocks, xb, w, w_self]
    kernel = _kernel_dual
    if y_in is not None:
        yb = y_in.reshape(nb, B, Fo)
        in_specs.append(pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)))
        operands.append(yb)
        kernel = _kernel_dual_acc
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, B, f_tile), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nb, B, Fo), x.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
    )(*operands)
    return out.reshape(n, Fo)

"""Sell-C-sigma (sliced-ELL, row-sorted) aggregation kernel — one-file
registration following kernels/csr.py.

Sell-C-sigma (Kreutzer et al., SIAM J. Sci. Comput. 2014) fixes ELL's
pathology on scale-free degree skew — exactly the profile neighbor-sampled
batches and power-law inter tiers produce: ELL pads *every* row to the
global max degree, so one hub row inflates the whole tensor.  Sell-C-sigma
sorts rows by degree inside windows of ``sigma`` rows, slices the sorted
rows into chunks of ``C``, and pads each chunk only to its *local* max
degree: hubs share a fat chunk, leaves share skinny ones, and the stored
slot count P = sum_c C * maxdeg_c collapses toward nnz.

TPU/XLA analogue of the vectorized row-major kernel: the chunk-padded
slots flatten to one (P,) gather + a *sorted* segment-sum over the
degree-sorted row index (slots are emitted chunk-major, so segment ids are
nondecreasing — gather-efficiency class, like ELL/CSR, never scatter
class), followed by a single (n,) gather that undoes the row sort.
Natively differentiable, same as CSR.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.kernels.registry import DIAG, OFFDIAG, REGISTRY, KernelSpec

CHUNK = 8          # C: rows per chunk (sublane-friendly)
SIGMA_CHUNKS = 8   # sigma = SIGMA_CHUNKS * C rows per sort window


@dataclass(frozen=True)
class SellCS:
    """Chunk-padded slices of the degree-sorted matrix, flattened."""
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    chunk: int = dataclasses.field(metadata=dict(static=True))
    sigma: int = dataclasses.field(metadata=dict(static=True))
    indices: Any = None   # (P,) int32 source (column) ids, 0 where padded
    vals: Any = None      # (P,) float, 0 where padded
    srow: Any = None      # (P,) int32 degree-sorted row index, nondecreasing
    rank: Any = None      # (n_rows,) int32: row id -> degree-sorted position

    @property
    def n_slots(self) -> int:
        return int(self.indices.shape[0])


jax.tree_util.register_dataclass(
    SellCS, ["indices", "vals", "srow", "rank"],
    ["n_rows", "n_cols", "chunk", "sigma"])


def coo_to_sell(coo: formats.COO, chunk: int = CHUNK,
                sigma: int | None = None) -> SellCS:
    """Host-side builder: degree-sort within sigma windows, chunk, pad each
    chunk to its local max degree, flatten chunk-major.  Fully vectorized
    (this runs inside every eager decompose; a per-row Python loop would
    dominate preprocessing on large graphs)."""
    n = coo.n_rows
    sigma = sigma or chunk * SIGMA_CHUNKS
    rows = np.asarray(jax.device_get(coo.rows))
    cols = np.asarray(jax.device_get(coo.cols))
    vals = np.asarray(jax.device_get(coo.vals))
    if rows.size and np.any(np.diff(rows) < 0):   # builder needs row-sorted
        edge_order = np.argsort(rows, kind="stable")
        rows, cols, vals = rows[edge_order], cols[edge_order], vals[edge_order]
    deg = np.bincount(rows, minlength=n)
    # stable degree sort inside each sigma window (lexsort: window id is
    # the primary key, so the community ordering survives across windows)
    window = np.arange(n) // sigma
    order = np.lexsort((np.arange(n), -deg, window))
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    # chunk-local widths; each sorted row owns w[its chunk] slots, laid out
    # row-major per chunk (consecutive sorted rows -> consecutive slots)
    n_ch = -(-n // chunk)
    deg_sorted = np.zeros(n_ch * chunk, np.int64)
    deg_sorted[:n] = deg[order]
    w = deg_sorted.reshape(n_ch, chunk).max(axis=1)
    slots_per_row = np.repeat(w, chunk)[:n]
    row_off = np.zeros(n + 1, np.int64)
    np.cumsum(slots_per_row, out=row_off[1:])
    P = int(row_off[-1])
    # per-edge slot index: position within its (row-sorted) row
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    slot = np.arange(len(rows), dtype=np.int64) - indptr[rows]
    flat = row_off[rank[rows]] + slot
    indices = np.zeros(P, np.int32)
    out_vals = np.zeros(P, np.float32)
    indices[flat] = cols
    out_vals[flat] = vals
    srow = np.repeat(np.arange(n, dtype=np.int32), slots_per_row)
    return SellCS(n, coo.n_cols, chunk, sigma,
                  jnp.asarray(indices), jnp.asarray(out_vals),
                  jnp.asarray(srow), jnp.asarray(rank.astype(np.int32)))


def sell_matvec(p: SellCS, x: jax.Array) -> jax.Array:
    """Y = A @ x: flat gather over the chunk-padded slots, sorted segment
    reduce in degree order, then one gather back to row order."""
    msgs = x[p.indices] * p.vals[:, None]
    y_sorted = jax.ops.segment_sum(msgs, p.srow, num_segments=p.n_rows,
                                   indices_are_sorted=True)
    return y_sorted[p.rank].astype(x.dtype)


def _sell_cost(sub, feat_dim, dtype, hw) -> float:
    be = np.dtype(dtype).itemsize
    P = sub.formats["sell_cs"].n_slots      # nnz + chunk-local padding only
    n = sub.n_rows
    flops = 2.0 * P * feat_dim
    # padded-slot gather + slot metadata + output write + un-sort gather
    bytes_ = P * (feat_dim * be + 8) + 2.0 * n * feat_dim * be
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.gather_eff)) + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="sell_cs",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=lambda coo, coo_t, B, stats: coo_to_sell(coo),
    matvec=sell_matvec,
    cost=_sell_cost,
    doc="sell-C-sigma: degree-sorted chunk-padded slices (scale-free skew; "
        "pads to chunk-local max degree instead of ELL's global max)",
))


# ---------------------------------------------------------------------------
# Fused epilogue path: Y = A_sell @ (x @ w) without materializing H = x @ w
# ---------------------------------------------------------------------------

def sell_transform_matvec(p: SellCS, x: jax.Array, w: jax.Array) -> jax.Array:
    """Per-slot gathered transform over the chunk-padded slices (the same
    trick as kernels/csr.py's fused path): each stored slot transforms its
    gathered source row and the sorted reduce + un-sort gather run at the
    *output* width — H never materializes.  Natively differentiable."""
    h = (x[p.indices] @ w) * p.vals[:, None]
    y_sorted = jax.ops.segment_sum(h, p.srow, num_segments=p.n_rows,
                                   indices_are_sorted=True)
    return y_sorted[p.rank].astype(x.dtype)


def _sell_fused_cost(sub, feat_dims, dtype, hw) -> float:
    fin, fout = feat_dims
    be = np.dtype(dtype).itemsize
    P = sub.formats["sell_cs"].n_slots
    flops = 2.0 * P * (fin * fout + fout)
    bytes_ = P * (fin * be + fout * be + 8) + 2.0 * sub.n_rows * fout * be
    return max(flops / hw.peak_flops,
               bytes_ / (hw.hbm_bw * hw.gather_eff)) + hw.launch_overhead_s


REGISTRY.register(KernelSpec(
    name="sell_fused",
    kinds=frozenset({DIAG, OFFDIAG}),
    build=None,
    payload_of="sell_cs",
    matvec=None,
    fused_matvec=sell_transform_matvec,
    cost=_sell_fused_cost,
    doc="fused sell-C-sigma A @ (X W): per-slot gathered transform over "
        "the degree-sorted chunks, no (n, F) intermediate",
))

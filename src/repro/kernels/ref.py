"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (tests assert_allclose kernels against
these across shape/dtype sweeps) and double as the portable fallback path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- SpMM family (AdaptGear subgraph kernels) ------------------------------

def block_diag_spmm(blocks: jax.Array, x: jax.Array) -> jax.Array:
    """Y[b*B:(b+1)*B] = blocks[b] @ x[b*B:(b+1)*B].

    blocks: (nb, B, B); x: (nb*B, F)  ->  (nb*B, F)
    """
    nb, B, _ = blocks.shape
    xb = x.reshape(nb, B, -1)
    y = jnp.einsum("bij,bjf->bif", blocks, xb,
                   preferred_element_type=jnp.float32)
    return y.reshape(nb * B, -1).astype(x.dtype)


def bell_spmm(blocks: jax.Array, col_idx: jax.Array, x: jax.Array) -> jax.Array:
    """Blocked-ELL SpMM.

    blocks: (nbr, K, B, B), col_idx: (nbr, K) block-column ids,
    x: (n_cols_pad, F) -> (nbr*B, F).  Padding blocks are all-zero so their
    contribution vanishes regardless of col_idx.
    """
    nbr, K, B, _ = blocks.shape
    xb = x.reshape(-1, B, x.shape[-1])            # (nbc, B, F)
    gathered = xb[col_idx]                        # (nbr, K, B, F)
    y = jnp.einsum("rkij,rkjf->rif", blocks, gathered,
                   preferred_element_type=jnp.float32)
    return y.reshape(nbr * B, -1).astype(x.dtype)


def ell_spmm(indices: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """Row-padded gather SpMM: Y[i] = sum_k vals[i,k] * x[indices[i,k]].

    indices/vals: (n, K) (vals zero where padded); x: (n_cols, F)."""
    gathered = x[indices]                          # (n, K, F)
    return jnp.einsum("nk,nkf->nf", vals, gathered,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def coo_spmm(rows: jax.Array, cols: jax.Array, vals: jax.Array,
             x: jax.Array, n_rows: int) -> jax.Array:
    """Edge-parallel scatter-add (the atomicAdd analogue)."""
    msgs = x[cols] * vals[:, None]
    return jax.ops.segment_sum(msgs, rows, num_segments=n_rows,
                               indices_are_sorted=True).astype(x.dtype)


def coo_spmm_dense_ref(rows, cols, vals, x, n_rows):
    """O(n^2) dense-materialized oracle (small shapes only)."""
    a = jnp.zeros((n_rows, x.shape[0]), jnp.float32)
    a = a.at[rows, cols].add(vals)
    return (a @ x.astype(jnp.float32)).astype(x.dtype)


# --- attention -------------------------------------------------------------

def mha(q, k, v, *, causal: bool = True, scale: float | None = None,
        bias=None) -> jax.Array:
    """Reference multi-head attention. q: (B, Hq, S, D); k/v: (B, Hkv, T, D).
    GQA handled by head-group broadcast."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    sc = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qf, kf) * sc
    if bias is not None:
        logits = logits + bias
    if causal:
        t = k.shape[2]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, vf)
    return out.reshape(b, hq, s, v.shape[-1]).astype(q.dtype)


# --- RWKV-6 / gated linear recurrence ---------------------------------------

def rwkv6_linear_attention(r, k, v, w, u) -> jax.Array:
    """RWKV-6 (Finch) recurrence, sequential oracle.

    r,k,v: (B, H, T, D); w: (B, H, T, D) per-step decay in (0,1);
    u: (H, D) bonus for the current token.
      S_t = diag(w_t) S_{t-1} + k_t^T v_t
      o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    Shapes follow arXiv:2404.05892 eq. (17)-(19).
    """
    B, H, T, D = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp          # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,D,D)
        out = jnp.einsum("bhd,bhde->bhe", rt, S + uf[:, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    inputs = tuple(jnp.moveaxis(a, 2, 0) for a in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, S0, inputs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype)  # (B,H,T,D)


# --- selective SSM (Mamba) ---------------------------------------------------

def mamba_ssm(x, dt, A, Bc, Cc, D) -> jax.Array:
    """Selective state space scan, sequential oracle.

    x: (B, T, d_inner); dt: (B, T, d_inner) (post-softplus);
    A: (d_inner, d_state); Bc/Cc: (B, T, d_state); D: (d_inner,)
      h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D x_t
    """
    xb, dtb, Bb, Cb = (a.astype(jnp.float32) for a in (x, dt, Bc, Cc))
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt[..., None] * Af)                  # (B, d_inner, d_state)
        dBx = (dtt * xt)[..., None] * Bt[:, None, :]       # (B, d_inner, d_state)
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, Ct)
        return h, y

    Bsz, T, d_inner = x.shape
    h0 = jnp.zeros((Bsz, d_inner, Af.shape[-1]), jnp.float32)
    inputs = (jnp.moveaxis(xb, 1, 0), jnp.moveaxis(dtb, 1, 0),
              jnp.moveaxis(Bb, 1, 0), jnp.moveaxis(Cb, 1, 0))
    _, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1) + xb * D.astype(jnp.float32)
    return y.astype(x.dtype)

"""AdamW + cosine schedule + global-norm clipping, pure pytree functions.

Optimizer state shards exactly like the parameters (state specs mirror the
param spec tree), giving ZeRO-style fully-sharded optimizer memory for free.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac*lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    return dict(
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        step=jnp.zeros((), jnp.int32),
    )


def state_specs(param_specs):
    return dict(m=param_specs, v=param_specs, step=())


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def update(params, grads, state, cfg: OptConfig):
    """One AdamW step.  Moments in fp32; params keep their own dtype
    (bf16 params + fp32 moments = mixed-precision training standard)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / (1 - b1 ** step.astype(jnp.float32))
        vh = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, dict(m=new_m, v=new_v, step=step), dict(grad_norm=gnorm, lr=lr)

"""Rotary position embeddings: standard RoPE and multi-modal M-RoPE
(Qwen2-VL, arXiv:2409.12191 §2.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int. Rotates pairs (even, odd
    halves convention, matching Llama/Qwen)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                         # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 10000.0) -> jax.Array:
    """M-RoPE: positions (3, B, S) for (temporal, height, width); the head
    dim's frequency bands are partitioned by ``sections`` (in d/2 units,
    e.g. (16, 24, 24) for D=128) and each band rotates by its own position
    stream.  For pure text the three streams are identical and M-RoPE
    reduces exactly to RoPE."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (d/2,)
    assert sum(sections) == d // 2, (sections, d)
    band = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                      total_repeat_length=d // 2)         # (d/2,) in {0,1,2}
    pos = positions.astype(jnp.float32)[band]             # (d/2, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                        # (B, S, d/2)
    ang = pos * freqs                                     # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings, (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out

"""Framework-free neural net primitives: inits, norms, embeddings.

Parameters are plain dicts of jnp arrays; every init_* has a matching
spec_* returning a pytree of logical-axis tuples with the same structure
(consumed by repro.launch.sharding to build PartitionSpecs).
Logical axes used across the codebase:
  "embed"   -- d_model           (FSDP-sharded over the data axis)
  "mlp"     -- d_ff / head*dh    (TP-sharded over the model axis)
  "heads"   -- attention head dim (TP over model when divisible)
  "kv_heads"-- kv head dim
  "vocab"   -- vocabulary        (TP over model)
  "expert"  -- MoE expert dim    (EP over model)
  "layer"   -- scan-stacked layer dim (never sharded in the 2-D mesh)
  None      -- replicated
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, std: float = 0.02, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * std


def lecun_normal(key, shape, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return jax.random.normal(key, shape, dtype) * math.sqrt(1.0 / fan_in)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """One-hot matmul lookup: on TPU this beats gather for sharded vocab
    tables (the matmul reduces over the vocab-sharded dim with a
    reduce-scatter instead of gathering the table)."""
    return jnp.take(table, ids, axis=0)


def embed_lookup_onehot(table: jax.Array, ids: jax.Array) -> jax.Array:
    oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    return oh @ table


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over mask; logits (..., V) in any dtype, computed in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

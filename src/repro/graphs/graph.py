"""Graph substrate: container, RMAT generator, and synthetic stand-ins for
the paper's Table-1 datasets (offline container -> we synthesize graphs with
matching vertex/edge/feature/class statistics, scaled by a factor)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    """Host-side graph. senders/receivers are the COO (src, dst) edge list;
    by GNN convention aggregation is over in-neighbors: dst row, src col."""
    n: int
    senders: np.ndarray     # (E,) int
    receivers: np.ndarray   # (E,) int
    features: np.ndarray    # (n, F) float32
    labels: np.ndarray      # (n,) int32
    n_classes: int
    name: str = "graph"

    @property
    def n_edges(self) -> int:
        return int(self.senders.shape[0])

    @property
    def density(self) -> float:
        return self.n_edges / max(self.n * self.n, 1)


def rmat(n: int, n_edges: int, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT recursive generator (Chakrabarti et al., SDM'04) — the paper uses
    RMAT in §2.1 to sweep density.  Returns deduplicated (src, dst)."""
    rng = np.random.default_rng(seed)
    scale = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    m = int(n_edges * 1.2) + 16  # oversample; dedup below
    # Each level picks a quadrant with probs (a, b, c, d): src bit set for the
    # bottom half (c, d), dst bit set for the right half (b, d).
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for _ in range(scale):
        r = rng.random(m)
        src_bit = (r > a + b).astype(np.int64)
        dst_bit = (((r > a) & (r <= a + b)) | (r > a + b + c)).astype(np.int64)
        src = src * 2 + src_bit
        dst = dst * 2 + dst_bit
    src %= n
    dst %= n
    eid = src * n + dst
    _, keep = np.unique(eid, return_index=True)
    keep = keep[: n_edges]
    return src[keep].astype(np.int32), dst[keep].astype(np.int32)


def community_graph(n: int, n_edges: int, comm_size: int = 16,
                    intra_frac: float = 0.7, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Planted-partition generator: real-world community structure
    (paper §2.2) with a controllable intra-community edge fraction."""
    rng = np.random.default_rng(seed)
    n_intra = int(n_edges * intra_frac)
    n_inter = n_edges - n_intra
    comm = rng.permutation(n)  # hide the communities behind a random labeling
    # intra edges: pick a community block, then two members
    n_comm = max(n // comm_size, 1)
    cblock = rng.integers(0, n_comm, n_intra)
    base = cblock * comm_size
    s_in = base + rng.integers(0, comm_size, n_intra)
    d_in = base + rng.integers(0, comm_size, n_intra)
    s_out = rng.integers(0, n, n_inter)
    d_out = rng.integers(0, n, n_inter)
    src = np.concatenate([s_in, s_out]) % n
    dst = np.concatenate([d_in, d_out]) % n
    src, dst = comm[src], comm[dst]   # apply hiding permutation
    eid = src.astype(np.int64) * n + dst
    _, keep = np.unique(eid, return_index=True)
    return src[keep].astype(np.int32), dst[keep].astype(np.int32)


# (#vertex, #edge, #feat, #class) from paper Table 1.
def aligned_community_graph(n: int, n_edges: int, block: int = 128,
                            intra_frac: float = 0.9, seed: int = 0
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Block-diagonal-dominant synthetic graph with *aligned* communities:
    intra edges land on size-``block`` diagonal blocks directly (use
    ``decompose(..., reorder=False)``), inter edges connect neighboring
    communities in a ring — so the off-diagonal blocks are few and
    coherent (small blocked-ELL K), the regime where the paper's dense
    intra kernel and the fused transform+aggregate pass dominate."""
    rng = np.random.default_rng(seed)
    nb = max(n // block, 1)
    n_intra = int(n_edges * intra_frac)
    n_inter = n_edges - n_intra
    cb = rng.integers(0, nb, n_intra) * block
    s_in = cb + rng.integers(0, block, n_intra)
    d_in = cb + rng.integers(0, block, n_intra)
    rb = rng.integers(0, nb, n_inter)
    s_out = ((rb + 1) % nb) * block + rng.integers(0, block, n_inter)
    d_out = rb * block + rng.integers(0, block, n_inter)
    src = np.concatenate([s_in, s_out]) % n
    dst = np.concatenate([d_in, d_out]) % n
    eid = src.astype(np.int64) * n + dst
    _, keep = np.unique(eid, return_index=True)
    return src[keep].astype(np.int32), dst[keep].astype(np.int32)


TABLE1 = {
    "cora": (2708, 10556, 1433, 7),
    "citeseer": (3327, 9228, 3703, 6),
    "pubmed": (19717, 99203, 500, 3),
    "proteins_full": (43466, 162088, 29, 2),
    "artist": (50515, 1638396, 100, 12),
    "ppi": (56944, 818716, 50, 121),
    "soc_blogcatalog": (88784, 2093195, 128, 39),
    "com_amazon": (334863, 1851744, 96, 22),
    "dd": (334925, 1686092, 89, 2),
    "amazon0601": (403394, 3387388, 96, 22),
    "amazon0505": (410236, 4878874, 96, 22),
    "twitter_partial": (580768, 1435116, 1323, 2),
    "yeast": (1710902, 3636546, 74, 2),
    "sw_620h": (1888584, 3944206, 66, 2),
    "ovcar_8h": (1889542, 3946402, 66, 2),
}


def synth_dataset(name: str, scale: float = 1.0, seed: int = 0,
                  comm_size: int = 16, intra_frac: float = 0.6,
                  max_feat: int | None = None) -> Graph:
    """Synthetic dataset matching a Table-1 row's statistics, optionally
    downscaled (offline container; no dataset downloads)."""
    nv, ne, nf, nc = TABLE1[name]
    n = max(int(nv * scale), 2 * comm_size)
    e = max(int(ne * scale), n)
    if max_feat is not None:
        nf = min(nf, max_feat)
    src, dst = community_graph(n, e, comm_size=comm_size,
                               intra_frac=intra_frac, seed=seed)
    rng = np.random.default_rng(seed + 1)
    feats = rng.standard_normal((n, nf)).astype(np.float32) * 0.1
    labels = rng.integers(0, nc, n).astype(np.int32)
    return Graph(n, src, dst, feats, labels, nc, name=name)


def add_self_loops(g: Graph) -> Graph:
    loop = np.arange(g.n, dtype=np.int32)
    return dataclasses.replace(
        g, senders=np.concatenate([g.senders, loop]),
        receivers=np.concatenate([g.receivers, loop]))


def gcn_norm_values(n: int, senders: np.ndarray, receivers: np.ndarray) -> np.ndarray:
    """Symmetric GCN normalization D^-1/2 (A) D^-1/2 per edge (Kipf&Welling)."""
    deg = np.bincount(receivers, minlength=n).astype(np.float32)
    deg_in = np.bincount(senders, minlength=n).astype(np.float32)
    d = np.maximum(deg, 1.0) ** -0.5
    ds = np.maximum(deg_in, 1.0) ** -0.5
    return (d[receivers] * ds[senders]).astype(np.float32)


def mean_norm_values(n: int, senders: np.ndarray,
                     receivers: np.ndarray) -> np.ndarray:
    """Mean-aggregation normalization 1/deg(dst) per edge (SAGE).

    Baked into the decomposition's edge values exactly like the GCN norm:
    ``A @ x`` then *is* the in-neighbor mean, so the dual-weight epilogue's
    neighbor transform pushes through the aggregation without a per-row
    rescale separating the fused self term from the accumulation."""
    deg = np.bincount(receivers, minlength=n).astype(np.float32)
    inv = 1.0 / np.maximum(deg, 1.0)
    return inv[receivers].astype(np.float32)

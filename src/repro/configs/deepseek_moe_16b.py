"""DeepSeekMoE-16B (arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base).
Fine-grained MoE: 64 routed experts top-6 + 2 shared experts; first layer
dense (official dense d_ff=10944, expert d_ff=1408 as in the assignment)."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
    kv_heads=16, head_dim=128, d_ff=10944, vocab=102400,
    n_experts=64, top_k=6, d_ff_expert=1408, n_shared_experts=2,
    first_k_dense=1, rope_theta=1e4, tie_embeddings=False,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="deepseek-moe-16b-smoke", n_layers=3, d_model=64, n_heads=4,
    kv_heads=4, head_dim=16, d_ff=160, vocab=256,
    n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=2,
    first_k_dense=1, tie_embeddings=False, dtype="float32",
)

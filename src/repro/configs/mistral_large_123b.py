"""Mistral-Large-2407 123B (hf mistralai/Mistral-Large-Instruct-2407,
unverified tier): deep dense GQA transformer."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
    kv_heads=8, head_dim=128, d_ff=28672, vocab=32768,
    rope_theta=1e6, tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="mistral-large-123b-smoke", n_layers=3, d_model=64, n_heads=8,
    kv_heads=2, head_dim=8, d_ff=160, vocab=256, tie_embeddings=False,
    dtype="float32",
)

"""CodeQwen1.5-7B (hf Qwen/CodeQwen1.5-7B): qwen1.5-arch dense MHA (kv=heads)."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="codeqwen1.5-7b", n_layers=32, d_model=4096, n_heads=32, kv_heads=32,
    head_dim=128, d_ff=13440, vocab=92416, qkv_bias=True,
    rope_theta=1e6, tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="codeqwen1.5-7b-smoke", n_layers=3, d_model=64, n_heads=4, kv_heads=4,
    head_dim=16, d_ff=160, vocab=256, qkv_bias=True, tie_embeddings=False,
    dtype="float32",
)

"""Qwen2-VL-7B (arXiv:2409.12191): dense GQA backbone with M-RoPE
(sections 16/24/24 of the 128-dim head, in half-dim units).  The vision
frontend is a STUB per the assignment: input_specs() provides precomputed
patch embeddings + 3-D position ids."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b", n_layers=28, d_model=3584, n_heads=28, kv_heads=4,
    head_dim=128, d_ff=18944, vocab=152064, qkv_bias=True,
    input_mode="embeds", mrope_sections=(16, 24, 24), rope_theta=1e6,
    tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="qwen2-vl-7b-smoke", n_layers=3, d_model=64, n_heads=4, kv_heads=2,
    head_dim=16, d_ff=160, vocab=256, qkv_bias=True,
    input_mode="embeds", mrope_sections=(2, 3, 3), tie_embeddings=False,
    dtype="float32",
)

"""Whisper-large-v3 (arXiv:2212.04356, unverified tier): encoder-decoder,
32+32 layers, d=1280, 20 heads, LayerNorm+GELU, QKV bias.  The conv/mel
frontend is a STUB: input_specs() provides precomputed frame embeddings
(1500 frames, the post-conv length).  Sinusoidal positions stand in for the
learned decoder positions (frontend-stub simplification, DESIGN.md)."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3", family="encdec", n_layers=32, encoder_layers=32,
    d_model=1280, n_heads=20, kv_heads=20, head_dim=64, d_ff=5120,
    vocab=51866, qkv_bias=True, encoder_seq=1500,
    tie_embeddings=True, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="whisper-large-v3-smoke", family="encdec", n_layers=2,
    encoder_layers=2, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
    d_ff=160, vocab=256, qkv_bias=True, encoder_seq=32,
    tie_embeddings=True, dtype="float32",
)

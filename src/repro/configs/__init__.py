"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the FULL published config (dry-run only —
never instantiated on CPU); ``get_config(name, reduced=True)`` returns the
same-family reduced config used by the per-arch smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.lm import ModelConfig

ARCHS = [
    "deepseek_moe_16b",
    "deepseek_v3_671b",
    "qwen2_5_14b",
    "codeqwen1_5_7b",
    "mistral_large_123b",
    "internlm2_1_8b",
    "jamba_v0_1_52b",
    "qwen2_vl_7b",
    "whisper_large_v3",
    "rwkv6_7b",
]

# assigned input-shape set (LM-family): seq_len x global_batch
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.REDUCED if reduced else mod.FULL


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; returns (ok, reason_if_skipped).
    long_500k needs sub-quadratic sequence mixing (DESIGN.md
    §Arch-applicability)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("full quadratic attention at 512k context is not "
                       "serviceable; skipped per assignment note")
    return True, ""

"""RWKV-6 (Finch) 7B (arXiv:2404.05892): attention-free, data-dependent
decay linear recurrence; head_dim 64 (64 heads at d=4096); channel-mix FFN."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="rwkv6-7b", n_layers=32, d_model=4096, n_heads=64, kv_heads=64,
    head_dim=64, d_ff=14336, vocab=65536, layer_pattern="rwkv",
    subquadratic=True, rwkv_chunk=128, tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="rwkv6-7b-smoke", n_layers=2, d_model=128, n_heads=2, kv_heads=2,
    head_dim=64, d_ff=256, vocab=256, layer_pattern="rwkv",
    subquadratic=True, rwkv_chunk=8, tie_embeddings=False, dtype="float32",
)

"""DeepSeek-V3 671B (arXiv:2412.19437).  MLA attention (q_lora 1536,
kv_lora 512, qk 128+64 rope, v 128); 1 shared + 256 routed top-8 experts,
first 3 layers dense (official dense d_ff=18432, expert d_ff=2048);
multi-token prediction head."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
    attn_type="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, d_ff_expert=2048, n_shared_experts=1,
    first_k_dense=3, mtp=True, rope_theta=1e4, tie_embeddings=False,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="deepseek-v3-671b-smoke", n_layers=4, d_model=64, n_heads=4,
    kv_heads=4, head_dim=16, d_ff=160, vocab=256,
    attn_type="mla", q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=1,
    first_k_dense=1, mtp=True, tie_embeddings=False, dtype="float32",
)

"""Jamba-v0.1 52B (arXiv:2403.19887): Mamba+attention 1:7 interleave
(1 attention layer per 8), MoE 16 experts top-2 on every other layer."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="jamba-v0.1-52b", n_layers=32, d_model=4096, n_heads=32, kv_heads=8,
    head_dim=128, d_ff=14336, vocab=65536,
    layer_pattern="jamba", n_experts=16, top_k=2, d_ff_expert=14336,
    mamba_d_state=16, mamba_expand=2, subquadratic=True,
    tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, n_heads=4, kv_heads=2,
    head_dim=16, d_ff=160, vocab=256,
    layer_pattern="jamba", n_experts=4, top_k=2, d_ff_expert=160,
    mamba_d_state=4, mamba_expand=2, subquadratic=True,
    tie_embeddings=False, dtype="float32",
)

"""Qwen2.5-14B (hf Qwen/Qwen2.5-14B): dense GQA transformer with QKV bias."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40, kv_heads=8,
    head_dim=128, d_ff=13824, vocab=152064, qkv_bias=True,
    rope_theta=1e6, tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="qwen2.5-14b-smoke", n_layers=3, d_model=64, n_heads=4, kv_heads=2,
    head_dim=16, d_ff=160, vocab=256, qkv_bias=True, tie_embeddings=False,
    dtype="float32",
)

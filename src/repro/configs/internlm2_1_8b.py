"""InternLM2-1.8B (arXiv:2403.17297): dense GQA transformer."""
from repro.models.lm import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16, kv_heads=8,
    head_dim=128, d_ff=8192, vocab=92544, rope_theta=1e6,
    tie_embeddings=False, dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="internlm2-1.8b-smoke", n_layers=3, d_model=64, n_heads=4, kv_heads=2,
    head_dim=16, d_ff=160, vocab=256, tie_embeddings=False, dtype="float32",
)

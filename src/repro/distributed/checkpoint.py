"""Fault-tolerant checkpointing.

Design (multi-host ready, exercised single-host in tests):
  * atomic: write to ``step_<N>.tmp/``, fsync, rename to ``step_<N>/`` —
    a crash mid-write never corrupts the restore set
  * async: a background thread serializes device_get'd arrays so the train
    loop only blocks for the host copy, not the disk write
  * integrity: every array file carries a crc32 in the manifest; restore
    validates and falls back to the previous step on mismatch
  * resharding restore: arrays are saved as full (host-replicated) numpy;
    ``restore`` accepts a target sharding tree and uses
    jax.device_put(..., sharding) so the same checkpoint restores onto any
    mesh (elastic scaling path)
  * aux payload: ``save(..., aux=...)`` pickles an arbitrary host-side
    object (training cursor, sampler draw count, PlanCache state) next to
    the array tree with its own crc — the recovery contract for the
    mini-batch loop (train/gnn_steps.py) is that params + aux together
    reproduce the uninterrupted run bit-identically from the cursor
"""
from __future__ import annotations

import json
import os
import pickle
import re
import shutil
import threading
import time
import zlib
from typing import Any

import jax
import numpy as np

from repro.obs import Telemetry


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True,
                 telemetry: Telemetry | None = None):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self.tele = telemetry if telemetry is not None else Telemetry()
        self._saves = self.tele.metrics.counter("checkpoint.saves")
        self._write_s = self.tele.metrics.histogram("checkpoint.write_s")
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)
        # a crash mid-write leaves a step_<N>.tmp/ behind; it was never
        # renamed, so it is not a restore candidate — GC it up front (no
        # writer can be live in __init__, so this never races a save)
        for name in os.listdir(directory):
            if re.fullmatch(r"step_\d+\.tmp", name):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, aux: Any = None,
             blocking: bool = False) -> None:
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self.wait()   # never two writers
        if self.async_write and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, aux), daemon=True,
                name="ckpt-writer")
            self._thread.start()
        else:
            self._write(step, host_tree, aux)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, aux: Any = None) -> None:
        t0 = time.perf_counter()
        with self.tele.tracer.span("checkpoint.write", cat="io", step=step):
            self._write_inner(step, host_tree, aux)
        self._saves.inc()
        self._write_s.observe(time.perf_counter() - t0)

    def _write_inner(self, step: int, host_tree, aux: Any = None) -> None:
        tmp = os.path.join(self.dir, f"step_{step:012d}.tmp")
        final = os.path.join(self.dir, f"step_{step:012d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "arrays": {}}
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **{k: v for k, v in flat})
        with open(os.path.join(tmp, "arrays.npz"), "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["npz_crc32"] = crc
        manifest["keys"] = [k for k, _ in flat]
        if aux is not None:
            blob = pickle.dumps(aux, protocol=pickle.HIGHEST_PROTOCOL)
            with open(os.path.join(tmp, "aux.pkl"), "wb") as f:
                f.write(blob)
            manifest["aux_crc32"] = zlib.crc32(blob)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step_{step:012d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            with open(os.path.join(d, "arrays.npz"), "rb") as f:
                crc = zlib.crc32(f.read())
            if crc != manifest["npz_crc32"]:
                return False
            if "aux_crc32" in manifest:
                with open(os.path.join(d, "aux.pkl"), "rb") as f:
                    if zlib.crc32(f.read()) != manifest["aux_crc32"]:
                        return False
            return True
        except (OSError, KeyError, json.JSONDecodeError):
            return False

    def latest_valid_step(self) -> int | None:
        for s in reversed(self.all_steps()):
            if self._valid(s):
                return s
        return None

    def load_aux(self, step: int | None = None) -> Any:
        """Unpickle the aux payload saved with ``step`` (latest valid step
        when None); None when the checkpoint carries no aux."""
        if step is None:
            step = self.latest_valid_step()
            if step is None:
                raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:012d}", "aux.pkl")
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return pickle.load(f)

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``tree_like``; optionally place onto
        ``shardings`` (a matching tree of NamedSharding) — this is the
        elastic/re-mesh path."""
        if step is None:
            step = self.latest_valid_step()
            if step is None:
                raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:012d}")
        data = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = _flatten_with_paths(tree_like)
        leaves = []
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(flat))
        for (key, like), shd in zip(flat, shard_flat):
            arr = data[key]
            assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
        return jax.tree.unflatten(treedef, leaves), step

"""Fault tolerance + straggler mitigation for the multi-host training loop.

On a real 1000-node cluster these hooks connect to the coordination service;
here every mechanism is implemented and unit-tested against simulated
heartbeats / step-time streams, and the training loop (launch/train.py)
drives them for real on the CPU host.

Components:
  HeartbeatMonitor  -- per-host liveness with timeout -> dead-host set
  StragglerDetector -- per-host step-time EWMA; z-score over the fleet
                       median flags stragglers (mitigation: demote the host's
                       data shard, or trigger elastic re-mesh)
  reassign_shards   -- deterministic data-shard reassignment when hosts die:
                       surviving hosts take over orphaned shards round-robin
                       (restart-stable: pure function of (n_shards, alive))
  RetryPolicy       -- exponential-backoff step retry for transient failures
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    _last: dict = field(default_factory=dict)

    def beat(self, host: int, now: float | None = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout_s)


@dataclass
class StragglerDetector:
    """EWMA step-time per host; a host is a straggler when its smoothed step
    time exceeds ``threshold`` x the fleet median."""
    alpha: float = 0.2
    threshold: float = 1.5
    min_samples: int = 3
    _ewma: dict = field(default_factory=dict)
    _count: dict = field(default_factory=dict)

    def observe(self, host: int, step_seconds: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (step_seconds if prev is None
                            else self.alpha * step_seconds + (1 - self.alpha) * prev)
        self._count[host] = self._count.get(host, 0) + 1

    def stragglers(self) -> list[int]:
        ready = {h: t for h, t in self._ewma.items()
                 if self._count[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = sorted(ready.values())[len(ready) // 2]
        return sorted(h for h, t in ready.items() if t > self.threshold * med)


def reassign_shards(n_shards: int, alive_hosts: list[int]) -> dict[int, list[int]]:
    """Deterministic shard->host map: shard i goes to alive_hosts[i % n].
    Any two hosts computing this agree without communication."""
    assert alive_hosts, "no hosts alive"
    hosts = sorted(alive_hosts)
    out: dict[int, list[int]] = {h: [] for h in hosts}
    for s in range(n_shards):
        out[hosts[s % len(hosts)]].append(s)
    return out


@dataclass
class RetryPolicy:
    max_retries: int = 3
    base_delay_s: float = 1.0
    backoff: float = 2.0

    def run(self, fn, *args, on_retry=None, _sleep=time.sleep, **kwargs):
        delay = self.base_delay_s
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except Exception:
                if attempt == self.max_retries:
                    raise
                if on_retry is not None:
                    on_retry(attempt)
                _sleep(delay)
                delay *= self.backoff

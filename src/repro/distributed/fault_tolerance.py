"""Fault tolerance + straggler mitigation for the training loops.

On a real 1000-node cluster these hooks connect to the coordination service;
here every mechanism is implemented and unit-tested against simulated
heartbeats / step-time streams, and the training loops (launch/train.py,
train/gnn_steps.py) drive them for real on the CPU host.

Components:
  HeartbeatMonitor  -- per-host liveness with timeout -> dead-host set;
                       reported dead hosts can be pruned so a long-dead
                       host is not re-reported forever
  StragglerDetector -- per-host step-time EWMA; z-score over the fleet
                       median flags stragglers (mitigation: demote the host's
                       data shard, or trigger elastic re-mesh)
  reassign_shards   -- deterministic data-shard reassignment when hosts die:
                       surviving hosts take over orphaned shards round-robin
                       (restart-stable: pure function of (n_shards, alive))
  RetryPolicy       -- exponential-backoff retry for transient failures,
                       with an interruptible backoff (``cancel`` event) and
                       a fatal-vs-transient classifier (``retryable``) so
                       real bugs fail fast instead of burning retries
  TransientError /
  default_transient -- the marker + default classifier the mini-batch
                       pipeline uses for per-item worker retries
  FaultPlan         -- deterministic fault-injection harness: worker
                       exceptions, Pallas kernel compile/execute failures,
                       non-finite losses, and simulated crashes at chosen
                       batch indices, driving the robustness tests and bench
"""
from __future__ import annotations

import dataclasses
import re
import threading
import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    _last: dict = field(default_factory=dict)

    def beat(self, host: int, now: float | None = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def forget(self, host: int) -> None:
        """Drop a host from liveness tracking (it was replaced, drained, or
        its death has been handled) so :meth:`dead_hosts` stops reporting
        it.  A later :meth:`beat` re-registers it fresh."""
        self._last.pop(host, None)

    def dead_hosts(self, now: float | None = None,
                   prune: bool = False) -> list[int]:
        """Hosts whose last beat is older than ``timeout_s``.

        With ``prune=True`` the reported hosts are forgotten in the same
        call (report-once semantics): without pruning, a host that died an
        hour ago is re-reported on every poll and the caller re-triggers
        shard reassignment forever."""
        now = time.monotonic() if now is None else now
        dead = sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)
        if prune:
            for h in dead:
                self.forget(h)
        return dead

    def alive_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout_s)


@dataclass
class StragglerDetector:
    """EWMA step-time per host; a host is a straggler when its smoothed step
    time exceeds ``threshold`` x the fleet median."""
    alpha: float = 0.2
    threshold: float = 1.5
    min_samples: int = 3
    _ewma: dict = field(default_factory=dict)
    _count: dict = field(default_factory=dict)

    def observe(self, host: int, step_seconds: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (step_seconds if prev is None
                            else self.alpha * step_seconds + (1 - self.alpha) * prev)
        self._count[host] = self._count.get(host, 0) + 1

    def stragglers(self) -> list[int]:
        ready = {h: t for h, t in self._ewma.items()
                 if self._count[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = sorted(ready.values())[len(ready) // 2]
        return sorted(h for h, t in ready.items() if t > self.threshold * med)


def reassign_shards(n_shards: int, alive_hosts: list[int]) -> dict[int, list[int]]:
    """Deterministic shard->host map: shard i goes to alive_hosts[i % n].
    Any two hosts computing this agree without communication."""
    assert alive_hosts, "no hosts alive"
    hosts = sorted(alive_hosts)
    out: dict[int, list[int]] = {h: [] for h in hosts}
    for s in range(n_shards):
        out[hosts[s % len(hosts)]].append(s)
    return out


# ---------------------------------------------------------------------------
# Transient-vs-fatal classification
# ---------------------------------------------------------------------------

class TransientError(RuntimeError):
    """Marker for failures worth retrying (flaky I/O, injected worker
    faults).  Anything not classified transient is a real bug and must
    fail fast — retrying a deterministic exception just repeats it
    ``max_retries`` times and then hides the first stack trace."""


def default_transient(exc: BaseException) -> bool:
    """The mini-batch pipeline's retry classifier: explicit markers plus
    the OS-level failure classes that are genuinely environmental."""
    return isinstance(exc, (TransientError, OSError, TimeoutError,
                            ConnectionError))


@dataclass
class RetryPolicy:
    max_retries: int = 3
    base_delay_s: float = 1.0
    backoff: float = 2.0
    # decorrelated jitter (Brooker, "Exponential Backoff and Jitter"):
    # each wait draws uniform(base, 3 * previous_wait) capped at
    # max_delay_s, so concurrent callers that failed together (a burst of
    # serving requests hitting one flaky build) spread their retries out
    # instead of re-arriving in lockstep as a retry storm.  Off by default
    # (plain exponential ladder, bit-reproducible timing); with it on,
    # determinism comes from ``seed``: the Nth ``run()`` call on this
    # policy draws from stream (seed, N), a pure function of call order —
    # tests replay exact delay sequences, while concurrent calls still
    # decorrelate because each holds its own stream.
    jitter: bool = False
    max_delay_s: float | None = None
    seed: int | None = None
    # optional obs.Tracer: each backoff wait records a "retry.backoff" span
    # on the waiting thread (attempt + delay visible in the trace)
    tracer: object = None
    _run_count: int = field(default=0, init=False, repr=False,
                            compare=False)
    _count_lock: threading.Lock = field(default_factory=threading.Lock,
                                        init=False, repr=False,
                                        compare=False)

    def _wait(self, delay, attempt, _sleep, cancel):
        if _sleep is not None:
            _sleep(delay)
        elif cancel is not None:
            if cancel.wait(delay):   # interruptible backoff
                raise                # noqa: PLE0704 — re-raise active exc
        else:
            time.sleep(delay)

    def _jitter_rng(self) -> np.random.Generator:
        """One rng stream per run() call: deterministic under ``seed``
        (stream i belongs to the i-th call, whatever thread makes it),
        OS-entropy fresh when seed is None."""
        with self._count_lock:
            i = self._run_count
            self._run_count += 1
        if self.seed is None:
            return np.random.default_rng()
        return np.random.default_rng(np.random.SeedSequence((self.seed, i)))

    def delays(self, rng: np.random.Generator | None = None) -> list[float]:
        """The full backoff-delay ladder one ``run()`` would use: plain
        exponential without jitter, decorrelated-jitter draws with it
        (pass the rng to inspect a specific stream; tests)."""
        cap = (self.max_delay_s if self.max_delay_s is not None
               else self.base_delay_s * self.backoff ** self.max_retries)
        if self.jitter and rng is None:
            rng = self._jitter_rng()
        out, delay = [], self.base_delay_s
        for _ in range(self.max_retries):
            if self.jitter:
                delay = min(cap, float(rng.uniform(self.base_delay_s,
                                                   3.0 * delay)))
                out.append(delay)
            else:
                out.append(min(delay, cap))
                delay *= self.backoff
        return out

    def run(self, fn, *args, on_retry=None, _sleep=None, cancel=None,
            retryable=None, **kwargs):
        """Call ``fn`` with bounded backoff retries (exponential, or
        decorrelated-jitter when ``jitter=True``).

        ``retryable(exc) -> bool`` classifies failures; a non-retryable
        exception re-raises immediately (fatal-fails-fast).  ``cancel`` is
        a ``threading.Event``: the backoff waits on it instead of sleeping,
        so a shutdown mid-backoff re-raises promptly rather than pinning a
        worker thread for the rest of the delay ladder.  ``_sleep``
        overrides the wait entirely (tests)."""
        ladder = iter(self.delays())
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except Exception as exc:
                if attempt == self.max_retries:
                    raise
                if retryable is not None and not retryable(exc):
                    raise
                if cancel is not None and cancel.is_set():
                    raise
                if on_retry is not None:
                    on_retry(attempt)
                delay = next(ladder)
                if self.tracer is not None:
                    with self.tracer.span("retry.backoff", cat="fault",
                                          attempt=attempt, delay_s=delay):
                        self._wait(delay, attempt, _sleep, cancel)
                else:
                    self._wait(delay, attempt, _sleep, cancel)


# ---------------------------------------------------------------------------
# Deterministic fault injection (tests + robustness bench)
# ---------------------------------------------------------------------------

class SimulatedCrash(RuntimeError):
    """Raised by :class:`FaultPlan` after the chosen batch commits — the
    process 'dies' with the checkpoint on disk, and the resume path must
    reproduce the uninterrupted run bit-identically."""


class InjectedWorkerFault(TransientError):
    """Transient worker failure injected into the batch-build stage."""


# marker embedded in injected kernel failures so the quarantine path can
# attribute the failure to one kernel even through jax's exception wrapping
_KERNEL_FAULT_MARK = "__fault_kernel__"
_KERNEL_FAULT_RE = re.compile(_KERNEL_FAULT_MARK + r":(\w+)")


def fault_kernel_from(exc: BaseException) -> str | None:
    """Kernel name attributed by an injected-fault marker anywhere in the
    exception chain (jax wraps both trace-time and runtime errors)."""
    seen = set()
    while exc is not None and id(exc) not in seen:
        seen.add(id(exc))
        m = _KERNEL_FAULT_RE.search(str(exc))
        if m:
            return m.group(1)
        exc = exc.__cause__ or exc.__context__
    return None


def drain_effect_tokens() -> None:
    """Block on pending jax effect tokens, swallowing errors from aborted
    dispatches.  A computation that failed mid-flight leaves a poisoned
    runtime token behind; jax's ``wait_for_tokens`` atexit hook would
    re-raise its error at interpreter exit, and ``RuntimeTokenSet.
    block_until_ready`` has no try/finally around its ``clear()``, so
    once poisoned the set can never drain itself — fall back to clearing
    the (thread-local) token set directly."""
    import jax
    try:
        jax.effects_barrier()
    except Exception:
        try:
            from jax._src.dispatch import runtime_tokens
            runtime_tokens.clear()
        except Exception:
            pass


class KernelFault(RuntimeError):
    """Injected Pallas kernel failure (compile- or execute-time)."""


def _raise_kernel_fault(name: str, mode: str):
    raise KernelFault(
        f"{_KERNEL_FAULT_MARK}:{name} injected {mode} failure")


@dataclass
class FaultPlan:
    """Deterministic fault schedule for one training run.

    Every injection is keyed by the *global* batch index (or kernel name),
    so a plan replays identically under any pipeline depth / worker count /
    retry schedule — which is what lets the tests assert bit-identical
    recovery instead of 'it eventually finished'.

      worker_faults   -- batch index -> how many times that batch's build
                         raises :class:`InjectedWorkerFault` (transient:
                         the retry path absorbs them)
      fatal_at        -- batch indices whose build raises ValueError once
                         (non-transient: must fail fast through any retry)
      kernel_faults   -- kernel name -> "compile" | "execute".  Activated
                         by :meth:`activate` (patches the kernel registry):
                         "compile" raises at trace/lower time, "execute"
                         compiles fine and fails at run time via
                         ``jax.pure_callback`` — the two failure surfaces
                         the quarantine path must cover
      nonfinite_at    -- batch indices whose features are corrupted to NaN
                         (flows through the jitted step without a retrace;
                         the non-finite guard must skip the update)
      crash_at        -- batch index after whose commit the loop raises
                         :class:`SimulatedCrash` (None = never)
    """
    worker_faults: dict = field(default_factory=dict)
    fatal_at: frozenset | set = field(default_factory=set)
    kernel_faults: dict = field(default_factory=dict)
    nonfinite_at: frozenset | set = field(default_factory=set)
    crash_at: int | None = None
    # counters (observable by tests/bench)
    injected_worker: int = 0
    injected_fatal: int = 0
    injected_nonfinite: int = 0
    kernel_trips: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)
    _pending: dict = field(default_factory=dict, repr=False)
    _saved_specs: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        self._pending = dict(self.worker_faults)
        self._fatal_pending = set(self.fatal_at)

    # -- build-stage hooks (driven by train_minibatch) ----------------------

    def on_built(self, index: int, batch):
        """Called after batch ``index``'s build on whatever thread built it.
        May raise (worker fault) or return a corrupted batch (non-finite
        injection); retries re-enter here, so injected failure counts are
        consumed under the lock."""
        with self._lock:
            if index in self._fatal_pending:
                self._fatal_pending.discard(index)
                self.injected_fatal += 1
                raise ValueError(
                    f"injected fatal (non-transient) fault at batch {index}")
            left = self._pending.get(index, 0)
            if left > 0:
                self._pending[index] = left - 1
                self.injected_worker += 1
                raise InjectedWorkerFault(
                    f"injected transient worker fault at batch {index} "
                    f"({left - 1} left)")
            if index in self.nonfinite_at:
                self.injected_nonfinite += 1
                batch = dataclasses.replace(
                    batch, features=np.full_like(batch.features, np.nan))
        return batch

    def on_committed(self, index: int) -> None:
        """Called after batch ``index``'s update committed (and any due
        checkpoint was scheduled) — the simulated kill point."""
        if self.crash_at is not None and index == self.crash_at:
            raise SimulatedCrash(f"injected crash after batch {index}")

    # -- kernel fault patching ---------------------------------------------

    def _wrap_device_fn(self, name: str, mode: str, fn):
        if fn is None:
            return None
        if mode == "compile":
            def broken(*args, **kwargs):
                with self._lock:
                    self.kernel_trips += 1
                _raise_kernel_fault(name, "compile")
            return broken

        def exec_broken(*args, **kwargs):
            import jax
            out = fn(*args, **kwargs)

            def die(*_):
                with self._lock:
                    self.kernel_trips += 1
                _raise_kernel_fault(name, "execute")

            # compile succeeds; the callback detonates at execution time
            # (out may be any pytree — matvec_acc variants return tuples).
            # The detonator needs a JVP rule: the training step
            # differentiates through the kernel, and a bare pure_callback
            # would raise "no JVP" at *trace* time — the wrong failure
            # surface.  Tangents pass through untouched; their values never
            # matter because the primal always raises at run time.
            shapes = jax.tree.map(
                lambda o: jax.ShapeDtypeStruct(o.shape, o.dtype), out)

            @jax.custom_jvp
            def bomb(o):
                return jax.pure_callback(die, shapes, o)

            @bomb.defjvp
            def bomb_jvp(primals, tangents):
                return (jax.pure_callback(die, shapes, primals[0]),
                        tangents[0])

            return bomb(out)
        return exec_broken

    def activate(self):
        """Context manager patching the kernel registry so the named
        kernels fail.  Use around the training call:

            with plan.activate():
                train_minibatch(..., fault_plan=plan)
        """
        return _PatchedKernels(self)


class _PatchedKernels:
    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._saved: dict = {}

    def __enter__(self):
        from repro.kernels.registry import REGISTRY
        for name, mode in self.plan.kernel_faults.items():
            spec = REGISTRY.get(name)
            self._saved[name] = spec
            wrap = lambda fn, n=name, m=mode: (
                self.plan._wrap_device_fn(n, m, fn))
            REGISTRY._specs[name] = dataclasses.replace(
                spec,
                matvec=wrap(spec.matvec),
                matvec_acc=wrap(spec.matvec_acc),
                fused_matvec=wrap(spec.fused_matvec),
                fused_matvec_acc=wrap(spec.fused_matvec_acc),
                fused_dual_matvec=wrap(spec.fused_dual_matvec),
                fused_dual_matvec_acc=wrap(spec.fused_dual_matvec_acc))
        return self.plan

    def __exit__(self, *exc):
        from repro.kernels.registry import REGISTRY
        for name, spec in self._saved.items():
            REGISTRY._specs[name] = spec
        self._saved.clear()

"""Elastic scaling: re-mesh onto a changed device set and reshard state.

Protocol (driven by launch/train.py when the fault-tolerance layer reports a
changed healthy-host set):
  1. pick the largest supported mesh that fits the healthy device count
     (``best_mesh_shape``),
  2. rebuild the mesh + sharding trees,
  3. restore the latest checkpoint *onto the new shardings*
     (CheckpointManager.restore(shardings=...)), preserving exact state,
  4. rescale the data shards deterministically (fault_tolerance.reassign_shards)
     and continue.

Supported meshes keep the model axis intact when possible (TP degree is a
property of the weights' layout on disk only insofar as divisibility; our
checkpoints are stored unsharded so any factorization works).
"""
from __future__ import annotations

import math


def best_mesh_shape(n_devices: int, model_parallel: int = 16,
                    multi_pod_at: int = 512) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) factorization under the device budget.
    Prefers keeping the model axis at ``model_parallel``; degrades it by
    powers of two when the fleet is too small."""
    mp = model_parallel
    while mp > 1 and n_devices < mp:
        mp //= 2
    usable = (n_devices // mp) * mp
    data = usable // mp
    if usable >= multi_pod_at and data % 2 == 0:
        return (2, data // 2, mp), ("pod", "data", "model")
    return (data, mp), ("data", "model")


def plan_rescale(old_devices: int, new_devices: int,
                 global_batch: int) -> dict:
    """Decide how a changed fleet affects the step: keep the global batch
    whenever divisible (per-device batch grows/shrinks), otherwise scale it
    to the nearest divisible value and rescale LR linearly."""
    shape, axes = best_mesh_shape(new_devices)
    n_data = math.prod(shape) // shape[-1]
    if global_batch % n_data == 0:
        gb = global_batch
    else:
        gb = max((global_batch // n_data), 1) * n_data
    return dict(mesh_shape=shape, mesh_axes=axes, global_batch=gb,
                lr_scale=gb / global_batch)

"""Gradient compression hooks (off by default).

Methods:
  none     -- identity
  bf16     -- cast gradients to bf16 before the (all-)reduce: halves the
              gradient-collective bytes; the optimizer re-expands to fp32
  topk_ef  -- per-tensor magnitude top-k sparsification with error feedback
              (the dropped residual is carried to the next step), Deep
              Gradient Compression style (arXiv:1712.01887)

The hook sits between grad computation and the optimizer inside train_step,
so under pjit the compressed representation is what crosses the data axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, method: str = "none", ef_state=None, topk_frac: float = 0.01):
    """Returns (compressed_grads, new_ef_state)."""
    if method == "none":
        return grads, ef_state
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(g.dtype),
                            grads), ef_state
    if method == "topk_ef":
        assert ef_state is not None

        def one(g, e):
            acc = g.astype(jnp.float32) + e
            flat = acc.reshape(-1)
            k = max(int(flat.shape[0] * topk_frac), 1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            mask = jnp.abs(acc) >= thresh
            sent = jnp.where(mask, acc, 0.0)
            return sent.astype(g.dtype), acc - sent

        outs = jax.tree.map(one, grads, ef_state)
        sent = jax.tree.map(lambda o: o[0], outs,
                            is_leaf=lambda t: isinstance(t, tuple))
        resid = jax.tree.map(lambda o: o[1], outs,
                             is_leaf=lambda t: isinstance(t, tuple))
        return sent, resid
    raise ValueError(method)

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, and extract the
roofline inputs (FLOPs / bytes from cost_analysis, collective bytes from the
HLO text) without ever allocating real tensors.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                        # all cells, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod            # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch rwkv6-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json
"""
import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import numpy as np  # noqa: E402

from repro import configs                       # noqa: E402
from repro.launch import mesh as mesh_mod       # noqa: E402
from repro.launch import sharding, specs        # noqa: E402
from repro.models import lm                     # noqa: E402
from repro.optim import adamw                   # noqa: E402
from repro.train import steps                   # noqa: E402


# -- HLO collective-bytes extraction -----------------------------------------

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"((?:[a-z0-9-]+)?(?:f|bf|s|u|pred)\d+(?:\[[\d,]*\])?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)

_SHAPE_RE = re.compile(r"(f|bf|s|u|pred)(\d+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f32": 4, "f64": 8, "f16": 2, "bf16": 2, "s32": 4, "s64": 8,
                "s8": 1, "u8": 1, "u32": 4, "pred8": 1}


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape like 'bf16[256,4096]' (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        kind, bits, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * (int(bits) // 8)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO, by kind.
    (Output bytes approximate the wire traffic within a small constant
    factor per algorithm; we report them per kind so the roofline's
    collective term can weight them.)"""
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r"^(?:\S+\s*=\s*)?((?:\([^)]*\)|\S+?))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", s)
        if not m:
            continue
        shape_str, kind = m.groups()
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items())
    out["counts"] = count
    return out


# -- one cell -----------------------------------------------------------------

def dryrun_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True,
                model_overrides: dict | None = None,
                rules_overrides: dict | None = None) -> dict:
    cfg = configs.get_config(arch)
    if model_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **model_overrides)
    ok, reason = configs.shape_applicable(cfg, shape_name)
    if not ok:
        return dict(arch=arch, shape=shape_name, status="skipped",
                    reason=reason)
    sh = configs.SHAPES[shape_name]
    mode = sh["mode"]
    t0 = time.perf_counter()

    # long-context decode with batch 1 cannot shard the batch: shard the
    # KV/sequence axis over the batch mesh axes instead
    shard_seq = (mode == "decode" and sh["batch"] <
                 np.prod([mesh.shape[a] for a in mesh.axis_names
                          if a != "model"]))
    rules = sharding.default_rules(mesh, shard_seq=shard_seq)
    if rules_overrides:
        rules.update(rules_overrides)

    pspecs = sharding.tree_shardings(specs.params_shapes(cfg),
                                     lm.param_specs(cfg), mesh, rules)

    with mesh:
        if mode == "train":
            batch_specs = specs.train_batch_specs(cfg, sh["seq"], sh["batch"])
            bshard = sharding.batch_specs(batch_specs, mesh, rules)
            opt_shapes = specs.opt_state_shapes(cfg)
            ospecs = dict(m=pspecs, v=pspecs,
                          step=jax.sharding.NamedSharding(
                              mesh, jax.sharding.PartitionSpec()))
            opt_cfg = adamw.OptConfig()
            fn = steps.make_train_step(cfg, opt_cfg)
            lowered = jax.jit(
                fn, in_shardings=(pspecs, ospecs, bshard),
                out_shardings=(pspecs, ospecs, None),
            ).lower(specs.params_shapes(cfg), opt_shapes, batch_specs)
        elif mode == "prefill":
            batch_specs = specs.prefill_batch_specs(cfg, sh["seq"], sh["batch"])
            bshard = sharding.batch_specs(batch_specs, mesh, rules)
            fn = steps.make_prefill_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(pspecs, bshard), out_shardings=None,
            ).lower(specs.params_shapes(cfg), batch_specs)
        else:  # decode
            cache_shapes, tok_spec, pos_spec = specs.decode_specs(
                cfg, sh["seq"], sh["batch"])
            cspecs = sharding.tree_shardings(cache_shapes,
                                             lm.cache_specs(cfg), mesh, rules)
            tshard = sharding.batch_specs(dict(t=tok_spec), mesh, rules)["t"]
            fn = steps.make_serve_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(pspecs, cspecs, tshard, None),
                out_shardings=(None, None, cspecs),
            ).lower(specs.params_shapes(cfg), cache_shapes, tok_spec, pos_spec)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # newer jax: one entry per computation
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    n_dev = int(np.prod(list(mesh.shape.values())))
    result = dict(
        arch=arch, shape=shape_name, status="ok", mode=mode,
        mesh="x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        n_devices=n_dev,
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=coll,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
            output_bytes=getattr(mem, "output_size_in_bytes", 0),
            temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
            generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", 0),
        ),
    )
    if verbose:
        tb = result["memory"]["temp_bytes"] / n_dev / 2**30
        print(f"  {arch:20s} {shape_name:12s} mesh={result['mesh']:8s} "
              f"flops={result['flops']:.3e} bytes={result['bytes_accessed']:.3e} "
              f"coll={coll['total']:.3e}B temp/dev={tb:.2f}GiB "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2x16x16 multi-pod mesh")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the SPerf execution profile (head padding, "
                         "flash/mamba Pallas cores, size-adaptive ZeRO-1)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(configs.SHAPES)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(mesh_mod.make_production_mesh(multi_pod=False))
    if args.multi_pod or args.multi_pod_only:
        meshes.append(mesh_mod.make_production_mesh(multi_pod=True))

    results = []
    for mesh in meshes:
        print(f"== mesh {dict(mesh.shape)} ==", flush=True)
        for arch in archs:
            for shape in shapes:
                try:
                    mo, ro = None, None
                    if args.optimized:
                        from repro.launch.profiles import optimized_overrides
                        cfg = configs.get_config(arch)
                        mo, ro = optimized_overrides(
                            cfg, configs.SHAPES[shape]["mode"],
                            mesh.shape["model"])
                        # Pallas cores can't lower on the CPU dry-run host;
                        # keep their XLA stand-ins for compile coverage
                        mo = {k: v for k, v in mo.items()
                              if k not in ("attn_core", "mamba_core", "wkv_core")}
                    results.append(dryrun_cell(arch, shape, mesh,
                                               model_overrides=mo,
                                               rules_overrides=ro))
                except Exception as e:  # a failure here is a bug in our system
                    traceback.print_exc()
                    results.append(dict(arch=arch, shape=shape,
                                        mesh=str(dict(mesh.shape)),
                                        status="FAILED", error=str(e)[-2000:]))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok / {n_skip} skipped / {n_fail} FAILED ==")
    for r in results:
        if r["status"] == "FAILED":
            print(f"  FAILED {r['arch']} {r['shape']}: {r['error'][:300]}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Logical-axis -> PartitionSpec resolution.

Parameters/caches/batches carry *logical* axis names (see layers/nn.py
docstring).  ``RULES`` maps logical names to mesh axes; ``spec_for`` resolves
one tensor, checking divisibility and never using a mesh axis twice within a
tensor (both would be sharding errors at lower time).  Non-divisible dims
fall back to replication -- e.g. kv_heads=8 cannot shard over model=16, so
KV projections replicate over model while the fused q/o projections still
TP-shard (head-padding to lift this is a §Perf hillclimb lever).
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# default logical->physical rules for the 2-D / 3-D production mesh.
# "embed" FSDP-shards over the data axis (ZeRO-3 style: all-gathered per
# layer under scan, overlapped by the XLA latency-hiding scheduler).
def default_rules(mesh: Mesh, *, shard_seq: bool = False) -> dict:
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return {
        "embed": ("data",),
        "mlp": ("model",),
        "qkv": ("model",),
        "kv": ("model",),
        "heads": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "layer": None,
        "batch": batch,
        "seq": ("model",) if shard_seq else None,
        # decode KV/sequence axis: sharded over the batch axes when the
        # batch itself is too small to fill them (long-context decode)
        "kv_seq": batch if shard_seq else None,
    }


def spec_for(shape: tuple[int, ...], logical: tuple, rules: dict,
             mesh: Mesh) -> P:
    axes: list = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        target = rules.get(name) if name is not None else None
        if target is None:
            axes.append(None)
            continue
        tgt = (target,) if isinstance(target, str) else tuple(target)
        tgt = tuple(a for a in tgt if a in mesh.axis_names and a not in used)
        size = math.prod(mesh.shape[a] for a in tgt) if tgt else 1
        if tgt and dim % size == 0:
            axes.append(tgt if len(tgt) > 1 else tgt[0])
            used.update(tgt)
        else:
            axes.append(None)
    return P(*axes)


def _is_logical(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in t)


def tree_shardings(tree_shapes: Any, tree_logical: Any, mesh: Mesh,
                   rules: dict | None = None) -> Any:
    """Resolve a pytree of ShapeDtypeStructs (or arrays) + matching logical
    spec tree into NamedShardings."""
    rules = rules or default_rules(mesh)

    def resolve(x, logical):
        if x is None or logical is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for(x.shape, logical, rules, mesh))

    return jax.tree.map(resolve, tree_shapes, tree_logical,
                        is_leaf=lambda t: t is None or _is_logical(t))


def batch_specs(batch_shapes: dict, mesh: Mesh,
                rules: dict | None = None) -> dict:
    """Shardings for an input batch: dim0 of every array is the global batch
    (except 'positions' (3,B,S) and scalars)."""
    rules = rules or default_rules(mesh)
    out = {}
    for k, v in batch_shapes.items():
        if v is None:
            out[k] = NamedSharding(mesh, P())
            continue
        if k == "positions" and len(v.shape) == 3:
            logical = (None, "batch", None)
        elif len(v.shape) == 0:
            logical = ()
        else:
            logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for(v.shape, logical, rules, mesh))
    return out


def count_unsharded_fallbacks(tree_shapes, tree_logical, mesh,
                              rules=None) -> list[str]:
    """Diagnostics: which logical axes silently fell back to replication
    (reported by the dry-run so nothing is truncated silently)."""
    rules = rules or default_rules(mesh)
    notes = []

    def walk(path, x, logical):
        if x is None or logical is None:
            return
        for dim, name in zip(x.shape, logical):
            if name is None:
                continue
            target = rules.get(name)
            if target is None:
                continue
            tgt = (target,) if isinstance(target, str) else tuple(target)
            tgt = tuple(a for a in tgt if a in mesh.axis_names)
            size = math.prod(mesh.shape[a] for a in tgt) if tgt else 1
            if size > 1 and dim % size != 0:
                notes.append(f"{path}: {name}={dim} !% {size} -> replicated")

    def rec(path, a, b):
        if b is None or _is_logical(b):
            walk(path, a, b)
        elif isinstance(b, dict):
            for k in b:
                rec(f"{path}/{k}", a[k] if a is not None else None, b[k])
        elif isinstance(b, (list, tuple)):
            for i, bb in enumerate(b):
                rec(f"{path}[{i}]", a[i] if a is not None else None, bb)

    rec("", tree_shapes, tree_logical)
    return sorted(set(notes))

"""ShapeDtypeStruct stand-ins for every model input: shardable, weak-type
correct, zero device allocation — what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: lm.ModelConfig, seq: int, batch: int) -> dict:
    dt = cfg.jdtype
    out = {}
    if cfg.family == "encdec":
        out["enc_embeds"] = SDS((batch, cfg.encoder_seq, cfg.d_model), dt)
        out["tokens"] = SDS((batch, seq), jnp.int32)
    elif cfg.input_mode == "embeds":
        out["embeds"] = SDS((batch, seq, cfg.d_model), dt)
        if cfg.mrope_sections is not None:
            out["positions"] = SDS((3, batch, seq), jnp.int32)
    else:
        out["tokens"] = SDS((batch, seq), jnp.int32)
    out["labels"] = SDS((batch, seq), jnp.int32)
    return out


def prefill_batch_specs(cfg: lm.ModelConfig, seq: int, batch: int) -> dict:
    out = train_batch_specs(cfg, seq, batch)
    out.pop("labels")
    return out


def decode_specs(cfg: lm.ModelConfig, s_max: int, batch: int):
    """(caches, tokens, pos) ShapeDtypeStructs for serve_step."""
    caches = jax.eval_shape(lambda: lm.init_cache(cfg, batch, s_max))
    if cfg.input_mode == "embeds":
        tokens = SDS((batch, 1, cfg.d_model), cfg.jdtype)
    else:
        tokens = SDS((batch, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return caches, tokens, pos


def params_shapes(cfg: lm.ModelConfig):
    return jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))


def opt_state_shapes(cfg: lm.ModelConfig):
    from repro.optim import adamw
    p = params_shapes(cfg)
    return jax.eval_shape(lambda: adamw.init_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p)))


def input_specs(arch: str, shape_name: str) -> dict:
    """Everything the dry-run needs for one (arch, shape) cell."""
    cfg = configs.get_config(arch)
    sh = configs.SHAPES[shape_name]
    mode = sh["mode"]
    out = dict(cfg=cfg, mode=mode, seq=sh["seq"], batch=sh["batch"])
    if mode == "train":
        out["batch_specs"] = train_batch_specs(cfg, sh["seq"], sh["batch"])
    elif mode == "prefill":
        out["batch_specs"] = prefill_batch_specs(cfg, sh["seq"], sh["batch"])
    else:
        caches, tokens, pos = decode_specs(cfg, sh["seq"], sh["batch"])
        out.update(cache_specs=caches, token_specs=tokens, pos_specs=pos)
    return out

"""Optimized execution profiles: the §Perf findings as first-class launcher
options (EXPERIMENTS.md §Perf documents the measurement behind each rule).

``optimized_overrides(cfg, mode, mesh_model=16)`` returns
(model_overrides, rules_overrides) implementing:

  1. head padding to the TP multiple when head counts are indivisible
     (qwen cell: 11.6x collective win; internlm2 decode cell: 34x),
  2. Pallas flash attention for full-sequence attention archs,
  3. Pallas selective scan for Mamba layers (jamba cell: 4.5x memory win),
  4. size-adaptive weight placement: ZeRO-1 (weights TP-only, replicated
     over data) when the TP shard fits HBM and the arch is not a hybrid
     whose re-partitioning regresses (jamba v3 refutation) — otherwise
     keep FSDP(data).
"""
from __future__ import annotations

from repro.models.lm import ModelConfig

HBM_BYTES = 16 * 2**30          # v5e
ZERO1_SAFETY = 0.5              # weights may use at most half of HBM


def _pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def padded_heads(cfg: ModelConfig, mesh_model: int) -> dict:
    out = {}
    if cfg.layer_pattern == "rwkv" or cfg.attn_type == "mla":
        return out    # rwkv: no attention; MLA: 128 heads already divide
    if cfg.n_heads % mesh_model:
        out["n_heads"] = _pad_to(cfg.n_heads, mesh_model)
    if cfg.kv_heads % mesh_model:
        kv = _pad_to(cfg.kv_heads, mesh_model)
        out["kv_heads"] = kv
        # GQA requires n_heads % kv_heads == 0
        nh = out.get("n_heads", cfg.n_heads)
        if nh % kv:
            out["n_heads"] = _pad_to(nh, kv)
    return out


def weights_fit_zero1(cfg: ModelConfig, mesh_model: int) -> bool:
    import numpy as np
    from repro.launch import specs
    import jax
    shapes = specs.params_shapes(cfg)
    n_bytes = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                  for s in jax.tree.leaves(shapes))
    return n_bytes / mesh_model < HBM_BYTES * ZERO1_SAFETY


def optimized_overrides(cfg: ModelConfig, mode: str,
                        mesh_model: int = 16) -> tuple[dict, dict | None]:
    model: dict = {}
    rules: dict | None = None
    model.update(padded_heads(cfg, mesh_model))
    if cfg.layer_pattern != "rwkv" and mode != "decode":
        model["attn_core"] = "flash"
    if cfg.layer_pattern == "jamba":
        model["mamba_core"] = "pallas"
    if cfg.layer_pattern == "rwkv":
        model["wkv_core"] = "pallas"
    hybrid = cfg.layer_pattern == "jamba"
    if not hybrid and weights_fit_zero1(cfg, mesh_model):
        rules = {"embed": None}      # ZeRO-1: weights TP-only
    return model, rules

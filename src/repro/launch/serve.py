"""CLI entry point for the resilient GNN inference server (repro.serve).

Trains a mini-batch model on a synthetic Table-1 dataset, warm-starts an
:class:`~repro.serve.InferenceServer` (optionally through a persisted
PlanCache snapshot), drives a short open-loop burst against it, and
prints the latency/shedding/degradation report:

  PYTHONPATH=src python -m repro.launch.serve --dataset cora --scale 0.2 \\
      --train-steps 20 --qps 200 --seconds 2 --deadline-ms 100 \\
      --plan-cache /tmp/plans.bin

The LM serving demo that used to live here moved to examples/serve_lm.py.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import gnn
from repro.graphs import graph as graph_mod
from repro.obs import Telemetry
from repro.serve import InferenceServer, ServeConfig
from repro.train.gnn_steps import train_minibatch


def build_server(dataset: str = "cora", scale: float = 0.2,
                 train_steps: int = 20, seed: int = 0,
                 batch_nodes: int = 32, fanouts: tuple = (4, 2),
                 model: str = "gcn", serve_cfg: ServeConfig | None = None,
                 telemetry: Telemetry | None = None,
                 verbose: bool = False) -> InferenceServer:
    """Train a small model and stand up a server over it, sharing the
    training PlanCache (committed plans + quarantine carry over)."""
    g = graph_mod.synth_dataset(dataset, scale=scale, seed=seed)
    cfg = gnn.GNNConfig(model=model, sampler="neighbor",
                        batch_nodes=batch_nodes, fanouts=tuple(fanouts),
                        hidden=16, seed=seed)
    res = train_minibatch(g, cfg, steps=train_steps, verbose=verbose,
                          eval_batches=1)
    return InferenceServer(g, cfg, res.params, serve_cfg=serve_cfg,
                           plan_cache=res.plan_cache, telemetry=telemetry)


def open_loop_burst(server: InferenceServer, qps: float, seconds: float,
                    deadline_s: float | None = None, seed: int = 0) -> list:
    """Open-loop load: submit at a fixed arrival rate regardless of
    completions (arrivals do not slow down when the server does — which
    is what makes overload visible instead of self-throttling).  Returns
    the futures; the server must be running (``server.start()``)."""
    rng = np.random.default_rng(seed)
    n = max(int(qps * seconds), 1)
    nodes = rng.integers(0, server.ego.graph.n, size=n)
    period = 1.0 / max(qps, 1e-9)
    futs = []
    t0 = time.monotonic()
    for i, node in enumerate(nodes):
        lag = t0 + i * period - time.monotonic()
        if lag > 0:
            time.sleep(lag)
        futs.append(server.submit(int(node), deadline_s))
    return futs


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--model", default="gcn", choices=("gcn", "gin", "sage"))
    ap.add_argument("--batch-nodes", type=int, default=32)
    ap.add_argument("--fanouts", type=int, nargs="+", default=[4, 2])
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--seconds", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=100.0)
    ap.add_argument("--queue-limit", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--plan-cache", default="",
                    help="PlanCache snapshot path: loaded before warmup, "
                         "saved after (cold-start mitigation)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", help="write the report here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    scfg = ServeConfig(deadline_s=args.deadline_ms / 1e3,
                       queue_limit=args.queue_limit,
                       max_batch=args.max_batch,
                       plan_cache_path=args.plan_cache, seed=args.seed)
    server = build_server(args.dataset, scale=args.scale,
                          train_steps=args.train_steps, seed=args.seed,
                          batch_nodes=args.batch_nodes,
                          fanouts=tuple(args.fanouts), model=args.model,
                          serve_cfg=scfg, verbose=args.verbose)
    warm = server.warmup(save=bool(args.plan_cache))
    print(f"warmup: loaded={warm['loaded']} new_traces={warm['new_traces']} "
          f"rungs={warm['rungs']}")
    with server:
        futs = open_loop_burst(server, args.qps, args.seconds,
                               seed=args.seed)
        for f in futs:
            f.result(timeout=scfg.deadline_s * 4 + 5)
    st = server.stats()
    lat = st["latency"]
    report = dict(
        qps_offered=args.qps,
        served=st["admitted"] - st["timeouts"] - st["errors"],
        shed=st["shed"], timeouts=st["timeouts"],
        shed_pct=st["shed_pct"], rung=st["rung"],
        degrades=st["degrades"], n_traces=st["n_traces"],
        p50_ms=lat["p50"] * 1e3, p99_ms=lat["p99"] * 1e3)
    print(f"served {report['served']}/{len(futs)} "
          f"(shed {st['shed']}, timeouts {st['timeouts']}) "
          f"p50 {report['p50_ms']:.1f}ms p99 {report['p99_ms']:.1f}ms "
          f"rung {st['rung']} traces {st['n_traces']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    main()

"""Production mesh construction.

Kept as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax initialization; smoke tests and
benchmarks must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (data, model); 2x16x16 = 512 chips across
    two pods (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic-scaling entry point: build any (data, model[, pod]) mesh from
    the currently visible devices (used by distributed/elastic.py when the
    healthy device set changes)."""
    return jax.make_mesh(shape, axes)


def host_local_mesh():
    """Single-process debug mesh over whatever devices exist."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))

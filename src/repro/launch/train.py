"""End-to-end LM training driver with the full fault-tolerance stack.

Runs for real on this host (reduced configs on CPU; full configs on a TPU
fleet) — checkpointing, straggler monitoring, deterministic data sharding,
and elastic re-mesh are all exercised by the loop, not just imported.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
      --reduced --steps 50 --seq 64 --batch 8 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data import pipeline as data_mod
from repro.distributed import checkpoint as ckpt_mod
from repro.distributed import fault_tolerance as ft
from repro.launch import mesh as mesh_mod, sharding
from repro.models import lm
from repro.optim import adamw
from repro.train import steps as steps_mod


def train(arch: str, *, reduced: bool = True, steps: int = 20, seq: int = 64,
          global_batch: int = 8, lr: float = 3e-4, accum: int = 1,
          ckpt_dir: str | None = None, ckpt_every: int = 10,
          grad_compression: str = "none", seed: int = 0,
          use_mesh=None, verbose: bool = True) -> dict:
    cfg = configs.get_config(arch, reduced=reduced)
    mesh = use_mesh or mesh_mod.host_local_mesh()
    rules = sharding.default_rules(mesh)

    pipe = data_mod.pipeline_for(cfg, seq, global_batch, seed=seed)
    opt_cfg = adamw.OptConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                              total_steps=steps)
    step_fn = steps_mod.make_train_step(cfg, opt_cfg, accum_steps=accum,
                                        grad_compression=grad_compression)

    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.init_state(params)
    if grad_compression == "topk_ef":
        from repro.distributed import compression
        opt_state["ef"] = compression.init_error_feedback(params)

    pspecs = sharding.tree_shardings(params, lm.param_specs(cfg), mesh, rules)
    params = jax.tree.map(jax.device_put, params, pspecs)

    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(ckpt_dir)
        latest = mgr.latest_valid_step()
        if latest is not None:
            (params, opt_state), start_step = mgr.restore(
                (params, opt_state), latest)
            start_step = latest
            if verbose:
                print(f"restored checkpoint at step {start_step}")

    monitor = ft.StragglerDetector()
    jit_step = jax.jit(step_fn)
    losses = []
    with mesh:
        for i in range(start_step, steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in pipe.batch(i).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            monitor.observe(host=jax.process_index(),
                            step_seconds=time.perf_counter() - t0)
            losses.append(float(metrics["loss"]))
            if verbose and (i % max(steps // 10, 1) == 0 or i == steps - 1):
                print(f"step {i:5d} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}")
            if mgr and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, (params, opt_state))
    if mgr:
        mgr.wait()
    return dict(losses=losses, final_loss=losses[-1] if losses else None,
                params=params, stragglers=monitor.stragglers())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compression", default="none")
    args = ap.parse_args()
    res = train(args.arch, reduced=args.reduced, steps=args.steps,
                seq=args.seq, global_batch=args.batch, lr=args.lr,
                accum=args.accum, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every,
                grad_compression=args.grad_compression)
    print(f"final loss: {res['final_loss']:.4f}")


if __name__ == "__main__":
    main()

"""End-to-end GNN models + training on top of AdaptGear aggregation.

Models follow the paper's benchmarks (§5): GCN (Kipf&Welling default: 2
layers, 16 hidden) and GIN (Xu et al. default: 5 layers, MLP per layer),
plus GAT and GraphSAGE as extensions.  Training = full-graph node
classification with Adam, the standard setting for the paper's datasets.

The training loop integrates the paper's feedback-driven selector: the first
``warmup_iters`` iterations time every registry kernel candidate per
subgraph on the real graph, then the loop commits to the fastest jitted step
function.  The committed choices form a KernelPlan (per-layer x
per-subgraph) that forward/train_step are keyed by.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptgear, decompose as dec_mod, selector as sel_mod
from repro.core import epilogue as ep_mod
from repro.core.plan import KernelPlan
from repro.graphs import graph as graph_mod

Params = Any


@dataclass
class GNNConfig:
    model: str = "gcn"            # gcn | gin | gat | sage
    hidden: int = 16
    n_layers: int = 2
    lr: float = 1e-2
    dropout: float = 0.0          # kept 0 for determinism in tests
    comm_size: int = 16
    reorder: str = "bfs"          # bfs | louvain
    inter_buckets: int = 1        # density tiers; 0 = autotune over {1,2,4}
    selector: str = "feedback"    # feedback | cost_model | fixed
    fixed_kernels: tuple = ("block_diag", "bell")
    warmup_iters: int = 2
    seed: int = 0
    # --- mini-batch sampling (train/gnn_steps.py; "full" = whole graph) ---
    sampler: str = "full"         # full | cluster | neighbor
    clusters_per_batch: int = 8   # cluster: batch = q community blocks
    batch_nodes: int = 128        # neighbor: loss-carrying seeds per batch
    fanouts: tuple = (8, 4)       # neighbor: per-layer in-neighbor caps
    edge_budget: int = 0          # cluster: padded edge slots (0 = auto)
    cache_entries: int = 128      # PlanCache LRU bound
    # probe-on-Nth-miss: every Nth PlanCache miss wall-clocks the top-2
    # cost-model candidates and pins the winner (0 = cost model only)
    probe_every: int = 0
    # adaptive probe widening: when the cost model's margin between
    # candidates is inside its observed error band, the probe widens from
    # top-2 up to probe_k_max candidates; probe_budget_s caps one miss's
    # probe wall time (compiles included)
    probe_k_max: int = 4
    probe_budget_s: float = 2.0
    # budget-K autotuning: feed observed capped-bell spill back into the
    # blocked-ELL budget cap's slack factor (padding waste vs spill volume
    # per workload).  Off by default: a slack change alters payload shapes
    # and costs one recompile of the affected step functions.
    adapt_budget_k: bool = False
    # skeleton cache: cluster-sampler batches revisit cluster tuples every
    # epoch; a small LRU keyed by the drawn tuple skips even the single
    # decompose_skeleton pass for repeated batches (0 disables)
    skeleton_cache_entries: int = 64
    # async sampler->trainer pipeline (train/pipeline.py): prefetch_depth
    # background-prepared batches staged ahead of the jitted step, so a
    # steady-state iteration pays max(compute, prepare) instead of their
    # sum.  0 = synchronous (prepare inline with the step, the pre-PR-6
    # behavior); pipeline_workers threads share the prepare work.  The
    # async batch stream is bit-identical to the sync one under the same
    # seed (samplers draw from per-index deterministic seed streams).
    prefetch_depth: int = 0
    pipeline_workers: int = 2
    # adaptive-K recompile budget: each bell-slack ladder step re-shapes
    # the capped-bell payloads and costs one recompile per affected step
    # function (pre-compiled in a pipeline worker when prefetching); the
    # cap bounds total slack steps per run
    max_ladder_recompiles: int = 4
    # --- fault tolerance (train/gnn_steps.py + distributed/) --------------
    # crash-safe checkpointing: every checkpoint_every consumed batches the
    # loop snapshots params, opt state, the batch cursor, the sampler draw
    # count, and the full PlanCache state (entries, counters, slack-ladder
    # position, quarantine) through distributed.checkpoint.CheckpointManager
    # (atomic tmp+rename, crc manifest, async writer).  resume_from names a
    # checkpoint directory to restore before training: the resumed run's
    # loss curve, committed plans, and cache hit history are bit-identical
    # to the uninterrupted run's.
    checkpoint_dir: str = ""        # "" = checkpointing off
    checkpoint_every: int = 0       # save every N consumed batches (0 = off)
    checkpoint_keep: int = 3        # CheckpointManager GC horizon
    resume_from: str = ""           # checkpoint dir to restore from ("" = no)
    # transient-failure retry for the racing pipeline stages (batch build /
    # device staging): bounded exponential backoff, interruptible by
    # close(); fatal (non-transient) failures still fail fast
    retry_max: int = 0              # 0 = no retries
    retry_base_delay_s: float = 0.05
    # non-finite guard: a NaN/Inf loss or gradient skips that batch's
    # update inside the jitted step (params and Adam state carried
    # unchanged, the skip counted) instead of silently corrupting params
    nonfinite_guard: bool = True
    # --- observability (repro.obs; train/gnn_steps.py) --------------------
    # telemetry=True enables the span tracer + selector audit for the run
    # (the metrics registry is always live); trace_out / telemetry_out
    # write the Chrome trace-event JSON and the JSONL audit export when
    # training finishes, and either being set implies telemetry on.
    # Telemetry is append-only: losses, plans, hit history, and n_traces
    # are bit-identical with it on or off.
    telemetry: bool = False
    trace_out: str = ""             # Chrome trace path ("" = no export)
    telemetry_out: str = ""         # audit JSONL path ("" = no export)


def prepare(graph: graph_mod.Graph, cfg: GNNConfig) -> dec_mod.Decomposed:
    """Preprocessing stage (paper §3.3/§4.2): self-loops + per-model edge
    normalization + reorder + decomposition, one pass.  GCN bakes the
    symmetric norm into the edge values; SAGE bakes the mean-aggregator's
    ``1/deg(dst)`` the same way, which is what lets its dual-weight
    epilogue push W_neigh through the aggregation (core.epilogue).
    ``cfg.inter_buckets == 0`` autotunes the bucket count: decompose at
    each k in {1, 2, 4}, total the cost-model estimate over the model's
    layers, commit the cheapest."""
    g = graph_mod.add_self_loops(graph) if cfg.model in ("gcn",) else graph
    vals = None
    if cfg.model == "gcn":
        vals = graph_mod.gcn_norm_values(g.n, g.senders, g.receivers)
    elif cfg.model == "sage":
        vals = graph_mod.mean_norm_values(g.n, g.senders, g.receivers)
    if cfg.inter_buckets == 0:
        return autotune_decomposition(
            g, cfg, vals, in_dim=graph.features.shape[-1],
            n_classes=graph.n_classes)
    return dec_mod.decompose(g, comm_size=cfg.comm_size, method=cfg.reorder,
                             edge_vals=vals,
                             inter_buckets=cfg.inter_buckets)


def autotune_decomposition(g: graph_mod.Graph, cfg: GNNConfig,
                           edge_vals, in_dim: int, n_classes: int,
                           ks: tuple = (1, 2, 4)) -> dec_mod.Decomposed:
    """Bucket-count autotuning: compare whole-model cost-model totals across
    candidate inter-bucket counts and commit the cheapest decomposition.
    The per-k totals land in ``dec.stats['bucket_autotune']``."""
    hw = sel_mod.default_hw()
    best, best_total, totals = None, None, {}
    for k in ks:
        dec = dec_mod.decompose(g, comm_size=cfg.comm_size,
                                method=cfg.reorder, edge_vals=edge_vals,
                                inter_buckets=k)
        # priced per k: GIN layers may flip structure with the bucket
        # count (the sparse-pass width tradeoff depends on the tiers)
        pairs, eps = layer_plan_inputs(cfg, in_dim, n_classes, dec=dec,
                                       hw=hw)
        total = sum(sel_mod.plan_layer_cost(dec, fout, hw=hw, in_dim=fin,
                                            epilogue=ep)
                    for (fin, fout), ep in zip(pairs, eps))
        totals[k] = float(total)
        if best_total is None or total < best_total:
            best, best_total = dec, total
    best.stats["bucket_autotune"] = totals
    return best


def init_model(key, cfg: GNNConfig, in_dim: int, n_classes: int) -> Params:
    keys = jax.random.split(key, cfg.n_layers)
    dims = [in_dim] + [cfg.hidden] * (cfg.n_layers - 1) + [n_classes]
    layers = []
    for i in range(cfg.n_layers):
        if cfg.model == "gcn":
            layers.append(adaptgear.init_gcn_conv(keys[i], dims[i], dims[i + 1]))
        elif cfg.model == "gin":
            layers.append(adaptgear.init_gin_conv(keys[i], dims[i], cfg.hidden,
                                                  dims[i + 1]))
        elif cfg.model == "gat":
            layers.append(adaptgear.init_gat_conv(keys[i], dims[i], dims[i + 1]))
        elif cfg.model == "sage":
            layers.append(adaptgear.init_sage_conv(keys[i], dims[i], dims[i + 1]))
        else:
            raise ValueError(cfg.model)
    return layers


def agg_widths(cfg: GNNConfig, in_dim: int, n_classes: int) -> list[int]:
    """Feature width each layer's aggregation runs at (kernel choice is
    width-dependent — per-layer selection, a beyond-paper refinement)."""
    return [fout for _, fout in agg_width_pairs(cfg, in_dim, n_classes)]


def agg_width_pairs(cfg: GNNConfig, in_dim: int,
                    n_classes: int) -> list[tuple]:
    """Per-layer ``(in_dim, agg_dim)`` width pairs.

    ``in_dim`` is non-None for transform-first layers: it is the width the
    fused transform+aggregate kernels consume, and what the selectors need
    to price fused candidates against unfused + shared transform.  GCN is
    transform-first natively; GIN and SAGE become transform-first through
    their epilogue rewrite (core.epilogue) — GIN aggregates at the MLP
    hidden width (W1 pushed through), SAGE at the layer output width
    (W_neigh pushed through).  Models that aggregate raw inputs (GAT) get
    ``(None, width)`` — fused kernels never compete there."""
    dims = [in_dim] + [cfg.hidden] * (cfg.n_layers - 1) + [n_classes]
    if cfg.model in ("gcn", "sage"):
        return list(zip(dims[:-1], dims[1:]))   # transform-first
    if cfg.model == "gin":
        # dec-free structure rule (mirrors epilogue.layer_epilogues):
        # aggregate raw features when they are narrower than the MLP
        # hidden width, else push W1 through and aggregate at hidden
        return [(None, d) if d < cfg.hidden else (d, cfg.hidden)
                for d in dims[:-1]]
    return [(None, w) for w in dims[:-1]]       # gat aggregates raw inputs


def layer_epilogues(cfg: GNNConfig, in_dim: int, n_classes: int) -> tuple:
    """Per-layer EpilogueSpecs aligned with :func:`agg_width_pairs`."""
    dims = [in_dim] + [cfg.hidden] * (cfg.n_layers - 1) + [n_classes]
    return ep_mod.layer_epilogues(cfg.model, dims, cfg.hidden)


def layer_plan_inputs(cfg: GNNConfig, in_dim: int, n_classes: int,
                      dec: dec_mod.Decomposed | None = None,
                      dtype=jnp.float32, hw=None) -> tuple[list, tuple]:
    """``(pairs, epilogues)`` for selection — the priced front door.

    Without ``dec`` this is just ``(agg_width_pairs, layer_epilogues)``:
    GIN layers use the dec-free width rule (aggregate-first iff the raw
    input is narrower than the MLP hidden width) — the mini-batch path
    lives here, since structure must be fixed before any batch exists.

    With ``dec`` (full-batch: the decomposition exists before selection)
    GIN layers where ``hidden > in_dim`` are *priced*: both structure
    candidates run through ``selector.plan_layer_cost`` — sparse pass at
    its structure's width, fused candidates competing only under
    transform-first, the dense MLP terms folded in via ``epilogue_cost``
    — and the cheaper one is committed on the layer's EpilogueSpec, so
    ``tcgnn_tile`` and friends compete under both structures."""
    pairs = agg_width_pairs(cfg, in_dim, n_classes)
    eps = layer_epilogues(cfg, in_dim, n_classes)
    if dec is None or cfg.model != "gin":
        return pairs, eps
    hw = hw or sel_mod.default_hw()
    dims = [in_dim] + [cfg.hidden] * (cfg.n_layers - 1) + [n_classes]
    pairs, eps = list(pairs), list(eps)
    for i in range(cfg.n_layers):
        fin = dims[i]
        if cfg.hidden <= fin:
            continue        # transform-first narrows the pass: keep it
        (tf_pair, tf_spec), (af_pair, af_spec) = \
            ep_mod.gin_structure_candidates(fin, cfg.hidden, dims[i + 1])
        tf_cost = sel_mod.plan_layer_cost(dec, tf_pair[1], dtype, hw=hw,
                                          in_dim=tf_pair[0],
                                          epilogue=tf_spec)
        af_cost = sel_mod.plan_layer_cost(dec, af_pair[1], dtype, hw=hw,
                                          in_dim=af_pair[0],
                                          epilogue=af_spec)
        pairs[i], eps[i] = ((af_pair, af_spec) if af_cost < tf_cost
                            else (tf_pair, tf_spec))
    return pairs, tuple(eps)


def _as_plan(dec: dec_mod.Decomposed, kernels, n_layers: int) -> KernelPlan:
    if isinstance(kernels, KernelPlan):
        if kernels.n_layers != n_layers:
            raise ValueError(f"plan has {kernels.n_layers} layers, "
                             f"model has {n_layers}")
        return kernels
    return KernelPlan.make(dec, kernels, n_layers=n_layers)


def forward(params: Params, cfg: GNNConfig, dec: dec_mod.Decomposed,
            x: jax.Array, kernels,
            inv_deg: jax.Array | None = None) -> jax.Array:
    """Model forward over a decomposition produced by :func:`prepare` (or
    the mini-batch ``prepare_skeleton``) — both bake per-model edge
    normalization, so SAGE dispatches the fused dual-weight epilogue and
    never consumes ``inv_deg`` here (the argument stays for callers whose
    own layers need it, e.g. ``aggregate_mean``)."""
    plan = _as_plan(dec, kernels, len(params))
    h = x
    for i, layer in enumerate(params):
        names = plan.for_layer(i)
        if cfg.model == "gcn":
            h = adaptgear.gcn_conv(layer, dec, h, names)
        elif cfg.model == "gin":
            # structure rides the plan's EpilogueSpec (selection priced
            # it); plans without epilogues keep the transform-first default
            ep = plan.epilogue_for_layer(i)
            h = adaptgear.gin_conv(layer, dec, h, names,
                                   structure=(ep.structure if ep is not None
                                              else "transform_first"))
        elif cfg.model == "gat":
            h = adaptgear.gat_conv(layer, dec, h)
        elif cfg.model == "sage":
            # mean norm is baked into dec's edge values (prepare): the
            # dual-weight epilogue path, fused when the plan picked it
            h = adaptgear.sage_conv(layer, dec, h, names)
        if i != len(params) - 1:
            h = jax.nn.relu(h)
    return h


def _loss(params, cfg, dec, x, labels, node_mask, plan, inv_deg):
    logits = forward(params, cfg, dec, x, plan, inv_deg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(node_mask, nll, 0.0)
    return nll.sum() / jnp.maximum(node_mask.sum(), 1)


def make_train_step(cfg: GNNConfig, dec, kernels, inv_deg):
    """SGD-with-Adam step over the full graph; jitted once per KernelPlan."""
    plan = _as_plan(dec, kernels, cfg.n_layers)

    def step(params, opt, x, labels, node_mask):
        loss, grads = jax.value_and_grad(_loss)(
            params, cfg, dec, x, labels, node_mask, plan, inv_deg)
        new_params, new_opt = _adam_update(params, grads, opt, cfg.lr)
        return new_params, new_opt, loss

    return jax.jit(step)


def _adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return dict(m=zeros, v=jax.tree.map(jnp.zeros_like, params),
                t=jnp.zeros((), jnp.int32))


def _adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    tf = t.astype(jnp.float32)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** tf), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** tf), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mh, vh)
    return new, dict(m=m, v=v, t=t)


@dataclass
class TrainResult:
    losses: list
    accuracy: float
    kernels: list          # per-layer tuples (KernelPlan rows)
    probe_times: dict
    step_seconds: float
    preprocess_seconds: float
    plan: Any = None       # the full KernelPlan


def select_plan(dec: dec_mod.Decomposed, cfg: GNNConfig,
                widths: list, dtype=jnp.float32,
                epilogues: tuple | None = None
                ) -> tuple[KernelPlan, dict]:
    """Commit a KernelPlan with the configured selector mode.  ``dtype``
    is the aggregation dtype — feedback probes must time the kernels that
    will actually run.

    ``widths`` entries are either aggregated widths (ints) or
    ``(in_dim, agg_dim)`` pairs from :func:`agg_width_pairs`; a non-None
    in_dim lets fused transform+aggregate candidates compete in both
    selector modes.  ``epilogues`` (from :func:`layer_epilogues`, aligned
    with ``widths``) adjusts the honest comparison per layer: an MLP
    epilogue's shared transform is free to unfused candidates, a dual
    epilogue's self matmul is flat across them."""
    pairs = [(None, w) if isinstance(w, int) else tuple(w) for w in widths]
    eps = tuple(epilogues) if epilogues is not None else (None,) * len(pairs)
    probe_times: dict = {}
    if cfg.selector == "fixed":
        plan = KernelPlan.make(dec, tuple(cfg.fixed_kernels),
                               n_layers=len(pairs), epilogues=eps)
    elif cfg.selector == "cost_model":
        hw = sel_mod.default_hw()
        plan = KernelPlan.make(
            dec, [sel_mod.select_by_cost_model(dec, fout, dtype, hw=hw,
                                               in_dim=fin, epilogue=ep)
                  for (fin, fout), ep in zip(pairs, eps)],
            epilogues=eps)
    elif cfg.selector == "feedback":
        # paper default: probe every registry candidate during warmup
        fused_ok = any(fin is not None for fin, _ in pairs)
        sel = sel_mod.AdaptiveSelector(dec, warmup_iters=cfg.warmup_iters,
                                       include_fused=fused_ok)
        ep_of = {p: e for p, e in zip(pairs, eps)}
        for fin, fout in sorted(set(pairs), key=lambda p: (p[1], p[0] or 0)):
            probe_x = jnp.ones((dec.n_pad, fout), dtype)
            transform = (None if fin is None else
                         (jnp.ones((dec.n_pad, fin), dtype),
                          jnp.ones((fin, fout), dtype)))
            ep = ep_of[(fin, fout)]
            res = sel.probe(probe_x, iters=cfg.warmup_iters,
                            transform=transform,
                            free_transform=bool(ep and ep.free_transform))
            probe_times.update({k + (fout,): v for k, v in res.times.items()})
        # choices are keyed by the full (in_dim, agg_dim) pair: layers that
        # share an output width but differ in input width sit on opposite
        # sides of the fused recompute crossover
        plan = KernelPlan.make(
            dec, [sel.choice(fout if fin is None else (fin, fout))
                  for fin, fout in pairs], epilogues=eps)
    else:
        raise ValueError(f"unknown selector {cfg.selector!r}")
    return plan, probe_times


def train(graph: graph_mod.Graph, cfg: GNNConfig, steps: int = 50,
          verbose: bool = False):
    """Full training driver with the paper's feedback selection protocol.

    ``cfg.sampler != "full"`` switches to mini-batch sampled-subgraph
    training (train/gnn_steps.py: Graph -> Sampler -> SampledBatch ->
    decompose -> PlanCache -> jitted step) and returns its
    MinibatchResult instead of a TrainResult.  There ``fixed`` selection
    is honored per batch, while ``feedback`` and ``cost_model`` both
    resolve to cached cost-model selection (per-batch wall-clock probing
    cannot amortize over fresh subgraphs — see train_minibatch)."""
    if cfg.sampler != "full":
        from repro.train import gnn_steps   # lazy: avoids an import cycle
        return gnn_steps.train_minibatch(graph, cfg, steps=steps,
                                         verbose=verbose)
    t0 = time.perf_counter()
    dec = prepare(graph, cfg)
    t_pre = time.perf_counter() - t0

    x = adaptgear.to_reordered(dec, jnp.asarray(graph.features))
    labels_r = np.zeros((dec.n_pad,), np.int32)
    labels_r[np.asarray(dec.perm)] = graph.labels
    labels_r = jnp.asarray(labels_r)
    node_mask = np.zeros((dec.n_pad,), bool)
    node_mask[np.asarray(dec.perm)] = True
    node_mask = jnp.asarray(node_mask)
    deg = np.bincount(graph.receivers, minlength=graph.n).astype(np.float32)
    inv_deg_r = np.zeros((dec.n_pad,), np.float32)
    inv_deg_r[np.asarray(dec.perm)] = 1.0 / np.maximum(deg, 1.0)
    inv_deg = jnp.asarray(inv_deg_r)

    key = jax.random.PRNGKey(cfg.seed)
    params = init_model(key, cfg, x.shape[-1], graph.n_classes)
    opt = _adam_init(params)

    # --- kernel selection (per layer: aggregation width differs by layer;
    # transform-first layers carry their input width so fused candidates
    # compete — GCN natively, GIN/SAGE through the epilogue rewrite)
    pairs, eps = layer_plan_inputs(cfg, x.shape[-1], graph.n_classes,
                                   dec=dec, dtype=x.dtype)
    plan, probe_times = select_plan(dec, cfg, pairs, dtype=x.dtype,
                                    epilogues=eps)

    step_fn = make_train_step(cfg, dec, plan, inv_deg)

    losses = []
    t_step0 = None
    for i in range(steps):
        if i == 1:
            t_step0 = time.perf_counter()
        params, opt, loss = step_fn(params, opt, x, labels_r, node_mask)
        losses.append(float(loss))
        if verbose and i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f} plan={plan.layers}")
    jax.block_until_ready(params)
    step_s = (time.perf_counter() - t_step0) / max(steps - 1, 1) if t_step0 else 0.0

    logits = forward(params, cfg, dec, x, plan, inv_deg)
    pred = jnp.argmax(logits, -1)
    acc = float(jnp.where(node_mask, pred == labels_r, False).sum()
                / node_mask.sum())
    return TrainResult(losses=losses, accuracy=acc,
                       kernels=[tuple(k) for k in plan.layers],
                       probe_times=probe_times, step_seconds=step_s,
                       preprocess_seconds=t_pre, plan=plan)

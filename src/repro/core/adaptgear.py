"""AdaptGear aggregation dispatch + GNN convolution layers (paper §3/§4).

``aggregate`` is the AG-equivalent of the paper's subgraph-level execution:
Y = sum_s A_s @ X over the decomposition's subgraphs (intra tier + one or
more inter density buckets), with an independently selected kernel per
subgraph.  Dispatch goes through the kernel registry — there is no
string-keyed if/elif chain here; a kernel choice is a registry name resolved
to a spec whose ``matvec`` runs on the subgraph's materialized payload.
Layers are pure functions over explicit parameter pytrees (init_* / apply
pattern; no framework dependency).
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.decompose import Decomposed, Subgraph
from repro.kernels.registry import REGISTRY

Params = Any

DEFAULT_KERNELS = ("block_diag", "bell")


# ---------------------------------------------------------------------------
# Aggregation dispatch
# ---------------------------------------------------------------------------

def to_reordered(dec: Decomposed, x: jax.Array) -> jax.Array:
    """Permute node features into community order and pad to n_pad rows."""
    xr = x[dec.inv_perm]
    pad = dec.n_pad - dec.n
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    return xr


def from_reordered(dec: Decomposed, xr: jax.Array) -> jax.Array:
    return xr[: dec.n][dec.perm]


def aggregate_sub(sub: Subgraph, x: jax.Array, kernel: str) -> jax.Array:
    """Aggregate over a single subgraph with an explicit registry kernel.
    x: (n_pad, F) in reordered space."""
    spec = REGISTRY.get(kernel)
    if spec.fused:
        raise ValueError(
            f"kernel {kernel!r} is fused (needs the weight operand); "
            "dispatch it through aggregate_sub_fused / aggregate_transform")
    return spec.matvec(sub.formats[spec.payload_key], x)


def aggregate_sub_fused(sub: Subgraph, x: jax.Array, w: jax.Array,
                        kernel: str) -> jax.Array:
    """A_s @ (x @ w) over a single subgraph with a fused registry kernel."""
    spec = REGISTRY.get(kernel)
    if not spec.fused:
        raise ValueError(f"kernel {kernel!r} is not fused")
    return spec.fused_matvec(sub.formats[spec.payload_key], x, w)


def aggregate(dec: Decomposed, x: jax.Array,
              kernels: Sequence[str] = DEFAULT_KERNELS, *,
              acc: bool | None = None) -> jax.Array:
    """Y = A @ X via per-subgraph kernels (x reordered, (n_pad, F)).

    ``kernels`` is one name per subgraph, or the ``(intra, inter)`` pair
    shorthand broadcast over inter buckets.  With ``acc=True`` one output
    buffer is threaded through the subgraph list: kernels exposing
    ``matvec_acc`` seed their accumulator from it instead of zeros, so no
    per-bucket partial (n_pad, F) tensors are materialized (kernels without
    the hook fall back to the explicit add).  ``acc=None`` resolves by
    backend, like :func:`aggregate_transform`: on by default on TPU (it
    saves HBM), off in CPU interpret mode (the extra per-grid-step operand
    costs more than the XLA adds it replaces)."""
    if acc is None:
        acc = jax.default_backend() == "tpu"
    names = plan_mod.normalize_layer(dec, kernels)
    y = None
    for sub, k in zip(dec.subgraphs, names):
        spec = REGISTRY.get(k)
        payload = sub.formats[spec.payload_key]
        if y is None:
            y = spec.matvec(payload, x)
        elif acc and spec.matvec_acc is not None:
            y = spec.matvec_acc(payload, x, y)
        else:
            y = y + spec.matvec(payload, x)
    return y


def aggregate_transform(dec: Decomposed, x: jax.Array, w: jax.Array,
                        kernels: Sequence[str] = DEFAULT_KERNELS,
                        bias: jax.Array | None = None, *,
                        seed: jax.Array | None = None,
                        h: jax.Array | None = None,
                        acc: bool | None = None) -> jax.Array:
    """Y = A @ (X W) (+ bias / + seed) with per-subgraph fused/unfused
    kernels.

    The transform-first hot path (GCN, and through the epilogue rewrite
    also GIN/SAGE): fused kernels consume the raw features and weight
    directly (H = X W never round-trips HBM); H is materialized once only
    if some subgraph picked an unfused kernel.  The bias seeds the threaded
    accumulator, so it rides along for free in accumulation mode.

    ``seed`` generalizes ``bias`` to a full (n, Fo) accumulator seed — the
    epilogue self terms (GIN's ``(1+eps) X W1 + b1``) enter the threaded
    accumulation through it instead of a separate add.  ``h`` optionally
    supplies a precomputed transform for the unfused candidates (GIN's
    ``S = X W1`` is already materialized for the self term; recomputing it
    here would double the transform).

    ``acc=None`` resolves by backend: on TPU the threaded accumulator saves
    one full-width HBM tensor per density bucket; on CPU (interpret mode)
    the extra per-grid-step operand costs more than the XLA adds it
    replaces, so partial sums stay explicit."""
    if acc is None:
        acc = jax.default_backend() == "tpu"
    names = plan_mod.normalize_layer(dec, kernels)
    specs = [REGISTRY.get(k) for k in names]
    if h is None:
        h = x @ w if any(not s.fused for s in specs) else None
    y = None
    if seed is not None:
        if bias is not None:
            raise ValueError("pass either bias or seed, not both")
        y = seed.astype(x.dtype)
    elif bias is not None:
        y = jnp.broadcast_to(bias.astype(x.dtype), (x.shape[0], w.shape[-1]))
    for sub, spec in zip(dec.subgraphs, specs):
        payload = sub.formats[spec.payload_key]
        if spec.fused:
            if y is None:
                y = spec.fused_matvec(payload, x, w)
            elif acc and spec.fused_matvec_acc is not None:
                y = spec.fused_matvec_acc(payload, x, w, y)
            else:
                y = y + spec.fused_matvec(payload, x, w)
        else:
            if y is None:
                y = spec.matvec(payload, h)
            elif acc and spec.matvec_acc is not None:
                y = spec.matvec_acc(payload, h, y)
            else:
                y = y + spec.matvec(payload, h)
    return y


def aggregate_transform_dual(dec: Decomposed, x: jax.Array, w: jax.Array,
                             w_self: jax.Array,
                             kernels: Sequence[str] = DEFAULT_KERNELS,
                             bias: jax.Array | None = None, *,
                             acc: bool | None = None) -> jax.Array:
    """Y = X W_self + A @ (X W) (+ bias): the dual-weight (SAGE) epilogue.

    Mean normalization is baked into the decomposition's edge values
    (``core.gnn.prepare``), so ``A @ (X W)`` *is* the normalized neighbor
    term — no per-row rescale separates the self term from the threaded
    accumulation.  When the first tier's committed kernel provides the
    ``fused_dual_matvec`` hook (the diagonal tier's Pallas kernel), the
    self-weight stripe rides in VMEM next to the neighbor stripe and the
    self term never materializes separately; otherwise it seeds the
    accumulator as a dense XLA matmul (still only (n, Fo)-wide — the
    (n, Fi) aggregation intermediate of the unfused layer is gone either
    way).

    The hook is gated on accumulation mode (``acc=None`` resolves by
    backend, like :func:`aggregate_transform`): it exists to keep the self
    term out of HBM, which only pays on TPU — in CPU interpret mode the
    extra per-grid-step matmul costs more than the one XLA matmul it
    replaces, so the seed path runs there."""
    if acc is None:
        acc = jax.default_backend() == "tpu"
    names = plan_mod.normalize_layer(dec, kernels)
    first = REGISTRY.get(names[0])
    if acc and first.fused_dual_matvec is not None:
        payload = dec.subgraphs[0].formats[first.payload_key]
        if bias is not None and acc and first.fused_dual_matvec_acc is not None:
            y0 = jnp.broadcast_to(bias.astype(x.dtype),
                                  (x.shape[0], w.shape[-1]))
            seed = first.fused_dual_matvec_acc(payload, x, w, w_self, y0)
        else:
            seed = first.fused_dual_matvec(payload, x, w, w_self)
            if bias is not None:
                seed = seed + bias.astype(x.dtype)
        rest = dec.subgraphs[1:]
        rest_names = names[1:]
    else:
        seed = x @ w_self
        if bias is not None:
            seed = seed + bias.astype(x.dtype)
        rest = dec.subgraphs
        rest_names = names
    sub_dec = Decomposed(n=dec.n, n_pad=dec.n_pad, block_size=dec.block_size,
                         perm=dec.perm, inv_perm=dec.inv_perm,
                         subgraphs=tuple(rest), stats=None)
    return aggregate_transform(sub_dec, x, w, rest_names, seed=seed, acc=acc)


def aggregate_full_static(dec: Decomposed, x: jax.Array,
                          kernel: str = "ell") -> jax.Array:
    """Baseline O1 (paper §6.2): a single static full-graph-level kernel —
    GNNAdvisor/NeuGraph-style.  Every subgraph runs the same format (the
    plan layer validates applicability before anything executes)."""
    return aggregate(dec, x, (kernel,) * len(dec.subgraphs))


# ---------------------------------------------------------------------------
# Convolution layers
# ---------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def init_gcn_conv(key, in_dim: int, out_dim: int) -> Params:
    kw, = jax.random.split(key, 1)
    return dict(w=_glorot(kw, (in_dim, out_dim)),
                b=jnp.zeros((out_dim,), jnp.float32))


def gcn_conv(params: Params, dec: Decomposed, x: jax.Array,
             kernels: Sequence[str]) -> jax.Array:
    """GCN layer: Y = Â (X W) + b  (Kipf & Welling; Â norm baked into the
    decomposition's edge values).  Transform-first ordering reduces the
    aggregated width when out_dim < in_dim — same trick DGL applies.
    Dispatched through aggregate_transform: subgraphs whose plan entry is a
    fused kernel run A_s @ (X W) in one Pallas pass, and the bias seeds the
    accumulator threaded across the subgraph list."""
    return aggregate_transform(dec, x, params["w"], kernels,
                               bias=params["b"])


def init_gin_conv(key, in_dim: int, hidden: int, out_dim: int) -> Params:
    k1, k2 = jax.random.split(key)
    return dict(eps=jnp.zeros(()),
                w1=_glorot(k1, (in_dim, hidden)), b1=jnp.zeros((hidden,)),
                w2=_glorot(k2, (hidden, out_dim)), b2=jnp.zeros((out_dim,)))


def gin_conv(params: Params, dec: Decomposed, x: jax.Array,
             kernels: Sequence[str],
             structure: str = "transform_first") -> jax.Array:
    """GIN layer: MLP((1+eps) x + sum-agg(x)) (Xu et al.), under the
    structure the selector priced (EpilogueSpec ``structure``):

    transform-first — the MLP's first weight pushed *through* the
    aggregation (linearity):

        h1 = relu((1+eps) S + A (X W1) + b1),   S = X W1
        y  = h1 W2 + b2

    The aggregation runs at the MLP hidden width instead of the raw feature
    width, the (n, Fi) aggregated intermediate is gone, and fused kernels
    compete on ``A (X W1)``.  ``S`` is needed by the self term regardless,
    so it doubles as the unfused candidates' precomputed transform (the
    selector prices their shared-transform share at zero — EpilogueSpec
    ``free_transform``).

    aggregate-first — when the raw input is narrower than the hidden width
    the rewrite would *widen* the sparse pass, so aggregate raw features
    and run the whole MLP after (same result, by the same linearity):

        z  = (1+eps) X + A X
        y  = relu(z W1 + b1) W2 + b2
    """
    if structure == "aggregate_first":
        names = plan_mod.normalize_layer(dec, kernels)
        if not any(REGISTRY.get(k).fused for k in names):
            z = (1.0 + params["eps"]) * x + aggregate(dec, x, names)
            h1 = jax.nn.relu(z @ params["w1"] + params["b1"])
            return h1 @ params["w2"] + params["b2"]
        # fused kernel names imply transform-first (A (X W1) is the only
        # pass they implement) — a pinned fused plan overrides the spec
    s = x @ params["w1"]
    seed = (1.0 + params["eps"]) * s + params["b1"]
    h1 = jax.nn.relu(aggregate_transform(dec, x, params["w1"], kernels,
                                         seed=seed, h=s))
    return h1 @ params["w2"] + params["b2"]


def init_sage_conv(key, in_dim: int, out_dim: int) -> Params:
    k1, k2 = jax.random.split(key)
    return dict(w_self=_glorot(k1, (in_dim, out_dim)),
                w_neigh=_glorot(k2, (in_dim, out_dim)),
                b=jnp.zeros((out_dim,)))


def sage_conv(params: Params, dec: Decomposed, x: jax.Array,
              kernels: Sequence[str],
              inv_deg: jax.Array | None = None) -> jax.Array:
    """GraphSAGE mean-aggregator: W_self x + W_neigh mean_agg(x) + b.

    With ``inv_deg=None`` (the fused dual-weight epilogue path) the
    decomposition's edge values must already carry the mean normalization
    (``core.gnn.prepare`` / ``train.gnn_steps.prepare_skeleton`` bake
    ``1/deg(dst)`` exactly like GCN's symmetric norm): the neighbor weight
    pushes through the aggregation — ``mean(A@X) W == (D^-1 A)(X W)`` —
    so the aggregation runs at the output width, the (n, Fi) aggregated
    intermediate is gone, and the self term fuses into the diagonal tier's
    dual-stripe kernel when the plan picked it.

    Passing ``inv_deg`` keeps the legacy unbaked form (aggregate raw x,
    rescale, transform after) for callers with unnormalized edge values."""
    if inv_deg is not None:
        agg = aggregate(dec, x, kernels) * inv_deg[:, None]
        return x @ params["w_self"] + agg @ params["w_neigh"] + params["b"]
    return aggregate_transform_dual(dec, x, params["w_neigh"],
                                    params["w_self"], kernels,
                                    bias=params["b"])


def init_gat_conv(key, in_dim: int, out_dim: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(w=_glorot(k1, (in_dim, out_dim)),
                a_dst=_glorot(k2, (out_dim, 1))[:, 0],
                a_src=_glorot(k3, (out_dim, 1))[:, 0],
                b=jnp.zeros((out_dim,)))


def gat_conv(params: Params, dec: Decomposed, x: jax.Array,
             negative_slope: float = 0.2) -> jax.Array:
    """Single-head GAT with subgraph-level execution.

    Attention logits e_ij = LeakyReLU(a_dst.h_i + a_src.h_j) must be
    softmax-normalized over *all* in-neighbors of i — across every subgraph —
    so the partial aggregations share row-max and row-sum statistics.
    The intra part is evaluated as dense masked per-block attention (an MXU
    batched matmul, AdaptGear's dense-kernel path); each inter density bucket
    as COO edge softmax (segment ops, the edge-parallel path)."""
    h = x @ params["w"]                                 # (n_pad, F)
    s_dst = h @ params["a_dst"]                         # (n_pad,)
    s_src = h @ params["a_src"]

    B = dec.block_size
    nb = dec.n_pad // B
    # -- intra: dense per-block logits
    mask = dec.intra.formats["block_diag"].blocks != 0  # (nb, B, B)
    e_in = s_dst.reshape(nb, B)[:, :, None] + s_src.reshape(nb, B)[:, None, :]
    e_in = jax.nn.leaky_relu(e_in, negative_slope)
    e_in = jnp.where(mask, e_in, -jnp.inf)
    # -- inter buckets: per-edge logits (each bucket's COO is row-sorted)
    edge_parts = []
    for sub in dec.inters:
        coo = sub.formats["coo"]
        e_out = jax.nn.leaky_relu(s_dst[coo.rows] + s_src[coo.cols],
                                  negative_slope)
        edge_parts.append((coo.rows, coo.cols, e_out))

    # -- joint row max across all subgraphs
    m = jnp.max(e_in, axis=-1).reshape(-1)              # (n_pad,) -inf if empty
    for rows, _, e_out in edge_parts:
        m_out = jax.ops.segment_max(e_out, rows, num_segments=dec.n_pad,
                                    indices_are_sorted=True)
        m = jnp.maximum(m, m_out)
    m = jnp.where(jnp.isfinite(m), m, 0.0)

    # -- exp + joint row sum
    p_in = jnp.where(mask, jnp.exp(e_in - m.reshape(nb, B)[:, :, None]), 0.0)
    z = jnp.sum(p_in, axis=-1).reshape(-1)
    p_outs = []
    for rows, _, e_out in edge_parts:
        p_out = jnp.exp(e_out - m[rows])
        p_outs.append(p_out)
        z = z + jax.ops.segment_sum(p_out, rows, num_segments=dec.n_pad,
                                    indices_are_sorted=True)
    z = jnp.maximum(z, 1e-9)

    # -- weighted aggregation, subgraph-level kernels
    hb = h.reshape(nb, B, -1)
    y = jnp.einsum("bij,bjf->bif", p_in, hb,
                   preferred_element_type=jnp.float32).reshape(dec.n_pad, -1)
    for (rows, cols, _), p_out in zip(edge_parts, p_outs):
        y = y + jax.ops.segment_sum(h[cols] * p_out[:, None], rows,
                                    num_segments=dec.n_pad,
                                    indices_are_sorted=True)
    return (y / z[:, None]).astype(x.dtype) + params["b"]


# ---------------------------------------------------------------------------
# non-sum aggregation operators (paper §2.1: aggregate-max / aggregate-mean)
# ---------------------------------------------------------------------------

def aggregate_mean(dec: Decomposed, x: jax.Array, inv_deg: jax.Array,
                   kernels: Sequence[str] = DEFAULT_KERNELS) -> jax.Array:
    """mean = sum x (1/deg): reuses the full adaptive sum machinery (the
    dense MXU path stays available)."""
    return aggregate(dec, x, kernels) * inv_deg[:, None]


def aggregate_max(dec: Decomposed, x: jax.Array) -> jax.Array:
    """aggregate-max over in-neighbors of all subgraphs.

    max is not a matmul, so the dense-block MXU candidate does not exist on
    TPU (faithful hardware note: the paper's dense kernel is equivalent to
    aggregation only for sum, §3.2); every subgraph runs the segment/gather
    paths, joined by an elementwise max.  Rows with no neighbors return 0
    (GNN convention)."""
    neg = jnp.float32(-3.4e38)
    # intra via masked ELL gather
    ell = dec.intra.formats["ell"]
    g_in = jnp.where(ell.mask[..., None], x[ell.indices], neg)
    m = jnp.max(g_in, axis=1)                            # (n_pad, F)
    # inter buckets via segment_max over edges
    for sub in dec.inters:
        coo = sub.formats["coo"]
        m_out = jax.ops.segment_max(x[coo.cols], coo.rows,
                                    num_segments=dec.n_pad,
                                    indices_are_sorted=True)
        m = jnp.maximum(m, m_out)
    return jnp.where(m <= neg / 2, 0.0, m).astype(x.dtype)

"""AdaptGear aggregation dispatch + GNN convolution layers (paper §3/§4).

``aggregate`` is the AG-equivalent of the paper's subgraph-level execution:
Y = A_intra @ X  +  A_inter @ X, with an independently selected kernel per
subgraph.  Layers are pure functions over explicit parameter pytrees
(init_* / apply pattern; no framework dependency).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.decompose import Decomposed
from repro.kernels import ops

Params = Any


# ---------------------------------------------------------------------------
# Aggregation dispatch
# ---------------------------------------------------------------------------

def to_reordered(dec: Decomposed, x: jax.Array) -> jax.Array:
    """Permute node features into community order and pad to n_pad rows."""
    xr = x[dec.inv_perm]
    pad = dec.n_pad - dec.n
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    return xr


def from_reordered(dec: Decomposed, xr: jax.Array) -> jax.Array:
    return xr[: dec.n][dec.perm]


def aggregate_one(dec: Decomposed, x: jax.Array, which: str,
                  kernel: str) -> jax.Array:
    """Aggregate over a single subgraph with an explicit kernel.
    x: (n_pad, F) in reordered space."""
    if which == "intra":
        if kernel == "block_diag":
            return ops.block_diag_matvec(dec.intra_bd.blocks, x)
        if kernel == "ell":
            return ops.ell_matvec(dec.intra_ell, x)
        if kernel == "coo":
            return ops.coo_matvec(dec.intra_coo, x)
    else:
        if kernel == "bell":
            return ops.bell_matvec(dec.inter_bell, dec.inter_bell_t, x)
        if kernel == "ell":
            return ops.ell_matvec(dec.inter_ell, x)
        if kernel == "coo":
            return ops.coo_matvec(dec.inter_coo, x)
    raise ValueError(f"unknown ({which}, {kernel})")


def aggregate(dec: Decomposed, x: jax.Array,
              intra_kernel: str = "block_diag",
              inter_kernel: str = "bell") -> jax.Array:
    """Y = A @ X via per-subgraph kernels (x reordered, (n_pad, F))."""
    return (aggregate_one(dec, x, "intra", intra_kernel)
            + aggregate_one(dec, x, "inter", inter_kernel))


def aggregate_full_static(dec: Decomposed, x: jax.Array,
                          kernel: str = "ell") -> jax.Array:
    """Baseline O1 (paper §6.2): a single static full-graph-level kernel —
    GNNAdvisor/NeuGraph-style.  Uses intra+inter merged through one format."""
    if kernel == "coo":
        y = ops.coo_matvec(dec.intra_coo, x) + ops.coo_matvec(dec.inter_coo, x)
        return y
    if kernel == "ell":
        return (ops.ell_matvec(dec.intra_ell, x)
                + ops.ell_matvec(dec.inter_ell, x))
    raise ValueError(kernel)


# ---------------------------------------------------------------------------
# Convolution layers
# ---------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def init_gcn_conv(key, in_dim: int, out_dim: int) -> Params:
    kw, = jax.random.split(key, 1)
    return dict(w=_glorot(kw, (in_dim, out_dim)),
                b=jnp.zeros((out_dim,), jnp.float32))


def gcn_conv(params: Params, dec: Decomposed, x: jax.Array,
             intra_kernel: str, inter_kernel: str) -> jax.Array:
    """GCN layer: Y = Â (X W) + b  (Kipf & Welling; Â norm baked into the
    decomposition's edge values).  Transform-first ordering reduces the
    aggregated width when out_dim < in_dim — same trick DGL applies."""
    h = x @ params["w"]
    h = aggregate(dec, h, intra_kernel, inter_kernel)
    return h + params["b"]


def init_gin_conv(key, in_dim: int, hidden: int, out_dim: int) -> Params:
    k1, k2 = jax.random.split(key)
    return dict(eps=jnp.zeros(()),
                w1=_glorot(k1, (in_dim, hidden)), b1=jnp.zeros((hidden,)),
                w2=_glorot(k2, (hidden, out_dim)), b2=jnp.zeros((out_dim,)))


def gin_conv(params: Params, dec: Decomposed, x: jax.Array,
             intra_kernel: str, inter_kernel: str) -> jax.Array:
    """GIN layer: MLP((1+eps) x + sum-agg(x)) (Xu et al.)."""
    agg = aggregate(dec, x, intra_kernel, inter_kernel)
    h = (1.0 + params["eps"]) * x + agg
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def init_sage_conv(key, in_dim: int, out_dim: int) -> Params:
    k1, k2 = jax.random.split(key)
    return dict(w_self=_glorot(k1, (in_dim, out_dim)),
                w_neigh=_glorot(k2, (in_dim, out_dim)),
                b=jnp.zeros((out_dim,)))


def sage_conv(params: Params, dec: Decomposed, x: jax.Array,
              intra_kernel: str, inter_kernel: str,
              inv_deg: jax.Array) -> jax.Array:
    """GraphSAGE mean-aggregator: W_s x + W_n mean_agg(x)."""
    agg = aggregate(dec, x, intra_kernel, inter_kernel) * inv_deg[:, None]
    return x @ params["w_self"] + agg @ params["w_neigh"] + params["b"]


def init_gat_conv(key, in_dim: int, out_dim: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(w=_glorot(k1, (in_dim, out_dim)),
                a_dst=_glorot(k2, (out_dim, 1))[:, 0],
                a_src=_glorot(k3, (out_dim, 1))[:, 0],
                b=jnp.zeros((out_dim,)))


def gat_conv(params: Params, dec: Decomposed, x: jax.Array,
             negative_slope: float = 0.2) -> jax.Array:
    """Single-head GAT with subgraph-level execution.

    Attention logits e_ij = LeakyReLU(a_dst.h_i + a_src.h_j) must be
    softmax-normalized over *all* in-neighbors of i — across both subgraphs —
    so the two partial aggregations share row-max and row-sum statistics.
    The intra part is evaluated as dense masked per-block attention (an MXU
    batched matmul, AdaptGear's dense-kernel path); the inter part as COO
    edge softmax (segment ops, the edge-parallel path).
    """
    h = x @ params["w"]                                 # (n_pad, F)
    s_dst = h @ params["a_dst"]                         # (n_pad,)
    s_src = h @ params["a_src"]

    B = dec.block_size
    nb = dec.n_pad // B
    # -- intra: dense per-block logits
    mask = dec.intra_bd.blocks != 0                     # (nb, B, B)
    e_in = s_dst.reshape(nb, B)[:, :, None] + s_src.reshape(nb, B)[:, None, :]
    e_in = jax.nn.leaky_relu(e_in, negative_slope)
    e_in = jnp.where(mask, e_in, -jnp.inf)
    # -- inter: per-edge logits
    rows, cols = dec.inter_coo.rows, dec.inter_coo.cols
    e_out = jax.nn.leaky_relu(s_dst[rows] + s_src[cols], negative_slope)

    # -- joint row max
    m_in = jnp.max(e_in, axis=-1).reshape(-1)           # (n_pad,) -inf if empty
    m_out = jax.ops.segment_max(e_out, rows, num_segments=dec.n_pad,
                                indices_are_sorted=True)
    m = jnp.maximum(m_in, m_out)
    m = jnp.where(jnp.isfinite(m), m, 0.0)

    # -- exp + joint row sum
    p_in = jnp.where(mask, jnp.exp(e_in - m.reshape(nb, B)[:, :, None]), 0.0)
    p_out = jnp.exp(e_out - m[rows])
    z = (jnp.sum(p_in, axis=-1).reshape(-1)
         + jax.ops.segment_sum(p_out, rows, num_segments=dec.n_pad,
                               indices_are_sorted=True))
    z = jnp.maximum(z, 1e-9)

    # -- weighted aggregation, subgraph-level kernels
    hb = h.reshape(nb, B, -1)
    y_in = jnp.einsum("bij,bjf->bif", p_in, hb,
                      preferred_element_type=jnp.float32).reshape(dec.n_pad, -1)
    y_out = jax.ops.segment_sum(h[cols] * p_out[:, None], rows,
                                num_segments=dec.n_pad, indices_are_sorted=True)
    return ((y_in + y_out) / z[:, None]).astype(x.dtype) + params["b"]


# ---------------------------------------------------------------------------
# non-sum aggregation operators (paper §2.1: aggregate-max / aggregate-mean)
# ---------------------------------------------------------------------------

def aggregate_mean(dec: Decomposed, x: jax.Array, inv_deg: jax.Array,
                   intra_kernel: str = "block_diag",
                   inter_kernel: str = "bell") -> jax.Array:
    """mean = sum x (1/deg): reuses the full adaptive sum machinery (the
    dense MXU path stays available)."""
    return aggregate(dec, x, intra_kernel, inter_kernel) * inv_deg[:, None]


def aggregate_max(dec: Decomposed, x: jax.Array) -> jax.Array:
    """aggregate-max over in-neighbors of both subgraphs.

    max is not a matmul, so the dense-block MXU candidate does not exist on
    TPU (faithful hardware note: the paper's dense kernel is equivalent to
    aggregation only for sum, §3.2); both subgraphs run the segment/gather
    paths, joined by an elementwise max.  Rows with no neighbors return 0
    (GNN convention)."""
    neg = jnp.float32(-3.4e38)
    # intra via masked ELL gather
    ell = dec.intra_ell
    g_in = jnp.where(ell.mask[..., None], x[ell.indices], neg)
    m_in = jnp.max(g_in, axis=1)                         # (n_pad, F)
    # inter via segment_max over edges
    coo = dec.inter_coo
    m_out = jax.ops.segment_max(x[coo.cols], coo.rows,
                                num_segments=dec.n_pad,
                                indices_are_sorted=True)
    m = jnp.maximum(m_in, m_out)
    return jnp.where(m <= neg / 2, 0.0, m).astype(x.dtype)

"""Adaptive kernel selector (paper §3.3).

Two modes, both enumerating candidates from the kernel registry per
subgraph (intra tier + every inter density bucket):

* ``feedback`` (paper-faithful): during the first few training iterations,
  time every candidate kernel on the *actual* decomposed input, then commit
  to the fastest.  GNN training reuses a static graph for hundreds to
  thousands of iterations, so the probe cost amortizes to ~0 (§6.3 measures
  <0.1 s).  On TPU every candidate is compiled once and cached, so probing
  costs K compilations + a few executions -- same amortization argument.

* ``cost_model`` (TPU adaptation, beyond-paper): an analytic two-term
  roofline estimate (compute term = FLOPs/peak, memory term = bytes/bw) per
  candidate, provided by each kernel's registry ``cost`` fn.  Used when
  wall-clock probing is impossible -- inside a traced computation, or during
  the multi-pod dry-run where kernels are only lowered, never run.  The
  model's constants can be calibrated from feedback probes (``calibrate``),
  closing the loop between the two modes.

The selector returns per-subgraph kernel-name tuples (one KernelPlan layer);
dispatch lives in core/adaptgear.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

import jax
import numpy as np

from repro.core.decompose import Decomposed, Subgraph
from repro.kernels.registry import REGISTRY


@dataclass(frozen=True)
class HwModel:
    """Per-chip hardware constants for the analytic cost model."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    # fixed per-kernel-invocation overhead (grid setup, DMA warmup) and
    # per-path efficiency de-rates (gather/scatter do not stream at peak).
    launch_overhead_s: float = 2e-6
    gather_eff: float = 0.30        # XLA gather achieves ~30% of streaming bw
    scatter_eff: float = 0.15       # scatter/segment-sum read-modify-write
    mxu_small_block_eff: dict = field(default_factory=dict)

    def mxu_eff(self, b: int) -> float:
        """MXU utilization for (b, b) tiles: a 128x128 systolic array runs
        b<128 tiles at (b/128)^2 of peak in the worst case; padding in ops.py
        lifts the lane dim, leaving a (b/128) sublane de-rate."""
        return min(b / 128.0, 1.0)


CPU_HW = HwModel(name="cpu_interpret", peak_flops=5e10, hbm_bw=2e10,
                 launch_overhead_s=5e-5)


def default_hw() -> HwModel:
    return CPU_HW if jax.default_backend() == "cpu" else HwModel()


def candidate_cost(sub: Subgraph, kernel: str, feat_dim: int,
                   dtype=np.float32, hw: HwModel = HwModel()) -> float:
    """Analytic seconds estimate for one (subgraph, kernel) candidate,
    delegated to the kernel's registered cost fn."""
    return REGISTRY.get(kernel).cost(sub, feat_dim, dtype, hw)


def select_for_subgraph(sub: Subgraph, feat_dim: int, dtype=np.float32,
                        hw: HwModel = HwModel()) -> str:
    specs = REGISTRY.candidates_for(sub)
    if not specs:
        raise ValueError(f"no kernel candidates for subgraph {sub.name!r}")
    return min(specs, key=lambda s: s.cost(sub, feat_dim, dtype, hw)).name


def select_by_cost_model(dec: Decomposed, feat_dim: int, dtype=np.float32,
                         hw: HwModel = HwModel()) -> tuple[str, ...]:
    """One KernelPlan layer: the cost-argmin kernel per subgraph."""
    return tuple(select_for_subgraph(s, feat_dim, dtype, hw)
                 for s in dec.subgraphs)


@dataclass
class ProbeResult:
    times: dict            # (subgraph name, kernel) -> median seconds
    choice: tuple          # kernel name per subgraph


class AdaptiveSelector:
    """Feedback-driven selector (paper §3.3).

    ``observe()`` is fed per-candidate wall times collected during the first
    training iterations; ``choice()`` commits to the argmin per subgraph.
    ``probe()`` is a convenience that measures all candidates immediately
    (used by benchmarks; the training loop uses the iteration-interleaved
    variant in core/gnn.py to match the paper's monitor design).
    """

    def __init__(self, dec: Decomposed, warmup_iters: int = 3):
        self.dec = dec
        self.warmup_iters = warmup_iters
        # keyed (subgraph, kernel, feat_width): GNN layers aggregate at
        # different widths (GIN's first layer at the raw feature width, GCN
        # at the hidden width), and the optimal kernel is width-dependent —
        # a beyond-paper refinement of the feedback selector.
        self._times: dict[tuple[str, str, int], list[float]] = {}
        self._committed: dict[int, tuple] = {}

    def observe(self, sub_name: str, kernel: str, seconds: float,
                width: int = 0) -> None:
        self._times.setdefault((sub_name, kernel, width), []).append(seconds)

    def _widths(self) -> set:
        return {w for (_, _, w) in self._times}

    def _need(self, width: int) -> list[tuple[str, str, int]]:
        return [(s.name, spec.name, width)
                for s in self.dec.subgraphs
                for spec in REGISTRY.candidates_for(s)]

    def ready(self, width: int = 0) -> bool:
        width = self._nearest_width(width)
        return all(len(self._times.get(key, [])) >= self.warmup_iters
                   for key in self._need(width))

    def _nearest_width(self, width: int) -> int:
        ws = self._widths()
        if not ws:
            return width
        return min(ws, key=lambda w: abs(w - width))

    def choice(self, feat_dim: int | None = None) -> tuple:
        w = self._nearest_width(feat_dim or 0)
        if w in self._committed:
            return self._committed[w]
        if self._times and self.ready(w):
            med = {k: float(np.median(v)) for k, v in self._times.items()}
            self._committed[w] = tuple(
                min(REGISTRY.candidates_for(s),
                    key=lambda spec: med[(s.name, spec.name, w)]).name
                for s in self.dec.subgraphs)
            return self._committed[w]
        # not enough observations yet: fall back to the cost model
        assert feat_dim is not None, "need feat_dim for cost-model fallback"
        return select_by_cost_model(self.dec, feat_dim, hw=default_hw())

    def probe(self, x: jax.Array, iters: int = 3) -> ProbeResult:
        from repro.core import adaptgear  # local import to avoid cycle
        width = x.shape[-1]
        for sub in self.dec.subgraphs:
            for spec in REGISTRY.candidates_for(sub):
                fn = jax.jit(lambda x, s=sub, k=spec.name:
                             adaptgear.aggregate_sub(s, x, k))
                fn(x).block_until_ready()      # compile outside the timing
                for _ in range(iters):
                    t0 = time.perf_counter()
                    fn(x).block_until_ready()
                    self.observe(sub.name, spec.name,
                                 time.perf_counter() - t0, width)
        med = {(s, k): float(np.median(v))
               for (s, k, w), v in self._times.items() if w == width}
        return ProbeResult(times=med, choice=self.choice(width))

    def calibrate_cost_model(self, feat_dim: int,
                             hw: HwModel | None = None) -> HwModel:
        """Fit a global time-scale from probes so the analytic model's
        *absolute* numbers match this machine (its *ranking* is what the
        dry-run uses)."""
        hw = hw or default_hw()
        if not self._times:
            return hw
        by_name = {s.name: s for s in self.dec.subgraphs}
        ratios = []
        for (sub_name, kern, w), ts in self._times.items():
            est = candidate_cost(by_name[sub_name], kern, w or feat_dim, hw=hw)
            ratios.append(np.median(ts) / max(est, 1e-12))
        scale = float(np.median(ratios))
        return replace(hw, peak_flops=hw.peak_flops / scale,
                       hbm_bw=hw.hbm_bw / scale)

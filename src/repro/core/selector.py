"""Adaptive kernel selector (paper §3.3).

Two modes, both enumerating candidates from the kernel registry per
subgraph (intra tier + every inter density bucket):

* ``feedback`` (paper-faithful): during the first few training iterations,
  time every candidate kernel on the *actual* decomposed input, then commit
  to the fastest.  GNN training reuses a static graph for hundreds to
  thousands of iterations, so the probe cost amortizes to ~0 (§6.3 measures
  <0.1 s).  On TPU every candidate is compiled once and cached, so probing
  costs K compilations + a few executions -- same amortization argument.

* ``cost_model`` (TPU adaptation, beyond-paper): an analytic two-term
  roofline estimate (compute term = FLOPs/peak, memory term = bytes/bw) per
  candidate, provided by each kernel's registry ``cost`` fn.  Used when
  wall-clock probing is impossible -- inside a traced computation, or during
  the multi-pod dry-run where kernels are only lowered, never run.  The
  model's constants can be calibrated from feedback probes (``calibrate``),
  closing the loop between the two modes.

The selector returns per-subgraph kernel-name tuples (one KernelPlan layer);
dispatch lives in core/adaptgear.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decompose import Decomposed, Subgraph
from repro.core.epilogue import EpilogueSpec, epilogue_cost
from repro.kernels.registry import REGISTRY


@dataclass(frozen=True)
class HwModel:
    """Per-chip hardware constants for the analytic cost model."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    # fixed per-kernel-invocation overhead (grid setup, DMA warmup) and
    # per-path efficiency de-rates (gather/scatter do not stream at peak).
    launch_overhead_s: float = 2e-6
    gather_eff: float = 0.30        # XLA gather achieves ~30% of streaming bw
    scatter_eff: float = 0.15       # scatter/segment-sum read-modify-write
    mxu_small_block_eff: dict = field(default_factory=dict)

    def mxu_eff(self, b: int) -> float:
        """MXU utilization for (b, b) tiles: a 128x128 systolic array runs
        b<128 tiles at (b/128)^2 of peak in the worst case; padding in ops.py
        lifts the lane dim, leaving a (b/128) sublane de-rate."""
        return min(b / 128.0, 1.0)


CPU_HW = HwModel(name="cpu_interpret", peak_flops=5e10, hbm_bw=2e10,
                 launch_overhead_s=5e-5)


def default_hw() -> HwModel:
    return CPU_HW if jax.default_backend() == "cpu" else HwModel()


def dense_transform_cost(n: int, fin: int, fout: int, dtype=np.float32,
                         hw: HwModel = HwModel()) -> float:
    """Roofline seconds for the standalone dense transform H = X @ W that
    the unfused GCN path pays before aggregation (and fused kernels fold
    into their pass)."""
    be = np.dtype(dtype).itemsize
    flops = 2.0 * n * fin * fout
    bytes_ = (n * fin + n * fout + fin * fout) * be
    return max(flops / hw.peak_flops, bytes_ / hw.hbm_bw) + hw.launch_overhead_s


def candidate_cost(sub: Subgraph, kernel: str, feat_dim: int,
                   dtype=np.float32, hw: HwModel = HwModel(),
                   in_dim: int | None = None,
                   transform_share: float = 0.0) -> float:
    """Analytic seconds estimate for one (subgraph, kernel) candidate,
    delegated to the kernel's registered cost fn.

    Fused kernels price the ``(in_dim, feat_dim)`` pair (their pass includes
    the transform); unfused kernels aggregate at ``feat_dim`` and carry
    ``transform_share`` — their slice of the shared H = X @ W cost — so the
    fused-vs-unfused comparison stays apples-to-apples."""
    spec = REGISTRY.get(kernel)
    if spec.fused:
        if in_dim is None:
            raise ValueError(
                f"fused kernel {kernel!r} needs in_dim to be costed")
        return spec.cost(sub, (in_dim, feat_dim), dtype, hw)
    return spec.cost(sub, feat_dim, dtype, hw) + transform_share


def select_for_subgraph(sub: Subgraph, feat_dim: int, dtype=np.float32,
                        hw: HwModel = HwModel(),
                        in_dim: int | None = None,
                        transform_share: float = 0.0,
                        exclude: frozenset = frozenset()) -> str:
    """Cost-argmin kernel name for one subgraph.  ``exclude`` removes
    candidates by name — the PlanCache's kernel quarantine: a kernel whose
    compile/execute failed for this signature is struck from the frontier
    and the next-best takes over (the XLA reference path always stays)."""
    specs = [s for s in REGISTRY.candidates_for(
                 sub, include_fused=in_dim is not None)
             if s.name not in exclude]
    if not specs:
        raise ValueError(f"no kernel candidates for subgraph {sub.name!r}"
                         + (f" outside exclusion set {sorted(exclude)}"
                            if exclude else ""))
    return min(specs, key=lambda s: candidate_cost(
        sub, s.name, feat_dim, dtype, hw, in_dim, transform_share)).name


def _transform_share(dec: Decomposed, feat_dim: int, dtype, hw,
                     in_dim: int | None,
                     epilogue: EpilogueSpec | None = None) -> float:
    """Per-subgraph slice of the shared dense-transform cost.

    Approximation: if *some* subgraphs pick unfused kernels the transform is
    paid once in full regardless of how many picked it; dividing by the
    subgraph count under-charges mixed layers slightly, but leaves the
    unfused-vs-unfused ranking untouched and prices the all-fused-vs-
    all-unfused crossover correctly.

    An epilogue with ``free_transform`` (GIN's MLP: the self term computes
    S = X W1 regardless) zeroes the share — unfused candidates aggregate
    the already-paid-for transform, so fused candidates must win on
    bandwidth alone there."""
    if in_dim is None or (epilogue is not None and epilogue.free_transform):
        return 0.0
    return (dense_transform_cost(dec.n_pad, in_dim, feat_dim, dtype, hw)
            / max(len(dec.subgraphs), 1))


def select_by_cost_model(dec: Decomposed, feat_dim: int, dtype=np.float32,
                         hw: HwModel = HwModel(),
                         in_dim: int | None = None,
                         epilogue: EpilogueSpec | None = None,
                         exclude: frozenset = frozenset()
                         ) -> tuple[str, ...]:
    """One KernelPlan layer: the cost-argmin kernel per subgraph.

    With ``in_dim`` set (transform-first layers: GCN, and GIN/SAGE through
    their epilogue rewrite) fused candidates compete: each unfused
    candidate is surcharged its share of the shared H = X @ W cost the
    fused kernels avoid — unless the layer's ``epilogue`` marks that
    transform as free (see :func:`_transform_share`).  ``exclude`` strikes
    quarantined kernel names from every subgraph's candidate set."""
    share = _transform_share(dec, feat_dim, dtype, hw, in_dim, epilogue)
    return tuple(select_for_subgraph(s, feat_dim, dtype, hw, in_dim, share,
                                     exclude=exclude)
                 for s in dec.subgraphs)


def plan_layer_cost(dec: Decomposed, feat_dim: int, dtype=np.float32,
                    hw: HwModel = HwModel(),
                    in_dim: int | None = None,
                    epilogue: EpilogueSpec | None = None) -> float:
    """Total modeled seconds for one layer under the cost-argmin choice —
    the objective the bucket-count autotuner minimizes across k.  The
    layer's dense epilogue terms (the dual self matmul, the MLP's second
    layer) are flat across candidates but enter the total so whole-model
    structures price honestly."""
    share = _transform_share(dec, feat_dim, dtype, hw, in_dim, epilogue)
    total = epilogue_cost(epilogue, dec.n_pad, in_dim, feat_dim, dtype, hw)
    for sub in dec.subgraphs:
        specs = REGISTRY.candidates_for(sub, include_fused=in_dim is not None)
        total += min(candidate_cost(sub, s.name, feat_dim, dtype, hw,
                                    in_dim, share) for s in specs)
    return total


def plan_modeled_costs(dec: Decomposed, layers, pairs, dtype=np.float32,
                       hw: HwModel | None = None,
                       epilogues=None) -> list[list[float]]:
    """Modeled seconds for each *chosen* kernel of a committed plan:
    ``layers`` is the plan's per-layer kernel-name tuples (aligned with
    ``dec.subgraphs``), ``pairs`` the ``(in_dim, agg_dim)`` per layer as
    in PlanCache.  Returns one cost row per layer — the selector audit's
    "modeled" side of the calibration report.  Unfused kernels carry
    their shared-transform share exactly as in selection, so the numbers
    match what ``select_by_cost_model`` compared."""
    hw = hw or default_hw()
    pairs = list(pairs)
    epilogues = epilogues or [None] * len(pairs)
    out = []
    for names, (fin, fout), ep in zip(layers, pairs, epilogues):
        share = _transform_share(dec, fout, dtype, hw, fin, ep)
        out.append([candidate_cost(sub, name, fout, dtype, hw, fin, share)
                    for sub, name in zip(dec.subgraphs, names)])
    return out


def _time_candidate(sub: Subgraph, spec, fin: int | None, fout: int,
                    dtype, iters: int) -> float:
    """Median wall seconds for one candidate on synthetic full-width
    operands (compile excluded) — the measurement unit probe_topk and the
    PlanCache's Nth-miss probe share with the full-batch feedback path."""
    from repro.core import adaptgear  # local import to avoid cycle
    if spec.fused:
        x_in = jnp.ones((sub.n_rows, fin), dtype)
        w = jnp.ones((fin, fout), dtype)
        fn = jax.jit(lambda xi, wi, s=sub, k=spec.name:
                     adaptgear.aggregate_sub_fused(s, xi, wi, k))
        args = (x_in, w)
    else:
        x = jnp.ones((sub.n_rows, fout), dtype)
        fn = jax.jit(lambda xx, s=sub, k=spec.name:
                     adaptgear.aggregate_sub(s, xx, k))
        args = (x,)
    fn(*args).block_until_ready()          # compile outside the timing
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def probe_topk(dec: Decomposed, pairs, dtype=np.float32,
               hw: HwModel | None = None, k: int = 2,
               iters: int = 2, time_dec: Decomposed | None = None,
               epilogues=None, k_max: int | None = None,
               margin: float | None = None,
               time_budget_s: float | None = None,
               errs: list | None = None,
               timings: dict | None = None) -> list[tuple[str, ...]]:
    """Wall-clock probe restricted to the ``k`` cheapest cost-model
    candidates per (layer, subgraph).

    This is the amortized feedback mode the PlanCache runs on every Nth
    miss: instead of timing every registered candidate (the full-batch
    AdaptiveSelector warmup), only the plausible frontier — the top-k by
    modeled cost — is compiled and measured, and the measured argmin is
    pinned.  Unfused candidates carry the *modeled* shared-transform share
    (measuring H = X W per probe would triple the compile bill for a term
    the model prices well); fused candidates are timed end-to-end.
    ``pairs`` are ``(in_dim, agg_dim)`` per layer as in PlanCache;
    ``epilogues`` the aligned per-layer EpilogueSpecs (share freeness).
    Returns one kernel-name tuple per pair.

    Adaptive widening (ROADMAP probe-budget shaping): with ``margin`` set
    — the cost model's observed relative-error band, measured by past
    probes and by ``calibrate_cost_model`` — the frontier widens past the
    top-``k`` to every candidate whose modeled cost sits within
    ``(1 + margin)`` of the modeled best, capped at ``k_max``: when the
    model cannot distinguish candidates to within its own error, the
    wall clock decides among all of them.  ``time_budget_s`` caps the
    probe's total wall time (compiles included): once exhausted, untimed
    candidates are skipped and the argmin runs over whatever was measured
    (falling back to the modeled best when nothing was).

    ``errs``, when given, accrues ``(modeled_seconds, measured_seconds)``
    per timed candidate — the PlanCache folds these into its running
    error band, closing the model-vs-measurement loop.  ``timings``, when
    given, is filled with ``(sub_name, kernel, in_dim, agg_dim) ->
    (modeled_seconds, measured_seconds)`` per timed candidate — the
    attributed form the selector audit records.

    ``time_dec`` optionally supplies the payloads to *time* (aligned with
    ``dec.subgraphs``) while ``dec`` still drives the cost-model ranking:
    the mini-batch probe passes the budget-padded twin, because that —
    not the real-nnz payload — is what the jitted step executes (a COO
    timed at 500 real edges but run at a 10k-slot budget would be pinned
    on the wrong side of the crossover).
    """
    hw = hw or default_hw()
    timed: dict[tuple, float] = {}
    layers = []
    time_subs = (time_dec or dec).subgraphs
    pairs = list(pairs)
    epilogues = epilogues or [None] * len(pairs)
    t_start = time.perf_counter()

    def budget_left() -> bool:
        return (time_budget_s is None
                or time.perf_counter() - t_start < time_budget_s)

    for (fin, fout), ep in zip(pairs, epilogues):
        share = _transform_share(dec, fout, dtype, hw, fin, ep)
        choice = []
        for sub, tsub in zip(dec.subgraphs, time_subs):
            specs = REGISTRY.candidates_for(sub,
                                            include_fused=fin is not None)
            if not specs:
                raise ValueError(
                    f"no kernel candidates for subgraph {sub.name!r}")
            modeled = {s.name: candidate_cost(sub, s.name, fout, dtype, hw,
                                              fin, share) for s in specs}
            ranked = sorted(specs, key=lambda s: modeled[s.name])
            cands = ranked[:max(k, 1)]
            if margin is not None and len(ranked) > len(cands):
                lim = modeled[ranked[0].name] * (1.0 + max(margin, 0.0))
                cands += [s for s in ranked[len(cands):max(k_max or k, k)]
                          if modeled[s.name] <= lim]
            if len(cands) < 2:
                choice.append(cands[0].name)
                continue
            best_name, best_t = None, None
            for spec in cands:
                key = (sub.name, spec.name, fin or 0, fout)
                if key not in timed:
                    if not budget_left():
                        continue        # budget spent: modeled ranking holds
                    timed[key] = _time_candidate(tsub, spec, fin, fout,
                                                 dtype, iters)
                    if errs is not None:
                        errs.append((modeled[spec.name] -
                                     (0.0 if spec.fused else share),
                                     timed[key]))
                    if timings is not None:
                        timings[(sub.name, spec.name, fin or 0, fout)] = (
                            modeled[spec.name] -
                            (0.0 if spec.fused else share),
                            timed[key])
                t = timed[key] + (0.0 if spec.fused else share)
                if best_t is None or t < best_t:
                    best_name, best_t = spec.name, t
            choice.append(best_name or cands[0].name)
        layers.append(tuple(choice))
    return layers


@dataclass
class ProbeResult:
    times: dict            # (subgraph name, kernel) -> median seconds
    choice: tuple          # kernel name per subgraph


class AdaptiveSelector:
    """Feedback-driven selector (paper §3.3).

    ``observe()`` is fed per-candidate wall times collected during the first
    training iterations; ``choice()`` commits to the argmin per subgraph.
    ``probe()`` is a convenience that measures all candidates immediately
    (used by benchmarks; the training loop uses the iteration-interleaved
    variant in core/gnn.py to match the paper's monitor design).
    """

    def __init__(self, dec: Decomposed, warmup_iters: int = 3,
                 include_fused: bool = False):
        self.dec = dec
        self.warmup_iters = warmup_iters
        # fused candidates need the transform operand at probe time; only
        # transform-first call sites (GCN) can supply it, so they opt in
        self.include_fused = include_fused
        # keyed (subgraph, kernel, width key): GNN layers aggregate at
        # different widths (GIN's first layer at the raw feature width, GCN
        # at the hidden width), and the optimal kernel is width-dependent —
        # a beyond-paper refinement of the feedback selector.  The width key
        # is the (in_dim, agg_dim) pair (in_dim 0 when no transform): two
        # GCN layers sharing an output width but differing in input width
        # sit on opposite sides of the fused recompute crossover, so their
        # observations and committed choices must not pool.
        self._times: dict[tuple[str, str, tuple], list[float]] = {}
        self._raw: dict[tuple[str, str, tuple], list[float]] = {}
        self._committed: dict[tuple, tuple] = {}

    def _cands(self, sub: Subgraph):
        return REGISTRY.candidates_for(sub, include_fused=self.include_fused)

    @staticmethod
    def _wkey(width) -> tuple:
        """Normalize a width spec (int or (in_dim, agg_dim)) to a key."""
        if isinstance(width, tuple):
            return (width[0] or 0, width[1])
        return (0, width or 0)

    def observe(self, sub_name: str, kernel: str, seconds: float,
                width=0, raw_seconds: float | None = None) -> None:
        key = (sub_name, kernel, self._wkey(width))
        self._times.setdefault(key, []).append(seconds)
        self._raw.setdefault(key, []).append(
            seconds if raw_seconds is None else raw_seconds)

    def _widths(self) -> set:
        return {w for (_, _, w) in self._times}

    def _need(self, width) -> list[tuple[str, str, tuple]]:
        wk = self._wkey(width)
        return [(s.name, spec.name, wk)
                for s in self.dec.subgraphs
                for spec in self._cands(s)]

    def ready(self, width=0) -> bool:
        width = self._nearest_width(width)
        return all(len(self._times.get(key, [])) >= self.warmup_iters
                   for key in self._need(width))

    def _nearest_width(self, width) -> tuple:
        ws = self._widths()
        wk = self._wkey(width)
        if not ws:
            return wk
        return min(ws, key=lambda w: (abs(w[1] - wk[1]), abs(w[0] - wk[0])))

    def choice(self, feat_dim=None) -> tuple:
        w = self._nearest_width(feat_dim or 0)
        if w in self._committed:
            return self._committed[w]
        if self._times and self.ready(w):
            med = {k: float(np.median(v)) for k, v in self._times.items()}
            self._committed[w] = tuple(
                min(self._cands(s),
                    key=lambda spec: med[(s.name, spec.name, w)]).name
                for s in self.dec.subgraphs)
            return self._committed[w]
        # not enough observations yet: fall back to the cost model
        assert feat_dim is not None, "need feat_dim for cost-model fallback"
        fin, fout = self._wkey(feat_dim)
        return select_by_cost_model(self.dec, fout, hw=default_hw(),
                                    in_dim=fin or None)

    def probe(self, x: jax.Array, iters: int = 3,
              transform: tuple | None = None,
              free_transform: bool = False) -> ProbeResult:
        """Time every candidate on the real decomposed input.

        ``x`` is the aggregated-width operand the unfused kernels consume.
        ``transform`` is the optional ``(x_in, w)`` pair for transform-first
        layers: fused candidates are timed end-to-end on A @ (x_in W), and
        each unfused candidate is charged its per-subgraph share of the
        measured standalone H = X @ W it depends on — keeping the committed
        argmin an honest whole-layer comparison.  ``free_transform`` (GIN's
        MLP epilogue: the self term computes H regardless) keeps the fused
        probes but zeroes that surcharge."""
        from repro.core import adaptgear  # local import to avoid cycle
        share = 0.0
        if transform is not None:
            x_in, w_mat = transform
            width = (x_in.shape[-1], x.shape[-1])
            if not free_transform:
                mm = jax.jit(lambda a, b: a @ b)
                mm(x_in, w_mat).block_until_ready()
                ts = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    mm(x_in, w_mat).block_until_ready()
                    ts.append(time.perf_counter() - t0)
                share = float(np.median(ts)) / max(len(self.dec.subgraphs), 1)
        else:
            width = x.shape[-1]
        wk = self._wkey(width)
        for sub in self.dec.subgraphs:
            for spec in self._cands(sub):
                if spec.fused:
                    if transform is None:
                        continue
                    fn = jax.jit(lambda xi, wi, s=sub, k=spec.name:
                                 adaptgear.aggregate_sub_fused(s, xi, wi, k))
                    args = (x_in, w_mat)
                    extra = 0.0
                else:
                    fn = jax.jit(lambda xx, s=sub, k=spec.name:
                                 adaptgear.aggregate_sub(s, xx, k))
                    args = (x,)
                    extra = share
                fn(*args).block_until_ready()  # compile outside the timing
                for _ in range(iters):
                    t0 = time.perf_counter()
                    fn(*args).block_until_ready()
                    t = time.perf_counter() - t0
                    # selection compares t + transform share; calibration
                    # fits the bare kernel time (raw_seconds)
                    self.observe(sub.name, spec.name, t + extra, width,
                                 raw_seconds=t)
        med = {(s, k): float(np.median(v))
               for (s, k, w), v in self._times.items() if w == wk}
        return ProbeResult(times=med, choice=self.choice(width))

    def calibrate_cost_model(self, feat_dim: int,
                             hw: HwModel | None = None) -> HwModel:
        """Fit a global time-scale from probes so the analytic model's
        *absolute* numbers match this machine (its *ranking* is what the
        dry-run uses).  Fitted against the *raw* kernel times: the selection
        surcharge (shared-transform share) is not part of any kernel's own
        cost fn."""
        hw = hw or default_hw()
        if not self._raw:
            return hw
        by_name = {s.name: s for s in self.dec.subgraphs}
        ratios = []
        for (sub_name, kern, w), ts in self._raw.items():
            if REGISTRY.get(kern).fused:
                continue   # fused probes fold in the transform; skip the fit
            est = candidate_cost(by_name[sub_name], kern, w[1] or feat_dim,
                                 hw=hw)
            ratios.append(np.median(ts) / max(est, 1e-12))
        if not ratios:
            return hw
        scale = float(np.median(ratios))
        return replace(hw, peak_flops=hw.peak_flops / scale,
                       hbm_bw=hw.hbm_bw / scale)

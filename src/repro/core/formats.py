"""Sparse/dense graph storage formats used by AdaptGear's subgraph kernels.

The paper (AdaptGear, CF'23 §2.1/§3.2) uses Dense / CSR / COO formats and a
block-diagonal dense layout for intra-community subgraphs.  On TPU we keep the
same taxonomy and add two block-structured variants that map onto the MXU and
scalar-prefetch DMA:

  COO       -- edge list (edge-parallel; TPU analogue = segment_sum)
  CSR       -- row-compressed (vertex-parallel; TPU analogue = gather+reduce)
  ELL       -- per-row padded neighbor lists (regular gather, XLA-friendly)
  BlockDiag -- dense (B,B) diagonal blocks (intra-community; Pallas MXU kernel)
  BlockELL  -- blocked-ELL: CSR over (B,B) blocks, padded to K blocks per block
               row (inter-community; Pallas scalar-prefetch kernel)

All containers are registered pytrees so they can cross jit boundaries.
Conversion happens on host in numpy during preprocessing (paper §3.3: one
pass over the edges).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

Array = Any



def _np(arr) -> np.ndarray:
    """Host view of a (possibly device) array.  The numpy short-circuit
    matters: converters run per batch on the mini-batch hot path, where
    payload leaves are host numpy until the jit boundary, and
    jax.device_get's tree dispatch costs more than the work itself."""
    if isinstance(arr, np.ndarray):
        return arr
    return np.asarray(jax.device_get(arr))


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(cls, data_fields, meta_fields)
    return cls


@dataclass(frozen=True)
class COO:
    """Edge-list format. rows = destination, cols = source (paper §2.1)."""
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    rows: Array = None   # (E,) int32, destination vertex per edge
    cols: Array = None   # (E,) int32, source vertex per edge
    vals: Array = None   # (E,) float, edge weight (e.g. GCN normalization)

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        denom = max(self.n_rows * self.n_cols, 1)
        return self.nnz / denom


@dataclass(frozen=True)
class CSR:
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    indptr: Array = None   # (n_rows+1,) int32
    indices: Array = None  # (E,) int32 column (source) indices
    vals: Array = None     # (E,) float

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])


@dataclass(frozen=True)
class ELL:
    """Per-row padded neighbor lists.  indices[i, k] is the k-th source
    neighbor of row i (0 where padded, masked by ``mask``)."""
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    max_deg: int = dataclasses.field(metadata=dict(static=True))
    indices: Array = None  # (n_rows, max_deg) int32
    vals: Array = None     # (n_rows, max_deg) float, 0 where padded
    mask: Array = None     # (n_rows, max_deg) bool

    @property
    def nnz(self) -> int:
        return int(_np(self.mask).sum())


@dataclass(frozen=True)
class BlockDiag:
    """Dense diagonal blocks: the intra-community subgraph after community
    reordering (paper Fig. 3a / §3.2 'Dense-based kernel')."""
    n: int = dataclasses.field(metadata=dict(static=True))            # padded node count
    block_size: int = dataclasses.field(metadata=dict(static=True))   # community size B
    blocks: Array = None   # (n // B, B, B) float dense adjacency blocks

    @property
    def n_blocks(self) -> int:
        return self.n // self.block_size

    @property
    def nnz(self) -> int:
        return int((_np(self.blocks) != 0).sum())

    @property
    def density(self) -> float:
        return self.nnz / max(self.blocks.size, 1)


@dataclass(frozen=True)
class BlockELL:
    """CSR-of-blocks padded to K non-empty (B,B) blocks per block-row.

    ``col_idx[i, k]`` names the block column of the k-th stored block in block
    row i; padding entries point at block column 0 with an all-zero block so
    the accumulation stays correct without a mask (TPU-friendly: no
    data-dependent control flow inside the kernel)."""
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))
    max_blocks: int = dataclasses.field(metadata=dict(static=True))   # K
    # per-bucket feature-tile cap chosen from the bucket's density stats at
    # build time (VMEM working-set budget); ops._f_tile clamps to a divisor
    f_tile_cap: int = dataclasses.field(default=512, metadata=dict(static=True))
    # True when K came from an edge *budget* rather than from this input's
    # max stored-block count: every array dim is then a function of
    # (budget, n_pad, B) alone — the contract the mini-batch no-retrace
    # path requires (sampling.plan_cache admits only budgeted payloads)
    budgeted: bool = dataclasses.field(default=False,
                                       metadata=dict(static=True))
    blocks: Array = None    # (n_brow, K, B, B) float
    col_idx: Array = None   # (n_brow, K) int32 block-column ids
    n_valid: Array = None   # (n_brow,) int32 number of real blocks per row

    @property
    def n_brow(self) -> int:
        return self.n_rows // self.block_size

    @property
    def nnz(self) -> int:
        return int((_np(self.blocks) != 0).sum())


for _cls, _data, _meta in [
    (COO, ("rows", "cols", "vals"), ("n_rows", "n_cols")),
    (CSR, ("indptr", "indices", "vals"), ("n_rows", "n_cols")),
    (ELL, ("indices", "vals", "mask"), ("n_rows", "n_cols", "max_deg")),
    (BlockDiag, ("blocks",), ("n", "block_size")),
    (BlockELL, ("blocks", "col_idx", "n_valid"),
     ("n_rows", "n_cols", "block_size", "max_blocks", "f_tile_cap",
      "budgeted")),
]:
    _register(_cls, list(_data), list(_meta))


# ---------------------------------------------------------------------------
# Host-side (numpy) constructors.  Preprocessing is a single pass over the
# edge list, matching the paper's §3.3 decomposition procedure.
# ---------------------------------------------------------------------------

def coo_from_edges(n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray,
                   vals: np.ndarray | None = None) -> COO:
    rows = np.asarray(rows, np.int32)
    cols = np.asarray(cols, np.int32)
    if vals is None:
        vals = np.ones(rows.shape[0], np.float32)
    # Sort by destination row: makes segment_sum use sorted (cheap) mode and
    # makes CSR conversion a cumsum.  Skipped when the caller already sorted
    # (the decompose skeleton row-sorts each tier once, so every per-batch
    # materialization takes the O(E) check instead of the O(E log E) sort).
    if rows.size and np.any(rows[1:] < rows[:-1]):
        order = np.argsort(rows, kind="stable")
        rows, cols = rows[order], cols[order]
        vals = np.asarray(vals, np.float32)[order]
    return COO(n_rows, n_cols, rows, cols, np.asarray(vals, np.float32))


def coo_to_csr(coo: COO) -> CSR:
    rows = _np(coo.rows)
    counts = np.bincount(rows, minlength=coo.n_rows)
    indptr = np.zeros(coo.n_rows + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSR(coo.n_rows, coo.n_cols, indptr, coo.cols, coo.vals)


def coo_to_ell(coo: COO, max_deg: int | None = None) -> ELL:
    rows = _np(coo.rows)
    cols = _np(coo.cols)
    vals = _np(coo.vals)
    counts = np.bincount(rows, minlength=coo.n_rows)
    K = int(counts.max()) if counts.size and max_deg is None else int(max_deg or 1)
    K = max(K, 1)
    idx = np.zeros((coo.n_rows, K), np.int32)
    v = np.zeros((coo.n_rows, K), np.float32)
    m = np.zeros((coo.n_rows, K), bool)
    slot = np.zeros(coo.n_rows, np.int32)
    for r, c, w in zip(rows, cols, vals):
        s = slot[r]
        if s < K:
            idx[r, s] = c
            v[r, s] = w
            m[r, s] = True
            slot[r] = s + 1
    return ELL(coo.n_rows, coo.n_cols, K, idx, v, m)


def coo_to_blockdiag(coo: COO, block_size: int) -> BlockDiag:
    """Densify assuming every edge lies on the diagonal blocks (caller must
    have already filtered to the intra-community subgraph)."""
    B = block_size
    n_pad = ((coo.n_rows + B - 1) // B) * B
    nb = n_pad // B
    rows = _np(coo.rows)
    cols = _np(coo.cols)
    vals = _np(coo.vals)
    blocks = np.zeros((nb, B, B), np.float32)
    b = rows // B
    assert np.all(b == cols // B), "coo_to_blockdiag: edge off the block diagonal"
    blocks[b, rows % B, cols % B] = vals
    return BlockDiag(n_pad, B, blocks)


def coo_to_bell(coo: COO, block_size: int, n_cols_pad: int | None = None,
                f_tile_cap: int = 512) -> BlockELL:
    """Blocked-ELL over (B,B) tiles; K = max non-empty blocks per block row."""
    B = block_size
    n_rpad = ((coo.n_rows + B - 1) // B) * B
    n_cpad = n_cols_pad or ((coo.n_cols + B - 1) // B) * B
    nbr = n_rpad // B
    rows = _np(coo.rows)
    cols = _np(coo.cols)
    vals = _np(coo.vals)
    brow, bcol = rows // B, cols // B
    # group edges per (brow, bcol)
    blk_of: dict[tuple[int, int], int] = {}
    per_row: list[list[int]] = [[] for _ in range(nbr)]
    for r in range(len(rows)):
        key = (int(brow[r]), int(bcol[r]))
        if key not in blk_of:
            blk_of[key] = len(per_row[key[0]])
            per_row[key[0]].append(key[1])
    K = max((len(p) for p in per_row), default=1)
    K = max(K, 1)
    blocks = np.zeros((nbr, K, B, B), np.float32)
    col_idx = np.zeros((nbr, K), np.int32)
    n_valid = np.zeros((nbr,), np.int32)
    for (i, j), slot in blk_of.items():
        col_idx[i, slot] = j
    for i, p in enumerate(per_row):
        n_valid[i] = len(p)
    for r in range(len(rows)):
        i, j = int(brow[r]), int(bcol[r])
        blocks[i, blk_of[(i, j)], rows[r] % B, cols[r] % B] = vals[r]
    return BlockELL(n_rpad, n_cpad, B, K, f_tile_cap,
                    blocks=blocks, col_idx=col_idx, n_valid=n_valid)


# ---------------------------------------------------------------------------
# Budget-padded blocked-ELL (the mini-batch fixed-shape variant)
# ---------------------------------------------------------------------------

def bell_budget_k(edge_budget: int, n_pad: int, block_size: int,
                  slack: float = 2.0) -> int:
    """Stored-block cap K for the budget-padded blocked-ELL.

    Derived from the sampler's *edge budget* alone — never from a batch's
    actual edges — so every batch's payload shares one (n_brow, K, B, B)
    shape.  K covers ``slack``x the per-block-row average stored-block
    count under dense packing (each stored block absorbing ~B edges); the
    block-column count bounds it above (a row cannot store more distinct
    blocks than exist — at that bound the cap is vacuous and nothing ever
    spills)."""
    nbr = max(n_pad // block_size, 1)
    k = -(-int(slack * edge_budget) // max(nbr * block_size, 1))
    return int(max(1, min(k, nbr)))


def coo_to_bell_capped(coo: COO, block_size: int, k_max: int,
                       n_cols_pad: int | None = None,
                       f_tile_cap: int = 512, build_blocks: bool = True
                       ) -> tuple[BlockELL | None, COO, COO]:
    """Blocked-ELL with exactly ``k_max`` stored-block slots per block row.

    Rows needing more keep their *densest* ``k_max`` blocks (ties broken
    toward the lower block column); the remaining edges come back as a
    row-sorted *spill* COO, and the stored edges as a third COO (what the
    transpose pass caps again — see the registry's capped builder).  Slots
    past a row's real block count stay all-zero pointing at block column 0,
    so the kernel needs no mask.  Returns ``(bell, spill, stored)`` with
    ``bell.budgeted=True``: all three shapes are functions of
    ``(k_max, n_pad, B)`` and the edge count only.

    ``build_blocks=False`` skips the (n_brow, K, B, B) scatter and returns
    ``bell=None`` — for callers that only need the stored/spill edge split
    (the capped builder's first partition pass discards its bell and
    rebuilds from the transpose-capped survivors)."""
    B = block_size
    n_rpad = ((coo.n_rows + B - 1) // B) * B
    n_cpad = n_cols_pad or ((coo.n_cols + B - 1) // B) * B
    nbr = n_rpad // B
    nbc = n_cpad // B
    K = int(max(1, min(k_max, nbc)))
    rows = _np(coo.rows)
    cols = _np(coo.cols)
    vals = _np(coo.vals)
    if build_blocks:
        blocks = np.zeros((nbr, K, B, B), np.float32)
        col_idx = np.zeros((nbr, K), np.int32)
        n_valid = np.zeros((nbr,), np.int32)

    if len(rows):
        brow = (rows // B).astype(np.int64)
        bcol = (cols // B).astype(np.int64)
        key = brow * nbc + bcol
        uniq, inv, counts = np.unique(key, return_inverse=True,
                                      return_counts=True)
        ubrow, ubcol = uniq // nbc, uniq % nbc
        # rank each block-row's blocks densest-first; the slot of a block is
        # its rank within its row (vectorized segmented rank: after the
        # lexsort rows are contiguous, so rank = index - first-in-group)
        order = np.lexsort((ubcol, -counts, ubrow))
        sorted_brow = ubrow[order]
        rank_sorted = (np.arange(len(uniq))
                       - np.searchsorted(sorted_brow, sorted_brow))
        slot = np.empty(len(uniq), np.int64)
        slot[order] = rank_sorted

        edge_slot = slot[inv]
        stored_m = edge_slot < K
        if build_blocks:
            sb = np.flatnonzero(slot < K)
            col_idx[ubrow[sb], slot[sb]] = ubcol[sb]
            n_valid[:] = np.minimum(np.bincount(ubrow, minlength=nbr), K)
            blocks[brow[stored_m], edge_slot[stored_m],
                   rows[stored_m] % B, cols[stored_m] % B] = vals[stored_m]
    else:
        stored_m = np.zeros(0, bool)

    bell = (BlockELL(n_rpad, n_cpad, B, K, f_tile_cap, budgeted=True,
                     blocks=blocks, col_idx=col_idx, n_valid=n_valid)
            if build_blocks else None)
    spill = coo_from_edges(n_rpad, n_cpad, rows[~stored_m], cols[~stored_m],
                           vals[~stored_m])
    stored = coo_from_edges(n_rpad, n_cpad, rows[stored_m], cols[stored_m],
                            vals[stored_m])
    return bell, spill, stored


def format_stats(fmt) -> dict:
    """Size/density statistics the selector's cost model consumes."""
    if isinstance(fmt, COO):
        return dict(kind="coo", nnz=fmt.nnz, n=fmt.n_rows, density=fmt.density)
    if isinstance(fmt, CSR):
        return dict(kind="csr", nnz=fmt.nnz, n=fmt.n_rows)
    if isinstance(fmt, ELL):
        return dict(kind="ell", n=fmt.n_rows, max_deg=fmt.max_deg,
                    padded=fmt.n_rows * fmt.max_deg)
    if isinstance(fmt, BlockDiag):
        return dict(kind="block_diag", n_blocks=fmt.n_blocks,
                    block_size=fmt.block_size, density=fmt.density)
    if isinstance(fmt, BlockELL):
        return dict(kind="bell", n_brow=fmt.n_brow, max_blocks=fmt.max_blocks,
                    block_size=fmt.block_size)
    raise TypeError(type(fmt))

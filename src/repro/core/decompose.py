"""Graph preprocessing: community-based reordering + N-way decomposition.

Paper §3.3: reorder with a community tool (METIS by default), then traverse
the edges once and split them by whether src and dst fall in the same
block of the (reordered) adjacency matrix diagonal.

METIS is not available offline; we provide two reorderers that play its role:
  * 'louvain'  -- networkx Louvain communities (quality ordering)
  * 'bfs'      -- deterministic BFS clustering (fast, no deps beyond numpy)
The reorder method is a parameter exactly as in the paper (§4.2: "the specific
reordering algorithm used in the backend has potential for future expansion";
§6.1 shows AdaptGear wins under both rabbit-order and METIS preprocessing).

Beyond the paper's two-way intra/inter split, ``decompose(...,
inter_buckets=k)`` partitions the inter-community edges into ``k`` density
tiers by block-row occupancy (TC-GNN-style: block-condensed formats justify
more than one sparse tier).  Each tier is a first-class :class:`Subgraph`
carrying its own density stats and candidate-format payloads, so the
selector can commit a different kernel per tier.  ``k=1`` reproduces the
paper-faithful two-subgraph behavior and is the default.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Sequence

import numpy as np

from repro.core import formats
from repro.graphs.graph import Graph
from repro.kernels.registry import DIAG, OFFDIAG, REGISTRY

Array = Any


# ---------------------------------------------------------------------------
# Community orderings
# ---------------------------------------------------------------------------

def bfs_reorder(n: int, senders: np.ndarray, receivers: np.ndarray,
                comm_size: int) -> np.ndarray:
    """Deterministic BFS clustering: grow clusters of exactly ``comm_size``
    by BFS from the lowest-degree unvisited vertex.  Returns perm such that
    new_id = perm[old_id]."""
    # adjacency as CSR (undirected view)
    und_s = np.concatenate([senders, receivers])
    und_r = np.concatenate([receivers, senders])
    order = np.argsort(und_s, kind="stable")
    und_s, und_r = und_s[order], und_r[order]
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(und_s, minlength=n), out=indptr[1:])
    deg = indptr[1:] - indptr[:-1]

    visited = np.zeros(n, bool)
    new_of_old = np.full(n, -1, np.int64)
    nxt = 0
    seeds = np.argsort(deg, kind="stable")
    seed_ptr = 0
    from collections import deque
    q: deque[int] = deque()
    while nxt < n:
        while seed_ptr < n and visited[seeds[seed_ptr]]:
            seed_ptr += 1
        if not q:
            if seed_ptr >= n:
                break
            q.append(int(seeds[seed_ptr]))
            visited[seeds[seed_ptr]] = True
        while q and nxt < n:
            v = q.popleft()
            new_of_old[v] = nxt
            nxt += 1
            for u in und_r[indptr[v]:indptr[v + 1]]:
                if not visited[u]:
                    visited[u] = True
                    q.append(int(u))
    assert nxt == n and (new_of_old >= 0).all()
    return new_of_old


def louvain_reorder(n: int, senders: np.ndarray, receivers: np.ndarray,
                    comm_size: int, seed: int = 0) -> np.ndarray:
    """Louvain community detection via networkx; communities are laid out
    contiguously, large communities chunked into comm_size groups."""
    import networkx as nx
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(zip(senders.tolist(), receivers.tolist()))
    comms = nx.community.louvain_communities(g, seed=seed)
    new_of_old = np.full(n, -1, np.int64)
    nxt = 0
    for comm in sorted(comms, key=len, reverse=True):
        for v in sorted(comm):
            new_of_old[v] = nxt
            nxt += 1
    assert nxt == n
    return new_of_old


REORDERERS = {"bfs": bfs_reorder, "louvain": louvain_reorder,
              "metis": louvain_reorder}

_SUBSTITUTIONS = {"metis": "louvain"}
_warned_substitutions: set = set()


def resolve_method(method: str) -> str:
    """Map unavailable reorderers to their stand-in, warning once."""
    effective = _SUBSTITUTIONS.get(method, method)
    if effective != method and method not in _warned_substitutions:
        _warned_substitutions.add(method)
        warnings.warn(
            f"reorder method {method!r} is unavailable offline; substituting "
            f"{effective!r} (recorded as stats['effective_method'])",
            UserWarning, stacklevel=3)
    return effective


# ---------------------------------------------------------------------------
# Decomposition result
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Subgraph:
    """One density tier of the decomposed graph.

    ``formats`` maps kernel name -> the payload that kernel's registry
    ``build`` produced (materialized once during preprocessing, paper §3.3,
    so the selector can probe kernels without re-conversion at runtime).
    """
    name: str = dataclasses.field(metadata=dict(static=True))
    kind: str = dataclasses.field(metadata=dict(static=True))   # diag|offdiag
    n_rows: int = dataclasses.field(metadata=dict(static=True))  # padded
    block_size: int = dataclasses.field(metadata=dict(static=True))
    formats: dict = None            # kernel name -> format payload
    stats: Any = dataclasses.field(default=None, metadata=dict(static=True))


@dataclass(frozen=True)
class Decomposed:
    """Reordered + decomposed graph: an ordered list of Subgraph entries
    (``subgraphs[0]`` is always the intra/diagonal tier, the rest are
    inter-community density buckets, sparsest first)."""
    n: int = dataclasses.field(metadata=dict(static=True))      # original nodes
    n_pad: int = dataclasses.field(metadata=dict(static=True))  # block multiple
    block_size: int = dataclasses.field(metadata=dict(static=True))
    perm: Array = None          # (n,) new_id of old_id
    inv_perm: Array = None      # (n,) old_id of new_id
    subgraphs: tuple = ()       # tuple[Subgraph, ...]
    stats: Any = dataclasses.field(default=None, metadata=dict(static=True))

    @property
    def intra(self) -> Subgraph:
        return self.subgraphs[0]

    @property
    def inters(self) -> tuple:
        return self.subgraphs[1:]

    def sub(self, name: str) -> Subgraph:
        for s in self.subgraphs:
            if s.name == name:
                return s
        raise KeyError(name)


import jax  # noqa: E402

jax.tree_util.register_dataclass(
    Subgraph, ["formats"], ["name", "kind", "n_rows", "block_size", "stats"])
jax.tree_util.register_dataclass(
    Decomposed, ["perm", "inv_perm", "subgraphs"],
    ["n", "n_pad", "block_size", "stats"])


def _tier_stats(kind: str, n_pad: int, block_size: int, rows: np.ndarray,
                cols: np.ndarray | None = None,
                edge_budget: int | None = None,
                bell_slack: float | None = None) -> dict:
    """Density statistics for one edge tier — everything the selectors, the
    PlanCache signature, and the format builders read.  Computed exactly
    once per tier per batch (the skeleton carries it forward to every
    materialization)."""
    nnz = len(rows)
    denom = (n_pad * block_size if kind == DIAG else n_pad * n_pad)
    n_brow = max(n_pad // block_size, 1)
    occ = (len(np.unique(np.asarray(rows) // block_size)) / n_brow
           if nnz else 0.0)
    # column occupancy: distinct (block-row, column) pairs per edge, in
    # (0, 1] — the column-condensability the tcgnn_tile kernel exploits.
    # Near 1.0 every edge owns a distinct condensed slot (no condensation);
    # low values mean few distinct columns absorb many edges (dense
    # condensed tiles, little padding).  The PlanCache signature bins it so
    # tile-condensability is visible to plan lookup.
    col_occ = 0.0
    if nnz and cols is not None:
        pairs = (np.asarray(rows, np.int64) // block_size) * np.int64(n_pad
                 ) + np.asarray(cols, np.int64)
        col_occ = len(np.unique(pairs)) / nnz
    stats = dict(nnz=nnz, density=nnz / max(denom, 1), brow_occupancy=occ,
                 col_occupancy=col_occ)
    if edge_budget:
        # budget-paddable builders key off this (blocked-ELL caps K from it)
        stats["edge_budget"] = int(edge_budget)
        if bell_slack is not None:
            # adapted blocked-ELL budget slack (PlanCache budget-K feedback)
            stats["bell_slack"] = float(bell_slack)
    return stats


def _materialize_subgraph(name: str, kind: str, n_pad: int, block_size: int,
                          rows: np.ndarray, cols: np.ndarray,
                          vals: np.ndarray, stats: dict,
                          kernels: Sequence[str] | None = None) -> Subgraph:
    """Materialize candidate format payloads for one tier, given its
    precomputed stats.  See :func:`build_subgraph` for semantics."""
    all_specs = REGISTRY.candidates(kind, include_fused=True)
    if kernels is not None:
        wanted = {REGISTRY.get(k).payload_key for k in kernels
                  if REGISTRY.get(k).applies_to(kind)}
        build_specs = [s for s in all_specs
                       if s.build is not None and s.name in wanted]
    else:
        build_specs = [s for s in all_specs if s.build is not None]
    stats = dict(stats)         # per-materialization copy ("kernels" differs)
    if build_specs:
        coo = formats.coo_from_edges(n_pad, n_pad, rows, cols, vals)
        # the transpose is only materialized when a candidate's VJP needs it
        coo_t = (formats.coo_from_edges(n_pad, n_pad, cols, rows, vals)
                 if any(s.wants_transpose(stats) for s in build_specs)
                 else None)
        fmts = {s.name: s.build(coo, coo_t, block_size, stats)
                for s in build_specs}
    else:
        # stats-only subgraph (kernels=()): the mini-batch hot path checks
        # the PlanCache before materializing any format
        fmts = {}
    stats["kernels"] = tuple(s.name for s in all_specs
                             if s.payload_key in fmts)
    return Subgraph(
        name=name, kind=kind, n_rows=n_pad, block_size=block_size,
        formats=fmts, stats=stats)


def build_subgraph(name: str, kind: str, n_pad: int, block_size: int,
                   rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                   kernels: Sequence[str] | None = None,
                   edge_budget: int | None = None) -> Subgraph:
    """Materialize every registered candidate format for one edge tier.

    ``kernels`` optionally restricts materialization (memory-lean mode for
    deployments that already know their plan); by default every registry
    candidate for the subgraph kind is built eagerly.  Fused kernels alias
    their unfused counterpart's payload (``KernelSpec.payload_of``): they
    never build anything, but requesting one materializes its base payload.
    Density stats are computed first and handed to each builder so formats
    can pick per-bucket tiling (blocked-ELL block size / feature-tile cap) —
    with ``edge_budget`` set, budget-paddable variants instead (blocked-ELL
    caps its stored-block count from the budget and spills the overflow).
    """
    stats = _tier_stats(kind, n_pad, block_size, rows, cols, edge_budget)
    return _materialize_subgraph(name, kind, n_pad, block_size, rows, cols,
                                 vals, stats, kernels)


def _bucket_inter(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                  n_brow: int, block_size: int, k: int,
                  keep_empty: bool = False) -> list[tuple]:
    """Partition inter edges into <=k tiers by destination block-row
    occupancy (sparsest tier first).  Tiers that receive no edges are
    dropped; k=1 (or an empty edge set) is the identity partition.

    ``keep_empty`` keeps empty tiers (as zero-edge entries) so the result
    always has exactly ``k`` buckets — the mini-batch path needs a fixed
    subgraph count across sampled batches so jitted steps never retrace."""
    if len(rows) == 0 or k <= 1:
        out = [(rows, cols, vals)]
        if keep_empty:
            empty = (rows[:0], cols[:0], vals[:0])
            out += [empty] * (k - len(out))
        return out
    brow = rows // block_size
    row_nnz = np.bincount(brow, minlength=n_brow)
    occupied = row_nnz[row_nnz > 0]
    # quantile thresholds over occupied block-rows; searchsorted maps each
    # block-row to its tier (0 = sparsest)
    qs = np.quantile(occupied, np.linspace(0.0, 1.0, k + 1)[1:-1])
    tier_of_row = np.searchsorted(qs, row_nnz, side="right")
    tier = tier_of_row[brow]
    out = []
    for t in range(k):
        m = tier == t
        if keep_empty or m.any():
            out.append((rows[m], cols[m], vals[m]))
    return out or [(rows, cols, vals)]


@dataclass(frozen=True)
class TierEdges:
    """One tier's partitioned edge arrays + precomputed density stats —
    everything a later materialization needs, so the partition pass never
    re-runs."""
    name: str
    kind: str                    # diag | offdiag
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    stats: dict


@dataclass(frozen=True)
class DecomposeSkeleton:
    """The single-pass decomposition skeleton (partition + stats, no format
    payloads).

    The mini-batch hot path partitions each batch's edges exactly once into
    this, runs the PlanCache lookup against :meth:`stats_only`, and then
    :meth:`materialize`\\ s only the payloads the committed plan dispatches
    (or the full candidate set when selection actually runs on a miss) —
    the double host-side decompose the old two-pass prepare paid is gone.
    """
    n: int
    n_pad: int
    block_size: int
    perm: np.ndarray             # (n,) int32 new_id of old_id
    inv_perm: np.ndarray
    tiers: tuple                 # tuple[TierEdges, ...], intra first
    stats: dict                  # whole-graph stats (decompose-compatible)

    def materialize(self, kernels=None, device: bool = False) -> Decomposed:
        """Build a :class:`Decomposed` from the skeleton: per-tier format
        payloads for ``kernels`` (None = every registry candidate, ``()``
        = stats-only), reusing the partition and stats already computed.

        ``kernels`` is either one name sequence applied to every tier, or
        a per-tier sequence of name collections (the committed-plan hot
        path: tier i materializes only what the plan dispatches on it).

        Payload leaves stay host numpy by default — right for the
        mini-batch hot loop, where each payload crosses the jit boundary
        exactly once as a traced argument (an eager device_put here would
        just add a host round-trip before fix_shapes).  Pass
        ``device=True`` for long-lived decompositions whose payloads are
        re-dispatched many times (the full-batch path): they are placed on
        device once so per-call kernels never re-upload them."""
        per_tier = (tuple(kernels)
                    if (kernels is not None and len(kernels) == len(self.tiers)
                        and not any(isinstance(k, str) for k in kernels))
                    else (kernels,) * len(self.tiers))
        subs = tuple(
            _materialize_subgraph(t.name, t.kind, self.n_pad,
                                  self.block_size, t.rows, t.cols, t.vals,
                                  t.stats, ks)
            for t, ks in zip(self.tiers, per_tier))
        if device:
            subs = tuple(
                dataclasses.replace(s, formats=jax.device_put(s.formats))
                for s in subs)
        return Decomposed(
            n=self.n, n_pad=self.n_pad, block_size=self.block_size,
            perm=self.perm, inv_perm=self.inv_perm, subgraphs=subs,
            stats=dict(self.stats))

    @property
    def subgraphs(self) -> tuple:
        """Duck-typed Decomposed view: TierEdges carry the same ``name`` /
        ``kind`` / ``stats`` attributes a Subgraph does, so stats readers
        (PlanCache signature/anchor) consume the skeleton directly without
        constructing a payload-free Decomposed first."""
        return self.tiers

    def stats_only(self) -> Decomposed:
        """Payload-free view for PlanCache signature/lookup, memoized: the
        hot loop reads it twice per batch (lookup + preserved signature)
        and it never changes once the skeleton exists."""
        cached = self.__dict__.get("_stats_only")
        if cached is None:
            cached = self.materialize(())
            object.__setattr__(self, "_stats_only", cached)
        return cached


def decompose_skeleton(graph: Graph, comm_size: int = 16,
                       method: str = "bfs",
                       edge_vals: np.ndarray | None = None,
                       reorder: bool = True, inter_buckets: int = 1,
                       keep_empty_buckets: bool = False,
                       edge_budget: int | None = None,
                       bell_slack: float | None = None) -> DecomposeSkeleton:
    """Steps 1-2 of the decomposition (reorder + partition + stats) as a
    reusable skeleton; :meth:`DecomposeSkeleton.materialize` is step 3.

    ``edge_budget`` marks the skeleton budget-paddable: it lands in every
    tier's stats, and format builders that support budget padding (the
    blocked-ELL K cap) key off it.  ``bell_slack`` rides along as the
    capped build's slack factor (the PlanCache's budget-K autotuner feeds
    observed spill back through it)."""
    n, B = graph.n, comm_size
    effective = method
    if reorder:
        effective = resolve_method(method)
        perm = REORDERERS[effective](n, graph.senders, graph.receivers, B)
    else:
        perm = np.arange(n, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n)

    rows = perm[graph.receivers]
    cols = perm[graph.senders]
    vals = (np.ones(len(rows), np.float32) if edge_vals is None
            else np.asarray(edge_vals, np.float32))

    n_pad = ((n + B - 1) // B) * B
    on_diag = (rows // B) == (cols // B)
    r_in, c_in, v_in = rows[on_diag], cols[on_diag], vals[on_diag]
    r_out, c_out, v_out = rows[~on_diag], cols[~on_diag], vals[~on_diag]

    def _tier(name, kind, r, c, v):
        # row-sort once here: every later materialization (possibly one per
        # cache outcome) then takes coo_from_edges' sorted fast path
        order = np.argsort(r, kind="stable")
        r, c, v = r[order], c[order], v[order]
        return TierEdges(name, kind, r, c, v,
                         _tier_stats(kind, n_pad, B, r, c, edge_budget,
                                     bell_slack))

    tiers = [_tier("intra", DIAG, r_in, c_in, v_in)]
    buckets = _bucket_inter(r_out, c_out, v_out, n_pad // B, B,
                            inter_buckets, keep_empty=keep_empty_buckets)
    for t, (rb, cb, vb) in enumerate(buckets):
        name = "inter" if len(buckets) == 1 else f"inter{t}"
        tiers.append(_tier(name, OFFDIAG, rb, cb, vb))

    return DecomposeSkeleton(
        n=n, n_pad=n_pad, block_size=B,
        perm=perm.astype(np.int32), inv_perm=inv.astype(np.int32),
        tiers=tuple(tiers),
        stats=dict(
            n=n, n_edges=len(rows), comm_size=B,
            method=method, effective_method=effective,
            inter_buckets=len(buckets),
            intra_edges=int(on_diag.sum()), inter_edges=int((~on_diag).sum()),
            intra_density=float(on_diag.sum()) / max(n_pad * B, 1),
            inter_density=float((~on_diag).sum()) / max(n_pad * n_pad, 1),
            subgraphs=tuple((t.name, t.stats["nnz"], t.stats["density"])
                            for t in tiers),
        ),
    )


def decompose(graph: Graph, comm_size: int = 16, method: str = "bfs",
              edge_vals: np.ndarray | None = None,
              reorder: bool = True, inter_buckets: int = 1,
              kernels: Sequence[str] | None = None,
              keep_empty_buckets: bool = False,
              edge_budget: int | None = None,
              bell_slack: float | None = None) -> Decomposed:
    """AG.graph_decompose equivalent (paper Fig. 7 line 19).

    1. community reordering (METIS-equivalent),
    2. one pass over edges: block(src) == block(dst) -> intra else inter,
       then the inter edges split into ``inter_buckets`` density tiers,
    3. materialize candidate formats for each subgraph via the kernel
       registry.
    Aggregation convention: rows = receivers (dst), cols = senders (src).

    ``keep_empty_buckets`` pins the bucket count at exactly
    ``inter_buckets`` (empty tiers included) so repeated per-batch
    decompositions share one pytree structure (sampling/plan_cache.py);
    ``edge_budget`` switches budget-paddable builders on (ditto).  Callers
    that need both a stats-only view *and* payloads should use
    :func:`decompose_skeleton` + ``materialize`` instead of calling this
    twice — the partition runs once per skeleton.

    Payloads are placed on device (``materialize(device=True)``): a
    decomposition built through this API is long-lived and re-dispatched
    every step, so the one-time transfer amortizes — unlike the mini-batch
    skeleton path, whose single-use payloads stay host-side until the jit
    boundary.
    """
    return decompose_skeleton(
        graph, comm_size=comm_size, method=method, edge_vals=edge_vals,
        reorder=reorder, inter_buckets=inter_buckets,
        keep_empty_buckets=keep_empty_buckets,
        edge_budget=edge_budget,
        bell_slack=bell_slack).materialize(kernels, device=True)


def decomposition_quality(dec: Decomposed) -> dict:
    """Fig. 4-style densities: full vs intra vs inter (buckets merged)."""
    s = dec.stats
    full_density = s["n_edges"] / max(dec.n_pad ** 2, 1)
    return dict(full=full_density, intra=s["intra_density"],
                inter=s["inter_density"],
                intra_frac=s["intra_edges"] / max(s["n_edges"], 1))

"""Graph preprocessing: community-based reordering + intra/inter decomposition.

Paper §3.3: reorder with a community tool (METIS by default), then traverse
the edges once and split them by whether src and dst fall in the same
block of the (reordered) adjacency matrix diagonal.

METIS is not available offline; we provide two reorderers that play its role:
  * 'louvain'  -- networkx Louvain communities (quality ordering)
  * 'bfs'      -- deterministic BFS clustering (fast, no deps beyond numpy)
The reorder method is a parameter exactly as in the paper (§4.2: "the specific
reordering algorithm used in the backend has potential for future expansion";
§6.1 shows AdaptGear wins under both rabbit-order and METIS preprocessing).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.core import formats
from repro.graphs.graph import Graph

Array = Any


# ---------------------------------------------------------------------------
# Community orderings
# ---------------------------------------------------------------------------

def bfs_reorder(n: int, senders: np.ndarray, receivers: np.ndarray,
                comm_size: int) -> np.ndarray:
    """Deterministic BFS clustering: grow clusters of exactly ``comm_size``
    by BFS from the lowest-degree unvisited vertex.  Returns perm such that
    new_id = perm[old_id]."""
    # adjacency as CSR (undirected view)
    und_s = np.concatenate([senders, receivers])
    und_r = np.concatenate([receivers, senders])
    order = np.argsort(und_s, kind="stable")
    und_s, und_r = und_s[order], und_r[order]
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(und_s, minlength=n), out=indptr[1:])
    deg = indptr[1:] - indptr[:-1]

    visited = np.zeros(n, bool)
    new_of_old = np.full(n, -1, np.int64)
    nxt = 0
    seeds = np.argsort(deg, kind="stable")
    seed_ptr = 0
    from collections import deque
    q: deque[int] = deque()
    while nxt < n:
        while seed_ptr < n and visited[seeds[seed_ptr]]:
            seed_ptr += 1
        if not q:
            if seed_ptr >= n:
                break
            q.append(int(seeds[seed_ptr]))
            visited[seeds[seed_ptr]] = True
        while q and nxt < n:
            v = q.popleft()
            new_of_old[v] = nxt
            nxt += 1
            for u in und_r[indptr[v]:indptr[v + 1]]:
                if not visited[u]:
                    visited[u] = True
                    q.append(int(u))
    assert nxt == n and (new_of_old >= 0).all()
    return new_of_old


def louvain_reorder(n: int, senders: np.ndarray, receivers: np.ndarray,
                    comm_size: int, seed: int = 0) -> np.ndarray:
    """Louvain community detection via networkx; communities are laid out
    contiguously, large communities chunked into comm_size groups."""
    import networkx as nx
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(zip(senders.tolist(), receivers.tolist()))
    comms = nx.community.louvain_communities(g, seed=seed)
    new_of_old = np.full(n, -1, np.int64)
    nxt = 0
    for comm in sorted(comms, key=len, reverse=True):
        for v in sorted(comm):
            new_of_old[v] = nxt
            nxt += 1
    assert nxt == n
    return new_of_old


REORDERERS = {"bfs": bfs_reorder, "louvain": louvain_reorder, "metis": louvain_reorder}


# ---------------------------------------------------------------------------
# Decomposition result
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Decomposed:
    """Reordered + decomposed graph, with every candidate format
    materialized once (preprocessing) so the adaptive selector can probe
    kernels without re-conversion at runtime."""
    n: int = dataclasses.field(metadata=dict(static=True))         # original node count
    n_pad: int = dataclasses.field(metadata=dict(static=True))     # padded to block multiple
    block_size: int = dataclasses.field(metadata=dict(static=True))
    perm: Array = None          # (n,) new_id of old_id
    inv_perm: Array = None      # (n,) old_id of new_id
    # intra-community candidates
    intra_bd: Any = None        # formats.BlockDiag
    intra_coo: Any = None       # formats.COO (padded ids)
    intra_ell: Any = None       # formats.ELL
    # inter-community candidates
    inter_bell: Any = None      # formats.BlockELL
    inter_bell_t: Any = None    # formats.BlockELL of A^T (for the VJP)
    inter_coo: Any = None       # formats.COO
    inter_ell: Any = None       # formats.ELL
    stats: Any = dataclasses.field(default=None, metadata=dict(static=True))


dataclasses_fields = [f.name for f in dataclasses.fields(Decomposed)]
import jax  # noqa: E402

jax.tree_util.register_dataclass(
    Decomposed,
    ["perm", "inv_perm", "intra_bd", "intra_coo", "intra_ell",
     "inter_bell", "inter_bell_t", "inter_coo", "inter_ell"],
    ["n", "n_pad", "block_size", "stats"],
)


def decompose(graph: Graph, comm_size: int = 16, method: str = "bfs",
              edge_vals: np.ndarray | None = None,
              reorder: bool = True) -> Decomposed:
    """AG.graph_decompose equivalent (paper Fig. 7 line 19).

    1. community reordering (METIS-equivalent),
    2. one pass over edges: block(src) == block(dst) -> intra else inter,
    3. materialize candidate formats for each subgraph.
    Aggregation convention: rows = receivers (dst), cols = senders (src).
    """
    n, B = graph.n, comm_size
    if reorder:
        perm = REORDERERS[method](n, graph.senders, graph.receivers, B)
    else:
        perm = np.arange(n, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n)

    rows = perm[graph.receivers]
    cols = perm[graph.senders]
    vals = (np.ones(len(rows), np.float32) if edge_vals is None
            else np.asarray(edge_vals, np.float32))

    n_pad = ((n + B - 1) // B) * B
    on_diag = (rows // B) == (cols // B)
    r_in, c_in, v_in = rows[on_diag], cols[on_diag], vals[on_diag]
    r_out, c_out, v_out = rows[~on_diag], cols[~on_diag], vals[~on_diag]

    intra_coo = formats.coo_from_edges(n_pad, n_pad, r_in, c_in, v_in)
    inter_coo = formats.coo_from_edges(n_pad, n_pad, r_out, c_out, v_out)
    inter_coo_t = formats.coo_from_edges(n_pad, n_pad, c_out, r_out, v_out)

    dec = Decomposed(
        n=n, n_pad=n_pad, block_size=B,
        perm=perm.astype(np.int32), inv_perm=inv.astype(np.int32),
        intra_bd=formats.coo_to_blockdiag(intra_coo, B),
        intra_coo=intra_coo,
        intra_ell=formats.coo_to_ell(intra_coo),
        inter_bell=formats.coo_to_bell(inter_coo, B),
        inter_bell_t=formats.coo_to_bell(inter_coo_t, B),
        inter_coo=inter_coo,
        inter_ell=formats.coo_to_ell(inter_coo),
        stats=dict(
            n=n, n_edges=len(rows), comm_size=B, method=method,
            intra_edges=int(on_diag.sum()), inter_edges=int((~on_diag).sum()),
            intra_density=float(on_diag.sum()) / max(n_pad * B, 1),
            inter_density=float((~on_diag).sum()) / max(n_pad * n_pad, 1),
        ),
    )
    return dec


def decomposition_quality(dec: Decomposed) -> dict:
    """Fig. 4-style densities: full vs intra vs inter."""
    s = dec.stats
    full_density = s["n_edges"] / max(dec.n_pad ** 2, 1)
    return dict(full=full_density, intra=s["intra_density"],
                inter=s["inter_density"],
                intra_frac=s["intra_edges"] / max(s["n_edges"], 1))

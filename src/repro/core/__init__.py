"""AdaptGear core: adaptive subgraph-level GNN aggregation.

Architecture (data flow, one arrow per module boundary):

  graphs.Graph
      |  core.decompose.decompose(..., inter_buckets=k)   [k=0: autotuned]
      v
  Decomposed -- an ordered list of Subgraph density tiers: the intra
      |         (block-diagonal) tier plus k inter-community buckets split
      |         by block-row occupancy.  Each Subgraph eagerly materializes
      |         one format payload per applicable kernel, built by the
      |         kernel registry (kernels.registry.REGISTRY); builders see
      |         the tier's density stats and pick per-bucket tiling (the
      |         blocked-ELL block size / feature-tile cap).  Fused kernels
      |         alias their unfused counterpart's payload — zero extra
      |         device memory.
      |  core.selector (feedback probe | analytic cost model), candidates
      |  enumerated from the registry per subgraph; on transform-first
      |  layers fused transform+aggregate kernels compete: the cost
      |  model surcharges unfused candidates their share of the shared
      |  H = X W pass, the feedback probe times it.  Every model's dense
      |  epilogue is described by a core.epilogue.EpilogueSpec (linear =
      |  GCN bias, dual = SAGE's W_self x + W_neigh agg with the mean
      |  norm baked into the edge values, mlp = GIN's 2-layer MLP whose
      |  W1 pushes through the aggregation by linearity): the spec makes
      |  GIN/SAGE transform-first too, zeroes the unfused surcharge where
      |  the epilogue's self term computes H anyway (mlp free_transform),
      |  and adds the flat dense epilogue terms to whole-layer totals
      v
  core.plan.KernelPlan -- per-layer x per-subgraph kernel names (+ the
      |  per-layer EpilogueSpecs the plan was selected under)
      |  core.adaptgear.aggregate / aggregate_transform(_dual) /
      |  core.gnn.forward
      v
  Y = sum_s A_s @ X   (or A_s @ (X W) + seed fused — the seed carries the
  epilogue self terms: GCN's bias, SAGE's X W_self, GIN's (1+eps) X W1),
  each subgraph dispatched through its registered kernel:
    * unfused matvec      -- Pallas MXU block kernels, XLA gather/segment
    * matvec_acc          -- accumulation mode: one output buffer threads
                             through the subgraph list, Pallas kernels seed
                             their VMEM scratch from it (no per-bucket
                             partial tensors); enabled on TPU, where it
                             saves HBM rather than costing interpret steps
    * fused_matvec(_acc)  -- A_s @ (X W) in one pass: the weight stripe
                             lives in VMEM and the transform product is
                             consumed immediately; the custom VJP runs the
                             same fused form over the materialized transpose
                             payload for dX and a blocked dW reduction —
                             no (n, F) intermediate in forward or backward.
                             CSR/sell-C-sigma get per-edge gathered-
                             transform fused paths (csr_fused, sell_fused)
    * fused_dual_matvec   -- the dual-weight epilogue on the diagonal tier:
                             X W_self + A (X W_neigh) with BOTH stripes in
                             VMEM (the row block is its own source block),
                             gated on accumulation mode like matvec_acc

Adding a kernel = one KernelSpec registration (name, kinds, format builder,
matvec / fused_matvec, cost fn) in one file — kernels/csr.py is the
template (kernels/sell_cs.py, the degree-sorted sell-C-sigma format, is a
second instance; kernels/tcgnn_tile.py, the column-condensed dense-tile
format that routes mid-density tiers through the MXU, a third);
decomposition, both selectors, dispatch, and the benchmarks pick it up
with no further edits.

Mini-batch mode (graphs too large for full-batch; repro.sampling +
train/gnn_steps.py) prepends a sampling stage and amortizes selection with
a SINGLE-PASS skeleton prepare:

  graphs.Graph
      |  sampling.sampler: ClusterSampler (community blocks = the
      |  decomposition's diagonal blocks, reusing the same orderings) or
      |  NeighborSampler (layer-wise fanouts, loss on seeds only)
      v
  SampledBatch -- fixed node/edge budgets, masked loss: every batch is one
      |           pytree shape, so the jitted step compiles once
      |  core.decompose.decompose_skeleton(reorder=False,
      |  keep_empty_buckets=True, edge_budget=...)   [ONE partition+stats
      |  pass per batch; tiers row-sorted once, payloads NOT built yet]
      v
  DecomposeSkeleton -- per-tier edge arrays + density stats (repeated
      |  cluster tuples skip even this: a small LRU keyed by the drawn
      |  tuple memoizes the skeleton, cfg.skeleton_cache_entries)
      |  sampling.plan_cache.PlanCache.lookup(skel): quantized density
      |  signature (per-tier log2-nnz + block-row occupancy) -> memoized
      |  KernelPlan, read straight off the skeleton's tier stats;
      |  cost-model selection on a miss only (materializing the full
      |  MB_KERNELS candidate set from the same skeleton); probe-on-Nth-
      |  miss (cfg.probe_every) wall-clocks the modeled frontier — top-2,
      |  widened up to cfg.probe_k_max when the modeled margin sits
      |  inside the model's own observed error band, capped at
      |  cfg.probe_budget_s wall seconds — and pins the measured winner
      |  in the cached entry.  With cfg.adapt_budget_k the cache also
      |  feeds committed capped-bell spill back into the blocked-ELL
      |  budget cap's slack factor (padding waste vs spill volume per
      |  workload; the adapted slack keys the signature)
      v
  skel.materialize(plan_payload_keys(plan)) -- tier i builds only the
      |  payloads the committed plan dispatches on tier i; the edges are
      |  never re-partitioned (the old two-pass prepare decomposed twice)
      v
  train.gnn_steps.make_sampled_step -- jit step(params, opt, dec, batch);
  fix_shapes pads COO/CSR payloads to the edge budget, scrubs per-batch
  stats, and stamps the plan's quantized signature bins (one canonical
  value per step function) so the traced Decomposed never changes
  structure (no retrace) yet stays debuggable

With cfg.prefetch_depth > 0 the whole host-side column above runs on
background threads (train.pipeline.BatchPipeline) in three stages:
cfg.pipeline_workers producers draw deterministic per-index sampler
tickets (sampler.draw / sampler.build — batch i is a pure function of
(seed, i), so the async stream is bit-identical to the sync one) and
race the heavy order-independent work (build + skeleton partition,
then fix_shapes padding + device staging + AOT pre-compile of novel
payload shapes), while every shared-cache decision in between —
PlanCache lookup/selection, spill feedback, signature seeding — runs
through an index-ordered resolve turnstile, up to prefetch_depth
batches ahead behind a bounded semaphore; the training loop is a pure
consumer dequeuing ready batches in index order, so one iteration pays
max(compute, prepare) instead of their sum.  PlanCache/SkeletonCache
are lock-protected (atomic plan_for: racing workers on one fresh
signature pay exactly one miss), and the ordered resolve stage is what
makes the cache counters, LRU/aliasing order, and hit history — not
just the batch stream — bit-identical to sync; backpressure counters
(queue-full / queue-empty waits, mean ready depth, starvation
warn-once) surface through MinibatchResult.pipeline.

Fault tolerance (repro.distributed.checkpoint + fault_tolerance, wired
into train/gnn_steps.py) layers four mechanisms over that loop without
touching the determinism contract:

  * crash-safe checkpoint/resume -- with cfg.checkpoint_dir set,
    CheckpointManager snapshots params + opt state (npz, crc32 manifest,
    atomic tmp-dir+rename, async writer) every cfg.checkpoint_every
    batches together with an aux payload: the batch cursor, loss/hit
    history, the PlanCache state_dict, and the committed plans +
    canonical signatures in step-fn order.  The cache/plan snapshot is
    captured inside the index-ordered resolve turnstile (consume-time
    cache state already holds future prefetched batches' decisions) and
    committed when the cursor batch retires, so cfg.resume_from
    fast-forwards the sampler draw stream and restarts mid-epoch
    bit-identical to the uninterrupted run -- losses, hit history,
    committed plans, cache counters.  (n_traces is the one field not
    comparable across a resume: restored plans re-trace lazily.)
  * transient-failure retry -- cfg.retry_max wraps batch build and the
    racing pipeline stages in ft.RetryPolicy: bounded exponential
    backoff, interruptible (close() cancels a sleeping retry), with
    fatal-vs-transient classification (ft.default_transient) so real
    bugs still fail fast.
  * kernel quarantine -- a Pallas compile/execute failure quarantines
    the (kernel, signature) pair in the PlanCache, purges the poisoned
    entry, and re-selects the next-best plan from the surviving
    candidate set; the XLA coo floor is never quarantined, so
    degradation always terminates.  Failed lowerings and failed plans
    are memoized, preserving traces == len(plans).
  * non-finite guard -- cfg.nonfinite_guard checks loss and grads
    inside the jitted step and no-ops the param/opt update on a
    non-finite result (the loss is still recorded; the skip is counted).

All four surface counters through MinibatchResult.faults (retries,
quarantined, recoveries, nonfinite_skips, checkpoints, resumed_at), and
ft.FaultPlan is a deterministic fault-injection harness (worker
exceptions, compile/execute kernel faults, non-finite losses, simulated
crashes at chosen batch indices) driving the fault-tolerance tests and
benchmarks/robustness.py.

Observability (repro.obs, wired through the whole column above) is one
Telemetry facade with three instruments and a hard contract:

  * span tracer -- every pipeline stage (draw -> build -> resolve ->
    finish -> device step), checkpoint write, probe, and retry backoff
    opens a thread-attributed span; export is Chrome trace-event JSON
    (cfg.trace_out), one swim lane per worker thread, so the overlap the
    pipeline claims is inspectable per run.
  * metrics registry -- thread-safe counters/gauges/bounded histograms
    (p50/p99) that PlanCache, BatchPipeline, CheckpointManager, and the
    fault-tolerance loop publish into; the legacy dict views
    (PlanCache.stats, BatchPipeline.stats, MinibatchResult.cache /
    pipeline / faults) are assembled FROM the registry with unchanged
    keys, and the registry is always live (counters are the system of
    record even with telemetry off).
  * selector audit -- every minted plan recorded with its per-(layer,
    tier) kernel choice and modeled seconds, every probe as a
    (kernel, modeled, measured) pair, quarantine/degrade events, and
    observed per-plan step times; SelectorAudit.calibration() derives
    the per-kernel predicted-vs-measured error report surfaced through
    MinibatchResult.telemetry and exported as JSONL (cfg.telemetry_out).

Contract: telemetry is append-only and never read by selection, the
cache, or the pipeline -- enabling it leaves losses, committed plans,
hit history, and trace counts bit-identical (tests/test_obs.py); with
telemetry off (the default) call sites pay only null-object hooks,
measured by benchmarks/minibatch.py (telemetry_overhead_pct) and gated
below 2% of the per-batch prepare cost in CI.

MB_KERNELS membership rule: a kernel is admissible iff its payload has a
fixed pytree shape *at the edge budget* — every array dim a function of
(edge budget, node budget, block size), nothing data-dependent.  BlockDiag
is shape-fixed by (n_pad, B); COO/CSR pad to the budget; blocked-ELL
qualifies via its budget-padded variant: K capped at
formats.bell_budget_k(budget, n_pad, B), block payloads padded to the cap
with masked zero-blocks, overflow edges spilled to an in-payload COO tier
(aggregated by segment-sum unfused, by per-edge gathered transform fused).
tcgnn_tile qualifies the same way: its condensed-column count C — normally
the data-dependent max distinct columns per block row — is capped at
tcgnn_budget_c(budget, n_pad, B) (lane-aligned, slack-scaled mean columns
per block row under the budget), tiles and gather index padded to the cap
with masked zero slots, and edges beyond a block row's cap spilled to the
same in-payload COO tier; the budgeted triple replaces the uncapped
payload pair, whose C would retrace on every batch.  ELL stays
full-batch-only (max-degree width is data-dependent).

Online inference serving (repro.serve, driven by repro.launch.serve and
benchmarks/serving.py) is the read path over a trained model — the same
sampled column as mini-batch training, forward-only, under deadlines:

  submit(node, deadline) -> serve.admission.AdmissionController
      |  bounded FIFO, shed at submit time when the queue is full OR the
      |  EWMA-predicted wait already blows the request's deadline (a shed
      |  future resolves immediately; serving it late helps nobody)
      v
  collect() -- deadline-aware micro-batch: block for the first request,
      |  coalesce arrivals until the size target (max_batch) or until
      |  waiting longer would eat the earliest deadline's service slack,
      |  whichever first (max_wait_s caps a lone request's wait);
      |  requests whose slack no longer covers one service time expire
      |  as ``timeout`` here — *before* dispatch, never after
      v
  serve.ego.EgoNetSampler.build -- NeighborSampler.ego_ticket: the
      |  caller's deduped seed set through the sampler's pure fixed-
      |  budget build (bit-identical to training batches for the same
      |  seeds+index; a retried build reproduces its batch exactly);
      |  transient failures absorbed by ft.RetryPolicy with decorrelated
      |  jitter (seeded: deterministic per run index, decorrelated
      |  across concurrent retries)
      v
  prepare_skeleton -> PlanCache lookup/plan_for -> fix_shapes at the
      |  rung's pad budget -> AOT executable keyed (plan, shapes) —
      |  compiled at warmup, which preloads a PlanCache.save/load disk
      |  snapshot (crc-checked atomic write; corruption falls back to
      |  cold start) and AOT-warms the full (plan x rung) cross product,
      |  so a warm-started server records ZERO new traces in steady
      |  state (n_traces is the observable, gated by serve_warm_traces
      |  in CI)
      v
  logits -> per-request futures (status ok/shed/timeout/error)

Resilience invariants (tests/test_serving.py + the CI serving-smoke
job): an ADMITTED request that reaches dispatch is never dropped — a
kernel fault on its batch quarantines the implicated kernels in the
shared PlanCache and re-serves the same batch on the re-selected plan
(the coo floor terminates escalation); overload is answered by shedding
and by serve.degrade.DegradationLadder stepping the fanout rungs down
to a cheaper pre-compiled shape — hysteretic (down_after <
up_after, post-transition cooldown), so an alternating load signal
never moves the rung; load generation in benchmarks/serving.py is
open-loop (arrivals do not slow when the server does), with rates
derived from the server's own measured capacity so the overload window
overloads any machine.
"""

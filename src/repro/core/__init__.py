"""AdaptGear core: adaptive subgraph-level GNN aggregation.

Architecture (data flow, one arrow per module boundary):

  graphs.Graph
      |  core.decompose.decompose(..., inter_buckets=k)
      v
  Decomposed -- an ordered list of Subgraph density tiers: the intra
      |         (block-diagonal) tier plus k inter-community buckets split
      |         by block-row occupancy.  Each Subgraph eagerly materializes
      |         one format payload per applicable kernel, built by the
      |         kernel registry (kernels.registry.REGISTRY).
      |  core.selector (feedback probe | analytic cost model), candidates
      |  enumerated from the registry per subgraph
      v
  core.plan.KernelPlan -- per-layer x per-subgraph kernel names
      |  core.adaptgear.aggregate / core.gnn.forward / train_step
      v
  Y = sum_s A_s @ X, each subgraph dispatched through its registered
  kernel's matvec (Pallas MXU block kernels, XLA gather/segment paths).

Adding a kernel = one KernelSpec registration (name, kinds, format builder,
matvec, cost fn); decomposition, both selectors, dispatch, and the
benchmarks pick it up with no further edits.
"""

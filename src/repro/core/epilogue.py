"""Epilogue specifications: the dense per-model compute fused *around* the
sparse aggregation.

AdaptGear's fused kernels were introduced for GCN's transform-first layer
``Y = A (X W) + b``.  GIN and SAGE wrap the same aggregation in different
dense epilogues — and because aggregation is linear, each epilogue's weight
can be pushed *through* the aggregation so the fused kernels apply:

  linear (GCN)   Y = A (X W) + b
  dual   (SAGE)  Y = X W_self + A (X W_neigh) + b
                 (mean normalization baked into the decomposition's edge
                 values, exactly like GCN's symmetric norm — see
                 ``core.gnn.prepare``; row scaling commutes with the right
                 weight multiply, so ``mean(A@X) @ W == (D^-1 A) @ (X W)``)
  mlp    (GIN)   Y = relu((1+eps) S + A (X W1) + b1) W2 + b2,  S = X W1
                 (the shared first-layer transform ``S`` is needed by the
                 self term anyway, so unfused aggregation candidates get
                 it for free — ``free_transform``).  When the raw input is
                 narrower than the MLP hidden width the rewrite widens the
                 sparse pass, so GIN layers carry a per-layer ``structure``
                 choice: transform-first (above) vs. aggregate-first
                 ``Y = MLP((1+eps) X + A X)`` — priced against each other
                 by the selector (``gin_structure_candidates``)

An :class:`EpilogueSpec` is a tiny frozen (hashable) record of that shape.
It is threaded from ``core.gnn`` through :class:`~repro.core.plan.KernelPlan`
into both selector modes, where it changes the honest fused-vs-unfused
comparison in two ways:

  * ``free_transform`` (mlp): unfused candidates pay *no* share of the
    shared ``H = X W`` transform — the epilogue's self term computes it
    regardless — so fused candidates must win on bandwidth alone;
  * ``epilogue_cost``: the dense terms every candidate pays alike (the
    dual self matmul, the MLP's second layer) enter whole-layer totals
    (``plan_layer_cost``, bucket autotuning) so layer structure is priced
    end to end, not just the sparse part.

Dispatch lives in ``core.adaptgear`` (``gcn_conv`` / ``gin_conv`` /
``sage_conv`` + ``aggregate_transform(_dual)``); the kernel layer's
contribution is the dual-weight Pallas variant (both stripes in VMEM,
``kernels.block_diag_spmm_fused``) and the per-edge gathered-transform
fused paths for CSR / sell-C-sigma.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class EpilogueSpec:
    """Shape of the dense epilogue around one layer's aggregation.

    ``kind``       -- "linear" | "dual" | "mlp"
    ``bias``       -- epilogue adds a bias (rides the accumulator seed)
    ``activation`` -- nonlinearity applied to the aggregated sum before the
                      epilogue's second stage (mlp: "relu")
    ``mean_norm``  -- aggregation is degree-normalized; baked into the
                      decomposition's edge values at prepare time so the
                      sparse kernels need no per-row rescale
    ``out_dim``    -- mlp only: the second matmul's output width (the
                      aggregated width itself is the MLP hidden width,
                      carried separately as the layer's ``(in, agg)`` pair)
    ``structure``  -- mlp only: "transform_first" aggregates at the MLP
                      hidden width with W1 pushed through the aggregation;
                      "aggregate_first" aggregates the raw features and
                      runs the whole MLP after (cheaper sparse pass when
                      the input is narrower than the hidden width)
    ``hidden``     -- mlp aggregate_first only: the MLP hidden width.  The
                      transform-first spec reads it off the layer's
                      ``(in, agg)`` pair (agg == hidden there), but the
                      aggregate-first pair is ``(None, in_dim)``, so the
                      hidden width must ride the spec for the dense MLP
                      terms to price.
    """
    kind: str
    bias: bool = True
    activation: str | None = None
    mean_norm: bool = False
    out_dim: int = 0
    structure: str = "transform_first"
    hidden: int = 0

    @property
    def free_transform(self) -> bool:
        """True when the epilogue's self term already computes the shared
        transform ``H = X W`` the unfused candidates aggregate — so the
        selector must not surcharge them for it.  Aggregate-first MLP
        layers aggregate raw features (no transform exists to share)."""
        return self.kind == "mlp" and self.structure == "transform_first"


def layer_epilogues(model: str, dims: list, hidden: int) -> tuple:
    """Per-layer epilogue specs for a model over its width chain ``dims``
    (``[in_dim, hidden, ..., n_classes]``).  ``None`` entries mean the layer
    aggregates raw features with no fusable epilogue (GAT)."""
    n_layers = len(dims) - 1
    if model == "gcn":
        return tuple(EpilogueSpec(kind="linear") for _ in range(n_layers))
    if model == "sage":
        return tuple(EpilogueSpec(kind="dual", mean_norm=True)
                     for _ in range(n_layers))
    if model == "gin":
        # Dec-free structure rule: aggregate-first iff the raw input is
        # narrower than the MLP hidden width.  Kernel costs are (to first
        # order) linear in the aggregated feature width and the dense MLP
        # flops are identical under both orderings, so in the dec-free
        # limit the priced comparison (gin_structure_specs + plan_layer_
        # cost, used by the full-batch path) reduces to this width test.
        return tuple(gin_layer_spec(dims[i], hidden, dims[i + 1],
                                    structure=("aggregate_first"
                                               if dims[i] < hidden
                                               else "transform_first"))
                     for i in range(n_layers))
    return tuple(None for _ in range(n_layers))


def gin_layer_spec(fin: int, hidden: int, out_dim: int,
                   structure: str) -> EpilogueSpec:
    """One GIN layer's EpilogueSpec under a chosen structure."""
    return EpilogueSpec(kind="mlp", activation="relu", out_dim=out_dim,
                        structure=structure,
                        hidden=hidden if structure == "aggregate_first" else 0)


def gin_structure_candidates(fin: int, hidden: int, out_dim: int) -> tuple:
    """Both structure candidates for one GIN layer, as
    ``((pair, spec), (pair, spec))`` aligned for a priced comparison:

      transform-first:  pair (fin, hidden)  — W1 pushed through, fused
                        kernels compete on A (X W1)
      aggregate-first:  pair (None, fin)    — raw-width aggregation, the
                        whole MLP runs after; fused kernels sit out

    The caller (``core.gnn.layer_plan_inputs``) prices each with
    ``selector.plan_layer_cost`` — which folds in ``epilogue_cost``, so the
    identical dense MLP terms cancel and the decision is carried by the
    sparse pass width plus fused-kernel availability."""
    tf = ((fin, hidden), gin_layer_spec(fin, hidden, out_dim,
                                        "transform_first"))
    af = ((None, fin), gin_layer_spec(fin, hidden, out_dim,
                                      "aggregate_first"))
    return tf, af


def epilogue_cost(spec: EpilogueSpec | None, n: int, fin: int | None,
                  agg_dim: int, dtype=np.float32, hw=None) -> float:
    """Roofline seconds of the dense epilogue compute *every* candidate
    pays alike (it cannot be avoided by kernel choice, so it never changes
    the per-subgraph ranking — it enters whole-layer totals so structures
    with different hidden widths compare honestly)."""
    if spec is None or hw is None or spec.kind == "linear":
        return 0.0          # the bias seeds the accumulator: no extra pass
    be = np.dtype(dtype).itemsize
    if spec.kind == "mlp" and spec.structure == "aggregate_first":
        # the whole MLP runs after the raw-width aggregation: here
        # ``agg_dim`` is the raw input width (the pair is (None, in_dim))
        # and the hidden width rides the spec.  z = (1+eps)x + agg is an
        # elementwise pass; then relu(z W1 + b1) W2 + b2.
        h = spec.hidden
        flops = 2.0 * n * agg_dim * h + 2.0 * n * h * spec.out_dim
        bytes_ = (3.0 * n * agg_dim + agg_dim * h + 2.0 * n * h
                  + h * spec.out_dim + n * spec.out_dim) * be
        return (max(flops / hw.peak_flops, bytes_ / hw.hbm_bw)
                + hw.launch_overhead_s)
    if fin is None:
        return 0.0
    if spec.kind == "dual":
        # self matmul X W_self + the combine add into the aggregated sum
        flops = 2.0 * n * fin * agg_dim
        bytes_ = (n * fin + fin * agg_dim + 3.0 * n * agg_dim) * be
    elif spec.kind == "mlp":
        # S = X W1 (shared with unfused aggregation: free_transform) plus
        # the activation pass and the second matmul at the hidden width
        flops = 2.0 * n * fin * agg_dim + 2.0 * n * agg_dim * spec.out_dim
        bytes_ = (n * fin + fin * agg_dim + 4.0 * n * agg_dim
                  + agg_dim * spec.out_dim + n * spec.out_dim) * be
    else:
        raise ValueError(f"unknown epilogue kind {spec.kind!r}")
    return (max(flops / hw.peak_flops, bytes_ / hw.hbm_bw)
            + hw.launch_overhead_s)

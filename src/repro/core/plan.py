"""Execution plans: per-layer x per-subgraph kernel choices.

A :class:`KernelPlan` is the first-class object the selectors produce and
aggregation/training consume (decompose -> registry -> plan -> aggregate).
Each layer entry is a tuple of kernel names aligned with
``Decomposed.subgraphs``.

For ergonomics (and paper fidelity, where the plan is just an
``(intra, inter)`` pair), ``normalize_layer`` accepts the 2-tuple shorthand
and broadcasts the inter choice across every inter density bucket.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.decompose import Decomposed, Subgraph
from repro.kernels.registry import REGISTRY


def _validate(sub: Subgraph, kernel: str) -> str:
    spec = REGISTRY.get(kernel)            # raises on unknown name
    if not spec.applies_to(sub.kind):
        raise ValueError(
            f"kernel {kernel!r} does not apply to subgraph {sub.name!r} "
            f"(kind={sub.kind!r})")
    # fused kernels alias their unfused counterpart's payload
    if spec.payload_key not in sub.formats:
        raise ValueError(
            f"kernel {kernel!r} has no materialized format on subgraph "
            f"{sub.name!r}; available: {tuple(sub.formats)}")
    return kernel


def normalize_layer(dec: Decomposed, choice: Sequence[str]) -> tuple[str, ...]:
    """Normalize one layer's kernel choice to a per-subgraph name tuple.

    Accepts either a full per-subgraph tuple or the paper's
    ``(intra_kernel, inter_kernel)`` pair, broadcast over inter buckets.
    """
    if isinstance(choice, str):
        raise TypeError("kernel choice must be a sequence of names, "
                        f"got {choice!r}")
    names = tuple(choice)
    n_sub = len(dec.subgraphs)
    if len(names) == 2 and n_sub != 2:
        names = (names[0],) + (names[1],) * (n_sub - 1)
    if len(names) != n_sub:
        raise ValueError(
            f"plan layer has {len(names)} kernels for {n_sub} subgraphs")
    return tuple(_validate(s, k) for s, k in zip(dec.subgraphs, names))


@dataclass(frozen=True)
class KernelPlan:
    """Per-layer x per-subgraph kernel assignment.

    ``epilogues`` optionally records the per-layer
    :class:`~repro.core.epilogue.EpilogueSpec` the plan was selected under
    (None per layer when the layer aggregates raw features).  It rides the
    plan so the dense epilogue shape the selector priced is visible at
    dispatch and in benchmarks — ``plan.layers`` alone stays the step-fn
    cache key (the epilogue is a function of the model config, identical
    for every plan a training run produces)."""
    subgraph_names: tuple      # aligned with Decomposed.subgraphs
    layers: tuple              # tuple[tuple[str, ...], ...]
    epilogues: tuple | None = None   # tuple[EpilogueSpec | None, ...] | None

    def for_layer(self, i: int) -> tuple:
        return self.layers[i]

    def epilogue_for_layer(self, i: int):
        return self.epilogues[i] if self.epilogues is not None else None

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    @classmethod
    def make(cls, dec: Decomposed, choices, n_layers: int | None = None,
             epilogues: tuple | None = None) -> "KernelPlan":
        """Build a validated plan.

        ``choices`` is a KernelPlan (re-validated), one layer choice
        (broadcast to ``n_layers``), or a list with one entry per layer.
        """
        sub_names = tuple(s.name for s in dec.subgraphs)
        if isinstance(choices, KernelPlan):
            if n_layers is not None and len(choices.layers) != n_layers:
                raise ValueError(f"plan has {len(choices.layers)} layers, "
                                 f"model has {n_layers}")
            layers = tuple(normalize_layer(dec, c) for c in choices.layers)
            return cls(sub_names, layers, epilogues or choices.epilogues)
        if (isinstance(choices, (tuple, list)) and choices
                and isinstance(choices[0], str)):
            layer = normalize_layer(dec, choices)
            layers = (layer,) * (n_layers or 1)
        else:
            layers = tuple(normalize_layer(dec, c) for c in choices)
            if n_layers is not None and len(layers) != n_layers:
                raise ValueError(
                    f"plan has {len(layers)} layers, model has {n_layers}")
        if epilogues is not None and len(epilogues) != len(layers):
            raise ValueError(
                f"plan has {len(layers)} layers but {len(epilogues)} "
                f"epilogue specs")
        return cls(sub_names, layers, epilogues)

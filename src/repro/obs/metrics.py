"""Thread-safe metrics registry: counters, gauges, bounded histograms.

The registry replaces the parallel ad-hoc counter dicts that grew across
PlanCache / BatchPipeline / the fault-tolerance loop: each component
creates its instruments from a :class:`MetricsRegistry` (its own private
one by default, the run's shared one when a ``Telemetry`` object is
threaded through) and publishes into them; the legacy views —
``PlanCache.stats``, ``BatchPipeline.stats``, ``MinibatchResult.faults``
— are *assembled from* the registry, so their keys and semantics are
unchanged and existing tests keep passing.

Unlike the tracer and the audit log, the registry is always live (there
is no "disabled" registry): an increment is one lock acquire plus an
add, cheap enough that per-batch bookkeeping never needs gating.  In
CPython ``x += 1`` is *not* atomic across threads (read-modify-write
spans bytecodes), which is exactly the bug class the racing pipeline
workers would hit with bare attributes — every instrument carries its
own lock instead.

Instruments:

* :class:`Counter` — monotonic-ish accumulator (float adds allowed: the
  pipeline's wait-time totals are counters of seconds).  ``set`` exists
  for checkpoint restore.
* :class:`Gauge` — last-value instrument (resume cursor, ladder slack).
* :class:`Histogram` — bounded-window distribution: total count/sum are
  exact forever, percentiles (p50/p99) are computed over the last
  ``window`` observations so memory stays O(window) on long runs.
"""
from __future__ import annotations

import threading
from collections import deque

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    add = inc

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value


class Gauge:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value


class Histogram:
    """Bounded-window histogram: exact count/sum, windowed percentiles."""
    __slots__ = ("name", "_lock", "_window", "count", "total")

    def __init__(self, name: str, window: int = 1024):
        self.name = name
        self._lock = threading.Lock()
        self._window: deque = deque(maxlen=window)
        self.count = 0
        self.total = 0.0

    def observe(self, v) -> None:
        with self._lock:
            self.count += 1
            self.total += v
            self._window.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100], over the bounded window (0.0 when empty)."""
        with self._lock:
            xs = sorted(self._window)
        if not xs:
            return 0.0
        i = min(int(round(p / 100.0 * (len(xs) - 1))), len(xs) - 1)
        return float(xs[i])

    def snapshot(self) -> dict:
        with self._lock:
            xs = sorted(self._window)
            count, total = self.count, self.total
        if not xs:
            return dict(count=count, mean=0.0, p50=0.0, p99=0.0, max=0.0)
        at = lambda p: float(xs[min(int(round(p / 100.0 * (len(xs) - 1))),
                                    len(xs) - 1)])
        return dict(count=count, mean=total / max(count, 1),
                    p50=at(50), p99=at(99), max=float(xs[-1]))


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    Creation is locked and idempotent: two racing workers asking for the
    same counter get the same object.  Asking for an existing name with a
    different instrument type raises — a silent re-type would split one
    metric across two objects.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, *args)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 1024) -> Histogram:
        return self._get(name, Histogram, window)

    def snapshot(self) -> dict:
        """{name: value | histogram summary dict}, sorted by name."""
        with self._lock:
            insts = dict(self._instruments)
        out = {}
        for name in sorted(insts):
            inst = insts[name]
            out[name] = (inst.snapshot() if isinstance(inst, Histogram)
                         else inst.value)
        return out

"""Thread-aware span tracer with Chrome trace-event JSON export.

One :class:`Tracer` instance per training run records *spans* (named,
timed intervals) and *instants* attributed to the thread that emitted
them.  The pipeline stages (draw -> build -> resolve -> finish -> device
step), checkpoint writes, and retry backoffs each open a span, so the
async overlap the pipeline claims becomes directly visible: load the
exported file into ``chrome://tracing`` or https://ui.perfetto.dev and
every worker thread gets its own swim lane.

Disabled-path contract: call sites always go through a tracer object, and
the :data:`NULL_TRACER` singleton makes that path near-free — ``span()``
returns one shared no-op context manager (no allocation, no clock read,
no lock).  The hot loop's per-batch cost with tracing off is a handful of
attribute lookups; benchmarks/minibatch.py measures it and CI gates it
below 2% of the prepare cost (``telemetry_overhead_pct``).

Recording a span when *enabled* is two ``perf_counter`` reads plus one
locked list append; events are kept as tuples and only formatted into
Chrome trace dicts at :meth:`Tracer.export` time.  Raw OS thread ids are
remapped to small sequential tids at export so the trace is readable,
with ``thread_name`` metadata events carrying the Python thread names
(``pipeline-<sampler>-<i>``, ``ckpt-writer``, ``MainThread``).
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["Tracer", "NullTracer", "NULL_TRACER"]


class _Span:
    """Context manager for one timed interval (allocated per span only
    when tracing is enabled)."""
    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._record(self.name, self.cat, self.args,
                             self._t0, time.perf_counter())
        return False


class _NullSpan:
    """Shared no-op span: the entire disabled-path cost of ``with
    tracer.span(...)`` is one method call returning this singleton."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans/instants; exports Chrome trace-event JSON."""

    enabled = True

    def __init__(self) -> None:
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        # (name, cat, tid, thread_name, t0, t1_or_None, args); t1 None
        # marks an instant event
        self._events: list[tuple] = []

    # -- recording ----------------------------------------------------------

    def span(self, name: str, cat: str = "host", **args) -> _Span:
        """Context manager timing one interval on the calling thread."""
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "host", **args) -> None:
        """Zero-duration marker (quarantine events, slack steps, ...)."""
        t = time.perf_counter()
        with self._lock:
            self._events.append(
                (name, cat, threading.get_ident(),
                 threading.current_thread().name, t, None, args))

    def _record(self, name: str, cat: str, args: dict,
                t0: float, t1: float) -> None:
        tid = threading.get_ident()
        tname = threading.current_thread().name
        with self._lock:
            self._events.append((name, cat, tid, tname, t0, t1, args))

    # -- export -------------------------------------------------------------

    def events(self) -> list[tuple]:
        """Raw event tuples recorded so far (copy)."""
        with self._lock:
            return list(self._events)

    def chrome_trace(self) -> dict:
        """The Chrome trace-event document: complete (``ph: X``) events
        with microsecond ``ts``/``dur`` relative to tracer creation,
        instant (``ph: i``) markers, and one ``thread_name`` metadata
        (``ph: M``) event per thread seen."""
        events = self.events()
        pid = os.getpid()
        tid_map: dict[int, int] = {}
        tid_names: dict[int, str] = {}
        out = []
        for name, cat, raw_tid, tname, t0, t1, args in events:
            tid = tid_map.setdefault(raw_tid, len(tid_map))
            tid_names[tid] = tname
            if t1 is None:
                ev = dict(name=name, cat=cat, ph="i", s="t",
                          ts=(t0 - self._epoch) * 1e6, pid=pid, tid=tid)
            else:
                ev = dict(name=name, cat=cat, ph="X",
                          ts=(t0 - self._epoch) * 1e6,
                          dur=(t1 - t0) * 1e6, pid=pid, tid=tid)
            if args:
                ev["args"] = args
            out.append(ev)
        meta = [dict(name="thread_name", ph="M", pid=pid, tid=tid,
                     args=dict(name=tname))
                for tid, tname in sorted(tid_names.items())]
        return dict(traceEvents=meta + out, displayTimeUnit="ms")

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON; returns the path."""
        doc = self.chrome_trace()
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, default=str)
        return path


class NullTracer:
    """Disabled tracer: every operation is a no-op, ``span`` returns one
    shared context manager.  All call sites stay unconditional."""

    enabled = False

    def span(self, name: str, cat: str = "host", **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "host", **args) -> None:
        return None

    def events(self) -> list:
        return []

    def chrome_trace(self) -> dict:
        return dict(traceEvents=[], displayTimeUnit="ms")

    def export(self, path: str) -> str:
        raise RuntimeError("cannot export a disabled (null) tracer; "
                           "enable telemetry to record spans")


NULL_TRACER = NullTracer()

"""Selector audit log: every committed kernel plan, with receipts.

AdaptGear's core claim — adaptive per-subgraph kernel selection balances
sparsity benefit against kernel efficiency — was previously only
assertable through end-of-run medians.  The audit log records the
*decision data*: every plan the PlanCache mints carries its per
(layer, tier) kernel choice and the cost model's modeled seconds for that
choice; every probe-on-Nth-miss measurement lands as a
(kernel, modeled, measured) pair; quarantine and degradation events are
stamped as they happen; and the training loop reports the observed
wall-time of each step attributed to the plan that ran it.

From that stream, :meth:`SelectorAudit.calibration` derives the cost
model calibration report the ROADMAP's TPU-recalibration and
GIN-structure debt items stall on: per-kernel and per-plan
predicted-vs-measured relative error.  ``export_jsonl`` writes the raw
event stream (one JSON object per line) for offline analysis.

Determinism non-interference: the audit is append-only and is never read
by selection, the cache, or the pipeline — recording cannot alter cache
decisions, plan choices, or batch order.  :class:`NullAudit` is the
disabled counterpart (every method a no-op), so call sites stay
unconditional.
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["SelectorAudit", "NullAudit", "NULL_AUDIT"]

# per-plan observed-step sample cap: enough for a stable median, bounded
# on long runs
_MAX_STEP_SAMPLES = 4096


def _layers_key(layers) -> tuple:
    return tuple(tuple(layer) for layer in layers)


def _median(xs: list) -> float:
    ys = sorted(xs)
    n = len(ys)
    if not n:
        return 0.0
    mid = n // 2
    return float(ys[mid]) if n % 2 else float((ys[mid - 1] + ys[mid]) / 2.0)


class SelectorAudit:
    """Append-only, thread-safe event log of selection decisions."""

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._events: list[dict] = []
        # plan layers -> observed step wall seconds
        self._step_s: dict[tuple, list] = {}
        # plan layers -> total modeled seconds at mint time
        self._modeled_total: dict[tuple, float] = {}

    def _append(self, event: str, **fields) -> None:
        rec = dict(event=event, t=time.perf_counter() - self._epoch)
        rec.update(fields)
        with self._lock:
            self._events.append(rec)

    # -- recording ----------------------------------------------------------

    def plan(self, *, sig, layers, tiers, modeled_s, source: str,
             bell_slack=None) -> None:
        """One committed (minted) plan: per-(layer, tier) kernel choices
        and the modeled seconds of each choice.  ``source`` says how it
        was selected: ``cost_model``, ``probe`` (probe-pinned winner), or
        ``fixed``."""
        layers = _layers_key(layers)
        total = float(sum(sum(row) for row in modeled_s)) if modeled_s else 0.0
        with self._lock:
            self._modeled_total.setdefault(layers, total)
        self._append("plan", sig=str(sig), tiers=list(tiers),
                     layers=[list(layer) for layer in layers],
                     modeled_s=[[float(c) for c in row]
                                for row in (modeled_s or [])],
                     modeled_total_s=total, source=source,
                     bell_slack=bell_slack)

    def probe(self, *, tier, kernel, modeled_s, measured_s,
              in_dim=None, agg_dim=None) -> None:
        """One wall-clock probe measurement of a candidate kernel."""
        self._append("probe", tier=tier, kernel=kernel,
                     modeled_s=float(modeled_s),
                     measured_s=float(measured_s),
                     in_dim=in_dim, agg_dim=agg_dim)

    def quarantine(self, *, sig, kernels, reason: str = "") -> None:
        self._append("quarantine", sig=str(sig),
                     kernels=sorted(str(k) for k in kernels), reason=reason)

    def degrade(self, *, from_layers, to_layers, error: str = "") -> None:
        """A broken plan was replaced by a re-selected fallback."""
        self._append("degrade",
                     from_layers=[list(l) for l in from_layers],
                     to_layers=[list(l) for l in to_layers], error=error)

    def observe_step(self, layers, seconds: float) -> None:
        """Observed device-step wall time attributed to the plan that ran
        it (the measured side of the per-plan calibration)."""
        key = _layers_key(layers)
        with self._lock:
            samples = self._step_s.setdefault(key, [])
            if len(samples) < _MAX_STEP_SAMPLES:
                samples.append(float(seconds))

    # -- reporting ----------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def calibration(self) -> dict:
        """Cost-model calibration report.

        ``kernels``: per probed kernel, the median relative error of the
        modeled cost against the probe's wall-clock measurement —
        |measured - modeled| / modeled (the same quantity PlanCache's
        adaptive probe widening keys on, now visible per kernel).

        ``plans``: per committed plan, the modeled whole-plan seconds at
        mint time against the median observed step wall time (the step
        includes the dense epilogue + optimizer the model doesn't price,
        so treat plan-level error as a trend signal, not an absolute).
        """
        with self._lock:
            events = list(self._events)
            step_s = {k: list(v) for k, v in self._step_s.items()}
            modeled = dict(self._modeled_total)
        by_kernel: dict[str, list] = {}
        for e in events:
            if e["event"] == "probe" and e["modeled_s"] > 0:
                by_kernel.setdefault(e["kernel"], []).append(
                    (e["modeled_s"], e["measured_s"]))
        kernels = {
            k: dict(n=len(v),
                    modeled_s=_median([m for m, _ in v]),
                    measured_s=_median([s for _, s in v]),
                    rel_err=_median([abs(s - m) / m for m, s in v]))
            for k, v in sorted(by_kernel.items())}
        plans = []
        for key, samples in step_s.items():
            mod = modeled.get(key)
            obs_s = _median(samples)
            entry = dict(layers=[list(l) for l in key], n_steps=len(samples),
                         observed_step_s=obs_s, modeled_s=mod)
            if mod:
                entry["rel_err"] = abs(obs_s - mod) / mod
            plans.append(entry)
        return dict(kernels=kernels, plans=plans)

    def export_jsonl(self, path: str, extra: list | None = None) -> str:
        """One JSON object per line: the event stream, then the
        calibration summary, then any ``extra`` records (the Telemetry
        facade appends the final metrics snapshot)."""
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            for e in self.events():
                f.write(json.dumps(e, default=str) + "\n")
            f.write(json.dumps(dict(event="calibration",
                                    **self.calibration()),
                               default=str) + "\n")
            for rec in extra or ():
                f.write(json.dumps(rec, default=str) + "\n")
        return path


class NullAudit:
    """Disabled audit: recording is a no-op, reports are empty."""

    enabled = False

    def plan(self, **kw) -> None:
        return None

    def probe(self, **kw) -> None:
        return None

    def quarantine(self, **kw) -> None:
        return None

    def degrade(self, **kw) -> None:
        return None

    def observe_step(self, layers, seconds: float) -> None:
        return None

    def events(self) -> list:
        return []

    def calibration(self) -> dict:
        return dict(kernels={}, plans=[])

    def export_jsonl(self, path: str, extra: list | None = None) -> str:
        raise RuntimeError("cannot export a disabled (null) audit; "
                           "enable telemetry to record selector decisions")


NULL_AUDIT = NullAudit()

"""Unified telemetry for the sampler -> pipeline -> kernel path.

Three instruments, one facade:

* :mod:`repro.obs.trace` — a thread-aware span tracer over the pipeline
  stages (draw -> build -> resolve -> finish -> device step, plus
  checkpoint writes and retry backoffs), exported as Chrome trace-event
  JSON (``chrome://tracing`` / Perfetto): the async overlap the pipeline
  claims becomes visible per thread.
* :mod:`repro.obs.metrics` — a thread-safe registry of counters, gauges,
  and bounded histograms (p50/p99).  PlanCache, BatchPipeline,
  CheckpointManager, and the fault-tolerance loop publish their counters
  into it; the legacy dict views (``PlanCache.stats``,
  ``BatchPipeline.stats``, ``MinibatchResult.cache/pipeline/faults``)
  are assembled *from* the registry, unchanged in keys and semantics.
* :mod:`repro.obs.audit` — the selector audit log: every committed plan
  with per-(layer, tier) kernel choices and modeled costs, probe
  measurements, quarantine/degradation events, observed step times, and
  a cost-model calibration report (per-kernel predicted-vs-measured
  error) surfaced through ``MinibatchResult.telemetry``.

The :class:`Telemetry` facade bundles the three.  Overhead contract:
``Telemetry(enabled=False)`` — the default everywhere — carries the real
metrics registry (counters are the system of record for the stats views)
but the null tracer and null audit, whose methods are no-ops returning
shared singletons.  Call sites are unconditional; the disabled cost is
measured by ``benchmarks/minibatch.py`` (``telemetry_overhead_pct``) and
gated below 2% of the per-batch prepare cost in CI.  Telemetry never
feeds back into decisions: tracing and auditing are append-only, so
enabling them leaves losses, plans, hit history, and trace counts
bit-identical (tests/test_obs.py locks this in).

Logging: :func:`get_logger` / :func:`enable_verbose` give the training
stack a namespaced ``repro.train`` logger; ``verbose=True`` on the
drivers installs a plain stdout stream handler (idempotent) instead of
scattering ``print`` calls.
"""
from __future__ import annotations

import logging
import sys

from repro.obs.audit import NULL_AUDIT, NullAudit, SelectorAudit
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer  # noqa: F401

__all__ = ["Telemetry", "Tracer", "NullTracer", "NULL_TRACER",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "SelectorAudit", "NullAudit", "NULL_AUDIT",
           "get_logger", "enable_verbose"]


class Telemetry:
    """One run's telemetry bundle: ``tracer`` + ``metrics`` + ``audit``.

    ``enabled=False`` (default) keeps the metrics registry live but
    swaps the tracer and audit for their null singletons; ``metrics``
    may be shared across components by passing one registry in.
    """

    def __init__(self, enabled: bool = False,
                 metrics: MetricsRegistry | None = None):
        self.enabled = bool(enabled)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = Tracer() if self.enabled else NULL_TRACER
        self.audit = SelectorAudit() if self.enabled else NULL_AUDIT

    def summary(self) -> dict:
        """The ``MinibatchResult.telemetry`` view: calibration report plus
        span/audit volume and the full metrics snapshot."""
        return dict(enabled=self.enabled,
                    n_span_events=len(self.tracer.events()),
                    n_audit_events=len(self.audit.events()),
                    calibration=self.audit.calibration(),
                    metrics=self.metrics.snapshot())

    def export(self, trace_out: str | None = None,
               jsonl_out: str | None = None) -> None:
        """Write the Chrome trace and/or the JSONL event export (audit
        events + calibration + final metrics snapshot)."""
        if trace_out:
            self.tracer.export(trace_out)
        if jsonl_out:
            self.audit.export_jsonl(
                jsonl_out,
                extra=[dict(event="metrics", **self.metrics.snapshot())])


# ---------------------------------------------------------------------------
# Namespaced logging (replaces print-based verbose output)
# ---------------------------------------------------------------------------

_VERBOSE_MARK = "_repro_verbose_handler"


def get_logger(name: str = "repro.train") -> logging.Logger:
    return logging.getLogger(name)


def enable_verbose(name: str = "repro.train",
                   level: int = logging.INFO) -> logging.Logger:
    """Install a plain message-only stdout handler on ``name`` once
    (idempotent) — the ``verbose=True`` convenience.  stdout, not stderr,
    so driver output stays pipeable the way the old prints were."""
    logger = logging.getLogger(name)
    logger.setLevel(level)
    if not any(getattr(h, _VERBOSE_MARK, False) for h in logger.handlers):
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter("%(message)s"))
        setattr(handler, _VERBOSE_MARK, True)
        logger.addHandler(handler)
    return logger

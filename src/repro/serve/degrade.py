"""Graceful-degradation ladder: fanout rungs with hysteresis.

Under sustained overload the server steps *down* a rung — a smaller
fanout configuration whose batches are cheaper and whose shapes were
pre-compiled at warmup — trading ego-net receptive field for latency
headroom instead of queuing unboundedly.  When load stays calm it steps
back up.

The transitions are deliberately asymmetric and damped (hysteresis):

* stepping **down** takes ``down_after`` *consecutive* overloaded
  observations — one bursty batch is absorbed by shedding, not by a
  quality change every client sees;
* stepping **up** takes ``up_after`` consecutive calm observations,
  with ``up_after > down_after`` so the ladder reacts fast to pain and
  slowly to relief;
* after any transition a ``cooldown`` of observations is ignored
  entirely, so the post-transition turbulence (queue draining, service
  estimate re-converging) cannot trigger an immediate bounce.

Together these guarantee the no-flapping property the tests pin down: an
alternating overloaded/calm signal never moves the rung, and a square
wave of load produces at most one transition per half-period.
"""
from __future__ import annotations

import threading

__all__ = ["DegradationLadder"]


class DegradationLadder:
    """Current rung index: 0 = full quality, ``n_rungs - 1`` = cheapest."""

    def __init__(self, n_rungs: int, down_after: int = 2,
                 up_after: int = 8, cooldown: int = 4, metrics=None):
        if n_rungs < 1:
            raise ValueError("need at least one rung")
        if up_after <= down_after:
            raise ValueError("hysteresis needs up_after > down_after "
                             f"(got {up_after} <= {down_after})")
        self.n_rungs = int(n_rungs)
        self.down_after = int(down_after)
        self.up_after = int(up_after)
        self.cooldown = int(cooldown)
        self._lock = threading.Lock()
        self._rung = 0
        self._hot = 0      # consecutive overloaded observations
        self._calm = 0     # consecutive calm observations
        self._cool = 0     # observations left to ignore post-transition
        m = metrics
        self._c_down = m.counter("serve.degrades") if m else None
        self._c_up = m.counter("serve.restores") if m else None
        self._g_rung = m.gauge("serve.rung") if m else None

    @property
    def rung(self) -> int:
        return self._rung

    def observe(self, overloaded: bool) -> bool:
        """Feed one load observation (one per served batch); returns True
        iff the rung changed."""
        with self._lock:
            if self._cool > 0:
                self._cool -= 1
                return False
            if overloaded:
                self._hot += 1
                self._calm = 0
            else:
                self._calm += 1
                self._hot = 0
            if overloaded and self._hot >= self.down_after \
                    and self._rung < self.n_rungs - 1:
                self._rung += 1
                self._hot = self._calm = 0
                self._cool = self.cooldown
                if self._c_down:
                    self._c_down.inc()
                if self._g_rung:
                    self._g_rung.set(self._rung)
                return True
            if not overloaded and self._calm >= self.up_after \
                    and self._rung > 0:
                self._rung -= 1
                self._hot = self._calm = 0
                self._cool = self.cooldown
                if self._c_up:
                    self._c_up.inc()
                if self._g_rung:
                    self._g_rung.set(self._rung)
                return True
            return False

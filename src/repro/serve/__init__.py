"""Resilient online GNN inference serving (the AdaptGear read path).

Answering ego-net queries over a trained model, with the robustness
envelope a public endpoint needs: deadline-aware micro-batching,
admission control with explicit load shedding, a hysteretic
graceful-degradation ladder over pre-compiled fanout rungs, kernel-fault
quarantine through the shared PlanCache, and persisted-plan warm starts
(zero steady-state compiles).  See serve/server.py for the dataflow and
the serving-contract section in repro.core for the invariants.
"""
from repro.serve.admission import (ERROR, OK, PENDING, SHED, TIMEOUT,
                                   AdmissionController, Request, ServeFuture)
from repro.serve.degrade import DegradationLadder
from repro.serve.ego import EgoNetSampler, default_rungs
from repro.serve.server import InferenceServer, ServeConfig

__all__ = [
    "AdmissionController", "DegradationLadder", "EgoNetSampler",
    "InferenceServer", "Request", "ServeConfig", "ServeFuture",
    "default_rungs",
    "PENDING", "OK", "SHED", "TIMEOUT", "ERROR",
]

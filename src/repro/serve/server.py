"""Resilient in-process GNN inference server (the AdaptGear read path).

Dataflow per micro-batch (the contract documented in repro.core):

    submit() -> AdmissionController (bounded queue, predictive shed)
            -> collect()            (micro-batch: flush on size | deadline)
            -> EgoNetSampler.build  (fixed-budget padded SampledBatch,
                                     ft.RetryPolicy w/ decorrelated jitter,
                                     FaultPlan injection point)
            -> prepare_skeleton -> PlanCache lookup/plan_for -> fix_shapes
            -> AOT executable       (one per (plan, rung shapes) — compiled
                                     at warmup, zero compiles steady state)
            -> logits -> per-request futures

Robustness properties:

* **bounded everything** — the queue sheds at capacity and predictively
  (admission.py); an admitted request is never dropped afterwards: a
  kernel fault on its batch quarantines the implicated kernels in the
  shared PlanCache, re-selects next-best, and serves the same batch on
  the degraded plan (the XLA ``coo`` floor guarantees termination).
* **graceful degradation** — sustained overload steps the fanout ladder
  down to a cheaper pre-compiled shape (degrade.py) instead of queuing;
  calm steps back up, with hysteresis so the rung never flaps.
* **cold-start robustness** — :meth:`InferenceServer.warmup` preloads a
  :meth:`PlanCache.load` snapshot (plans bit-identical to the run that
  saved them) and AOT-compiles every (rung, plan) executable up front,
  so a warm-started server records zero new traces in steady state
  (``n_traces`` is the observable).
* **observability** — per-request latency histograms (p50/p99), queue
  wait, shed/timeout/degrade counters, and spans over every stage ride
  the run's ``repro.obs`` Telemetry.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core import gnn, selector as sel_mod
from repro.distributed import fault_tolerance as ft
from repro.graphs import graph as graph_mod
from repro.kernels.registry import REGISTRY
from repro.obs import Telemetry, get_logger
from repro.sampling.plan_cache import (MB_KERNELS, PlanCache, fix_shapes,
                                       plan_payload_keys)
from repro.serve.admission import (ERROR, OK, SHED, AdmissionController,
                                   Request)
from repro.serve.degrade import DegradationLadder
from repro.serve.ego import EgoNetSampler, default_rungs
from repro.train.gnn_steps import make_infer_step, prepare_skeleton

__all__ = ["ServeConfig", "InferenceServer"]

_log = get_logger("repro.serve")


@dataclass
class ServeConfig:
    """Serving knobs (the model/sampling knobs stay on GNNConfig)."""
    deadline_s: float = 0.25      # default per-request deadline
    queue_limit: int = 64         # admission bound (requests)
    max_batch: int = 16           # micro-batch size flush target (seeds)
    max_wait_s: float = 0.01      # coalescing cap: a partial batch never
    #                               waits longer than this for company
    rungs: tuple = ()             # fanout ladder; () = derived from
    #                               cfg.fanouts by repeated halving
    down_after: int = 2           # ladder hysteresis (degrade.py)
    up_after: int = 6
    cooldown: int = 3
    ewma_alpha: float = 0.3       # service-time estimate smoothing
    est_service_s: float = 0.02   # pre-warmup service estimate
    retry_max: int = 2            # transient build retries (0 = off)
    retry_base_delay_s: float = 0.002
    plan_cache_path: str = ""     # PlanCache.save/load snapshot for warmup
    seed: int = 0                 # retry-jitter determinism


class _CompileFailed:
    """Memoized AOT-lowering failure for a (plan, shapes) key: in-flight
    batches sharing the broken plan reuse the verdict and go straight to
    quarantine instead of re-tracing (mirrors train.gnn_steps)."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class InferenceServer:
    """In-process ego-net inference over a trained model.

    ``plan_cache`` may be the training run's cache (shared quarantine +
    committed plans); otherwise a fresh one is built and optionally
    preloaded from ``serve_cfg.plan_cache_path`` at :meth:`warmup`.
    ``fault_plan`` injects deterministic faults on the request path
    (sampler-build exceptions retried, kernel faults quarantined) —
    kernel compile/execute faults additionally need the registry patched
    via ``with fault_plan.activate(): ...`` around the serving calls,
    exactly as in training."""

    def __init__(self, graph: graph_mod.Graph, cfg: gnn.GNNConfig, params,
                 serve_cfg: ServeConfig | None = None,
                 plan_cache: PlanCache | None = None,
                 fault_plan: "ft.FaultPlan | None" = None,
                 telemetry: Telemetry | None = None,
                 clock=time.monotonic):
        if cfg.model not in ("gcn", "gin", "sage"):
            raise ValueError(f"serving supports gcn/gin/sage, "
                             f"not {cfg.model!r}")
        self.cfg = cfg
        self.scfg = serve_cfg or ServeConfig()
        self.params = params
        self.fault_plan = fault_plan
        self.clock = clock
        self.tele = telemetry if telemetry is not None else Telemetry()
        m = self.tele.metrics
        rungs = self.scfg.rungs or default_rungs(cfg.fanouts)
        self.ego = EgoNetSampler(graph, cfg, rungs)

        in_dim = graph.features.shape[-1]
        pairs = gnn.agg_width_pairs(cfg, in_dim, graph.n_classes)
        epilogues = gnn.layer_epilogues(cfg, in_dim, graph.n_classes)
        if plan_cache is not None:
            plan_cache.attach_telemetry(self.tele)
        self.cache = plan_cache or PlanCache(
            pairs, dtype=np.float32, hw=sel_mod.default_hw(),
            max_entries=cfg.cache_entries, probe_every=0,
            edge_budget=self.ego.pad_budget(0), epilogues=epilogues,
            telemetry=self.tele)

        self.ladder = DegradationLadder(
            len(self.ego), down_after=self.scfg.down_after,
            up_after=self.scfg.up_after, cooldown=self.scfg.cooldown,
            metrics=m)
        self._est_service = float(self.scfg.est_service_s)
        self.admission = AdmissionController(
            self.scfg.queue_limit, self._estimate_wait, clock=clock,
            metrics=m)
        self.retry = (ft.RetryPolicy(
            max_retries=self.scfg.retry_max,
            base_delay_s=self.scfg.retry_base_delay_s,
            jitter=True, seed=self.scfg.seed,
            tracer=self.tele.tracer if self.tele.enabled else None)
            if self.scfg.retry_max > 0 else None)

        # jit/AOT machinery — same shape as the training consumer:
        # plan.layers -> jitted infer fn; (layers, treedef, shapes) -> AOT
        # executable; failures memoized so broken plans never re-trace
        self._counters = dict(traces=0)
        self._infer_fns: dict[tuple, object] = {}
        self._compiled: dict[tuple, object] = {}
        self._failed_compiles: dict[tuple, _CompileFailed] = {}
        self._failed_steps: dict[tuple, BaseException] = {}
        self._sig_of_layers: dict[tuple, tuple] = {}
        self._compile_lock = threading.Lock()
        aval = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        self._warm_params = jax.tree.map(aval, params)

        self._c_batches = m.counter("serve.batches")
        self._c_errors = m.counter("serve.errors")
        self._c_retries = m.counter("serve.retries")
        self._c_quar = m.counter("serve.quarantined")
        self._c_recov = m.counter("serve.recoveries")
        self._c_shed = m.counter("serve.shed")        # shared w/ admission
        self._c_timeouts = m.counter("serve.timeouts")
        self._h_latency = m.histogram("serve.latency_s", window=4096)
        self._h_service = m.histogram("serve.service_s")
        self._h_bsize = m.histogram("serve.batch_size")
        self._g_qlen = m.gauge("serve.queue_len")
        self._last_pain = 0

        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- load estimation ----------------------------------------------------

    def _estimate_wait(self, queue_len: int) -> float:
        """Expected seconds until a request arriving behind ``queue_len``
        others is served: whole micro-batches ahead of it, each one EWMA
        service time (admission's predictive-shed input)."""
        batches_ahead = queue_len // max(self.scfg.max_batch, 1) + 1
        return batches_ahead * self._est_service

    @property
    def n_traces(self) -> int:
        return self._counters["traces"]

    # -- plan resolution + AOT ----------------------------------------------

    def _infer_fn(self, plan):
        fn = self._infer_fns.get(plan.layers)
        if fn is None:
            with self._compile_lock:
                fn = self._infer_fns.get(plan.layers)
                if fn is None:
                    fn = self._infer_fns[plan.layers] = make_infer_step(
                        self.cfg, plan, self._counters)
        return fn

    def _executable(self, plan, args):
        leaves, treedef = jax.tree_util.tree_flatten(args)
        skey = (plan.layers, treedef,
                tuple((tuple(l.shape), str(l.dtype)) for l in leaves))
        fn = self._infer_fn(plan)
        with self._compile_lock:
            failed = self._failed_compiles.get(skey)
            if failed is not None:
                return failed
            exe = self._compiled.get(skey)
            if exe is None:
                try:
                    exe = self._compiled[skey] = fn.lower(
                        self._warm_params, *args).compile()
                except Exception as exc:
                    failed = self._failed_compiles[skey] = \
                        _CompileFailed(exc)
                    return failed
            return exe

    def _resolve(self, rung: int, batch):
        """PlanCache resolution + fixed-shape padding for one batch:
        returns (plan, args, skel) where args is the infer tail
        ``(fixed_dec, x, inv_deg)`` staged on device."""
        skel, inv_deg = prepare_skeleton(batch, self.cfg)
        plan = self.cache.lookup(skel)
        if plan is None:
            dec = skel.materialize(MB_KERNELS)
            plan, _ = self.cache.plan_for(dec)
        else:
            dec = skel.materialize(plan_payload_keys(plan))
        # canonical signature per step-fn key, as in training: the sig is
        # static jit metadata, so every batch sharing a compiled fn must
        # stamp the same value
        csig = self._sig_of_layers.setdefault(plan.layers,
                                              self.cache.signature(skel))
        fixed = fix_shapes(dec, self.ego.pad_budget(rung),
                           keep=plan_payload_keys(plan), stats=csig)
        args = jax.device_put((fixed, batch.features, inv_deg))
        return plan, args, skel

    # -- kernel-fault recovery (quarantine + plan degradation) --------------

    def _recover(self, rung: int, batch, skel, plan, exc: BaseException):
        """Forward-only twin of the training loop's recover_step: drain
        poisoned effect tokens, quarantine the implicated kernels for
        this signature in the shared PlanCache, re-select among the
        survivors, rebuild the payloads, and run the degraded plan —
        escalating until a plan runs (the never-quarantined ``coo`` floor
        terminates the loop).  Failures that implicate no kernel re-raise
        unchanged: real bugs fail fast, they don't degrade."""
        for _ in range(len(MB_KERNELS)):
            ft.drain_effect_tokens()
            self._failed_steps.setdefault(plan.layers, exc)
            used = {k for layer in plan.layers for k in layer}
            named = ft.fault_kernel_from(exc)
            bad = ({named} if named is not None and named in used
                   else {k for k in used if REGISTRY.get(k).pallas})
            bad.discard("coo")
            if not bad:
                raise exc
            sig = self.cache.signature(skel)
            self._c_quar.inc(len(self.cache.quarantine(sig, bad)))
            dec = skel.materialize(MB_KERNELS)
            new_plan, _ = self.cache.plan_for(dec)
            if new_plan.layers == plan.layers:
                raise exc       # quarantine changed nothing: not a kernel
            csig = self._sig_of_layers.setdefault(new_plan.layers, sig)
            fixed = fix_shapes(dec, self.ego.pad_budget(rung),
                               keep=plan_payload_keys(new_plan), stats=csig)
            _, inv_deg = prepare_skeleton(batch, self.cfg)
            args = jax.device_put((fixed, batch.features, inv_deg))
            exe = self._executable(new_plan, args)
            if isinstance(exe, _CompileFailed):
                plan, exc = new_plan, exe.exc
                continue
            try:
                logits = exe(self.params, *args)
                out = np.asarray(logits)      # blocks; surfaces exec faults
                self._c_recov.inc()
                self.tele.audit.degrade(from_layers=plan.layers,
                                        to_layers=new_plan.layers,
                                        error=str(exc))
                return out
            except Exception as deeper:
                plan, exc = new_plan, deeper
        raise exc

    # -- the serving path ---------------------------------------------------

    def _build(self, rung: int, seeds, index: int):
        """Sampler build + fault injection, the unit the jittered retry
        policy re-runs on a transient failure (injection precedes the
        skeleton, so a retried batch never double-counts the cache)."""
        def once():
            batch = self.ego.build(rung, seeds, index)
            if self.fault_plan is not None:
                batch = self.fault_plan.on_built(index, batch)
            return batch

        if self.retry is None:
            return once()
        return self.retry.run(once, on_retry=lambda a: self._c_retries.inc(),
                              retryable=ft.default_transient)

    def _serve_batch(self, rung: int, reqs: list[Request]) -> None:
        tracer = self.tele.tracer
        t0 = self.clock()
        seeds = sorted({r.node for r in reqs})
        index = self.ego.next_index()
        try:
            with tracer.span("serve.batch", cat="serve", index=index,
                             rung=rung, n=len(reqs)):
                with tracer.span("serve.build", cat="host"):
                    batch = self._build(rung, seeds, index)
                with tracer.span("serve.resolve", cat="host"):
                    plan, args, skel = self._resolve(rung, batch)
                with tracer.span("serve.infer", cat="device",
                                 plan=str(plan.layers[0])):
                    if plan.layers in self._failed_steps:
                        logits = self._recover(
                            rung, batch, skel, plan,
                            self._failed_steps[plan.layers])
                    else:
                        exe = self._executable(plan, args)
                        if isinstance(exe, _CompileFailed):
                            logits = self._recover(rung, batch, skel, plan,
                                                   exe.exc)
                        else:
                            try:
                                logits = np.asarray(exe(self.params, *args))
                            except Exception as exc:
                                logits = self._recover(rung, batch, skel,
                                                       plan, exc)
        except Exception as exc:
            # permanent failure (non-transient build, recovery exhausted):
            # the admitted requests get an explicit error, never silence
            self._c_errors.inc(len(reqs))
            for r in reqs:
                r.future.finish(ERROR, exc)
            return
        row_of = {int(n): i for i, n in enumerate(batch.nodes) if n >= 0}
        now = self.clock()
        for r in reqs:
            row = logits[row_of[r.node]]
            r.future.finish(OK, dict(node=r.node, rung=rung,
                                     pred=int(np.argmax(row)),
                                     logits=row.copy(),
                                     latency_s=now - r.t_submit))
            self._h_latency.observe(now - r.t_submit)
            if self.tele.enabled:
                with tracer.span("serve.request", cat="serve", node=r.node,
                                 latency_s=now - r.t_submit):
                    pass
        service = now - t0
        self._h_service.observe(service)
        self._h_bsize.observe(len(reqs))
        self._c_batches.inc()
        a = self.scfg.ewma_alpha
        self._est_service = (1 - a) * self._est_service + a * service
        qlen = len(self.admission)
        self._g_qlen.set(qlen)
        # ladder signal: shedding/expiry since the last batch, or a queue
        # holding more than one flush's worth of backlog
        pain = self._c_shed.value + self._c_timeouts.value
        overloaded = (pain > self._last_pain
                      or qlen >= max(self.scfg.queue_limit // 2, 1))
        self._last_pain = pain
        self.ladder.observe(overloaded)

    # -- public API ---------------------------------------------------------

    def submit(self, node: int, deadline_s: float | None = None):
        """Enqueue one ego-net query; returns its :class:`ServeFuture`
        (already finished with status ``shed`` if admission rejected)."""
        return self.admission.submit(
            int(node),
            self.scfg.deadline_s if deadline_s is None else deadline_s)

    def step(self) -> int:
        """Serve one micro-batch inline (deterministic single-threaded
        mode for tests/benchmarks — no background thread).  Returns the
        number of requests terminated (served or expired)."""
        rung = self.ladder.rung
        before = self._c_timeouts.value
        reqs = self.admission.collect(
            min(self.scfg.max_batch, self.ego.max_seeds(rung)),
            self._est_service, stop=self._stop,
            max_wait_s=self.scfg.max_wait_s)
        expired = self._c_timeouts.value - before
        if reqs:
            self._serve_batch(rung, reqs)
        return len(reqs) + int(expired)

    def _loop(self):
        while not self._stop.is_set():
            try:
                self.step()
            except Exception:
                _log.exception("serving loop error")

    def start(self) -> "InferenceServer":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="serve-loop", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        for r in self.admission.drain():    # unserved stragglers: shed,
            if r.future.finish(SHED):       # never silently dropped
                self._c_shed.inc()

    def __enter__(self) -> "InferenceServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- warm start ---------------------------------------------------------

    def warmup(self, path: str | None = None, save: bool = False,
               probe_seeds=None) -> dict:
        """Cold-start mitigation: optionally preload a persisted PlanCache
        snapshot (plans bit-identical to the saving run; a corrupt file
        falls back to cold start), then AOT-compile one probe batch per
        rung so every steady-state shape has its executable before the
        first request arrives.  With ``save=True`` the (possibly newly
        selected) plans are persisted back for the next cold start.

        Returns ``dict(loaded, new_traces, rungs)`` — a warm-started
        server re-warmed from its own snapshot reports steady-state
        batches with ``n_traces`` unchanged (the acceptance observable)."""
        path = self.scfg.plan_cache_path if path is None else path
        loaded = bool(path) and self.cache.load(path)
        t0 = self.n_traces
        n = self.ego.graph.n
        if probe_seeds is None:
            k = min(self.scfg.max_batch, self.ego.max_seeds(0), n)
            probe_seeds = np.unique(np.linspace(0, n - 1, k).astype(int))
        # pass 1 — one probe per rung: commits a plan for each rung's
        # density signature (selection happens now, not on a request)
        probes = []
        for rung in range(len(self.ego)):
            batch = self.ego.build(rung, probe_seeds, self.ego.next_index())
            self._resolve(rung, batch)
            probes.append((rung, batch))
        # pass 2 — the (plan x rung) cross product: a plan committed for
        # one rung's signature can be served at any rung (loaded snapshot
        # entries, plan drift between batches), and the AOT cache is
        # keyed by (plan, shapes), so every pair needs its executable up
        # front for steady state to stay compile-free
        plans: dict[tuple, object] = {}
        for _, p, _ in self.cache.state_dict()["entries"]:
            plans.setdefault(p.layers, p)
        for rung, batch in probes:
            skel, inv_deg = prepare_skeleton(batch, self.cfg)
            sig = self.cache.signature(skel)
            for p in plans.values():
                keys = plan_payload_keys(p)
                dec = skel.materialize(keys)
                csig = self._sig_of_layers.setdefault(p.layers, sig)
                fixed = fix_shapes(dec, self.ego.pad_budget(rung),
                                   keep=keys, stats=csig)
                args = jax.device_put((fixed, batch.features, inv_deg))
                exe = self._executable(p, args)
                if isinstance(exe, _CompileFailed):
                    continue    # broken kernel: request path quarantines
                try:
                    np.asarray(exe(self.params, *args))
                except Exception:
                    ft.drain_effect_tokens()   # ditto for execute faults

        if save and path:
            self.cache.save(path)
        return dict(loaded=loaded, new_traces=self.n_traces - t0,
                    rungs=len(self.ego))

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        m = self.tele.metrics
        admitted = m.counter("serve.admitted").value
        shed = self._c_shed.value
        return dict(
            admitted=admitted, shed=shed,
            timeouts=self._c_timeouts.value,
            errors=self._c_errors.value,
            batches=self._c_batches.value,
            retries=self._c_retries.value,
            quarantined=self._c_quar.value,
            recoveries=self._c_recov.value,
            degrades=m.counter("serve.degrades").value,
            restores=m.counter("serve.restores").value,
            rung=self.ladder.rung,
            n_traces=self.n_traces,
            est_service_s=self._est_service,
            shed_pct=100.0 * shed / max(admitted + shed, 1),
            latency=self._h_latency.snapshot(),
            service=self._h_service.snapshot(),
            batch_size=self._h_bsize.snapshot(),
            queue_wait=m.histogram("serve.queue_wait_s").snapshot())

"""Admission control + deadline-aware micro-batching for the inference
server (serve/server.py).

The queue is the only place load can accumulate, so it is bounded twice
over:

* **capacity shedding** — a full queue rejects at submit time, before the
  request costs anything (no build, no device work, no unbounded memory).
* **predictive shedding** — even with room, a request whose deadline the
  current backlog would already blow is rejected at submit time: serving
  it late helps nobody and steals capacity from requests that can still
  make their deadlines.  The wait estimate comes from the server's EWMA
  service time (``estimate_wait``), so the admission decision tracks the
  device's actual speed, not a static guess.

:meth:`AdmissionController.collect` is the micro-batcher: it blocks for
the first request, then keeps coalescing arrivals into one batch until
either the size target is hit or waiting any longer would eat into the
earliest admitted deadline's service slack — flush on size or deadline,
whichever first.  Requests whose remaining slack can no longer cover one
service time are expired (``timeout``) at collect time rather than
served late; an *admitted* request that makes it into a batch is never
dropped after that point (the server's recovery path degrades the plan,
not the request).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

__all__ = ["ServeFuture", "Request", "AdmissionController",
           "PENDING", "OK", "SHED", "TIMEOUT", "ERROR"]

PENDING = "pending"
OK = "ok"          # served; value holds the prediction payload
SHED = "shed"      # rejected at admission (queue full / deadline hopeless)
TIMEOUT = "timeout"  # admitted but expired before a batch could take it
ERROR = "error"    # admitted but the serving path failed permanently


class ServeFuture:
    """One request's completion handle (threading.Event under the hood).

    ``result(timeout)`` blocks until the terminal status lands and
    returns ``(status, value)``; value is the prediction payload for
    ``ok``, an exception for ``error``, None otherwise.  Terminal status
    is set exactly once — late finishers lose silently, so a racing
    expire/serve pair cannot flip an already-delivered result."""

    __slots__ = ("_event", "_lock", "status", "value")

    def __init__(self):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self.status = PENDING
        self.value = None

    def finish(self, status: str, value=None) -> bool:
        with self._lock:
            if self.status is not PENDING:
                return False
            self.status, self.value = status, value
        self._event.set()
        return True

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request still in flight")
        return self.status, self.value


@dataclass
class Request:
    """One admitted ego-net query: seed node + absolute deadline
    (monotonic clock) + its completion future."""
    node: int
    deadline: float                  # absolute, clock() units
    t_submit: float
    future: ServeFuture = field(default_factory=ServeFuture)


class AdmissionController:
    """Bounded FIFO with predictive shedding and deadline-aware flush.

    ``estimate_wait(queue_len)`` returns the expected seconds until a
    request arriving behind ``queue_len`` others reaches the device —
    the server wires this to its EWMA service estimate.  ``clock`` is
    injectable so tests can drive deadlines without real sleeps.
    """

    def __init__(self, limit: int, estimate_wait,
                 clock=time.monotonic, metrics=None):
        if limit < 1:
            raise ValueError(f"queue limit must be >= 1, got {limit}")
        self.limit = int(limit)
        self.estimate_wait = estimate_wait
        self.clock = clock
        self._q: deque[Request] = deque()
        self._cond = threading.Condition()
        m = metrics
        self._c_admit = m.counter("serve.admitted") if m else None
        self._c_shed = m.counter("serve.shed") if m else None
        self._c_expired = m.counter("serve.timeouts") if m else None
        self._h_wait = m.histogram("serve.queue_wait_s") if m else None

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    # -- producer side ------------------------------------------------------

    def submit(self, node: int, deadline_s: float) -> ServeFuture:
        """Admit or shed; never blocks.  A shed future is already done
        (status ``shed``) when it returns — the caller sees the verdict
        immediately instead of waiting out its deadline."""
        now = self.clock()
        fut = ServeFuture()
        with self._cond:
            shed = (len(self._q) >= self.limit
                    or self.estimate_wait(len(self._q)) > deadline_s)
            if not shed:
                self._q.append(Request(node=node, deadline=now + deadline_s,
                                       t_submit=now, future=fut))
                if self._c_admit:
                    self._c_admit.inc()
                self._cond.notify()
        if shed:
            fut.finish(SHED)
            if self._c_shed:
                self._c_shed.inc()
        return fut

    # -- consumer side (the server's batch loop) ----------------------------

    def _expire_front(self, now: float, service_s: float) -> None:
        # under self._cond: drop requests that can no longer be served
        # inside their deadline even if dispatched right now
        while self._q and self._q[0].deadline - now < service_s:
            req = self._q.popleft()
            if req.future.finish(TIMEOUT) and self._c_expired:
                self._c_expired.inc()

    def collect(self, max_n: int, service_s: float,
                stop: threading.Event | None = None,
                poll_s: float = 0.005,
                max_wait_s: float | None = None) -> list[Request]:
        """Coalesce one micro-batch: block until a request arrives, then
        keep gathering until ``max_n`` requests (size flush) or until the
        earliest deadline minus one ``service_s`` arrives (deadline
        flush).  ``max_wait_s`` additionally caps the coalescing wait, so
        a lone request under a generous deadline doesn't idle out most of
        it waiting for company.  Returns [] promptly when ``stop`` is
        set."""
        out: list[Request] = []
        with self._cond:
            while True:
                now = self.clock()
                self._expire_front(now, service_s)
                if self._q:
                    break
                if stop is not None and stop.is_set():
                    return out
                self._cond.wait(timeout=poll_s)
            # flush when waiting longer would eat the earliest admitted
            # request's service slack
            flush_at = self._q[0].deadline - service_s
            if max_wait_s is not None:
                flush_at = min(flush_at, self.clock() + max_wait_s)
            while len(out) < max_n:
                now = self.clock()
                self._expire_front(now, service_s)
                while self._q and len(out) < max_n:
                    out.append(self._q.popleft())
                if (len(out) >= max_n or now >= flush_at
                        or (stop is not None and stop.is_set())):
                    break
                self._cond.wait(timeout=min(poll_s, max(flush_at - now,
                                                        1e-4)))
        if self._h_wait:
            now = self.clock()
            for r in out:
                self._h_wait.observe(now - r.t_submit)
        return out

    def drain(self) -> list[Request]:
        """Pop everything still queued (server shutdown): the caller
        decides their terminal status."""
        with self._cond:
            out = list(self._q)
            self._q.clear()
        return out

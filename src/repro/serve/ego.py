"""Ego-net batch construction for serving: one NeighborSampler per
degradation rung.

A *rung* is a fanout configuration (rung 0 = the training fanouts, later
rungs progressively smaller — see serve/degrade.py).  Each rung owns its
own :class:`~repro.sampling.sampler.NeighborSampler` because the fanouts
fix the node/edge budgets and with them every padded payload shape: one
rung == one set of ShapeDtypeStructs == one pre-compiled executable per
plan.  The samplers' pure ``build()`` path does all the work — serving
batches are bit-identical to what training would sample for the same
(seed set, stream index), which is what lets the server reuse the
training PlanCache and the training-calibrated cost model unchanged.

Request randomness streams off a dedicated index space: every query
batch gets a fresh monotonically increasing index, so retries of a
failed build reproduce the same batch (the retry re-runs the same
ticket) while distinct queries decorrelate.
"""
from __future__ import annotations

import itertools
import threading

from repro.core import gnn
from repro.graphs import graph as graph_mod
from repro.sampling.sampler import NeighborSampler, SampledBatch

__all__ = ["EgoNetSampler", "default_rungs"]


def default_rungs(fanouts: tuple, n_rungs: int = 3) -> tuple:
    """Degradation ladder of fanout tuples: the configured fanouts, then
    repeated halvings (floor 1) until they bottom out or ``n_rungs`` is
    reached.  ((8, 4)) -> ((8, 4), (4, 2), (2, 1))."""
    rungs = [tuple(int(f) for f in fanouts)]
    while len(rungs) < n_rungs:
        nxt = tuple(max(f // 2, 1) for f in rungs[-1])
        if nxt == rungs[-1]:
            break
        rungs.append(nxt)
    return tuple(rungs)


class EgoNetSampler:
    """Per-rung NeighborSamplers sharing one graph + config."""

    def __init__(self, graph: graph_mod.Graph, cfg: gnn.GNNConfig,
                 rungs: tuple):
        if not rungs:
            raise ValueError("need at least one fanout rung")
        self.graph = graph
        self.cfg = cfg
        self.rungs = tuple(tuple(r) for r in rungs)
        self.samplers = [
            NeighborSampler(graph, batch_nodes=cfg.batch_nodes, fanouts=f,
                            method=cfg.reorder, block=cfg.comm_size,
                            seed=cfg.seed)
            for f in self.rungs]
        self._index = itertools.count()
        self._index_lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.rungs)

    def max_seeds(self, rung: int) -> int:
        return self.samplers[rung].batch_nodes

    def pad_budget(self, rung: int) -> int:
        """Edge slots the padded payloads see at this rung: the sampler's
        edge budget plus one self-loop slot per node for GCN (mirrors
        train.gnn_steps.batch_edge_budget)."""
        s = self.samplers[rung]
        return s.edge_budget + (s.node_budget
                                if self.cfg.model == "gcn" else 0)

    def next_index(self) -> int:
        with self._index_lock:
            return next(self._index)

    def build(self, rung: int, seeds, index: int) -> SampledBatch:
        """Pure, thread-safe ego-net build: dedupe/validate the seeds into
        a ticket and run the rung sampler's fixed-budget padded build.
        Deterministic in (rung, seed set, index) — a retried build
        reproduces its batch bit-for-bit."""
        s = self.samplers[rung]
        return s.build(s.ego_ticket(seeds, index))

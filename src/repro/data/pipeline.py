"""Deterministic synthetic data pipelines (offline container: no downloads).

Token stream: a counter-based hash (splittable, restart-stable) -> any
(step, shard) batch is reproducible with no state, which is what makes the
fault-tolerance shard-reassignment sound: a host taking over shard k resumes
exactly where the dead host would have been.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """xorshift-mul counter hash (splitmix-style), vectorized."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


@dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq: int
    global_batch: int
    n_shards: int = 1          # data-parallel host shards
    seed: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int, shard: int = 0) -> dict:
        """Host-shard slice of the global batch for ``step``.  tokens/labels
        are next-token shifted views of one stream."""
        b = self.shard_batch
        rows = np.arange(b, dtype=np.uint64) + shard * b
        base = (np.uint64(self.seed) * np.uint64(0x9E3779B97F4A7C15)
                + np.uint64(step) * np.uint64(1 << 20))
        counters = (base + rows[:, None] * np.uint64(self.seq + 1)
                    + np.arange(self.seq + 1, dtype=np.uint64)[None, :])
        toks = (_hash_u32(counters) % np.uint32(self.vocab)).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])

    def global_batch_at(self, step: int) -> dict:
        parts = [self.batch(step, s) for s in range(self.n_shards)]
        return {k: np.concatenate([p[k] for p in parts], 0) for k in parts[0]}


@dataclass(frozen=True)
class EmbedsPipeline:
    """Stub-modality pipeline (VLM patches / audio frames): deterministic
    gaussian embeddings + next-'token' labels."""
    d_model: int
    seq: int
    global_batch: int
    vocab: int
    n_shards: int = 1
    seed: int = 0
    mrope: bool = False
    encoder_seq: int = 0      # >0 -> enc-dec batch

    def batch(self, step: int, shard: int = 0) -> dict:
        b = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step * 1009 + shard) & 0x7FFFFFFF)
        toks = rng.integers(0, self.vocab, (b, self.seq + 1)).astype(np.int32)
        out = dict(labels=toks[:, 1:])
        if self.encoder_seq:
            out["enc_embeds"] = rng.standard_normal(
                (b, self.encoder_seq, self.d_model)).astype(np.float32)
            out["tokens"] = toks[:, :-1]
        else:
            out["embeds"] = rng.standard_normal(
                (b, self.seq, self.d_model)).astype(np.float32)
            if self.mrope:
                base = np.arange(self.seq, dtype=np.int32)
                out["positions"] = np.broadcast_to(
                    base[None, None], (3, b, self.seq)).copy()
        return out


def pipeline_for(cfg, seq: int, global_batch: int, n_shards: int = 1,
                 seed: int = 0):
    if cfg.family == "encdec":
        return EmbedsPipeline(cfg.d_model, seq, global_batch, cfg.vocab,
                              n_shards, seed, encoder_seq=cfg.encoder_seq)
    if cfg.input_mode == "embeds":
        return EmbedsPipeline(cfg.d_model, seq, global_batch, cfg.vocab,
                              n_shards, seed, mrope=cfg.mrope_sections is not None)
    return TokenPipeline(cfg.vocab, seq, global_batch, n_shards, seed)

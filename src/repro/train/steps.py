"""jit-able train / prefill / serve step factories.

These are what the launcher jits with in/out shardings and what the dry-run
lowers against ShapeDtypeStructs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.models import lm
from repro.optim import adamw


def make_train_step(cfg: lm.ModelConfig, opt_cfg: adamw.OptConfig,
                    accum_steps: int = 1, grad_compression: str = "none"):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1 splits the global batch into microbatches scanned
    sequentially (activation memory / collective-size lever)."""

    def loss_for(params, batch):
        return lm.loss_fn(params, cfg, batch)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
        else:
            def micro(batch_slice):
                return jax.value_and_grad(loss_for, has_aux=True)(
                    params, batch_slice)

            def split(k, x):
                if x is None or x.ndim == 0:
                    return x
                if k == "positions":          # (3, B, S): batch is dim 1
                    r = x.reshape(3, accum_steps, -1, *x.shape[2:])
                    return jnp.moveaxis(r, 1, 0)
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro_batches = {k: split(k, v) for k, v in batch.items()}

            def body(acc, mb):
                (loss, metrics), grads = micro(mb)
                acc_loss, acc_metrics, acc_grads = acc
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_metrics, metrics),
                        acc_grads), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            first = jax.tree.map(lambda v: v[0], micro_batches)
            (l0, m0), g0 = micro(first)
            rest = jax.tree.map(lambda v: v[1:], micro_batches)
            (loss, metrics, grads), _ = jax.lax.scan(
                body, (l0, m0, jax.tree.map(lambda a, b: a.astype(jnp.float32) + b,
                                            g0, zero_g)), rest)
            inv = 1.0 / accum_steps
            loss = loss * inv
            metrics = jax.tree.map(lambda m: m * inv, metrics)
            grads = jax.tree.map(lambda g: g * inv, grads)

        if grad_compression != "none":
            ef = opt_state.get("ef")
            grads, ef = compression.compress(grads, grad_compression, ef)
        new_params, new_opt, stats = adamw.update(params, grads, opt_state, opt_cfg)
        if grad_compression != "none":
            new_opt["ef"] = ef
        metrics = dict(loss=loss, **metrics, **stats)
        return new_params, new_opt, metrics

    return step


def make_prefill_step(cfg: lm.ModelConfig):
    """Prompt-processing forward: logits for every position (the serving
    prefill compute shape; cache-filling chunked prefill shares this math)."""

    def step(params, batch):
        logits, _ = lm.forward(params, cfg, batch)
        return logits

    return step


def make_serve_step(cfg: lm.ModelConfig):
    """One decode step: new token in, next token + updated caches out."""

    def step(params, caches, tokens, pos):
        logits, next_tok, caches = lm.decode_step(params, cfg, caches,
                                                  tokens, pos)
        return next_tok, logits, caches

    return step

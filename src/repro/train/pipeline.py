"""Bounded-queue producer/consumer pipeline for the mini-batch hot path.

The ROADMAP's async sampler/trainer item: per-batch host prepare (sample ->
``decompose_skeleton`` -> PlanCache resolve -> ``fix_shapes`` -> device
staging) is ~1 ms and used to run *serially* with the device step, so one
training iteration paid ``compute + prepare``.  :class:`BatchPipeline` runs
the prepare on N background threads up to ``prefetch_depth`` batches ahead
of the consumer, so a steady-state iteration pays ``max(compute, prepare)``
instead.  The fixed-budget padded shapes built in the sampling layer are
what make this safe: a consumer thread never retraces, so the only shared
state is the (now lock-protected) PlanCache/SkeletonCache bookkeeping.

Determinism contract: per-item work is split into up to three stages, and
the two *stateful* ones run in strictly increasing index order.  Item
``i``'s *draw* (``draw_fn``) runs under one lock in index order — it
consumes sequential sampler state.  ``work_fn`` is the heavy,
order-independent stage and races freely across workers.  The optional
``resolve_fn`` then runs through an index-ordered turnstile: item ``i``'s
resolve starts only after items ``0..i-1`` have finished theirs, so
shared-cache decisions (lookup, selection, LRU order, near-hit aliasing,
feedback counters) are made in exactly the order the sequential loop would
make them — completion-order racing is NOT enough for that, because a
later-index batch can otherwise run its lookup before an earlier-index
batch commits the entry it would have hit.  The optional ``finish_fn``
(payload padding, device staging, pre-compile) races again.  Items are
delivered to :meth:`get` in index order.  With samplers whose per-batch
randomness is a pure function of (seed, index) (see
``sampling.sampler.DrawTicket``), the async batch stream *and* every
cache decision are bit-identical to the sequential ones.

Backpressure is a semaphore with ``prefetch_depth`` permits: a worker takes
a permit before drawing (blocking when ``depth`` batches are staged or in
flight — the queue-full wait) and the consumer returns it on :meth:`get`
(blocking when batch ``i`` isn't ready — the queue-empty wait).  Both wait
totals are exported through :attr:`stats`, and a warn-once fires when the
ready queue averages below half of ``prefetch_depth`` (the producers can't
keep up; raise ``workers`` or accept prepare-bound steps).

Worker exceptions are captured per item and re-raised in the consumer at
that item's :meth:`get` (the pipeline closes itself first); a failed item
vacates its turnstile slot so later items never deadlock behind it.
:meth:`close` is idempotent, joins every worker, and is safe mid-stream —
used directly or via the context manager.

With a ``retry`` policy (``distributed.fault_tolerance.RetryPolicy``) the
two *racing* stages — ``work_fn`` and ``finish_fn``, which are pure per
item — are retried with exponential backoff on transient failures before
the item is failed; ``retryable`` classifies (default: everything), so
deterministic bugs still fail fast on the first attempt.  The stateful
stages (draw, resolve) are never retried: re-running them would replay
shared-state mutations.  Backoff waits on the pipeline's stop event, so
:meth:`close` during a mid-backoff retry joins promptly instead of
sleeping out the delay ladder; per-item retry counts ride :attr:`stats`.
"""
from __future__ import annotations

import threading
import time
import warnings
from typing import Any, Callable

from repro.obs import Telemetry

__all__ = ["BatchPipeline", "PipelineError"]


def _pipe_counter(key: str):
    """Attribute <-> registry-counter bridge (``pipeline.<key>``): the
    backpressure totals the stats view reports live in the run's metrics
    registry.  Mutating paths already serialize on the pipeline's own
    locks, so the read-modify-write of ``+=`` is safe."""
    def fget(self):
        return self._counters[key].value

    def fset(self, v):
        self._counters[key].set(v)

    return property(fget, fset)


class PipelineError(RuntimeError):
    """Pipeline used after close, or its workers died without output."""


class _Cancelled(BaseException):
    """Internal: unwinds a worker parked on the turnstile at close()."""


class BatchPipeline:
    """Run ``work_fn(index, draw_fn())`` for ``n_items`` items on background
    threads, delivering results to :meth:`get` in index order, at most
    ``prefetch_depth`` items ahead of the consumer.

    ``draw_fn`` consumes sequential sampler state and must be cheap: it runs
    under the pipeline's dispatch lock so draws happen in index order no
    matter which worker wins the race.  ``work_fn`` is the heavy
    order-independent stage (sampler build + skeleton) and runs concurrently
    on up to ``workers`` threads.  ``resolve_fn(index, item)``, if given,
    runs through an index-ordered turnstile — put every shared-state
    decision that must match the sequential loop bit-for-bit here, and keep
    it cheap (it serializes).  ``finish_fn(index, item)``, if given, races
    again after the resolve (padding, device staging, pre-compile).
    """

    def __init__(self, draw_fn: Callable[[], Any],
                 work_fn: Callable[[int, Any], Any], n_items: int,
                 prefetch_depth: int = 4, workers: int = 2,
                 name: str = "sampler", warn_after: int = 16,
                 resolve_fn: Callable[[int, Any], Any] | None = None,
                 finish_fn: Callable[[int, Any], Any] | None = None,
                 retry: Any = None,
                 retryable: Callable[[BaseException], bool] | None = None,
                 telemetry: Telemetry | None = None):
        # telemetry before the counter-backed attributes below
        self.tele = telemetry if telemetry is not None else Telemetry()
        m = self.tele.metrics
        self._counters = {k: m.counter(f"pipeline.{k}")
                          for k in ("wait_full_s", "wait_empty_s", "retries")}
        # ready-queue depth observed at each get(): mean drives the
        # starvation warn-once, p50/p99 ride the metrics snapshot
        self._ready = m.histogram("pipeline.ready_depth")
        self.n_items = int(n_items)
        self.depth = max(int(prefetch_depth), 1)
        # more workers than permits can never run concurrently
        self.workers = max(1, min(int(workers), self.depth))
        self.name = name
        self.warn_after = int(warn_after)
        self._draw_fn = draw_fn
        self._work_fn = work_fn
        self._resolve_fn = resolve_fn
        self._finish_fn = finish_fn
        self._retry = retry
        self._retryable = retryable
        self.retries = 0           # transient-failure retries absorbed
        self._slots = threading.Semaphore(self.depth)
        self._draw_lock = threading.Lock()
        self._stat_lock = threading.Lock()
        self._cond = threading.Condition()
        self._results: dict[int, tuple[bool, Any]] = {}   # idx -> (ok, item)
        self._next_draw = 0
        self._next_out = 0
        # index-ordered turnstile for resolve_fn: _next_turn is the index
        # whose resolve may run; finished (or failed/skipped) indices are
        # parked in _turns_done until the sequence catches up to them
        self._turn_cond = threading.Condition()
        self._next_turn = 0
        self._turns_done: set[int] = set()
        self._stop = threading.Event()
        self._closed = False
        self.wait_full_s = 0.0     # producers blocked: every slot staged
        self.wait_empty_s = 0.0    # consumer blocked: next item not ready
        self.starved = False       # warn-once latch (queue below half-full)
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"pipeline-{name}-{i}")
            for i in range(self.workers)]
        self._live = self.workers
        for t in self._threads:
            t.start()

    # registry-backed backpressure counters (see _pipe_counter)
    wait_full_s = _pipe_counter("wait_full_s")
    wait_empty_s = _pipe_counter("wait_empty_s")
    retries = _pipe_counter("retries")

    # -- producer side ------------------------------------------------------

    def _worker(self) -> None:
        try:
            while not self._stop.is_set():
                t0 = time.perf_counter()
                acquired = self._slots.acquire(timeout=0.05)
                waited = time.perf_counter() - t0
                if self._stop.is_set():
                    if acquired:
                        self._slots.release()
                    return
                if not acquired:
                    with self._draw_lock:
                        drained = self._next_draw >= self.n_items
                    if drained:
                        return             # drained: nothing left to draw
                    with self._stat_lock:  # genuine full-queue backpressure
                        self.wait_full_s += waited
                    continue
                with self._stat_lock:
                    self.wait_full_s += waited
                with self._draw_lock:
                    if self._next_draw >= self.n_items:
                        self._slots.release()
                        return
                    idx = self._next_draw
                    self._next_draw += 1
                    try:
                        # in-order under the lock: batch idx's sequential
                        # draw is identical to the single-threaded path
                        with self.tele.tracer.span("draw", cat="pipeline",
                                                   index=idx):
                            ticket = self._draw_fn()
                    except BaseException as e:   # noqa: BLE001 — propagated
                        self._finish_turn(idx)
                        self._post(idx, False, e)
                        continue
                try:
                    item = self._run_racing(self._work_fn, idx, ticket)
                    if self._resolve_fn is not None:
                        self._await_turn(idx)
                        try:
                            item = self._resolve_fn(idx, item)
                        finally:
                            self._finish_turn(idx)
                    else:
                        self._finish_turn(idx)
                    if self._finish_fn is not None:
                        item = self._run_racing(self._finish_fn, idx, item)
                except _Cancelled:
                    return
                except BaseException as e:       # noqa: BLE001 — propagated
                    self._finish_turn(idx)
                    self._post(idx, False, e)
                else:
                    self._post(idx, True, item)
        finally:
            with self._cond:
                self._live -= 1
                self._cond.notify_all()

    def _run_racing(self, fn, idx: int, item):
        """Run a racing (pure, per-item) stage, absorbing transient
        failures through the retry policy.  The backoff waits on the stop
        event (close() interrupts it); retries of an item re-run the stage
        from the same input, which is safe because the racing stages make
        no shared-state decisions."""
        if self._retry is None:
            return fn(idx, item)

        def on_retry(attempt):
            with self._stat_lock:
                self.retries += 1

        return self._retry.run(fn, idx, item, on_retry=on_retry,
                               cancel=self._stop,
                               retryable=self._retryable)

    def _await_turn(self, idx: int) -> None:
        """Block until every lower index has finished its resolve stage."""
        with self.tele.tracer.span("turn_wait", cat="pipeline", index=idx):
            with self._turn_cond:
                while self._next_turn != idx:
                    if self._stop.is_set():
                        raise _Cancelled()
                    self._turn_cond.wait(0.05)

    def _finish_turn(self, idx: int) -> None:
        """Mark ``idx``'s resolve slot done (idempotent, any order): failed
        and skipped items vacate their slot so later turns never wait on a
        resolve that will not happen."""
        with self._turn_cond:
            if idx < self._next_turn or idx in self._turns_done:
                return
            self._turns_done.add(idx)
            while self._next_turn in self._turns_done:
                self._turns_done.discard(self._next_turn)
                self._next_turn += 1
            self._turn_cond.notify_all()

    def _post(self, idx: int, ok: bool, payload: Any) -> None:
        with self._cond:
            self._results[idx] = (ok, payload)
            self._cond.notify_all()

    # -- consumer side ------------------------------------------------------

    def get(self) -> Any:
        """Next item, in index order; blocks until its worker finishes.
        Re-raises the worker's exception (closing the pipeline) if that
        item failed."""
        if self._closed:
            raise PipelineError(f"pipeline {self.name!r} is closed")
        if self._next_out >= self.n_items:
            raise PipelineError(
                f"pipeline {self.name!r} already delivered all "
                f"{self.n_items} items")
        with self._cond:
            self._ready.observe(len(self._results))
            t0 = time.perf_counter()
            while self._next_out not in self._results:
                if self._live == 0:
                    raise PipelineError(
                        f"all pipeline {self.name!r} workers exited before "
                        f"item {self._next_out} was produced")
                self._cond.wait(0.1)
            self.wait_empty_s += time.perf_counter() - t0
            ok, payload = self._results.pop(self._next_out)
            self._next_out += 1
        self._slots.release()
        self._maybe_warn()
        if not ok:
            self.close()
            raise payload
        return payload

    def _maybe_warn(self) -> None:
        if self.starved or self._ready.count < self.warn_after:
            return
        mean_ready = self._ready.mean
        if mean_ready < self.depth / 2:
            self.starved = True
            warnings.warn(
                f"pipeline {self.name!r}: prefetch queue averaged "
                f"{mean_ready:.1f}/{self.depth} ready batches — "
                f"{self.workers} worker(s) can't keep it half-full; raise "
                f"pipeline_workers (or prefetch_depth) or accept "
                f"prepare-bound steps", RuntimeWarning, stacklevel=3)

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        """Idempotent shutdown: stop workers, join them, drop staged items.
        Safe mid-stream; after close, :meth:`get` raises."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        for _ in self._threads:     # unblock producers parked on the queue
            self._slots.release()
        with self._turn_cond:       # and those parked on the turnstile
            self._turn_cond.notify_all()
        for t in self._threads:
            t.join(timeout=10.0)
        with self._cond:
            self._results.clear()
            self._cond.notify_all()

    def __enter__(self) -> "BatchPipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def stats(self) -> dict:
        """Backpressure counters for MinibatchResult / benches / logs —
        assembled from the run's metrics registry (same instruments the
        telemetry snapshot exports), keys unchanged."""
        return dict(depth=self.depth, workers=self.workers,
                    delivered=self._next_out,
                    wait_full_s=self.wait_full_s,
                    wait_empty_s=self.wait_empty_s,
                    ready_mean=self._ready.mean,
                    starved=self.starved, retries=self.retries)
